# MicroAdam reproduction — build/test lanes.
#
#   make ci          default lane: XLA-free build + tests + doctests +
#                    the simd feature matrix (scalar-only build + a full
#                    --features simd test pass) + warning-clean rustdoc +
#                    `make lint` (runs anywhere)
#   make lint        correctness-analysis lane, toolchain-free: repolint
#                    self-test + repolint over the repo, then clippy with
#                    -D warnings where clippy is installed (the allowlist
#                    is committed in the root Cargo.toml [workspace.lints])
#   make loom        model-checking lane: RUSTFLAGS="--cfg loom" builds the
#                    rust/tests/loom suite against the in-tree minloom
#                    checker and explores the ExecPool dispatch/barrier and
#                    StreamHub relay-ordering protocols schedule-by-schedule
#   make miri        nightly-gated: Miri over the unsafe-exercising unit
#                    tests (exec dispatch, checkpoint byte reinterprets);
#                    skips with a notice where no nightly+miri toolchain
#   make ci-sanitize nightly-gated: ThreadSanitizer over the exec pool and
#                    the uds/tcp transport parity tests; skips with a
#                    notice where nightly+rust-src are unavailable
#   make ci-pjrt     PJRT-gated lane: `cargo test --features pjrt` where the
#                    vendored xla crate exists (see rust/Cargo.toml); skips
#                    with a notice elsewhere, so CI can always invoke it.
#                    --all-targets deliberately EXCLUDES doctests: doctest
#                    binaries don't inherit the rpath to the image's
#                    libstdc++ that the xla-linked targets need, so runnable
#                    doctests live in the default (XLA-free) ci lane only
#   make bench-smoke few-second perf probe: bench_optimizer_step in smoke
#                    mode (writes $(BENCH_JSON): steps/s, resident
#                    bytes/param, wire bytes, per-kernel scalar-vs-simd
#                    medians, the real-socket tcp gather/compress overlap
#                    ms, and the star/ring/tree topology × ranks sweep
#                    with rank-0 bytes + overlaps) + bench_kernels + the
#                    artifact-free perf_probe --native size sweep, all
#                    built --features simd so the vector kernels are the
#                    ones measured; every PR records the perf trajectory
#   make trace-smoke observability lane (part of `make ci`): a short traced
#                    2-rank eftopk training run, then `microadam tracecheck`
#                    validates both sinks (the Chrome trace-event file and
#                    the JSONL {"kind":"trace"} records incl. the EF-health
#                    gauges), then the disabled-tracing overhead bound
#                    (< 1% of a fused step) is asserted
#   make artifacts   AOT-lower the L2 graphs (needs python/ + JAX; only for
#                    machines building the artifact set)
#
# The pjrt lane is the entry point ROADMAP's "PJRT-gated CI job" item names:
# it keeps test_artifact_parity exercised on the baked image while the
# default lane stays XLA-free.

# Where the vendored xla crate lives on the baked image.
XLA_RS ?= /opt/xla-rs
# Where the smoke lane writes its JSON record.
BENCH_JSON ?= BENCH_SMOKE.json

.PHONY: ci ci-pjrt bench-smoke trace-smoke artifacts test-tcp test-topology lint loom miri ci-sanitize

ci:
	cargo build --release
	# `cargo test -q` includes the tcp transport lane (test_tcp_parity:
	# parity + fault injection, pinned to 127.0.0.1 ephemeral ports — no
	# external network needed) and the topology lane (test_topology_parity:
	# ring/tree vs loopback bit-parity + fold-order properties); run them
	# alone via `make test-tcp` / `make test-topology`
	cargo test -q
	$(MAKE) test-topology
	cargo test --doc -q
	# Feature matrix: the scalar kernels must build standalone, and the
	# simd feature (runtime-dispatched vector kernels) must pass the whole
	# suite — including the scalar-vs-simd bit-exactness parity tiers.
	cargo build --release --no-default-features
	cargo build --release --features simd
	cargo test -q --features simd
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	$(MAKE) lint
	$(MAKE) trace-smoke

# Static invariants (rust/tools/repolint: SAFETY comments on unsafe,
# panic-free dist:: decode paths, wire constants pinned to the normative
# spec, lossless byte accounting) + clippy. The repolint self-test runs
# first: every rule must fire on its seeded fixture violation before the
# real tree is trusted to a clean pass.
lint:
	cargo run --release -p repolint -- --self-test
	cargo run --release -p repolint -- --root .
	@if cargo clippy --version >/dev/null 2>&1; then \
		cargo clippy --workspace --all-targets -- -D warnings; \
	else \
		echo "lint: cargo clippy not installed — skipping the clippy leg"; \
	fi

# Model-checking lane. --cfg loom swaps the exec/dist sync shims for the
# scheduler-instrumented minloom types (rust/Cargo.toml maps the `loom`
# name onto rust/tools/minloom, so resolution stays offline) and compiles
# the rust/tests/loom suite, which is empty under a plain `cargo test`.
# Release mode: the checker replays each test thousands of times.
loom:
	RUSTFLAGS="--cfg loom" cargo test --release -p microadam --test loom

# Miri over the targeted unsafe-exercising tests: the ExecPool dispatch
# protocol (raw job pointer + barrier) and the checkpoint f32/i32 byte
# reinterprets. Gated: runs only where a nightly toolchain with the miri
# component exists, and skips loudly otherwise so CI can always invoke it.
miri:
	@if ! cargo +nightly miri --version >/dev/null 2>&1; then \
		echo "miri: no nightly toolchain with the miri component — skipping"; \
		echo "      (rustup toolchain install nightly && rustup +nightly component add miri)"; \
		exit 0; \
	fi; \
	MIRIFLAGS="-Zmiri-disable-isolation" \
		cargo +nightly miri test -p microadam --lib -- exec:: checkpoint:: bf16
# -Zmiri-disable-isolation: the trainer/checkpoint tests touch the real
# filesystem (tempdirs) and the clock.

# ThreadSanitizer over the threaded subsystems: the exec pool unit tests
# and the uds/tcp transport parity suites (launcher_ tests excluded — they
# drive the release `microadam` binary, which TSan did not instrument).
# Needs nightly + rust-src (-Zbuild-std rebuilds std with TSan); skips
# loudly otherwise.
ci-sanitize:
	@if ! cargo +nightly --version >/dev/null 2>&1; then \
		echo "ci-sanitize: no nightly toolchain — skipping"; \
		exit 0; \
	fi; \
	if ! rustup +nightly component list --installed 2>/dev/null | grep -q rust-src; then \
		echo "ci-sanitize: nightly rust-src component missing — skipping"; \
		echo "             (rustup +nightly component add rust-src)"; \
		exit 0; \
	fi; \
	HOST=$$(rustc -vV | sed -n 's/^host: //p'); \
	RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Zbuild-std \
		--target $$HOST -p microadam --lib -- exec:: && \
	RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Zbuild-std \
		--target $$HOST -p microadam --test test_transport_parity \
		--test test_tcp_parity -- --skip launcher_

# The tcp transport lane by itself (also part of `make ci` via cargo test).
test-tcp:
	cargo test -q --test test_tcp_parity

# The topology lane: ring/tree vs loopback bit-parity across reducers ×
# ranks × carriers, plus the partial-aggregate fold-order property tests
# (invoked by `make ci`; everything binds 127.0.0.1 ephemeral ports).
test-topology:
	cargo test -q --test test_topology_parity

ci-pjrt:
	@if [ ! -d "$(XLA_RS)" ]; then \
		echo "ci-pjrt: vendored xla crate not found at $(XLA_RS) — skipping"; \
		echo "         (set XLA_RS=/path/to/xla-rs on an image that has it)"; \
		exit 0; \
	fi; \
	if ! grep -q '^xla *=' rust/Cargo.toml; then \
		echo "ci-pjrt: enable the xla dependency in rust/Cargo.toml first"; \
		echo "         (uncomment the 'xla = { path = ... }' line, pointing at $(XLA_RS))"; \
		exit 1; \
	fi; \
	cargo build --release --features pjrt && cargo test -q --features pjrt --all-targets

bench-smoke:
	MICROADAM_BENCH_SMOKE=1 MICROADAM_BENCH_JSON=$(BENCH_JSON) \
		cargo bench --features simd --bench bench_optimizer_step
	MICROADAM_BENCH_SMOKE=1 cargo bench --features simd --bench bench_kernels
	cargo run --release --features simd --bin perf_probe -- \
		--native 262144 5 --sizes 64k,256k,1m
	@python3 -c "\
	import json, sys; \
	rec = json.load(open('$(BENCH_JSON)')); \
	rows = rec.get('frontier'); \
	assert isinstance(rows, list) and rows, 'BENCH json: missing/empty frontier key'; \
	names = [r['optimizer'] for r in rows]; \
	need = {'micro-adam', 'adamw', 'adamw-8bit', 'ldadam', 'adammini'}; \
	assert need <= set(names), 'frontier missing optimizers: %s' % (need - set(names)); \
	[(float(r['resident_bytes_per_param']), float(r['paper_bytes_per_param']), float(r['final_loss'])) for r in rows]; \
	print('bench-smoke: frontier OK (%d optimizers)' % len(rows))"
	@python3 -c "\
	import json, sys; \
	rec = json.load(open('$(BENCH_JSON)')); \
	rows = rec.get('topology'); \
	assert isinstance(rows, list) and rows, 'BENCH json: missing/empty topology key'; \
	topos = {r['topology'] for r in rows}; \
	assert {'star', 'ring'} <= topos, 'topology sweep missing star/ring rows: %s' % topos; \
	assert all(float(r['gather_overlap_ms']) >= 0.0 for r in rows), 'negative gather overlap'; \
	assert all(float(r['decode_overlap_ms']) >= 0.0 for r in rows), 'negative decode overlap'; \
	[(int(r['ranks']), int(r['rank0_bytes_sent']), int(r['rank0_bytes_received'])) for r in rows]; \
	print('bench-smoke: topology OK (%d rows: %s)' % (len(rows), sorted(topos)))"
	@echo "bench-smoke: record in $(BENCH_JSON)"

# Observability lane: a short traced 2-rank eftopk run (loopback — no
# sockets), both sinks validated by `microadam tracecheck` (--require-ef
# insists on the EF-health gauges the reducer computes per step), then the
# disabled-tracing overhead bound asserted by the bench (< 1% of a fused
# step, MICROADAM_TRACE_ASSERT=1 turns the bound into a hard failure).
trace-smoke:
	mkdir -p runs
	cargo run --release --bin microadam -- train \
		--model mlp_tiny --ranks 2 --reduce eftopk --steps 25 \
		--out runs/trace_smoke.jsonl --trace runs/trace_smoke.trace.json
	cargo run --release --bin microadam -- tracecheck \
		--chrome runs/trace_smoke.trace.json \
		--jsonl runs/trace_smoke.jsonl --require-ef yes
	MICROADAM_TRACE_ASSERT=1 MICROADAM_BENCH_SMOKE=1 \
		cargo bench --bench bench_optimizer_step
	@echo "trace-smoke: sinks validated (runs/trace_smoke.*)"

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
