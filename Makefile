# MicroAdam reproduction — build/test lanes.
#
#   make ci        default lane: XLA-free build + tests (runs anywhere)
#   make ci-pjrt   PJRT-gated lane: `cargo test --features pjrt` where the
#                  vendored xla crate exists (see rust/Cargo.toml); skips
#                  with a notice elsewhere, so CI can always invoke it
#   make artifacts AOT-lower the L2 graphs (needs python/ + JAX; only for
#                  machines building the artifact set)
#
# The pjrt lane is the entry point ROADMAP's "PJRT-gated CI job" item names:
# it keeps test_artifact_parity exercised on the baked image while the
# default lane stays XLA-free.

# Where the vendored xla crate lives on the baked image.
XLA_RS ?= /opt/xla-rs

.PHONY: ci ci-pjrt artifacts

ci:
	cargo build --release
	cargo test -q

ci-pjrt:
	@if [ ! -d "$(XLA_RS)" ]; then \
		echo "ci-pjrt: vendored xla crate not found at $(XLA_RS) — skipping"; \
		echo "         (set XLA_RS=/path/to/xla-rs on an image that has it)"; \
		exit 0; \
	fi; \
	if ! grep -q '^xla *=' rust/Cargo.toml; then \
		echo "ci-pjrt: enable the xla dependency in rust/Cargo.toml first"; \
		echo "         (uncomment the 'xla = { path = ... }' line, pointing at $(XLA_RS))"; \
		exit 1; \
	fi; \
	cargo build --release --features pjrt && cargo test -q --features pjrt

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
