# MicroAdam reproduction — build/test lanes.
#
#   make ci          default lane: XLA-free build + tests + doctests +
#                    warning-clean rustdoc (runs anywhere)
#   make ci-pjrt     PJRT-gated lane: `cargo test --features pjrt` where the
#                    vendored xla crate exists (see rust/Cargo.toml); skips
#                    with a notice elsewhere, so CI can always invoke it.
#                    --all-targets deliberately EXCLUDES doctests: doctest
#                    binaries don't inherit the rpath to the image's
#                    libstdc++ that the xla-linked targets need, so runnable
#                    doctests live in the default (XLA-free) ci lane only
#   make bench-smoke few-second perf probe: bench_optimizer_step in smoke
#                    mode (writes $(BENCH_JSON): steps/s, resident
#                    bytes/param, wire bytes, and the real-socket tcp
#                    gather/compress overlap ms) + the artifact-free
#                    perf_probe --native row, so every PR can record the
#                    perf trajectory
#   make artifacts   AOT-lower the L2 graphs (needs python/ + JAX; only for
#                    machines building the artifact set)
#
# The pjrt lane is the entry point ROADMAP's "PJRT-gated CI job" item names:
# it keeps test_artifact_parity exercised on the baked image while the
# default lane stays XLA-free.

# Where the vendored xla crate lives on the baked image.
XLA_RS ?= /opt/xla-rs
# Where the smoke lane writes its JSON record.
BENCH_JSON ?= BENCH_SMOKE.json

.PHONY: ci ci-pjrt bench-smoke artifacts test-tcp

ci:
	cargo build --release
	# `cargo test -q` includes the tcp transport lane (test_tcp_parity:
	# parity + fault injection, pinned to 127.0.0.1 ephemeral ports — no
	# external network needed); run it alone via `make test-tcp`
	cargo test -q
	cargo test --doc -q
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# The tcp transport lane by itself (also part of `make ci` via cargo test).
test-tcp:
	cargo test -q --test test_tcp_parity

ci-pjrt:
	@if [ ! -d "$(XLA_RS)" ]; then \
		echo "ci-pjrt: vendored xla crate not found at $(XLA_RS) — skipping"; \
		echo "         (set XLA_RS=/path/to/xla-rs on an image that has it)"; \
		exit 0; \
	fi; \
	if ! grep -q '^xla *=' rust/Cargo.toml; then \
		echo "ci-pjrt: enable the xla dependency in rust/Cargo.toml first"; \
		echo "         (uncomment the 'xla = { path = ... }' line, pointing at $(XLA_RS))"; \
		exit 1; \
	fi; \
	cargo build --release --features pjrt && cargo test -q --features pjrt --all-targets

bench-smoke:
	MICROADAM_BENCH_SMOKE=1 MICROADAM_BENCH_JSON=$(BENCH_JSON) \
		cargo bench --bench bench_optimizer_step
	cargo run --release --bin perf_probe -- --native 262144 5
	@echo "bench-smoke: record in $(BENCH_JSON)"

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
