//! Block-wise Top-K selection and the sliding gradient window `G = (I, V)`.
//!
//! The paper applies Top-K in blocks of `B_d < 2^15` so indices are
//! block-relative and fit `u16` (§3.1 "Top-K"). [`SlidingWindow`] is the
//! ring buffer of the last `m` sparse gradients, the only optimizer state
//! MicroAdam keeps besides the quantized EF: `m * k` `u16` indices plus
//! `m * k` values, stored **physically in bf16** ([`WinDtype::Bf16`],
//! the paper's 2 B/value accounting made real). Selection always ranks on
//! the full-precision f32 magnitudes — only the stored value is rounded —
//! and every read widens back to f32 before entering AdamStats.
//!
//! [`WinDtype::F32`] keeps the old full-precision storage as the baseline
//! for the tolerance-bounded parity tier (see
//! `rust/tests/test_parallel_parity.rs`).

use crate::simd::{self, Level};
use crate::util::bf16::{bf16_to_f32, f32_to_bf16};

/// Select the `k` largest-|x| entries of `block` (len <= 2^15).
///
/// Writes block-relative indices into `idx` and the *signed* values into
/// `vals`. Uses an O(n) quickselect partition over a scratch index array,
/// then sorts the selected prefix by index for reproducible layouts. The
/// scratch is reused across calls (per-worker arenas pre-size it from the
/// layout so steady state never reallocates).
pub fn topk_abs_block(block: &[f32], k: usize, idx: &mut [u16], vals: &mut [f32], scratch: &mut Vec<u16>) {
    topk_abs_block_with(Level::Scalar, block, k, idx, vals, scratch);
}

/// [`topk_abs_block`] with an explicit simd [`Level`]: a non-scalar level
/// engages the vectorized magnitude prefilter in `topk_select`. The
/// selected set is identical at every level (the ranking is a strict
/// total order), so this changes speed, never output.
pub fn topk_abs_block_with(level: Level, block: &[f32], k: usize, idx: &mut [u16], vals: &mut [f32], scratch: &mut Vec<u16>) {
    topk_select(level, block, k, idx, scratch);
    for (o, &s) in idx.iter().enumerate().take(k.min(block.len())) {
        vals[o] = block[s as usize];
    }
}

/// bf16-aware write path of [`topk_abs_block`]: selection still ranks on
/// the full-precision f32 magnitudes; only the stored value is rounded to
/// bf16 (round-to-nearest-even).
pub fn topk_abs_block_bf16(block: &[f32], k: usize, idx: &mut [u16], vals: &mut [u16], scratch: &mut Vec<u16>) {
    topk_abs_block_bf16_with(Level::Scalar, block, k, idx, vals, scratch);
}

/// [`topk_abs_block_bf16`] with an explicit simd [`Level`] (see
/// [`topk_abs_block_with`]).
pub fn topk_abs_block_bf16_with(level: Level, block: &[f32], k: usize, idx: &mut [u16], vals: &mut [u16], scratch: &mut Vec<u16>) {
    topk_select(level, block, k, idx, scratch);
    for (o, &s) in idx.iter().enumerate().take(k.min(block.len())) {
        vals[o] = f32_to_bf16(block[s as usize]);
    }
}

/// |x| as an ordered bit pattern: for non-negative IEEE-754 floats the
/// unsigned bit order *is* the magnitude order (subnormals < normals <
/// inf < NaN payloads), which gives the selection ranking below a strict
/// total order with no float compares.
#[inline(always)]
fn abs_bits(v: f32) -> u32 {
    v.to_bits() & 0x7FFF_FFFF
}

/// Count entries with |x| bit pattern >= `thr`. Written as an integer
/// sum of per-lane predicates — associative, so it lane-parallelizes
/// under the `target_feature` instantiations.
///
/// Scalar twin of the vector instantiations in [`crate::simd`].
#[inline(always)]
pub fn count_abs_ge(block: &[f32], thr: u32) -> usize {
    block.iter().map(|&v| usize::from(abs_bits(v) >= thr)).sum()
}

/// The selection ranking: |x| bits descending, index ascending on ties.
/// Total and antisymmetric for *any* input bits — NaN magnitudes order
/// above infinities by payload instead of poisoning the quickselect
/// pivot order (the old `partial_cmp(..).unwrap_or(Equal)` hazard) —
/// and since no two candidates share an index, the top-k *set* is
/// unique: every selection algorithm over this ranking returns the same
/// sorted index output.
#[inline(always)]
fn rank(block: &[f32], a: u16, b: u16) -> std::cmp::Ordering {
    abs_bits(block[b as usize])
        .cmp(&abs_bits(block[a as usize]))
        .then(a.cmp(&b))
}

/// Shared selection core: leaves the chosen block-relative indices
/// (sorted ascending) in `idx`.
///
/// At a non-scalar [`Level`], a vectorized magnitude pass first shrinks
/// the quickselect candidate set: binary-search the largest exponent
/// threshold `e` with [`count_abs_ge`]`(block, e << 23) >= k` (8 wide
/// counting passes), then quickselect only the candidates above it. The
/// k-th largest magnitude is >= that threshold by construction, so the
/// candidate set always contains the true top-k, and the shared [`rank`]
/// total order makes the output identical to the full quickselect.
fn topk_select(level: Level, block: &[f32], k: usize, idx: &mut [u16], scratch: &mut Vec<u16>) {
    let n = block.len();
    debug_assert!(n <= u16::MAX as usize + 1);
    let k = k.min(n);
    scratch.clear();
    scratch.reserve(n);
    if level != Level::Scalar && k > 0 && k < n && n >= 128 {
        let mut lo_e = 0u32;
        let mut hi_e = 255u32;
        let mut cand = n;
        while lo_e < hi_e {
            let mid = (lo_e + hi_e + 1) / 2;
            let c = simd::count_abs_ge(level, block, mid << 23);
            if c >= k {
                lo_e = mid;
                cand = c;
            } else {
                hi_e = mid - 1;
            }
        }
        // Engage only when the filter actually pays: with >= n/2
        // candidates (flat magnitude spectra) fall through to the plain
        // full-index quickselect below.
        if cand < n / 2 {
            let thr = lo_e << 23;
            for (i, &v) in block.iter().enumerate() {
                if abs_bits(v) >= thr {
                    scratch.push(i as u16);
                }
            }
            debug_assert_eq!(scratch.len(), cand);
            if k < scratch.len() {
                scratch.select_nth_unstable_by(k - 1, |&a, &b| rank(block, a, b));
            }
            let sel = &mut scratch[..k];
            sel.sort_unstable();
            idx[..k].copy_from_slice(sel);
            return;
        }
        scratch.clear();
    }
    scratch.extend(0..n as u16);
    if k < n {
        scratch.select_nth_unstable_by(k - 1, |&a, &b| rank(block, a, b));
    }
    let sel = &mut scratch[..k];
    sel.sort_unstable();
    idx[..k].copy_from_slice(sel);
}

/// AdamStats accumulation over one `(row, block)` entry with bf16-stored
/// values: `z1[j] += w1 * v`, `z2[j] += w2 * v^2`, `v` widened to f32.
///
/// Free function shared verbatim by the fused engine (over carved window
/// shards) and [`SlidingWindow::accumulate_stats`] (the reference sweep),
/// so the two paths cannot diverge by a single float op.
///
/// Scalar twin of the vector instantiations in [`crate::simd`]: the
/// per-element bounds checks are hoisted into one vectorizable max-index
/// validation pass, so the gather/widen/multiply runs lane-parallel and
/// only the scatter into `z1`/`z2` stays scalar.
#[inline(always)]
pub fn stats_accum_bf16(idx: &[u16], val: &[u16], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    let n = z1.len().min(z2.len());
    let mut ok = true;
    for &j in idx {
        ok &= (j as usize) < n;
    }
    assert!(ok, "window index out of block range");
    for (&j, &v) in idx.iter().zip(val) {
        let v = bf16_to_f32(v);
        // SAFETY: the validation pass above checked every index in `idx`
        // against both z-slab lengths (`n = min(len z1, len z2)`).
        unsafe {
            *z1.get_unchecked_mut(j as usize) += w1 * v;
            *z2.get_unchecked_mut(j as usize) += w2 * v * v;
        }
    }
}

/// f32-storage twin of [`stats_accum_bf16`].
///
/// Scalar twin of the vector instantiations in [`crate::simd`]; same
/// hoisted-bounds-check shape as the bf16 variant.
#[inline(always)]
pub fn stats_accum_f32(idx: &[u16], val: &[f32], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    let n = z1.len().min(z2.len());
    let mut ok = true;
    for &j in idx {
        ok &= (j as usize) < n;
    }
    assert!(ok, "window index out of block range");
    for (&j, &v) in idx.iter().zip(val) {
        // SAFETY: the validation pass above checked every index in `idx`
        // against both z-slab lengths (`n = min(len z1, len z2)`).
        unsafe {
            *z1.get_unchecked_mut(j as usize) += w1 * v;
            *z2.get_unchecked_mut(j as usize) += w2 * v * v;
        }
    }
}

/// Physical storage dtype of the window values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WinDtype {
    /// bf16 bit patterns in `SlidingWindow::val` — the paper dtype and the
    /// default: 2 B/value resident, widen-on-read / round-on-write.
    Bf16,
    /// f32 in `SlidingWindow::val_f32` — the full-precision baseline kept
    /// for the tolerance-bounded parity tier.
    F32,
}

/// The sliding window `G = (I, V)` over all `NB` blocks: a ring buffer of
/// `m` rows, each holding `NB * k_b` (index, value) pairs.
///
/// Storage is **block-major** `[block][row][k]`: the whole `m`-row history
/// of one block is a single contiguous `m * k_b` span. That is what lets
/// the fused step engine ([`crate::exec`]) hand each worker a disjoint
/// `&mut` sub-slice per contiguous block range — and it keeps the AdamStats
/// recomputation streaming through one cache-resident span per block
/// instead of striding across `NB * k_b`-sized rows.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    /// Window length `m`.
    pub m: usize,
    /// Number of parameter blocks `NB`.
    pub nb: usize,
    /// Entries kept per block `k_b`.
    pub kb: usize,
    /// Physical value dtype.
    pub dtype: WinDtype,
    /// Block-relative indices, `m * nb * kb`, block-major `[block][row][k]`.
    pub idx: Vec<u16>,
    /// Top-K values as bf16 bits, same layout (empty in [`WinDtype::F32`]).
    pub val: Vec<u16>,
    /// Top-K values as f32, same layout (empty in [`WinDtype::Bf16`]).
    pub val_f32: Vec<f32>,
    /// Number of rows ever written (`min(t, m)` valid rows).
    pub written: u64,
}

impl SlidingWindow {
    /// Paper-dtype window: bf16 value storage.
    pub fn new(m: usize, nb: usize, kb: usize) -> Self {
        Self::with_dtype(m, nb, kb, WinDtype::Bf16)
    }

    pub fn with_dtype(m: usize, nb: usize, kb: usize, dtype: WinDtype) -> Self {
        let n = m * nb * kb;
        let (val, val_f32) = match dtype {
            WinDtype::Bf16 => (vec![0u16; n], Vec::new()),
            WinDtype::F32 => (Vec::new(), vec![0f32; n]),
        };
        Self { m, nb, kb, dtype, idx: vec![0; n], val, val_f32, written: 0 }
    }

    /// Total `(index, value)` entries across all rows and blocks.
    pub fn entries(&self) -> usize {
        self.m * self.nb * self.kb
    }

    /// Row that step `t` (1-based) writes: `(t-1) % m` (Algorithm 1 line 14).
    pub fn row_for_step(&self, t: u64) -> usize {
        ((t - 1) % self.m as u64) as usize
    }

    /// Flat offset of `(row, block)` in the block-major layout.
    #[inline]
    fn off(&self, row: usize, block: usize) -> usize {
        (block * self.m + row) * self.kb
    }

    /// Block-relative indices stored for `(row, block)`.
    pub fn idx_at(&self, row: usize, block: usize) -> &[u16] {
        let o = self.off(row, block);
        &self.idx[o..o + self.kb]
    }

    /// Values of `(row, block)` widened to f32 into `out[..kb]`.
    pub fn vals_f32_at(&self, row: usize, block: usize, out: &mut [f32]) {
        let o = self.off(row, block);
        match self.dtype {
            WinDtype::Bf16 => {
                for (d, &v) in out[..self.kb].iter_mut().zip(&self.val[o..o + self.kb]) {
                    *d = bf16_to_f32(v);
                }
            }
            WinDtype::F32 => out[..self.kb].copy_from_slice(&self.val_f32[o..o + self.kb]),
        }
    }

    /// Run block Top-K on `acc` and store the winners into `(row, block)`,
    /// rounding values to the window dtype (selection ranks on the full
    /// f32 magnitudes either way). The chosen indices are readable via
    /// [`SlidingWindow::idx_at`] afterwards.
    pub fn select_into(&mut self, row: usize, block: usize, acc: &[f32], scratch: &mut Vec<u16>) {
        let o = self.off(row, block);
        let kb = self.kb;
        match self.dtype {
            WinDtype::Bf16 => topk_abs_block_bf16(acc, kb, &mut self.idx[o..o + kb], &mut self.val[o..o + kb], scratch),
            WinDtype::F32 => topk_abs_block(acc, kb, &mut self.idx[o..o + kb], &mut self.val_f32[o..o + kb], scratch),
        }
    }

    /// AdamStats contribution of `(row, block)`: delegates to the same
    /// [`stats_accum_bf16`]/[`stats_accum_f32`] kernels the fused engine
    /// runs over its carved shards — bit-identical by construction.
    pub fn accumulate_stats(&self, row: usize, block: usize, w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
        let o = self.off(row, block);
        let idx = &self.idx[o..o + self.kb];
        match self.dtype {
            WinDtype::Bf16 => stats_accum_bf16(idx, &self.val[o..o + self.kb], w1, w2, z1, z2),
            WinDtype::F32 => stats_accum_f32(idx, &self.val_f32[o..o + self.kb], w1, w2, z1, z2),
        }
    }

    /// Flat element range covering the full history of `blocks` — a single
    /// contiguous span thanks to the block-major layout. Used by the fused
    /// engine to carve disjoint per-worker `&mut` window shards.
    pub fn block_range(&self, blocks: std::ops::Range<usize>) -> std::ops::Range<usize> {
        blocks.start * self.m * self.kb..blocks.end * self.m * self.kb
    }

    /// Record a full step's Top-K results by marking one more row written.
    pub fn commit_row(&mut self) {
        self.written += 1;
    }

    /// Valid row count `min(t, m)`.
    pub fn valid_rows(&self) -> usize {
        (self.written as usize).min(self.m)
    }

    /// Decay exponent ("age") of `row` at step `t`: the newest row has age
    /// 0, the oldest `m - 1` (ADAMSTATS line 4).
    pub fn age(&self, row: usize, t: u64) -> usize {
        let w = self.row_for_step(t);
        (w + self.m - row) % self.m
    }

    /// Whether `row` holds data at step `t` (warm-up masking).
    pub fn is_valid(&self, row: usize, t: u64) -> bool {
        (row as u64) < t
    }

    /// Resident state bytes, measured from the actual buffers: `m*k` u16
    /// indices + `m*k` values at 2 B (bf16) or 4 B (f32). In the default
    /// bf16 mode this *is* the paper accounting — no separate fiction.
    pub fn state_bytes(&self) -> usize {
        self.idx.len() * 2 + self.val.len() * 2 + self.val_f32.len() * 4
    }

    /// Measured bytes per stored value (2 for bf16, 4 for f32), derived
    /// from the resident buffer rather than a formula.
    pub fn value_bytes_per_entry(&self) -> usize {
        (self.val.len() * 2 + self.val_f32.len() * 4) / self.entries().max(1)
    }

    /// Window values widened to f32 (checkpoint serialization; exact —
    /// every bf16 value is representable in f32).
    pub fn values_to_f32(&self) -> Vec<f32> {
        match self.dtype {
            WinDtype::Bf16 => self.val.iter().map(|&v| bf16_to_f32(v)).collect(),
            WinDtype::F32 => self.val_f32.clone(),
        }
    }

    /// Restore values from an f32 slab (checkpoint resume). Rounds back to
    /// the storage dtype; for data produced by [`Self::values_to_f32`] the
    /// round trip is bit-exact.
    pub fn set_values_from_f32(&mut self, vals: &[f32]) {
        assert_eq!(vals.len(), self.entries(), "window value count mismatch");
        match self.dtype {
            WinDtype::Bf16 => {
                for (d, &v) in self.val.iter_mut().zip(vals) {
                    *d = f32_to_bf16(v);
                }
            }
            WinDtype::F32 => self.val_f32.copy_from_slice(vals),
        }
    }

    /// Per-row folded weights for AdamStats: `valid * (1-beta) * beta^age /
    /// (1 - beta^min(t,m))` — matches `model.window_weights` on the L2 side.
    pub fn folded_weights(&self, t: u64, beta: f64) -> Vec<f32> {
        let eff = (t.min(self.m as u64)) as i32;
        let bc = 1.0 - beta.powi(eff);
        (0..self.m)
            .map(|row| {
                if !self.is_valid(row, t) {
                    return 0.0;
                }
                let age = self.age(row, t) as i32;
                ((1.0 - beta) * beta.powi(age) / bc) as f32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_selects_largest_abs() {
        let block = vec![0.1, -5.0, 0.2, 3.0, -0.05, 4.0];
        let mut idx = vec![0u16; 3];
        let mut vals = vec![0f32; 3];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 3, &mut idx, &mut vals, &mut scratch);
        assert_eq!(idx, vec![1, 3, 5]); // sorted by index
        assert_eq!(vals, vec![-5.0, 3.0, 4.0]); // signed values
    }

    #[test]
    fn topk_k_equals_n() {
        let block = vec![1.0, -2.0];
        let mut idx = vec![0u16; 2];
        let mut vals = vec![0f32; 2];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 2, &mut idx, &mut vals, &mut scratch);
        assert_eq!(idx, vec![0, 1]);
        assert_eq!(vals, vec![1.0, -2.0]);
    }

    #[test]
    fn nan_ranks_above_everything_and_ties_break_by_index() {
        // The rank total order: NaN |bits| > inf > finite, equal
        // magnitudes keep the lowest indices. A NaN gradient must yield
        // the same deterministic selection on every path.
        let block = vec![1.0f32, f32::NAN, 2.0, 2.0, f32::INFINITY, 2.0];
        let mut idx = vec![0u16; 3];
        let mut vals = vec![0f32; 3];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 3, &mut idx, &mut vals, &mut scratch);
        assert_eq!(idx, vec![1, 2, 4]); // NaN, inf, then the first 2.0
        assert!(vals[0].is_nan());
        assert_eq!(vals[2], f32::INFINITY);
    }

    #[test]
    fn count_abs_ge_counts_magnitude_bits() {
        let block = vec![0.5f32, -1.5, 2.0, -0.25, f32::NAN, 0.0];
        assert_eq!(count_abs_ge(&block, 0), 6);
        assert_eq!(count_abs_ge(&block, 1.0f32.to_bits()), 3); // 1.5, 2.0, NaN
        assert_eq!(count_abs_ge(&block, 255u32 << 23), 1); // only the NaN
    }

    #[test]
    fn topk_handles_all_zero_block() {
        let block = vec![0.0; 8];
        let mut idx = vec![9u16; 2];
        let mut vals = vec![9f32; 2];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 2, &mut idx, &mut vals, &mut scratch);
        assert_eq!(vals, vec![0.0, 0.0]);
        assert!(idx.iter().all(|&i| (i as usize) < 8));
    }

    #[test]
    fn topk_bf16_selects_on_f32_magnitudes() {
        // Two values that collide after bf16 rounding but differ in f32:
        // selection must still pick the larger f32 magnitude.
        let a = 1.0f32 + 1.0 / 512.0; // rounds to 1.0 in bf16
        let block = vec![0.5f32, a, 1.0, 0.1];
        let mut idx = vec![0u16; 1];
        let mut vals = vec![0u16; 1];
        let mut scratch = Vec::new();
        topk_abs_block_bf16(&block, 1, &mut idx, &mut vals, &mut scratch);
        assert_eq!(idx[0], 1, "must rank on full precision");
        // the stored value is the bf16 rounding of the winner
        assert_eq!(vals[0], f32_to_bf16(a));
    }

    #[test]
    fn topk_bf16_and_f32_select_same_indices() {
        let block: Vec<f32> = (0..64).map(|i| ((i * 37 % 101) as f32 - 50.0) / 7.0).collect();
        let mut idx_a = vec![0u16; 8];
        let mut idx_b = vec![0u16; 8];
        let mut vals_a = vec![0f32; 8];
        let mut vals_b = vec![0u16; 8];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 8, &mut idx_a, &mut vals_a, &mut scratch);
        topk_abs_block_bf16(&block, 8, &mut idx_b, &mut vals_b, &mut scratch);
        assert_eq!(idx_a, idx_b);
        for (o, &v) in vals_a.iter().enumerate() {
            assert_eq!(vals_b[o], f32_to_bf16(v));
        }
    }

    #[test]
    fn ring_rows_and_ages() {
        let mut w = SlidingWindow::new(4, 1, 2);
        assert_eq!(w.row_for_step(1), 0);
        assert_eq!(w.row_for_step(4), 3);
        assert_eq!(w.row_for_step(5), 0);
        for _ in 0..6 {
            w.commit_row();
        }
        let t = 6; // w = row 1
        assert_eq!(w.age(1, t), 0);
        assert_eq!(w.age(0, t), 1);
        assert_eq!(w.age(3, t), 2);
        assert_eq!(w.age(2, t), 3);
        assert_eq!(w.valid_rows(), 4);
    }

    #[test]
    fn warmup_validity() {
        let w = SlidingWindow::new(4, 1, 2);
        assert!(w.is_valid(0, 1));
        assert!(!w.is_valid(1, 1));
        assert!(w.is_valid(3, 4));
        assert!(w.is_valid(3, 100));
    }

    #[test]
    fn folded_weights_sum_to_one_after_warmup() {
        let mut w = SlidingWindow::new(10, 1, 1);
        for _ in 0..15 {
            w.commit_row();
        }
        let ws = w.folded_weights(15, 0.9);
        let sum: f32 = ws.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
    }

    #[test]
    fn block_major_history_is_contiguous() {
        let mut w = SlidingWindow::new(3, 4, 2);
        // tag every entry with (row, block) so the layout is observable;
        // values are small integers, exact in bf16
        for row in 0..3 {
            for b in 0..4 {
                let o = (b * 3 + row) * 2;
                for k in 0..2 {
                    w.idx[o + k] = (100 * b + 10 * row + k) as u16;
                    w.val[o + k] = f32_to_bf16((100 * b + 10 * row + k) as f32);
                }
            }
        }
        // block b's full history occupies w.block_range(b..b+1)
        for b in 0..4 {
            let r = w.block_range(b..b + 1);
            assert_eq!(r.len(), 3 * 2);
            for (o, &i) in w.idx[r.clone()].iter().enumerate() {
                let (row, k) = (o / 2, o % 2);
                assert_eq!(i as usize, 100 * b + 10 * row + k);
            }
        }
        // multi-block spans concatenate
        assert_eq!(w.block_range(1..3), 6..18);
        // the accessors agree with the raw span
        assert_eq!(w.idx_at(2, 3), &[320, 321]);
        let mut vals = vec![0f32; 2];
        w.vals_f32_at(2, 3, &mut vals);
        assert_eq!(vals, &[320.0, 321.0]);
    }

    #[test]
    fn window_resident_value_bytes_is_two_in_bf16() {
        // The acceptance target of the bf16-storage change: measured
        // resident bytes per window value is 2, not 4.
        let w = SlidingWindow::new(10, 8, 41);
        assert_eq!(w.value_bytes_per_entry(), 2);
        assert_eq!(w.state_bytes(), w.entries() * 4); // 2 B idx + 2 B val
        let wf = SlidingWindow::with_dtype(10, 8, 41, WinDtype::F32);
        assert_eq!(wf.value_bytes_per_entry(), 4);
    }

    #[test]
    fn select_into_and_accumulate_match_free_kernels() {
        let block: Vec<f32> = (0..32).map(|i| ((i * 13 % 17) as f32 - 8.0) * 0.3).collect();
        for dtype in [WinDtype::Bf16, WinDtype::F32] {
            let mut w = SlidingWindow::with_dtype(2, 1, 4, dtype);
            let mut scratch = Vec::new();
            w.select_into(0, 0, &block, &mut scratch);
            let mut z1 = vec![0f32; 32];
            let mut z2 = vec![0f32; 32];
            w.accumulate_stats(0, 0, 0.5, 0.9, &mut z1, &mut z2);
            // recompute through the free kernels on the raw storage
            let mut z1b = vec![0f32; 32];
            let mut z2b = vec![0f32; 32];
            match dtype {
                WinDtype::Bf16 => stats_accum_bf16(&w.idx[..4], &w.val[..4], 0.5, 0.9, &mut z1b, &mut z2b),
                WinDtype::F32 => stats_accum_f32(&w.idx[..4], &w.val_f32[..4], 0.5, 0.9, &mut z1b, &mut z2b),
            }
            assert_eq!(z1, z1b);
            assert_eq!(z2, z2b);
        }
    }

    #[test]
    fn values_f32_roundtrip_is_bit_exact() {
        let mut w = SlidingWindow::new(3, 2, 4);
        let mut scratch = Vec::new();
        let block: Vec<f32> = (0..16).map(|i| (i as f32 * 0.717).sin()).collect();
        for row in 0..3 {
            for b in 0..2 {
                w.select_into(row, b, &block, &mut scratch);
            }
        }
        let vals = w.values_to_f32();
        let mut w2 = SlidingWindow::new(3, 2, 4);
        w2.idx.copy_from_slice(&w.idx);
        w2.set_values_from_f32(&vals);
        assert_eq!(w.val, w2.val, "bf16 bits must survive the f32 detour");
    }

    #[test]
    fn folded_weights_first_step_is_delta() {
        let w = SlidingWindow::new(10, 1, 1);
        let ws = w.folded_weights(1, 0.9);
        assert!((ws[0] - 1.0).abs() < 1e-6);
        assert!(ws[1..].iter().all(|&x| x == 0.0));
    }
}
