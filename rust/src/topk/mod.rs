//! Block-wise Top-K selection and the sliding gradient window `G = (I, V)`.
//!
//! The paper applies Top-K in blocks of `B_d < 2^15` so indices are
//! block-relative and fit `u16` (§3.1 "Top-K"). [`SlidingWindow`] is the
//! ring buffer of the last `m` sparse gradients, the only optimizer state
//! MicroAdam keeps besides the quantized EF: `m * k` `u16` indices plus
//! `m * k` values.

/// Select the `k` largest-|x| entries of `block` (len <= 2^15).
///
/// Writes block-relative indices into `idx` and the *signed* values into
/// `vals`. Uses an O(n) quickselect partition over a scratch index array,
/// then sorts the selected prefix by index for reproducible layouts.
pub fn topk_abs_block(block: &[f32], k: usize, idx: &mut [u16], vals: &mut [f32], scratch: &mut Vec<u16>) {
    let n = block.len();
    debug_assert!(n <= u16::MAX as usize + 1);
    let k = k.min(n);
    scratch.clear();
    scratch.extend(0..n as u16);
    if k < n {
        scratch.select_nth_unstable_by(k - 1, |&a, &b| {
            let fa = block[a as usize].abs();
            let fb = block[b as usize].abs();
            fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal)
        });
    }
    let sel = &mut scratch[..k];
    sel.sort_unstable();
    for (o, &s) in sel.iter().enumerate() {
        idx[o] = s;
        vals[o] = block[s as usize];
    }
}

/// The sliding window `G = (I, V)` over all `NB` blocks: a ring buffer of
/// `m` rows, each holding `NB * k_b` (index, value) pairs.
///
/// Storage is **block-major** `[block][row][k]`: the whole `m`-row history
/// of one block is a single contiguous `m * k_b` span. That is what lets
/// the fused step engine ([`crate::exec`]) hand each worker a disjoint
/// `&mut` sub-slice per contiguous block range — and it keeps the AdamStats
/// recomputation streaming through one cache-resident span per block
/// instead of striding across `NB * k_b`-sized rows.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    /// Window length `m`.
    pub m: usize,
    /// Number of parameter blocks `NB`.
    pub nb: usize,
    /// Entries kept per block `k_b`.
    pub kb: usize,
    /// Block-relative indices, `m * nb * kb`, block-major `[block][row][k]`.
    pub idx: Vec<u16>,
    /// Top-K values (signed), same layout.
    pub val: Vec<f32>,
    /// Number of rows ever written (`min(t, m)` valid rows).
    pub written: u64,
}

impl SlidingWindow {
    pub fn new(m: usize, nb: usize, kb: usize) -> Self {
        Self { m, nb, kb, idx: vec![0; m * nb * kb], val: vec![0.0; m * nb * kb], written: 0 }
    }

    /// Row that step `t` (1-based) writes: `(t-1) % m` (Algorithm 1 line 14).
    pub fn row_for_step(&self, t: u64) -> usize {
        ((t - 1) % self.m as u64) as usize
    }

    /// Flat offset of `(row, block)` in the block-major layout.
    #[inline]
    fn off(&self, row: usize, block: usize) -> usize {
        (block * self.m + row) * self.kb
    }

    /// Mutable (idx, val) slices for `block` within `row`.
    pub fn entry_mut(&mut self, row: usize, block: usize) -> (&mut [u16], &mut [f32]) {
        let o = self.off(row, block);
        (&mut self.idx[o..o + self.kb], &mut self.val[o..o + self.kb])
    }

    /// (idx, val) slices for `block` within `row`.
    pub fn entry(&self, row: usize, block: usize) -> (&[u16], &[f32]) {
        let o = self.off(row, block);
        (&self.idx[o..o + self.kb], &self.val[o..o + self.kb])
    }

    /// Flat element range covering the full history of `blocks` — a single
    /// contiguous span thanks to the block-major layout. Used by the fused
    /// engine to carve disjoint per-worker `&mut` window shards.
    pub fn block_range(&self, blocks: std::ops::Range<usize>) -> std::ops::Range<usize> {
        blocks.start * self.m * self.kb..blocks.end * self.m * self.kb
    }

    /// Record a full step's Top-K results by marking one more row written.
    pub fn commit_row(&mut self) {
        self.written += 1;
    }

    /// Valid row count `min(t, m)`.
    pub fn valid_rows(&self) -> usize {
        (self.written as usize).min(self.m)
    }

    /// Decay exponent ("age") of `row` at step `t`: the newest row has age
    /// 0, the oldest `m - 1` (ADAMSTATS line 4).
    pub fn age(&self, row: usize, t: u64) -> usize {
        let w = self.row_for_step(t);
        (w + self.m - row) % self.m
    }

    /// Whether `row` holds data at step `t` (warm-up masking).
    pub fn is_valid(&self, row: usize, t: u64) -> bool {
        (row as u64) < t
    }

    /// State bytes: `m*k` u16 indices + `m*k` f32 values. The paper stores
    /// V in bf16 (2 B); we keep f32 in RAM but report the paper's 2 B cost
    /// separately in [`crate::memory`].
    pub fn state_bytes(&self) -> usize {
        self.idx.len() * 2 + self.val.len() * 4
    }

    /// Per-row folded weights for AdamStats: `valid * (1-beta) * beta^age /
    /// (1 - beta^min(t,m))` — matches `model.window_weights` on the L2 side.
    pub fn folded_weights(&self, t: u64, beta: f64) -> Vec<f32> {
        let eff = (t.min(self.m as u64)) as i32;
        let bc = 1.0 - beta.powi(eff);
        (0..self.m)
            .map(|row| {
                if !self.is_valid(row, t) {
                    return 0.0;
                }
                let age = self.age(row, t) as i32;
                ((1.0 - beta) * beta.powi(age) / bc) as f32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_selects_largest_abs() {
        let block = vec![0.1, -5.0, 0.2, 3.0, -0.05, 4.0];
        let mut idx = vec![0u16; 3];
        let mut vals = vec![0f32; 3];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 3, &mut idx, &mut vals, &mut scratch);
        assert_eq!(idx, vec![1, 3, 5]); // sorted by index
        assert_eq!(vals, vec![-5.0, 3.0, 4.0]); // signed values
    }

    #[test]
    fn topk_k_equals_n() {
        let block = vec![1.0, -2.0];
        let mut idx = vec![0u16; 2];
        let mut vals = vec![0f32; 2];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 2, &mut idx, &mut vals, &mut scratch);
        assert_eq!(idx, vec![0, 1]);
        assert_eq!(vals, vec![1.0, -2.0]);
    }

    #[test]
    fn topk_handles_all_zero_block() {
        let block = vec![0.0; 8];
        let mut idx = vec![9u16; 2];
        let mut vals = vec![9f32; 2];
        let mut scratch = Vec::new();
        topk_abs_block(&block, 2, &mut idx, &mut vals, &mut scratch);
        assert_eq!(vals, vec![0.0, 0.0]);
        assert!(idx.iter().all(|&i| (i as usize) < 8));
    }

    #[test]
    fn ring_rows_and_ages() {
        let mut w = SlidingWindow::new(4, 1, 2);
        assert_eq!(w.row_for_step(1), 0);
        assert_eq!(w.row_for_step(4), 3);
        assert_eq!(w.row_for_step(5), 0);
        for _ in 0..6 {
            w.commit_row();
        }
        let t = 6; // w = row 1
        assert_eq!(w.age(1, t), 0);
        assert_eq!(w.age(0, t), 1);
        assert_eq!(w.age(3, t), 2);
        assert_eq!(w.age(2, t), 3);
        assert_eq!(w.valid_rows(), 4);
    }

    #[test]
    fn warmup_validity() {
        let w = SlidingWindow::new(4, 1, 2);
        assert!(w.is_valid(0, 1));
        assert!(!w.is_valid(1, 1));
        assert!(w.is_valid(3, 4));
        assert!(w.is_valid(3, 100));
    }

    #[test]
    fn folded_weights_sum_to_one_after_warmup() {
        let mut w = SlidingWindow::new(10, 1, 1);
        for _ in 0..15 {
            w.commit_row();
        }
        let ws = w.folded_weights(15, 0.9);
        let sum: f32 = ws.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
    }

    #[test]
    fn block_major_history_is_contiguous() {
        let mut w = SlidingWindow::new(3, 4, 2);
        // tag every entry with (row, block) so the layout is observable
        for row in 0..3 {
            for b in 0..4 {
                let (idx, vals) = w.entry_mut(row, b);
                for (k, (i, v)) in idx.iter_mut().zip(vals.iter_mut()).enumerate() {
                    *i = (100 * b + 10 * row + k) as u16;
                    *v = (100 * b + 10 * row + k) as f32;
                }
            }
        }
        // block b's full history occupies w.block_range(b..b+1)
        for b in 0..4 {
            let r = w.block_range(b..b + 1);
            assert_eq!(r.len(), 3 * 2);
            for (o, &i) in w.idx[r.clone()].iter().enumerate() {
                let (row, k) = (o / 2, o % 2);
                assert_eq!(i as usize, 100 * b + 10 * row + k);
            }
        }
        // multi-block spans concatenate
        assert_eq!(w.block_range(1..3), 6..18);
        // entry() agrees with the raw span
        let (idx, vals) = w.entry(2, 3);
        assert_eq!(idx, &[320, 321]);
        assert_eq!(vals, &[320.0, 321.0]);
    }

    #[test]
    fn folded_weights_first_step_is_delta() {
        let w = SlidingWindow::new(10, 1, 1);
        let ws = w.folded_weights(1, 0.9);
        assert!((ws[0] - 1.0).abs() < 1e-6);
        assert!(ws[1..].iter().all(|&x| x == 0.0));
    }
}
