//! Minimal dense linear algebra for the GaLore baseline.
//!
//! GaLore needs the top-`r` column space of each layer's gradient matrix.
//! The original uses full SVD; on this substrate we implement a randomized
//! range finder (Halko-Martinsson-Tropp): `P = orth(G (G^T G)^p Omega)` via
//! Gaussian sketching + optional power iterations + modified Gram-Schmidt
//! QR. For the rank-r projection task this matches SVD's subspace up to the
//! spectral-gap terms — the property GaLore actually relies on.

use crate::util::rng::Rng;

/// C = A (a_rows x a_cols) * B (a_cols x b_cols), row-major.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], a_rows: usize, a_cols: usize, b_cols: usize) {
    assert_eq!(a.len(), a_rows * a_cols);
    assert_eq!(b.len(), a_cols * b_cols);
    assert_eq!(c.len(), a_rows * b_cols);
    c.fill(0.0);
    for i in 0..a_rows {
        for k in 0..a_cols {
            let aik = a[i * a_cols + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * b_cols..(k + 1) * b_cols];
            let crow = &mut c[i * b_cols..(i + 1) * b_cols];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// C = A^T (a_cols x a_rows) * B (a_rows x b_cols): A stored (a_rows x a_cols).
pub fn matmul_tn(a: &[f32], b: &[f32], c: &mut [f32], a_rows: usize, a_cols: usize, b_cols: usize) {
    assert_eq!(c.len(), a_cols * b_cols);
    c.fill(0.0);
    for k in 0..a_rows {
        let arow = &a[k * a_cols..(k + 1) * a_cols];
        let brow = &b[k * b_cols..(k + 1) * b_cols];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * b_cols..(i + 1) * b_cols];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// In-place modified Gram-Schmidt orthonormalization of the `cols` columns
/// of `a` (rows x cols, row-major). Degenerate columns are zeroed.
pub fn orthonormalize_columns(a: &mut [f32], rows: usize, cols: usize) {
    for j in 0..cols {
        let mut orig = 0f32;
        for i in 0..rows {
            orig += a[i * cols + j] * a[i * cols + j];
        }
        let orig = orig.sqrt();
        // "Twice is enough" (Kahan): re-orthogonalize so nearly-dependent
        // columns don't leave normalized fp-cancellation noise that is still
        // strongly correlated with the previous columns.
        for _ in 0..2 {
            for p in 0..j {
                let mut dot = 0f32;
                for i in 0..rows {
                    dot += a[i * cols + j] * a[i * cols + p];
                }
                for i in 0..rows {
                    a[i * cols + j] -= dot * a[i * cols + p];
                }
            }
        }
        let mut norm = 0f32;
        for i in 0..rows {
            norm += a[i * cols + j] * a[i * cols + j];
        }
        let norm = norm.sqrt();
        // Rank-deficiency guard: a residual far below the column's original
        // scale is pure cancellation noise, not a new direction.
        if norm > 1e-5 * orig.max(1e-30) && norm > 1e-12 {
            for i in 0..rows {
                a[i * cols + j] /= norm;
            }
        } else {
            for i in 0..rows {
                a[i * cols + j] = 0.0;
            }
        }
    }
}

/// Randomized rank-`r` range finder for `g` (rows x cols): returns a
/// row-major (rows x r) matrix with orthonormal columns approximating the
/// top-r left singular subspace of `g`.
pub fn randomized_range_finder(
    g: &[f32],
    rows: usize,
    cols: usize,
    r: usize,
    power_iters: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    let r = r.min(rows).min(cols);
    // Gaussian sketch Omega (cols x r)
    let omega: Vec<f32> = (0..cols * r).map(|_| sample_gauss(rng)).collect();
    let mut y = vec![0f32; rows * r];
    matmul(g, &omega, &mut y, rows, cols, r);
    orthonormalize_columns(&mut y, rows, r);
    let mut z = vec![0f32; cols * r];
    for _ in 0..power_iters {
        // z = G^T y ; y = G z (power iteration sharpens the subspace)
        matmul_tn(g, &y, &mut z, rows, cols, r);
        matmul(g, &z, &mut y, rows, cols, r);
        orthonormalize_columns(&mut y, rows, r);
    }
    y
}

fn sample_gauss(rng: &mut Rng) -> f32 {
    rng.gauss()
}

/// Frobenius norm.
pub fn fro_norm(a: &[f32]) -> f32 {
    a.iter().map(|v| v * v).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] * [[1,0],[0,1]] = same
        let a = vec![1., 2., 3., 4.];
        let b = vec![1., 0., 0., 1.];
        let mut c = vec![0.; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_tn_is_transpose_times() {
        // A = [[1,2],[3,4]] (2x2); A^T B with B = I -> A^T
        let a = vec![1., 2., 3., 4.];
        let b = vec![1., 0., 0., 1.];
        let mut c = vec![0.; 4];
        matmul_tn(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![1., 3., 2., 4.]);
    }

    #[test]
    fn gram_schmidt_gives_orthonormal_columns() {
        let mut rng = Rng::seed_from_u64(0);
        let rows = 12;
        let cols = 4;
        let mut a: Vec<f32> = (0..rows * cols).map(|_| rng.gen_f32() - 0.5).collect();
        orthonormalize_columns(&mut a, rows, cols);
        for j in 0..cols {
            for p in 0..=j {
                let mut dot = 0f32;
                for i in 0..rows {
                    dot += a[i * cols + j] * a[i * cols + p];
                }
                let expect = if p == j { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-4, "col {j}x{p}: {dot}");
            }
        }
    }

    #[test]
    fn range_finder_recovers_lowrank_subspace() {
        // G = u v^T (rank 1); the range finder must capture u.
        let rows = 16;
        let cols = 10;
        let mut rng = Rng::seed_from_u64(1);
        let u: Vec<f32> = (0..rows).map(|i| (i as f32 * 0.37).sin() + 1.0).collect();
        let v: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.11).cos() + 0.5).collect();
        let mut g = vec![0f32; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                g[i * cols + j] = u[i] * v[j];
            }
        }
        let p = randomized_range_finder(&g, rows, cols, 2, 1, &mut rng);
        // projection of G onto span(P) should reproduce G: ||G - P P^T G|| small
        let mut ptg = vec![0f32; 2 * cols];
        matmul_tn(&p, &g, &mut ptg, rows, 2, cols);
        let mut rec = vec![0f32; rows * cols];
        matmul(&p, &ptg, &mut rec, rows, 2, cols);
        let mut diff = 0f32;
        for i in 0..g.len() {
            diff += (g[i] - rec[i]).powi(2);
        }
        assert!(diff.sqrt() / fro_norm(&g) < 1e-2, "{}", diff.sqrt() / fro_norm(&g));
    }
}
