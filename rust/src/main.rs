//! `microadam` — launcher CLI for the MicroAdam reproduction.
//!
//! Subcommands:
//!   train   --config cfg.json | --model lm_tiny --optimizer micro-adam ...
//!   repro   memory|fig1|fig8|fig9|theory|table1|table2|table3|table4|all
//!   list    (artifacts in the manifest)
//!   selftest (load + run one artifact end-to-end)
//!
//! Offline note: argument parsing is hand-rolled (clap is not in the
//! vendored crate set); `--flag value` pairs only.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use microadam::bench;
use microadam::coordinator::config::{parse_optimizer, OptBackend, TrainConfig};
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::coordinator::trainer::Trainer;
use microadam::dist::{
    default_rendezvous, parse_reducer, parse_topology, parse_transport, ring_tcp_coordinator,
    ring_tcp_worker, ring_uds_coordinator, ring_uds_worker, transport_name, tree_tcp_coordinator,
    tree_tcp_worker, tree_uds_coordinator, tree_uds_worker, DistTrainer, ShmTransport,
    TcpPending, TcpTransport, Topology, Transport, TransportKind, UdsPending, UdsTransport,
};
use microadam::runtime::Runtime;
use microadam::trace;
use microadam::util::json::Json;

struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{name} needs a value"))?
                    .clone();
                flags.insert(name.to_string(), val);
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self { flags, positional })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v}")),
        }
    }

    fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad float {v}")),
        }
    }
}

const USAGE: &str = "\
microadam — MicroAdam (NeurIPS 2024) reproduction launcher

USAGE:
  microadam train   [--config cfg.json] [--model lm_tiny] [--optimizer micro-adam]
                    [--backend aot|native] [--steps N] [--lr F] [--schedule const|warmup-cosine]
                    [--warmup N] [--weight-decay F] [--seed N] [--grad-accum N]
                    [--workers N (0 = auto)] [--pin-workers yes]
                      (--pin-workers pins each exec worker to a cpu —
                       NUMA nodes round-robin first — and keeps the
                       shard→worker mapping static so first-touch page
                       placement sticks; best-effort, silently unpinned
                       where the platform refuses.)
                    [--out runs/x.jsonl] [--artifacts artifacts]
                    [--checkpoint path.bin] [--trace runs/x.trace.json]
                      (--trace enables the tracing layer: per-phase span /
                       EF-health records go into the --out JSONL and a
                       Chrome trace-event file is written to the given
                       path — open it in Perfetto or chrome://tracing.)
                    [--ranks N] [--reduce dense|topk|eftopk]
                    [--transport loopback|uds|tcp|shm] [--topology star|ring|tree]
                    [--rendezvous PATH|host:port] [--external yes]
                      (--topology picks the aggregation shape for the
                       uds/tcp transports: rank-0 star (default), a
                       successor ring that forwards partially-aggregated
                       hop frames, or a binary tree that gathers from
                       children and relays the bundle down. loopback/shm
                       are star-only.)
                      (--ranks > 1, or any --reduce/--transport, routes
                       through the data-parallel engine; artifact-free
                       models use the native mlp_tiny/mlp_small workloads.
                       With --transport uds|tcp|shm, rank 0 spawns one
                       worker process per extra rank; --rendezvous only
                       picks the socket path / mailbox dir / tcp
                       host:port (tcp defaults to 127.0.0.1:0 — an
                       ephemeral port workers inherit resolved). Pass
                       --external yes to join workers you started by hand
                       instead — each one runs `train --dist-rank R
                       --rendezvous ADDR` with the same config; with tcp
                       the workers may live on other hosts.)
  microadam repro   <memory|fig1|fig8|fig9|theory|table1|table2|table3|table4|dist|all>
                    [--steps N] [--model NAME] [--out-dir runs] [--artifacts artifacts]
  microadam list    [--artifacts artifacts]
  microadam selftest [--artifacts artifacts]
  microadam tracecheck [--chrome out.trace.json] [--jsonl runs/x.jsonl]
                    [--require-ef yes]
                      (validate the two trace sinks: the Chrome
                       trace-event file and/or the JSONL
                       {\"kind\":\"trace\"} records; --require-ef yes also
                       insists on the EF-health gauges.)

Optimizers: micro-adam adam adamw adamw-8bit sgd adafactor came galore galore-ef
            ldadam adammini   (--optim is an alias for --optimizer)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "repro" => cmd_repro(&args),
        "list" => cmd_list(&args),
        "selftest" => cmd_selftest(&args),
        "tracecheck" => cmd_tracecheck(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other}\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_file(path)?,
        None => TrainConfig::default(),
    };
    if let Some(v) = args.get("model") {
        cfg.model = v.into();
    }
    if let Some(v) = args.get("optimizer").or_else(|| args.get("optim")) {
        cfg.optimizer = parse_optimizer(v)?;
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = match v {
            "aot" => OptBackend::Aot,
            "native" => OptBackend::Native,
            other => bail!("--backend {other}: expected aot|native"),
        };
    }
    cfg.steps = args.get_u64("steps", cfg.steps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.weight_decay = args.get_f32("weight-decay", cfg.weight_decay)?;
    cfg.grad_accum = args.get_u64("grad-accum", cfg.grad_accum as u64)? as usize;
    cfg.workers = args.get_u64("workers", cfg.workers as u64)? as usize;
    if let Some(v) = args.get("pin-workers") {
        cfg.pin_workers = matches!(v, "yes" | "true" | "1");
    }
    cfg.ranks = (args.get_u64("ranks", cfg.ranks as u64)? as usize).max(1);
    if let Some(v) = args.get("reduce") {
        cfg.reduce = parse_reducer(v)?;
    }
    if let Some(v) = args.get("transport") {
        cfg.transport = parse_transport(v)?;
    }
    if let Some(v) = args.get("topology") {
        cfg.topology = parse_topology(v)?;
    }
    if let Some(v) = args.get("out") {
        cfg.out = v.into();
    }
    if let Some(v) = args.get("trace") {
        cfg.trace = v.into();
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.into();
    }
    // Only rebuild the schedule when a schedule-shaping flag is present,
    // and then only change what the flags name: `--lr`/`--warmup` keep the
    // config's schedule *kind* and its other knobs. (Crucial for the
    // multi-process launcher: workers are driven by the coordinator's
    // provenance JSON and must reconstruct the identical schedule.)
    if args.get("lr").is_some() || args.get("schedule").is_some() || args.get("warmup").is_some()
    {
        let current_kind = match cfg.schedule {
            LrSchedule::Const { .. } => "const",
            LrSchedule::WarmupCosine { .. } => "warmup-cosine",
            LrSchedule::LinearDecay { .. } => "linear-decay",
        };
        let lr = args.get_f32("lr", cfg.schedule.peak())?;
        cfg.schedule = match args.get("schedule").unwrap_or(current_kind) {
            "const" => LrSchedule::Const { lr },
            "warmup-cosine" => {
                let (dw, dt, df) = match cfg.schedule {
                    LrSchedule::WarmupCosine { warmup, total, floor_frac, .. } => {
                        (warmup, total, floor_frac)
                    }
                    _ => (cfg.steps / 20, cfg.steps, 0.05),
                };
                LrSchedule::WarmupCosine {
                    lr,
                    warmup: args.get_u64("warmup", dw)?,
                    total: dt,
                    floor_frac: df,
                }
            }
            "linear-decay" => {
                let total = match cfg.schedule {
                    LrSchedule::LinearDecay { total, .. } => total,
                    _ => cfg.steps,
                };
                LrSchedule::LinearDecay { lr, total }
            }
            other => bail!("--schedule {other}: expected const|warmup-cosine|linear-decay"),
        };
    }
    // `--steps` retargets a horizon-shaped schedule to the new run length:
    // reusing a 1000-step run's provenance JSON for a 100-step probe must
    // not leave a cosine (or decay) pinned to the old 1000-step horizon.
    if args.get("steps").is_some() {
        cfg.schedule = match cfg.schedule {
            LrSchedule::WarmupCosine { lr, warmup, floor_frac, .. } => LrSchedule::WarmupCosine {
                lr,
                warmup: warmup.min(cfg.steps / 2),
                total: cfg.steps,
                floor_frac,
            },
            LrSchedule::LinearDecay { lr, .. } => LrSchedule::LinearDecay { lr, total: cfg.steps },
            s @ LrSchedule::Const { .. } => s,
        };
    }

    // A spawned worker process joins its coordinator's run and exits.
    if args.get("dist-rank").is_some() {
        return cmd_train_dist_worker(args, cfg);
    }
    // --ranks > 1 (or an explicit --ranks/--reduce/--transport flag) routes
    // through the data-parallel engine; single-process training is
    // unchanged. The uds/shm transports go through the launcher.
    if cfg.ranks > 1
        || args.get("ranks").is_some()
        || args.get("reduce").is_some()
        || args.get("transport").is_some()
        || args.get("topology").is_some()
        || cfg.transport != TransportKind::Loopback
        || cfg.topology != Topology::Star
    {
        if cfg.transport != TransportKind::Loopback {
            return cmd_train_dist_launch(args, cfg);
        }
        return cmd_train_dist(args, cfg);
    }

    let mut trainer = Trainer::new(cfg)?;
    let session = (!trainer.cfg.trace.is_empty()).then(|| trace::session_to(&trainer.cfg.trace));
    let mut logger = MetricsLogger::new(&trainer.cfg.out)?;
    let t0 = std::time::Instant::now();
    trainer.train(&mut logger)?;
    let dt = t0.elapsed().as_secs_f64();
    if let Some(s) = session {
        s.finish()?;
        println!("chrome trace written to {}", trainer.cfg.trace);
    }
    println!(
        "done: {} steps in {:.1}s ({:.2} steps/s), loss {:.4} -> {:.4}, opt state {} bytes",
        trainer.cfg.steps,
        dt,
        trainer.cfg.steps as f64 / dt,
        logger.first_loss(),
        logger.tail_loss(10),
        trainer.opt_state_bytes()
    );
    if let Some(path) = args.get("checkpoint") {
        let ck = microadam::coordinator::checkpoint::Checkpoint {
            step: trainer.t,
            params: trainer.params_vec()?,
            opt: trainer.opt_snapshot()?,
        };
        ck.save(path)?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

fn cmd_train_dist(args: &Args, cfg: TrainConfig) -> Result<()> {
    let mut trainer = DistTrainer::new(cfg)?;
    let session = (!trainer.cfg.trace.is_empty()).then(|| trace::session_to(&trainer.cfg.trace));
    let mut logger = MetricsLogger::new(&trainer.cfg.out)?;
    let t0 = std::time::Instant::now();
    trainer.train(&mut logger)?;
    let dt = t0.elapsed().as_secs_f64();
    if let Some(s) = session {
        s.finish()?;
        println!("chrome trace written to {}", trainer.cfg.trace);
    }
    dist_summary(args, &trainer, &logger, dt)
}

/// The coordinator-side wrap-up shared by the loopback and multi-process
/// paths: throughput/loss summary, framed-bytes accounting, checkpoint.
fn dist_summary(
    args: &Args,
    trainer: &DistTrainer,
    logger: &MetricsLogger,
    dt: f64,
) -> Result<()> {
    println!(
        "done: {} ranks x {} steps ({} via {}) in {:.1}s ({:.2} steps/s), loss {:.4} -> {:.4}",
        trainer.ranks,
        trainer.cfg.steps,
        trainer.reducer_name(),
        trainer.transport_name(),
        dt,
        trainer.cfg.steps as f64 / dt,
        logger.first_loss(),
        logger.tail_loss(10),
    );
    println!(
        "communicated {:.2} MB total ({} framed B/rank/step = payload + {} B frame overhead), \
         opt state {} B, reducer residual {} B",
        trainer.wire_bytes_total() as f64 / (1u64 << 20) as f64,
        trainer.frame_bytes_per_rank(),
        microadam::dist::FRAME_OVERHEAD,
        trainer.opt_state_bytes(),
        trainer.reducer_state_bytes(),
    );
    let overlap = trainer.gather_overlap_ms();
    if overlap > 0.0 {
        println!("gather/relay overlap (pipelined coordinator): {overlap:.1} ms hidden");
    }
    if let Some(path) = args.get("checkpoint") {
        trainer.save_checkpoint(path)?;
        println!(
            "checkpoint written to {path} (params + optimizer state when the \
             optimizer snapshots; reducer EF state is not persisted)"
        );
    }
    Ok(())
}

/// Launch a multi-process run: rank 0 binds the rendezvous, spawns one
/// worker process per extra rank (unless `--external yes` points at
/// workers started by hand), trains as rank 0, then reaps the workers.
fn cmd_train_dist_launch(args: &Args, cfg: TrainConfig) -> Result<()> {
    let ranks = cfg.ranks;
    let kind = cfg.transport;
    if cfg.topology != Topology::Star
        && !matches!(kind, TransportKind::Uds | TransportKind::Tcp)
    {
        bail!(
            "--topology ring|tree re-wires the per-rank links, which only the uds/tcp \
             transports expose — {} is star-only",
            transport_name(kind)
        );
    }
    // --rendezvous only picks the path/address; --external yes switches to
    // join-by-hand mode (the operator starts the workers themselves with
    // `train --dist-rank R --rendezvous ADDR`).
    let spawn_workers = !matches!(args.get("external"), Some("yes") | Some("true") | Some("1"));
    let rdv = match args.get("rendezvous") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_rendezvous(kind),
    };

    // Bind/create the rendezvous BEFORE spawning so no worker can race it.
    let pending = match kind {
        TransportKind::Uds => Some(UdsPending::bind(&rdv, ranks)?),
        TransportKind::Tcp | TransportKind::Shm => None,
        TransportKind::Loopback => unreachable!("loopback has no launcher"),
    };
    let tcp_pending = match kind {
        TransportKind::Tcp => Some(TcpPending::bind(&rdv.to_string_lossy(), ranks)?),
        _ => None,
    };
    let shm = match kind {
        TransportKind::Shm => Some(ShmTransport::coordinator(&rdv, ranks)?),
        _ => None,
    };
    // What workers are pointed at. For tcp this is the *resolved* bound
    // address — `--rendezvous 127.0.0.1:0` becomes a concrete ephemeral
    // port only after the bind, and workers must inherit that port.
    let worker_rdv: std::path::PathBuf = match &tcp_pending {
        Some(p) => p.local_addr()?.to_string().into(),
        None => rdv.clone(),
    };

    // Workers get the full provenance config plus their rank.
    let cfg_path = std::env::temp_dir()
        .join(format!("microadam-dist-cfg-{}.json", std::process::id()));
    std::fs::write(&cfg_path, cfg.to_json().to_string())?;
    let mut children = Vec::new();
    if spawn_workers {
        let exe = std::env::current_exe()?;
        for r in 1..ranks {
            let spawned = std::process::Command::new(&exe)
                .arg("train")
                .arg("--config")
                .arg(&cfg_path)
                .arg("--dist-rank")
                .arg(r.to_string())
                .arg("--rendezvous")
                .arg(&worker_rdv)
                .spawn();
            match spawned {
                Ok(child) => children.push(child),
                Err(e) => {
                    // don't leak the workers already launched
                    for mut c in children {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    let _ = std::fs::remove_file(&cfg_path);
                    bail!("spawn worker rank {r}: {e}");
                }
            }
        }
        eprintln!(
            "[dist] launched {} worker process(es) ({} rendezvous {})",
            ranks - 1,
            transport_name(kind),
            worker_rdv.display()
        );
    } else {
        // External mode: the operator starts the workers by hand, so the
        // *resolved* rendezvous must be surfaced — for tcp an ephemeral
        // `:0` bind only has a concrete port after the bind above.
        eprintln!(
            "[dist] waiting for {} hand-started worker(s) — each must run:\n\
             [dist]   microadam train --config <same config> --dist-rank R \
             --rendezvous {}",
            ranks - 1,
            worker_rdv.display()
        );
    }

    let mut result = (|| -> Result<()> {
        let transport: Box<dyn Transport> = match (kind, cfg.topology) {
            (TransportKind::Uds, Topology::Star) => {
                Box::new(pending.expect("bound above").accept()?)
            }
            (TransportKind::Uds, Topology::Ring) => {
                Box::new(ring_uds_coordinator(pending.expect("bound above"))?)
            }
            (TransportKind::Uds, Topology::Tree) => {
                Box::new(tree_uds_coordinator(pending.expect("bound above"))?)
            }
            (TransportKind::Tcp, Topology::Star) => {
                Box::new(tcp_pending.expect("bound above").accept()?)
            }
            (TransportKind::Tcp, Topology::Ring) => {
                Box::new(ring_tcp_coordinator(tcp_pending.expect("bound above"))?)
            }
            (TransportKind::Tcp, Topology::Tree) => {
                Box::new(tree_tcp_coordinator(tcp_pending.expect("bound above"))?)
            }
            (TransportKind::Shm, _) => Box::new(shm.expect("created above")),
            (TransportKind::Loopback, _) => unreachable!(),
        };
        let mut trainer = DistTrainer::with_transport(cfg, transport, vec![0])?;
        let session =
            (!trainer.cfg.trace.is_empty()).then(|| trace::session_to(&trainer.cfg.trace));
        let mut logger = MetricsLogger::new(&trainer.cfg.out)?;
        let t0 = std::time::Instant::now();
        trainer.train(&mut logger)?;
        let dt = t0.elapsed().as_secs_f64();
        if let Some(s) = session {
            s.finish()?;
            println!("chrome trace written to {}", trainer.cfg.trace);
        }
        dist_summary(args, &trainer, &logger, dt)
    })();

    // Reap every worker (kill first if the run already failed — they would
    // otherwise sit out their transport timeouts); only then report.
    for c in &mut children {
        if result.is_err() {
            let _ = c.kill();
        }
        match c.wait() {
            Ok(status) if result.is_ok() && !status.success() => {
                result = Err(anyhow!("dist worker exited with {status}"));
                // failure mode switch: put the remaining workers down too
            }
            Ok(_) => {}
            Err(e) => {
                if result.is_ok() {
                    result = Err(anyhow!("reap dist worker: {e}"));
                }
            }
        }
    }
    let _ = std::fs::remove_file(&cfg_path);
    result
}

/// A spawned (or hand-started) worker process: connect to the rendezvous
/// as `--dist-rank R`, train silently in lockstep, exit.
fn cmd_train_dist_worker(args: &Args, mut cfg: TrainConfig) -> Result<()> {
    let rank = args.get_u64("dist-rank", 0)? as usize;
    let ranks = cfg.ranks;
    if rank == 0 || rank >= ranks {
        bail!("--dist-rank {rank}: workers are ranks 1..{ranks}");
    }
    let rdv = args
        .get("rendezvous")
        .ok_or_else(|| anyhow!("--dist-rank needs --rendezvous"))?
        .to_string();
    // Only the coordinator writes metrics/checkpoints/traces.
    cfg.out = String::new();
    cfg.trace = String::new();
    let transport: Box<dyn Transport> = match (cfg.transport, cfg.topology) {
        (TransportKind::Uds, Topology::Star) => {
            Box::new(UdsTransport::connect(&rdv, rank, ranks)?)
        }
        (TransportKind::Uds, Topology::Ring) => Box::new(ring_uds_worker(&rdv, rank, ranks)?),
        (TransportKind::Uds, Topology::Tree) => Box::new(tree_uds_worker(&rdv, rank, ranks)?),
        (TransportKind::Tcp, Topology::Star) => {
            Box::new(TcpTransport::connect(&rdv, rank, ranks)?)
        }
        (TransportKind::Tcp, Topology::Ring) => Box::new(ring_tcp_worker(&rdv, rank, ranks)?),
        (TransportKind::Tcp, Topology::Tree) => Box::new(tree_tcp_worker(&rdv, rank, ranks)?),
        (TransportKind::Shm, Topology::Star) => {
            Box::new(ShmTransport::worker(&rdv, rank, ranks)?)
        }
        (TransportKind::Shm, _) => {
            bail!("--topology ring|tree needs the uds or tcp transport")
        }
        (TransportKind::Loopback, _) => {
            bail!("--dist-rank only applies to the uds/tcp/shm transports")
        }
    };
    let mut trainer = DistTrainer::with_transport(cfg, transport, vec![rank])?;
    let mut logger = MetricsLogger::new("")?;
    trainer.train(&mut logger)
}

/// Validate the two trace sinks (the `make trace-smoke` lane is built on
/// this): `--chrome FILE` checks the Chrome trace-event document parses,
/// has a non-empty `traceEvents` array and a monotonic `ts`; `--jsonl
/// FILE` checks every `{"kind":"trace"}` record against the v1 schema.
/// `--require-ef yes` additionally insists the JSONL carries the three
/// EF-health gauges.
fn cmd_tracecheck(args: &Args) -> Result<()> {
    let mut checked = false;
    if let Some(path) = args.get("chrome") {
        check_chrome_trace(path)?;
        checked = true;
    }
    if let Some(path) = args.get("jsonl") {
        let require_ef =
            matches!(args.get("require-ef"), Some("yes") | Some("true") | Some("1"));
        check_jsonl_trace(path, require_ef)?;
        checked = true;
    }
    if !checked {
        bail!("tracecheck needs --chrome FILE and/or --jsonl FILE\n{USAGE}");
    }
    Ok(())
}

fn check_chrome_trace(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("{path}: not JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{path}: no traceEvents array"))?;
    if events.is_empty() {
        bail!("{path}: traceEvents is empty");
    }
    let (mut spans, mut counters) = (0usize, 0usize);
    let mut last_ts = f64::NEG_INFINITY;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{path}: event {i} has no ph"))?;
        let ts = ev
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("{path}: event {i} has no ts"))?;
        if ev.get("name").and_then(Json::as_str).is_none() {
            bail!("{path}: event {i} has no name");
        }
        if ts < last_ts {
            bail!("{path}: ts not monotonic at event {i} ({ts} < {last_ts})");
        }
        last_ts = ts;
        match ph {
            "X" => spans += 1,
            "C" => counters += 1,
            other => bail!("{path}: event {i}: unexpected ph {other:?}"),
        }
    }
    println!("tracecheck chrome: {path} OK ({spans} spans, {counters} counter samples)");
    Ok(())
}

fn check_jsonl_trace(path: &str, require_ef: bool) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let mut n = 0usize;
    // residual_norm / topk_mass / quant_abs_err seen?
    let mut ef = [false; 3];
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let lineno = i + 1;
        let j = Json::parse(line).map_err(|e| anyhow!("{path}:{lineno}: bad JSON: {e}"))?;
        if j.get("kind").and_then(Json::as_str) != Some("trace") {
            continue;
        }
        n += 1;
        if j.get("v").and_then(Json::as_f64) != Some(trace::SCHEMA_VERSION as f64) {
            bail!("{path}:{lineno}: trace record with wrong schema version");
        }
        if j.get("step").and_then(Json::as_f64).is_none() {
            bail!("{path}:{lineno}: trace record has no step");
        }
        let ty = j.get("type").and_then(Json::as_str).unwrap_or("");
        let well_formed = match ty {
            "gauge" | "counter" => {
                j.get("name").and_then(Json::as_str).is_some()
                    && j.get("value").and_then(Json::as_f64).is_some()
            }
            "spans" => {
                j.get("cat").and_then(Json::as_str).is_some()
                    && j.get("name").and_then(Json::as_str).is_some()
                    && j.get("count").and_then(Json::as_f64).is_some()
                    && j.get("total_us").and_then(Json::as_f64).is_some()
            }
            _ => false,
        };
        if !well_formed {
            bail!("{path}:{lineno}: malformed trace record (type {ty:?})");
        }
        if ty == "gauge" {
            match j.get("name").and_then(Json::as_str) {
                Some("ef.residual_norm") => ef[0] = true,
                Some("ef.topk_mass") => ef[1] = true,
                Some("ef.quant_abs_err") => ef[2] = true,
                _ => {}
            }
        }
    }
    if n == 0 {
        bail!("{path}: no {{\"kind\":\"trace\"}} records");
    }
    if require_ef && ef != [true; 3] {
        bail!(
            "{path}: missing EF-health gauges \
             (residual_norm/topk_mass/quant_abs_err seen: {ef:?})"
        );
    }
    println!("tracecheck jsonl: {path} OK ({n} trace records)");
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("repro needs an experiment id\n{USAGE}"))?;
    let out_dir = args.get("out-dir").unwrap_or("runs").to_string();
    let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
    match what.as_str() {
        "memory" => bench::run_memory()?,
        "fig1" => bench::run_fig1(&out_dir, args.get_u64("steps", 1500)? as usize)?,
        "fig8" => bench::run_fig8(&out_dir, args.get_u64("steps", 300)? as usize)?,
        "fig9" => bench::run_fig9(&out_dir, args.get_u64("steps", 1500)? as usize)?,
        "theory" => bench::run_theory(&out_dir)?,
        "table1" => {
            let model = args.get("model").unwrap_or("cls_tiny");
            bench::run_table1(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "table2" => {
            let model = args.get("model").unwrap_or("lm_tiny");
            bench::run_table2(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "table3" => {
            let model = args.get("model").unwrap_or("cls_tiny");
            bench::run_table3(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "table4" => {
            let model = args.get("model").unwrap_or("cnn_tiny");
            bench::run_table4(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "dist" => {
            bench::run_dist_sweep(&out_dir, args.get_u64("steps", 60)?)?;
        }
        "all" => {
            bench::run_memory()?;
            bench::run_fig1(&out_dir, 1500)?;
            bench::run_fig9(&out_dir, 1500)?;
            bench::run_fig8(&out_dir, 300)?;
            bench::run_theory(&out_dir)?;
            let steps = args.get_u64("steps", 150)?;
            bench::run_table1(&artifacts, &out_dir, "cls_tiny", steps)?;
            bench::run_table2(&artifacts, &out_dir, "lm_tiny", steps)?;
            bench::run_table3(&artifacts, &out_dir, "cls_tiny", steps)?;
            bench::run_table4(&artifacts, &out_dir, "cnn_tiny", steps)?;
            bench::run_dist_sweep(&out_dir, 60)?;
        }
        other => bail!("unknown experiment {other}"),
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::load(args.get("artifacts").unwrap_or("artifacts"))?;
    println!("{:<28} {:<9} inputs -> outputs", "artifact", "kind");
    for name in rt.names() {
        let m = rt.meta(name)?;
        let ins: Vec<String> = m
            .inputs
            .iter()
            .map(|(n, d, s)| format!("{n}:{d}{s:?}"))
            .collect();
        println!("{:<28} {:<9} {} -> {:?}", name, m.kind, ins.join(", "), m.outputs);
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    // Registry <-> CLI agreement: every registered optimizer kind must
    // round-trip through its CLI name, so a kind added to the registry
    // cannot silently be unreachable from `--optim`.
    use microadam::coordinator::config::optimizer_name;
    use microadam::optim::OptimizerKind;
    for &kind in OptimizerKind::all() {
        let name = optimizer_name(kind);
        if parse_optimizer(name)? != kind {
            bail!("selftest: optimizer registry/CLI mismatch for {name}");
        }
    }
    println!(
        "selftest: optimizer registry <-> CLI names agree ({} kinds)",
        OptimizerKind::all().len()
    );

    // End-to-end smoke: one train step of each backend on the tiny model.
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    for (backend, name) in [(OptBackend::Aot, "aot"), (OptBackend::Native, "native")] {
        let cfg = TrainConfig {
            model: "lm_tiny".into(),
            backend,
            steps: 3,
            artifacts_dir: artifacts.into(),
            log_every: 1,
            ..Default::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let mut logger = MetricsLogger::new("")?;
        trainer.train(&mut logger)?;
        println!(
            "selftest [{name}]: loss {:.4} -> {:.4} OK",
            logger.first_loss(),
            logger.tail_loss(1)
        );
    }
    println!("selftest passed");
    Ok(())
}
