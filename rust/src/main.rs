//! `microadam` — launcher CLI for the MicroAdam reproduction.
//!
//! Subcommands:
//!   train   --config cfg.json | --model lm_tiny --optimizer micro-adam ...
//!   repro   memory|fig1|fig8|fig9|theory|table1|table2|table3|table4|all
//!   list    (artifacts in the manifest)
//!   selftest (load + run one artifact end-to-end)
//!
//! Offline note: argument parsing is hand-rolled (clap is not in the
//! vendored crate set); `--flag value` pairs only.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use microadam::bench;
use microadam::coordinator::config::{parse_optimizer, OptBackend, TrainConfig};
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::coordinator::trainer::Trainer;
use microadam::dist::{parse_reducer, DistTrainer};
use microadam::runtime::Runtime;

struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("flag --{name} needs a value"))?
                    .clone();
                flags.insert(name.to_string(), val);
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self { flags, positional })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v}")),
        }
    }

    fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad float {v}")),
        }
    }
}

const USAGE: &str = "\
microadam — MicroAdam (NeurIPS 2024) reproduction launcher

USAGE:
  microadam train   [--config cfg.json] [--model lm_tiny] [--optimizer micro-adam]
                    [--backend aot|native] [--steps N] [--lr F] [--schedule const|warmup-cosine]
                    [--warmup N] [--weight-decay F] [--seed N] [--grad-accum N]
                    [--workers N (0 = auto)] [--out runs/x.jsonl] [--artifacts artifacts]
                    [--checkpoint path.bin]
                    [--ranks N] [--reduce dense|topk|eftopk]
                      (--ranks > 1, or any --reduce, routes through the
                       data-parallel engine; artifact-free models use the
                       native mlp_tiny/mlp_small workloads)
  microadam repro   <memory|fig1|fig8|fig9|theory|table1|table2|table3|table4|dist|all>
                    [--steps N] [--model NAME] [--out-dir runs] [--artifacts artifacts]
  microadam list    [--artifacts artifacts]
  microadam selftest [--artifacts artifacts]

Optimizers: micro-adam adam adamw adamw-8bit sgd adafactor came galore galore-ef
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "repro" => cmd_repro(&args),
        "list" => cmd_list(&args),
        "selftest" => cmd_selftest(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other}\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::from_file(path)?,
        None => TrainConfig::default(),
    };
    if let Some(v) = args.get("model") {
        cfg.model = v.into();
    }
    if let Some(v) = args.get("optimizer") {
        cfg.optimizer = parse_optimizer(v)?;
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = match v {
            "aot" => OptBackend::Aot,
            "native" => OptBackend::Native,
            other => bail!("--backend {other}: expected aot|native"),
        };
    }
    cfg.steps = args.get_u64("steps", cfg.steps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.weight_decay = args.get_f32("weight-decay", cfg.weight_decay)?;
    cfg.grad_accum = args.get_u64("grad-accum", cfg.grad_accum as u64)? as usize;
    cfg.workers = args.get_u64("workers", cfg.workers as u64)? as usize;
    cfg.ranks = (args.get_u64("ranks", cfg.ranks as u64)? as usize).max(1);
    if let Some(v) = args.get("reduce") {
        cfg.reduce = parse_reducer(v)?;
    }
    if let Some(v) = args.get("out") {
        cfg.out = v.into();
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.into();
    }
    let lr = args.get_f32("lr", cfg.schedule.peak())?;
    cfg.schedule = match args.get("schedule").unwrap_or("const") {
        "const" => LrSchedule::Const { lr },
        "warmup-cosine" => LrSchedule::WarmupCosine {
            lr,
            warmup: args.get_u64("warmup", cfg.steps / 20)?,
            total: cfg.steps,
            floor_frac: 0.05,
        },
        other => bail!("--schedule {other}: expected const|warmup-cosine"),
    };

    // --ranks > 1 (or an explicit --ranks/--reduce flag) routes through the
    // data-parallel engine; plain single-process training is unchanged.
    if cfg.ranks > 1 || args.get("ranks").is_some() || args.get("reduce").is_some() {
        return cmd_train_dist(args, cfg);
    }

    let mut trainer = Trainer::new(cfg)?;
    let mut logger = MetricsLogger::new(&trainer.cfg.out)?;
    let t0 = std::time::Instant::now();
    trainer.train(&mut logger)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "done: {} steps in {:.1}s ({:.2} steps/s), loss {:.4} -> {:.4}, opt state {} bytes",
        trainer.cfg.steps,
        dt,
        trainer.cfg.steps as f64 / dt,
        logger.first_loss(),
        logger.tail_loss(10),
        trainer.opt_state_bytes()
    );
    if let Some(path) = args.get("checkpoint") {
        let ck = microadam::coordinator::checkpoint::Checkpoint {
            step: trainer.t,
            params: trainer.params_vec()?,
            opt: trainer.microadam_state().map(|s| s.snapshot()).transpose()?,
        };
        ck.save(path)?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

fn cmd_train_dist(args: &Args, cfg: TrainConfig) -> Result<()> {
    let mut trainer = DistTrainer::new(cfg)?;
    let mut logger = MetricsLogger::new(&trainer.cfg.out)?;
    let t0 = std::time::Instant::now();
    trainer.train(&mut logger)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "done: {} ranks x {} steps ({}) in {:.1}s ({:.2} steps/s), loss {:.4} -> {:.4}",
        trainer.ranks,
        trainer.cfg.steps,
        trainer.reducer_name(),
        dt,
        trainer.cfg.steps as f64 / dt,
        logger.first_loss(),
        logger.tail_loss(10),
    );
    println!(
        "communicated {:.2} MB total ({} B/rank/step), opt state {} B, reducer residual {} B",
        trainer.wire_bytes_total() as f64 / (1u64 << 20) as f64,
        trainer.wire_bytes_total() / (trainer.ranks as u64 * trainer.cfg.steps.max(1)),
        trainer.opt_state_bytes(),
        trainer.reducer_state_bytes(),
    );
    if let Some(path) = args.get("checkpoint") {
        trainer.save_checkpoint(path)?;
        println!(
            "checkpoint written to {path} (params-only: dist does not snapshot \
             optimizer/reducer state yet)"
        );
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("repro needs an experiment id\n{USAGE}"))?;
    let out_dir = args.get("out-dir").unwrap_or("runs").to_string();
    let artifacts = args.get("artifacts").unwrap_or("artifacts").to_string();
    match what.as_str() {
        "memory" => bench::run_memory()?,
        "fig1" => bench::run_fig1(&out_dir, args.get_u64("steps", 1500)? as usize)?,
        "fig8" => bench::run_fig8(&out_dir, args.get_u64("steps", 300)? as usize)?,
        "fig9" => bench::run_fig9(&out_dir, args.get_u64("steps", 1500)? as usize)?,
        "theory" => bench::run_theory(&out_dir)?,
        "table1" => {
            let model = args.get("model").unwrap_or("cls_tiny");
            bench::run_table1(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "table2" => {
            let model = args.get("model").unwrap_or("lm_tiny");
            bench::run_table2(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "table3" => {
            let model = args.get("model").unwrap_or("cls_tiny");
            bench::run_table3(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "table4" => {
            let model = args.get("model").unwrap_or("cnn_tiny");
            bench::run_table4(&artifacts, &out_dir, model, args.get_u64("steps", 150)?)?
        }
        "dist" => {
            bench::run_dist_sweep(&out_dir, args.get_u64("steps", 60)?)?;
        }
        "all" => {
            bench::run_memory()?;
            bench::run_fig1(&out_dir, 1500)?;
            bench::run_fig9(&out_dir, 1500)?;
            bench::run_fig8(&out_dir, 300)?;
            bench::run_theory(&out_dir)?;
            let steps = args.get_u64("steps", 150)?;
            bench::run_table1(&artifacts, &out_dir, "cls_tiny", steps)?;
            bench::run_table2(&artifacts, &out_dir, "lm_tiny", steps)?;
            bench::run_table3(&artifacts, &out_dir, "cls_tiny", steps)?;
            bench::run_table4(&artifacts, &out_dir, "cnn_tiny", steps)?;
            bench::run_dist_sweep(&out_dir, 60)?;
        }
        other => bail!("unknown experiment {other}"),
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::load(args.get("artifacts").unwrap_or("artifacts"))?;
    println!("{:<28} {:<9} inputs -> outputs", "artifact", "kind");
    for name in rt.names() {
        let m = rt.meta(name)?;
        let ins: Vec<String> = m
            .inputs
            .iter()
            .map(|(n, d, s)| format!("{n}:{d}{s:?}"))
            .collect();
        println!("{:<28} {:<9} {} -> {:?}", name, m.kind, ins.join(", "), m.outputs);
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    // End-to-end smoke: one train step of each backend on the tiny model.
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    for (backend, name) in [(OptBackend::Aot, "aot"), (OptBackend::Native, "native")] {
        let cfg = TrainConfig {
            model: "lm_tiny".into(),
            backend,
            steps: 3,
            artifacts_dir: artifacts.into(),
            log_every: 1,
            ..Default::default()
        };
        let mut trainer = Trainer::new(cfg)?;
        let mut logger = MetricsLogger::new("")?;
        trainer.train(&mut logger)?;
        println!(
            "selftest [{name}]: loss {:.4} -> {:.4} OK",
            logger.first_loss(),
            logger.tail_loss(1)
        );
    }
    println!("selftest passed");
    Ok(())
}
