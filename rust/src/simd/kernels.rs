//! `target_feature` instantiations of the fused-step kernels.
//!
//! Nothing in this file contains new math: every function below calls its
//! `#[inline(always)]` scalar twin, so LLVM inlines the one-and-only body
//! into a context where AVX2 (x86_64) or NEON (aarch64) is enabled and
//! auto-vectorizes the elementwise loops. Inlining is always legal in
//! this direction (the callee's feature set — none — is a subset of the
//! caller's), and Rust's strict IEEE float semantics make every such
//! re-codegen value-preserving; see the [`crate::simd`] module doc for
//! the full bit-exactness argument.
//!
//! Scalar twin: each wrapper names its twin in its doc comment; the twins
//! live in `util::bf16`, `quant`, `topk`, and `simd` itself.
//!
//! The functions are `unsafe fn` solely because `#[target_feature]`
//! requires it: calling one on a machine without the feature is UB, which
//! is why the only call sites are the [`crate::simd`] dispatchers, gated
//! on the cached runtime probe.

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use crate::quant::{BucketStats, Quant4};

/// Scalar twin: [`crate::util::bf16::widen_into`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn bf16_widen_avx2(src: &[u16], dst: &mut [f32]) {
    crate::util::bf16::widen_into(src, dst);
}

/// Scalar twin: [`crate::util::bf16::widen_into`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn bf16_widen_neon(src: &[u16], dst: &mut [f32]) {
    crate::util::bf16::widen_into(src, dst);
}

/// Scalar twin: [`crate::util::bf16::round_into`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn bf16_round_avx2(src: &[f32], dst: &mut [u16]) {
    crate::util::bf16::round_into(src, dst);
}

/// Scalar twin: [`crate::util::bf16::round_into`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn bf16_round_neon(src: &[f32], dst: &mut [u16]) {
    crate::util::bf16::round_into(src, dst);
}

/// Scalar twin: [`crate::quant::Quant4::quantize`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn quant4_quantize_avx2(q: &Quant4, x: &[f32], packed: &mut [u8], stats: &mut [BucketStats]) {
    q.quantize(x, packed, stats);
}

/// Scalar twin: [`crate::quant::Quant4::quantize`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn quant4_quantize_neon(q: &Quant4, x: &[f32], packed: &mut [u8], stats: &mut [BucketStats]) {
    q.quantize(x, packed, stats);
}

/// Scalar twin: [`crate::quant::Quant4::dequantize_add`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn quant4_dequantize_add_avx2(q: &Quant4, packed: &[u8], stats: &[BucketStats], out: &mut [f32]) {
    q.dequantize_add(packed, stats, out);
}

/// Scalar twin: [`crate::quant::Quant4::dequantize_add`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn quant4_dequantize_add_neon(q: &Quant4, packed: &[u8], stats: &[BucketStats], out: &mut [f32]) {
    q.dequantize_add(packed, stats, out);
}

/// Scalar twin: [`crate::topk::stats_accum_bf16`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn stats_accum_bf16_avx2(idx: &[u16], val: &[u16], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    crate::topk::stats_accum_bf16(idx, val, w1, w2, z1, z2);
}

/// Scalar twin: [`crate::topk::stats_accum_bf16`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn stats_accum_bf16_neon(idx: &[u16], val: &[u16], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    crate::topk::stats_accum_bf16(idx, val, w1, w2, z1, z2);
}

/// Scalar twin: [`crate::topk::stats_accum_f32`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn stats_accum_f32_avx2(idx: &[u16], val: &[f32], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    crate::topk::stats_accum_f32(idx, val, w1, w2, z1, z2);
}

/// Scalar twin: [`crate::topk::stats_accum_f32`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn stats_accum_f32_neon(idx: &[u16], val: &[f32], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    crate::topk::stats_accum_f32(idx, val, w1, w2, z1, z2);
}

/// Scalar twin: [`crate::simd::adam_update_scalar`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn adam_update_avx2(params: &mut [f32], z1: &[f32], z2: &[f32], lr: f32, eps: f32, decay: f32) {
    crate::simd::adam_update_scalar(params, z1, z2, lr, eps, decay);
}

/// Scalar twin: [`crate::simd::adam_update_scalar`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn adam_update_neon(params: &mut [f32], z1: &[f32], z2: &[f32], lr: f32, eps: f32, decay: f32) {
    crate::simd::adam_update_scalar(params, z1, z2, lr, eps, decay);
}

/// Scalar twin: [`crate::topk::count_abs_ge`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn count_abs_ge_avx2(block: &[f32], thr: u32) -> usize {
    crate::topk::count_abs_ge(block, thr)
}

/// Scalar twin: [`crate::topk::count_abs_ge`].
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn count_abs_ge_neon(block: &[f32], thr: u32) -> usize {
    crate::topk::count_abs_ge(block, thr)
}
