//! Runtime-dispatched vector acceleration for the fused-step kernels.
//!
//! ## Strategy: one body, many instantiations
//!
//! Every hot kernel in the fused five-phase step keeps exactly one
//! implementation — the scalar body that already ships in its home module
//! (the **scalar twin**), marked `#[inline(always)]`. The `simd` cargo
//! feature compiles the [`kernels`] wrappers, which are nothing but the
//! same bodies re-instantiated inside `#[target_feature(enable = ...)]`
//! functions (AVX2 on x86_64, NEON on aarch64) so LLVM re-codegens them
//! with wide registers enabled and auto-vectorizes the elementwise loops.
//! Which instantiation runs is decided once, at optimizer construction,
//! by [`resolve`] — a cached CPUID/`hwcap` probe plus the
//! `MICROADAM_SIMD=scalar` env override — and threaded through the step
//! as a [`Level`] value (no global mutable state, so tests can pin both
//! paths in one process via [`Policy`]).
//!
//! ## Why this is bit-exact by construction
//!
//! Rust floating-point semantics are strict IEEE-754: the compiler may
//! not reassociate float reductions, contract mul+add into FMA, or apply
//! any fast-math value change. Every transform LLVM runs on a
//! `target_feature` instantiation is therefore semantics-preserving —
//! elementwise loops (bf16 widen/round, nibble unpack `code*u+lo`, the
//! `m̂/(√v̂+ε)` update) vectorize because each lane's result is the same
//! chain of ops as the scalar loop iteration, while order-sensitive
//! float reductions (e.g. `min_max` in [`crate::quant`]) simply stay
//! scalar. That is the whole parity argument: the vector path cannot
//! produce different bits because it *is* the scalar path, compiled
//! twice. `rust/tests/test_simd_parity.rs` enforces this over
//! adversarial bit patterns, and the `simd × WinDtype × workers` tier in
//! `rust/tests/test_parallel_parity.rs` enforces it end to end.
//!
//! (Deliberate deviation: `std::simd` is nightly-only, and this crate
//! builds on stable — the `target_feature` re-instantiation approach
//! delivers the same runtime-dispatched AVX2/NEON code paths with the
//! scalar kernels as the always-compiled fallback and parity oracle.)
//!
//! Scalar twin: [`crate::util::bf16::widen_into`] / [`round_into`](crate::util::bf16::round_into),
//! [`crate::quant::Quant4::quantize`] / [`dequantize_add`](crate::quant::Quant4::dequantize_add),
//! [`crate::topk::stats_accum_bf16`] / [`stats_accum_f32`](crate::topk::stats_accum_f32),
//! [`crate::topk::count_abs_ge`], and [`adam_update_scalar`] in this module.

use crate::quant::{BucketStats, Quant4};

#[cfg(feature = "simd")]
pub(crate) mod kernels;

/// Requested dispatch policy — carried in `MicroAdamConfig` so the level
/// is a per-optimizer decision, not process-global state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Use the widest instruction set the host supports (the default).
    /// Identical to [`Policy::Scalar`] when the `simd` feature is off.
    #[default]
    Auto,
    /// Force the scalar kernels — the parity oracle and the baseline side
    /// of every scalar-vs-simd bench row.
    Scalar,
}

/// Resolved instruction-set level, decided once per optimizer and
/// threaded through the step context.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// The always-compiled scalar kernels.
    Scalar,
    /// x86_64 AVX2 instantiations (256-bit lanes).
    Avx2,
    /// aarch64 NEON instantiations (128-bit lanes).
    Neon,
}

/// Short lowercase name for bench records and trace gauges.
pub fn level_name(level: Level) -> &'static str {
    match level {
        Level::Scalar => "scalar",
        Level::Avx2 => "avx2",
        Level::Neon => "neon",
    }
}

fn detect_uncached() -> Level {
    if std::env::var("MICROADAM_SIMD").map(|v| v == "scalar").unwrap_or(false) {
        return Level::Scalar;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return Level::Avx2;
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Level::Neon;
        }
    }
    Level::Scalar
}

/// The widest level this host supports (cached after the first probe).
/// [`Level::Scalar`] whenever the `simd` feature is off, the arch has no
/// compiled instantiations, or `MICROADAM_SIMD=scalar` is set.
pub fn detected() -> Level {
    use std::sync::OnceLock;
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(detect_uncached)
}

/// Resolve a configured [`Policy`] to the [`Level`] the step will run at.
pub fn resolve(policy: Policy) -> Level {
    match policy {
        Policy::Auto => detected(),
        Policy::Scalar => Level::Scalar,
    }
}

/// Every level worth testing on this host: always `Scalar`, plus the
/// detected vector level when there is one. Parity tests sweep this.
pub fn active_levels() -> Vec<Level> {
    let mut out = vec![Level::Scalar];
    if detected() != Level::Scalar {
        out.push(detected());
    }
    out
}

/// Scalar twin of the vectorized `update` phase: `u = lr·ẑ1/(ε+√ẑ2)`,
/// `p = decay·p − u`, lane-parallel under the vector instantiations.
/// The float-op chain matches `step_reference`'s update loop exactly.
#[inline(always)]
pub fn adam_update_scalar(params: &mut [f32], z1: &[f32], z2: &[f32], lr: f32, eps: f32, decay: f32) {
    for (p, (&a, &b)) in params.iter_mut().zip(z1.iter().zip(z2)) {
        let u = lr * a / (eps + b.sqrt());
        *p = decay * *p - u;
    }
}

// ---------------------------------------------------------------------
// Dispatchers: match the resolved level to an instantiation. Each arm is
// cfg-gated to the arch that compiles it; everything else falls through
// to the scalar twin. The `unsafe` here discharges the target_feature
// obligation only — the wrapped body is safe code.
// ---------------------------------------------------------------------

/// Widen a bf16 slab to f32. Scalar twin: [`crate::util::bf16::widen_into`].
pub fn bf16_widen(level: Level, src: &[u16], dst: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced by `detect_uncached` after
        // `is_x86_feature_detected!("avx2")` returned true on this host.
        unsafe { kernels::bf16_widen_avx2(src, dst) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        unsafe { kernels::bf16_widen_neon(src, dst) };
        return;
    }
    let _ = level;
    crate::util::bf16::widen_into(src, dst);
}

/// Round an f32 slab to bf16 (RNE). Scalar twin: [`crate::util::bf16::round_into`].
pub fn bf16_round(level: Level, src: &[f32], dst: &mut [u16]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced after runtime AVX2 detection.
        unsafe { kernels::bf16_round_avx2(src, dst) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        unsafe { kernels::bf16_round_neon(src, dst) };
        return;
    }
    let _ = level;
    crate::util::bf16::round_into(src, dst);
}

/// 4-bit EF quantization. Scalar twin: [`crate::quant::Quant4::quantize`].
pub fn quant4_quantize(level: Level, q: &Quant4, x: &[f32], packed: &mut [u8], stats: &mut [BucketStats]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced after runtime AVX2 detection.
        unsafe { kernels::quant4_quantize_avx2(q, x, packed, stats) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        unsafe { kernels::quant4_quantize_neon(q, x, packed, stats) };
        return;
    }
    let _ = level;
    q.quantize(x, packed, stats);
}

/// 4-bit EF dequantize-accumulate. Scalar twin:
/// [`crate::quant::Quant4::dequantize_add`].
pub fn quant4_dequantize_add(level: Level, q: &Quant4, packed: &[u8], stats: &[BucketStats], out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced after runtime AVX2 detection.
        unsafe { kernels::quant4_dequantize_add_avx2(q, packed, stats, out) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        unsafe { kernels::quant4_dequantize_add_neon(q, packed, stats, out) };
        return;
    }
    let _ = level;
    q.dequantize_add(packed, stats, out);
}

/// AdamStats accumulation, bf16-stored values. Scalar twin:
/// [`crate::topk::stats_accum_bf16`].
pub fn stats_accum_bf16(level: Level, idx: &[u16], val: &[u16], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced after runtime AVX2 detection.
        unsafe { kernels::stats_accum_bf16_avx2(idx, val, w1, w2, z1, z2) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        unsafe { kernels::stats_accum_bf16_neon(idx, val, w1, w2, z1, z2) };
        return;
    }
    let _ = level;
    crate::topk::stats_accum_bf16(idx, val, w1, w2, z1, z2);
}

/// AdamStats accumulation, f32-stored values. Scalar twin:
/// [`crate::topk::stats_accum_f32`].
pub fn stats_accum_f32(level: Level, idx: &[u16], val: &[f32], w1: f32, w2: f32, z1: &mut [f32], z2: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced after runtime AVX2 detection.
        unsafe { kernels::stats_accum_f32_avx2(idx, val, w1, w2, z1, z2) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        unsafe { kernels::stats_accum_f32_neon(idx, val, w1, w2, z1, z2) };
        return;
    }
    let _ = level;
    crate::topk::stats_accum_f32(idx, val, w1, w2, z1, z2);
}

/// The `update` phase. Scalar twin: [`adam_update_scalar`].
pub fn adam_update(level: Level, params: &mut [f32], z1: &[f32], z2: &[f32], lr: f32, eps: f32, decay: f32) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced after runtime AVX2 detection.
        unsafe { kernels::adam_update_avx2(params, z1, z2, lr, eps, decay) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        unsafe { kernels::adam_update_neon(params, z1, z2, lr, eps, decay) };
        return;
    }
    let _ = level;
    adam_update_scalar(params, z1, z2, lr, eps, decay);
}

/// Count entries whose |x| bit pattern is >= `thr` — the vectorized
/// magnitude pass Top-K uses to shrink its quickselect candidate set.
/// Scalar twin: [`crate::topk::count_abs_ge`].
pub fn count_abs_ge(level: Level, block: &[f32], thr: u32) -> usize {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if level == Level::Avx2 {
        // SAFETY: Level::Avx2 is only produced after runtime AVX2 detection.
        return unsafe { kernels::count_abs_ge_avx2(block, thr) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if level == Level::Neon {
        // SAFETY: Level::Neon is only produced after runtime NEON detection.
        return unsafe { kernels::count_abs_ge_neon(block, thr) };
    }
    let _ = level;
    crate::topk::count_abs_ge(block, thr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_policy_always_resolves_scalar() {
        assert_eq!(resolve(Policy::Scalar), Level::Scalar);
    }

    #[test]
    fn active_levels_start_with_scalar() {
        let ls = active_levels();
        assert_eq!(ls[0], Level::Scalar);
        assert!(ls.len() <= 2);
        #[cfg(not(feature = "simd"))]
        assert_eq!(ls, vec![Level::Scalar]);
    }

    #[test]
    fn auto_policy_resolves_to_detected() {
        assert_eq!(resolve(Policy::Auto), detected());
    }

    #[test]
    fn dispatchers_match_scalar_on_every_active_level() {
        let n = 1027; // odd length exercises the remainder lanes
        let src: Vec<f32> = (0..n).map(|i| ((i * 37 % 101) as f32 - 50.0) / 7.0).collect();
        let mut bits_ref = vec![0u16; n];
        crate::util::bf16::round_into(&src, &mut bits_ref);
        for level in active_levels() {
            let mut bits = vec![0u16; n];
            bf16_round(level, &src, &mut bits);
            assert_eq!(bits, bits_ref, "{level:?}");
            let mut wide = vec![0f32; n];
            bf16_widen(level, &bits, &mut wide);
            let mut wide_ref = vec![0f32; n];
            crate::util::bf16::widen_into(&bits_ref, &mut wide_ref);
            assert_eq!(wide, wide_ref, "{level:?}");
            let mut p = src.clone();
            let mut p_ref = src.clone();
            let z1: Vec<f32> = src.iter().map(|v| v * 0.5).collect();
            let z2: Vec<f32> = src.iter().map(|v| v * v).collect();
            adam_update(level, &mut p, &z1, &z2, 1e-3, 1e-8, 0.999);
            adam_update_scalar(&mut p_ref, &z1, &z2, 1e-3, 1e-8, 0.999);
            assert!(p.iter().zip(&p_ref).all(|(a, b)| a.to_bits() == b.to_bits()), "{level:?}");
            let thr = 1.0f32.to_bits();
            assert_eq!(count_abs_ge(level, &src, thr), crate::topk::count_abs_ge(&src, thr));
        }
    }

    #[test]
    fn level_names_are_stable() {
        assert_eq!(level_name(Level::Scalar), "scalar");
        assert_eq!(level_name(Level::Avx2), "avx2");
        assert_eq!(level_name(Level::Neon), "neon");
    }
}
