//! Block-wise low-bit quantization substrates.
//!
//! * [`Quant4`] — the paper's EF compressor (Algorithm 2, Q / Q^-1): 4-bit
//!   codes packed two-per-byte with per-bucket `(delta, Delta)` statistics.
//!   Deterministic nearest rounding matches the practical algorithm; the
//!   stochastic-rounding variant realizes the unbiased, omega-bounded
//!   compressor analysed in Lemma 1 (Assumption 2).
//! * [`quant8_signed`] / [`quant8_unsigned`] — 8-bit block quantizers used
//!   by the AdamW-8bit baseline state (Dettmers-style storage cost, linear
//!   scales; see DESIGN.md substitutions).

use crate::util::rng::Rng;

/// Number of representable steps for `bits`-bit codes (`2^b - 1`).
pub fn levels(bits: u32) -> f32 {
    ((1u32 << bits) - 1) as f32
}

/// Per-bucket quantization statistics (Algorithm 1 line 8).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BucketStats {
    /// `delta` = bucket minimum.
    pub lo: f32,
    /// `Delta` = bucket maximum.
    pub hi: f32,
}

impl BucketStats {
    /// Resident bytes of one stats record (two f32 scales) — the single
    /// source of truth for every state-accounting site.
    pub const BYTES: usize = 8;

    /// Quantization step `u = (Delta - delta) / (2^b - 1)`.
    pub fn step(&self, bits: u32) -> f32 {
        (self.hi - self.lo) / levels(bits)
    }
}

/// 4-bit nibble-packed bucketed quantizer (the EF compressor `Q`).
///
/// Layout matches the paper's CUDA implementation and the Pallas kernel:
/// element `2i` occupies the low nibble of byte `i`, element `2i+1` the high
/// nibble, so the full EF costs `d/2` bytes plus `2 * d/B_q` f32 stats.
#[derive(Debug, Clone)]
pub struct Quant4 {
    /// Bucket size `B_q`; must be even.
    pub bucket: usize,
}

impl Default for Quant4 {
    fn default() -> Self {
        Self { bucket: crate::QBUCKET }
    }
}

impl Quant4 {
    pub fn new(bucket: usize) -> Self {
        assert!(bucket >= 2 && bucket % 2 == 0, "bucket must be even, got {bucket}");
        Self { bucket }
    }

    /// Number of buckets covering a length-`d` vector.
    pub fn n_buckets(&self, d: usize) -> usize {
        assert_eq!(d % self.bucket, 0, "d={d} not a multiple of bucket={}", self.bucket);
        d / self.bucket
    }

    /// State bytes for a length-`d` vector: packed codes + f32 stats.
    pub fn state_bytes(&self, d: usize) -> usize {
        d / 2 + BucketStats::BYTES * self.n_buckets(d)
    }

    /// Deterministic (round-to-nearest) quantization of `x` into
    /// pre-allocated `packed` (`d/2` bytes) and `stats` (`d/B_q`).
    ///
    /// Scalar twin of the vector instantiations in [`crate::simd`]
    /// (`inline(always)` so the `target_feature` wrappers re-codegen this
    /// exact body). The `min_max` reduction inside is order-sensitive and
    /// deliberately stays a scalar fold either way; the `code4` pack loop
    /// is the part that lane-parallelizes.
    #[inline(always)]
    pub fn quantize(&self, x: &[f32], packed: &mut [u8], stats: &mut [BucketStats]) {
        let nb = self.n_buckets(x.len());
        assert_eq!(packed.len(), x.len() / 2);
        assert_eq!(stats.len(), nb);
        for b in 0..nb {
            let xs = &x[b * self.bucket..(b + 1) * self.bucket];
            let (lo, hi) = min_max(xs);
            stats[b] = BucketStats { lo, hi };
            let u = (hi - lo) / levels(4);
            let ps = &mut packed[b * self.bucket / 2..(b + 1) * self.bucket / 2];
            if u <= 0.0 {
                ps.fill(0);
                continue;
            }
            for (i, p) in ps.iter_mut().enumerate() {
                let q0 = code4(xs[2 * i], lo, u, 0.5);
                let q1 = code4(xs[2 * i + 1], lo, u, 0.5);
                *p = q0 | (q1 << 4);
            }
        }
    }

    /// Stochastic-rounding quantization (Lemma 1): unbiased,
    /// `E[Q^{-1}(Q(x))] = x`.
    pub fn quantize_stochastic(
        &self,
        x: &[f32],
        packed: &mut [u8],
        stats: &mut [BucketStats],
        rng: &mut Rng,
    ) {
        let nb = self.n_buckets(x.len());
        for b in 0..nb {
            let xs = &x[b * self.bucket..(b + 1) * self.bucket];
            let (lo, hi) = min_max(xs);
            stats[b] = BucketStats { lo, hi };
            let u = (hi - lo) / levels(4);
            let ps = &mut packed[b * self.bucket / 2..(b + 1) * self.bucket / 2];
            if u <= 0.0 {
                ps.fill(0);
                continue;
            }
            for (i, p) in ps.iter_mut().enumerate() {
                let q0 = code4(xs[2 * i], lo, u, rng.gen_f32());
                let q1 = code4(xs[2 * i + 1], lo, u, rng.gen_f32());
                *p = q0 | (q1 << 4);
            }
        }
    }

    /// Dequantize into `out` (`Q^-1`): `x = code * u + delta`.
    pub fn dequantize(&self, packed: &[u8], stats: &[BucketStats], out: &mut [f32]) {
        assert_eq!(out.len(), packed.len() * 2);
        assert_eq!(stats.len(), self.n_buckets(out.len()));
        for (b, st) in stats.iter().enumerate() {
            let u = st.step(4);
            let ps = &packed[b * self.bucket / 2..(b + 1) * self.bucket / 2];
            let os = &mut out[b * self.bucket..(b + 1) * self.bucket];
            for (i, &p) in ps.iter().enumerate() {
                os[2 * i] = (p & 0xF) as f32 * u + st.lo;
                os[2 * i + 1] = (p >> 4) as f32 * u + st.lo;
            }
        }
    }

    /// Dequantize-and-add: `out[i] += Q^-1(packed)[i]`. This is the
    /// paper's "accumulate EF straight into the grad buffer" trick (§3.1),
    /// avoiding a dense scratch vector.
    ///
    /// Scalar twin of the vector instantiations in [`crate::simd`]: the
    /// nibble unpack + `code·u + lo` accumulate is elementwise and
    /// lane-parallelizes under the `target_feature` re-codegen.
    #[inline(always)]
    pub fn dequantize_add(&self, packed: &[u8], stats: &[BucketStats], out: &mut [f32]) {
        assert_eq!(out.len(), packed.len() * 2);
        // A short stats slice would silently skip the tail buckets (the
        // iteration is stats-driven), leaving stale EF unapplied.
        assert_eq!(stats.len(), self.n_buckets(out.len()));
        for (b, st) in stats.iter().enumerate() {
            let u = st.step(4);
            let ps = &packed[b * self.bucket / 2..(b + 1) * self.bucket / 2];
            let os = &mut out[b * self.bucket..(b + 1) * self.bucket];
            for (i, &p) in ps.iter().enumerate() {
                os[2 * i] += (p & 0xF) as f32 * u + st.lo;
                os[2 * i + 1] += (p >> 4) as f32 * u + st.lo;
            }
        }
    }

    /// L2 norm of the dequantized vector, streamed per bucket — no dense
    /// `O(d)` scratch. Accumulation order matches dequantize-then-sum
    /// (bucket-ascending, element-ascending), so the result is bit-identical
    /// to `||Q^-1(packed)||` computed through a dense buffer.
    pub fn l2_norm(&self, packed: &[u8], stats: &[BucketStats]) -> f32 {
        assert_eq!(stats.len(), self.n_buckets(packed.len() * 2));
        let mut sum = 0f32;
        for (b, st) in stats.iter().enumerate() {
            let u = st.step(4);
            for &p in &packed[b * self.bucket / 2..(b + 1) * self.bucket / 2] {
                let x0 = (p & 0xF) as f32 * u + st.lo;
                let x1 = (p >> 4) as f32 * u + st.lo;
                sum += x0 * x0;
                sum += x1 * x1;
            }
        }
        sum.sqrt()
    }

    /// Mean absolute quantization error `mean_i |Q^-1(Q(x))[i] - x[i]|`,
    /// streamed per bucket — no dense scratch. `reference` must be the
    /// exact slice `quantize` consumed when producing `packed`/`stats`.
    pub fn mean_abs_err(&self, packed: &[u8], stats: &[BucketStats], reference: &[f32]) -> f32 {
        assert_eq!(reference.len(), packed.len() * 2);
        assert_eq!(stats.len(), self.n_buckets(reference.len()));
        if reference.is_empty() {
            return 0.0;
        }
        let mut sum = 0f64;
        for (b, st) in stats.iter().enumerate() {
            let u = st.step(4);
            let ps = &packed[b * self.bucket / 2..(b + 1) * self.bucket / 2];
            let rs = &reference[b * self.bucket..(b + 1) * self.bucket];
            for (i, &p) in ps.iter().enumerate() {
                let x0 = (p & 0xF) as f32 * u + st.lo;
                let x1 = (p >> 4) as f32 * u + st.lo;
                sum += (x0 - rs[2 * i]).abs() as f64;
                sum += (x1 - rs[2 * i + 1]).abs() as f64;
            }
        }
        (sum / reference.len() as f64) as f32
    }
}

#[inline(always)]
fn code4(x: f32, lo: f32, u: f32, xi: f32) -> u8 {
    let q = ((x - lo) / u + xi).floor();
    q.clamp(0.0, levels(4)) as u8
}

#[inline]
pub(crate) fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in xs {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

/// Signed 8-bit block quantization (for Adam first moments): symmetric
/// absmax scaling, codes biased by 128 into u8.
pub fn quant8_signed(x: &[f32], bucket: usize, codes: &mut [u8], scales: &mut [f32]) {
    let nb = x.len() / bucket;
    for b in 0..nb {
        let xs = &x[b * bucket..(b + 1) * bucket];
        let absmax = xs.iter().fold(0f32, |a, &v| a.max(v.abs()));
        let scale = absmax / 127.0;
        scales[b] = scale;
        let s = if scale > 0.0 { scale } else { 1.0 };
        for (i, &v) in xs.iter().enumerate() {
            let q = (v / s).round().clamp(-127.0, 127.0);
            codes[b * bucket + i] = (q + 128.0) as u8;
        }
    }
}

/// Inverse of [`quant8_signed`].
pub fn dequant8_signed(codes: &[u8], bucket: usize, scales: &[f32], out: &mut [f32]) {
    for (b, &scale) in scales.iter().enumerate() {
        for i in 0..bucket {
            out[b * bucket + i] = (codes[b * bucket + i] as f32 - 128.0) * scale;
        }
    }
}

/// Unsigned 8-bit block quantization (for Adam second moments, v >= 0).
pub fn quant8_unsigned(x: &[f32], bucket: usize, codes: &mut [u8], scales: &mut [f32]) {
    let nb = x.len() / bucket;
    for b in 0..nb {
        let xs = &x[b * bucket..(b + 1) * bucket];
        let max = xs.iter().fold(0f32, |a, &v| a.max(v));
        let scale = max / 255.0;
        scales[b] = scale;
        let s = if scale > 0.0 { scale } else { 1.0 };
        for (i, &v) in xs.iter().enumerate() {
            codes[b * bucket + i] = (v / s).round().clamp(0.0, 255.0) as u8;
        }
    }
}

/// Inverse of [`quant8_unsigned`].
pub fn dequant8_unsigned(codes: &[u8], bucket: usize, scales: &[f32], out: &mut [f32]) {
    for (b, &scale) in scales.iter().enumerate() {
        for i in 0..bucket {
            out[b * bucket + i] = codes[b * bucket + i] as f32 * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randvec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * scale).collect()
    }

    #[test]
    fn roundtrip_error_within_half_step() {
        let q = Quant4::new(64);
        let x = randvec(0, 256, 3.0);
        let mut packed = vec![0u8; 128];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; 4];
        q.quantize(&x, &mut packed, &mut stats);
        let mut out = vec![0f32; 256];
        q.dequantize(&packed, &stats, &mut out);
        for b in 0..4 {
            let u = stats[b].step(4);
            for i in 0..64 {
                let err = (out[b * 64 + i] - x[b * 64 + i]).abs();
                assert!(err <= u / 2.0 + 1e-6, "err {err} > u/2 {}", u / 2.0);
            }
        }
    }

    #[test]
    fn extremes_are_exact() {
        let q = Quant4::new(64);
        let x = randvec(1, 64, 1.0);
        let mut packed = vec![0u8; 32];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; 1];
        q.quantize(&x, &mut packed, &mut stats);
        let mut out = vec![0f32; 64];
        q.dequantize(&packed, &stats, &mut out);
        let (lo, hi) = min_max(&x);
        let imin = x.iter().position(|&v| v == lo).unwrap();
        let imax = x.iter().position(|&v| v == hi).unwrap();
        assert!((out[imin] - lo).abs() < 1e-6);
        assert!((out[imax] - hi).abs() < 1e-6);
    }

    #[test]
    fn constant_bucket_roundtrips_exactly() {
        let q = Quant4::new(4);
        let x = vec![2.5f32; 4];
        let mut packed = vec![0u8; 2];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; 1];
        q.quantize(&x, &mut packed, &mut stats);
        let mut out = vec![0f32; 4];
        q.dequantize(&packed, &stats, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        let q = Quant4::new(32);
        let x = randvec(2, 32, 1.0);
        let mut rng = crate::util::rng::Rng::seed_from_u64(7);
        let mut mean = vec![0f64; 32];
        let reps = 2000;
        let mut packed = vec![0u8; 16];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; 1];
        let mut out = vec![0f32; 32];
        for _ in 0..reps {
            q.quantize_stochastic(&x, &mut packed, &mut stats, &mut rng);
            q.dequantize(&packed, &stats, &mut out);
            for (m, &o) in mean.iter_mut().zip(&out) {
                *m += o as f64;
            }
        }
        let u = stats[0].step(4) as f64;
        for (i, m) in mean.iter().enumerate() {
            let avg = m / reps as f64;
            assert!(
                (avg - x[i] as f64).abs() < 5.0 * u / (reps as f64).sqrt(),
                "coord {i}: mean {avg} vs {}",
                x[i]
            );
        }
    }

    #[test]
    fn dequantize_add_accumulates() {
        let q = Quant4::new(4);
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut packed = vec![0u8; 2];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; 1];
        q.quantize(&x, &mut packed, &mut stats);
        let mut acc = vec![10f32; 4];
        q.dequantize_add(&packed, &stats, &mut acc);
        let mut deq = vec![0f32; 4];
        q.dequantize(&packed, &stats, &mut deq);
        for i in 0..4 {
            assert!((acc[i] - 10.0 - deq[i]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "assertion")]
    fn dequantize_add_rejects_short_stats() {
        // Regression: a stats slice covering only the first bucket used to
        // silently skip the tail buckets instead of panicking.
        let q = Quant4::new(4);
        let x = randvec(7, 16, 1.0);
        let mut packed = vec![0u8; 8];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; 4];
        q.quantize(&x, &mut packed, &mut stats);
        let mut acc = vec![0f32; 16];
        q.dequantize_add(&packed, &stats[..1], &mut acc);
    }

    #[test]
    fn l2_norm_matches_dense_dequantize() {
        let q = Quant4::new(32);
        let x = randvec(8, 256, 2.0);
        let mut packed = vec![0u8; 128];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; 8];
        q.quantize(&x, &mut packed, &mut stats);
        let mut dense = vec![0f32; 256];
        q.dequantize(&packed, &stats, &mut dense);
        let reference = dense.iter().map(|v| v * v).sum::<f32>().sqrt();
        // bit-identical, not just close: same accumulation order
        assert_eq!(q.l2_norm(&packed, &stats), reference);
    }

    #[test]
    fn quant8_signed_roundtrip() {
        let x = randvec(3, 512, 0.1);
        let mut codes = vec![0u8; 512];
        let mut scales = vec![0f32; 2];
        quant8_signed(&x, 256, &mut codes, &mut scales);
        let mut out = vec![0f32; 512];
        dequant8_signed(&codes, 256, &scales, &mut out);
        for i in 0..512 {
            assert!((out[i] - x[i]).abs() <= scales[i / 256] / 2.0 + 1e-7);
        }
    }

    #[test]
    fn quant8_unsigned_roundtrip() {
        let x: Vec<f32> = randvec(4, 512, 0.1).iter().map(|v| v * v).collect();
        let mut codes = vec![0u8; 512];
        let mut scales = vec![0f32; 2];
        quant8_unsigned(&x, 256, &mut codes, &mut scales);
        let mut out = vec![0f32; 512];
        dequant8_unsigned(&codes, 256, &scales, &mut out);
        for i in 0..512 {
            assert!((out[i] - x[i]).abs() <= scales[i / 256] / 2.0 + 1e-7);
            assert!(out[i] >= 0.0);
        }
    }

    #[test]
    fn state_bytes_match_paper_formula() {
        // 0.5 bytes/param for codes + negligible stats.
        let q = Quant4::new(64);
        let d = 1 << 20;
        let bytes = q.state_bytes(d);
        assert_eq!(bytes, d / 2 + 2 * 4 * (d / 64));
    }
}

/// Dettmers-style *dynamic* 8-bit quantizer: log-spaced code table covering
/// ~7 orders of magnitude relative to the per-bucket absmax, so small
/// entries keep relative precision instead of collapsing to zero (the
/// failure mode of linear scales that destabilizes quantized Adam states).
#[derive(Debug, Clone)]
pub struct Dynamic8 {
    /// Sorted 256-entry code table over [-1, 1] (signed) or [0, 1] (unsigned).
    table: Vec<f32>,
}

impl Dynamic8 {
    /// Signed table: code 128 = 0, codes above/below are +/- log-spaced.
    pub fn signed() -> Self {
        let mut table = vec![0f32; 256];
        for k in 1..=127usize {
            let mag = 10f32.powf(-7.0 * (127 - k) as f32 / 126.0);
            table[128 + k] = mag;
            table[128 - k] = -mag;
        }
        table[0] = -1.0;
        Self { table }
    }

    /// Unsigned table: code 0 = 0, codes 1..=255 log-spaced in (1e-7, 1].
    pub fn unsigned() -> Self {
        let mut table = vec![0f32; 256];
        for (c, t) in table.iter_mut().enumerate().skip(1) {
            *t = 10f32.powf(-7.0 * (255 - c) as f32 / 254.0);
        }
        Self { table }
    }

    fn nearest(&self, x: f32) -> u8 {
        // binary search on the sorted table, then pick the closer neighbour
        let mut lo = 0usize;
        let mut hi = self.table.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.table[mid] < x {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo == 0 {
            return 0;
        }
        if lo >= self.table.len() {
            return 255;
        }
        if (x - self.table[lo - 1]).abs() <= (self.table[lo] - x).abs() {
            (lo - 1) as u8
        } else {
            lo as u8
        }
    }

    /// Quantize bucket-wise: codes index the table, scale = bucket absmax.
    pub fn quantize(&self, x: &[f32], bucket: usize, codes: &mut [u8], scales: &mut [f32]) {
        let nb = x.len() / bucket;
        let zero = self.nearest(0.0);
        for b in 0..nb {
            let xs = &x[b * bucket..(b + 1) * bucket];
            let absmax = xs.iter().fold(0f32, |a, &v| a.max(v.abs()));
            scales[b] = absmax;
            if absmax == 0.0 {
                codes[b * bucket..(b + 1) * bucket].fill(zero);
                continue;
            }
            for (i, &v) in xs.iter().enumerate() {
                codes[b * bucket + i] = self.nearest(v / absmax);
            }
        }
    }

    /// Inverse of [`Dynamic8::quantize`].
    pub fn dequantize(&self, codes: &[u8], bucket: usize, scales: &[f32], out: &mut [f32]) {
        for (b, &scale) in scales.iter().enumerate() {
            for i in 0..bucket {
                out[b * bucket + i] = self.table[codes[b * bucket + i] as usize] * scale;
            }
        }
    }

    /// Max relative error of the nonzero code range (table spacing bound).
    pub fn max_relative_error(&self) -> f32 {
        // adjacent magnitudes differ by factor 10^(7/254) => rel err ~3.2%
        (10f32.powf(7.0 / 254.0) - 1.0) / 2.0
    }
}

#[cfg(test)]
mod dynamic_tests {
    use super::*;

    #[test]
    fn tables_are_sorted_and_bounded() {
        for t in [Dynamic8::signed(), Dynamic8::unsigned()] {
            for w in t.table.windows(2) {
                assert!(w[0] <= w[1]);
            }
            assert!(t.table.iter().all(|v| (-1.0..=1.0).contains(v)));
        }
    }

    #[test]
    fn small_values_keep_relative_precision() {
        let q = Dynamic8::unsigned();
        // values across 5 orders of magnitude in one bucket
        let x: Vec<f32> = (0..8).map(|i| 10f32.powi(-(i as i32))).collect();
        let mut codes = vec![0u8; 8];
        let mut scales = vec![0f32; 1];
        q.quantize(&x, 8, &mut codes, &mut scales);
        let mut out = vec![0f32; 8];
        q.dequantize(&codes, 8, &scales, &mut out);
        for i in 0..6 {
            let rel = (out[i] - x[i]).abs() / x[i];
            assert!(rel < 0.05, "coord {i}: {} vs {} (rel {rel})", out[i], x[i]);
        }
    }

    #[test]
    fn signed_roundtrip_preserves_sign_and_zero() {
        let q = Dynamic8::signed();
        let x = vec![0.5f32, -0.5, 0.0, 1e-4, -1e-4, 1.0, -1.0, 0.01];
        let mut codes = vec![0u8; 8];
        let mut scales = vec![0f32; 1];
        q.quantize(&x, 8, &mut codes, &mut scales);
        let mut out = vec![0f32; 8];
        q.dequantize(&codes, 8, &scales, &mut out);
        for i in 0..8 {
            assert_eq!(out[i] == 0.0, x[i] == 0.0, "{i}");
            assert!(out[i].signum() * x[i].signum() >= 0.0);
            if x[i] != 0.0 {
                // signed table: 127 levels over 7 decades -> ~7% max rel err
                assert!(((out[i] - x[i]) / x[i]).abs() < 0.08, "{}: {} vs {}", i, out[i], x[i]);
            }
        }
    }

    #[test]
    fn all_zero_bucket() {
        let q = Dynamic8::unsigned();
        let x = vec![0f32; 16];
        let mut codes = vec![9u8; 16];
        let mut scales = vec![9f32; 1];
        q.quantize(&x, 16, &mut codes, &mut scales);
        let mut out = vec![9f32; 16];
        q.dequantize(&codes, 16, &scales, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
