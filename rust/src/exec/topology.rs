//! CPU/NUMA topology discovery for worker placement.
//!
//! Reads the kernel's node→cpu map from
//! `/sys/devices/system/node/node<N>/cpulist` and turns it into a
//! deterministic worker→cpu plan: workers round-robin across nodes first
//! (so memory bandwidth spreads over every memory controller), then
//! across the cpus within a node. On machines without the sysfs tree
//! (non-Linux, sandboxes, containers with a masked `/sys`) detection
//! degrades to a single node covering `available_parallelism()` cpus —
//! the plan is still well-formed, it just encodes no locality.

use std::fs;

/// Parse a kernel cpulist string (`"0-3,8,10-11"`) into explicit cpu ids.
/// Malformed pieces are skipped rather than failing the whole list —
/// placement is best-effort by design.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                // Cap the expansion so a corrupt "0-18446744073709551615"
                // cannot allocate the universe.
                if a <= b && b - a < 4096 {
                    out.extend(a..=b);
                }
            }
        } else if let Ok(v) = part.parse::<usize>() {
            out.push(v);
        }
    }
    out
}

/// The machine's NUMA nodes as lists of cpu ids, from sysfs. Falls back
/// to one synthetic node spanning `available_parallelism()` cpus when the
/// sysfs tree is absent or yields nothing — callers never see an empty
/// topology.
pub fn nodes() -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    // Node directories are numbered densely from 0; stop at the first gap.
    for n in 0..1024 {
        match fs::read_to_string(format!("/sys/devices/system/node/node{n}/cpulist")) {
            Ok(s) => {
                let cpus = parse_cpulist(&s);
                if !cpus.is_empty() {
                    out.push(cpus);
                }
            }
            Err(_) => break,
        }
    }
    if out.is_empty() {
        let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        out.push((0..ncpu).collect());
    }
    out
}

/// Worker→cpu plan over the detected topology: `plan(w)[i]` is the cpu
/// worker `i` should pin to. See [`plan_over`] for the placement rule.
pub fn plan(workers: usize) -> Vec<usize> {
    plan_over(&nodes(), workers)
}

/// Deterministic placement over an explicit topology: worker `w` goes to
/// node `w % n_nodes`, taking that node's cpus in order (wrapping when
/// there are more workers than cpus). Nodes first, cpus second — adjacent
/// workers land on different memory controllers.
pub fn plan_over(nodes: &[Vec<usize>], workers: usize) -> Vec<usize> {
    let nodes: Vec<&Vec<usize>> = nodes.iter().filter(|c| !c.is_empty()).collect();
    if nodes.is_empty() {
        return vec![0; workers];
    }
    (0..workers)
        .map(|w| {
            let node = nodes[w % nodes.len()];
            node[(w / nodes.len()) % node.len()]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parses_ranges_and_singles() {
        assert_eq!(parse_cpulist("0-3,8"), vec![0, 1, 2, 3, 8]);
        assert_eq!(parse_cpulist("0\n"), vec![0]);
        assert_eq!(parse_cpulist("4-4"), vec![4]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        // malformed pieces are skipped, not fatal
        assert_eq!(parse_cpulist("x,2,3-z,5-4,7"), vec![2, 7]);
        // absurd ranges are refused instead of expanded
        assert_eq!(parse_cpulist("0-99999999"), Vec::<usize>::new());
    }

    #[test]
    fn plan_round_robins_nodes_first() {
        let topo = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        assert_eq!(plan_over(&topo, 4), vec![0, 4, 1, 5]);
        // more workers than cpus wraps within each node
        assert_eq!(plan_over(&topo, 10), vec![0, 4, 1, 5, 2, 6, 3, 7, 0, 4]);
    }

    #[test]
    fn plan_handles_degenerate_topologies() {
        assert_eq!(plan_over(&[], 3), vec![0, 0, 0]);
        assert_eq!(plan_over(&[vec![]], 2), vec![0, 0]);
        assert_eq!(plan_over(&[vec![5]], 3), vec![5, 5, 5]);
    }

    #[test]
    fn detection_never_returns_empty() {
        let topo = nodes();
        assert!(!topo.is_empty());
        assert!(topo.iter().all(|n| !n.is_empty()));
        let p = plan(4);
        assert_eq!(p.len(), 4);
    }
}
