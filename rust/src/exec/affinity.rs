//! Best-effort thread→cpu pinning.
//!
//! The crate carries no libc dependency, so `sched_setaffinity` is issued
//! as a raw syscall with inline asm on the two supported Linux
//! architectures. Everywhere else — other OSes/arches, Miri, the loom
//! model-checking lane — pinning compiles to a no-op that reports
//! `false`, which [`crate::exec::ExecPool`] treats as "run unpinned".
//! Failure is always tolerated at the call site: container cpusets and
//! seccomp filters can deny the call at runtime even where it compiles.

#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64"),
    not(miri),
    not(loom)
))]
mod imp {
    /// Linux cpu_set_t is 1024 bits.
    const MASK_WORDS: usize = 16;

    pub fn supported() -> bool {
        true
    }

    /// Pin the *calling* thread to `cpu`. Returns whether the kernel
    /// accepted the new mask.
    pub fn pin_to_cpu(cpu: usize) -> bool {
        if cpu >= MASK_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[cpu / 64] = 1u64 << (cpu % 64);
        let size = core::mem::size_of_val(&mask);
        let ret: isize;
        #[cfg(target_arch = "x86_64")]
        // SAFETY: sched_setaffinity(pid=0 → calling thread, len, mask) is
        // nr 203 on x86_64. The mask buffer outlives the syscall (it is a
        // live stack local), the kernel only reads `size` bytes from it,
        // and rcx/r11 are declared clobbered as the syscall ABI requires.
        unsafe {
            core::arch::asm!(
                "syscall",
                inlateout("rax") 203isize => ret,
                in("rdi") 0usize,
                in("rsi") size,
                in("rdx") mask.as_ptr(),
                out("rcx") _,
                out("r11") _,
                options(nostack),
            );
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: sched_setaffinity is nr 122 on aarch64 (`svc 0` with the
        // number in x8, args in x0..x2). The mask buffer outlives the
        // syscall and the kernel only reads `size` bytes from it.
        unsafe {
            core::arch::asm!(
                "svc 0",
                in("x8") 122isize,
                inlateout("x0") 0isize => ret,
                in("x1") size,
                in("x2") mask.as_ptr(),
                options(nostack),
            );
        }
        ret == 0
    }
}

#[cfg(not(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64"),
    not(miri),
    not(loom)
)))]
mod imp {
    pub fn supported() -> bool {
        false
    }

    pub fn pin_to_cpu(_cpu: usize) -> bool {
        false
    }
}

pub use imp::{pin_to_cpu, supported};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_is_best_effort() {
        // Run the real attempt on a scratch thread so a success does not
        // leave the test-runner thread pinned. Success is NOT asserted:
        // cpusets, seccomp, or an unsupported platform may all say no —
        // the contract is only "no crash, honest boolean".
        let ok = std::thread::spawn(|| pin_to_cpu(0)).join().unwrap();
        if !supported() {
            assert!(!ok);
        }
    }

    #[test]
    fn out_of_range_cpu_is_rejected() {
        assert!(!pin_to_cpu(usize::MAX));
    }
}
