//! Block-sharded parallel execution engine.
//!
//! MicroAdam's step is embarrassingly parallel across the `NB` independent
//! parameter blocks (§3.2 "GPU-efficient implementation"): EF dequantize,
//! Top-K, re-quantize, AdamStats and the parameter update for block `b`
//! touch only block-`b` state. [`ExecPool`] exploits that on CPU: the caller
//! pre-splits its buffers into disjoint per-worker shards (plain `&mut`
//! slices — no `unsafe`, no locks) and the pool runs one scoped thread per
//! shard (`std::thread::scope`, so non-`'static` borrows work and no extra
//! dependency is pulled in). Thread-spawn cost is ~tens of microseconds,
//! negligible against a multi-million-parameter fused step.
//!
//! [`Arena`] is the per-worker scratch arena: the dense per-block `z1`/`z2`
//! AdamStats accumulators and the Top-K selection buffer, allocated once and
//! reused every step so the hot path stays allocation-free.

use std::ops::Range;

/// A fixed-width worker pool over scoped threads.
///
/// Holds no threads between calls — it is a worker *count* plus the
/// fork/join logic. Sequential execution is the `workers == 1` special case
/// (shards run inline on the caller's thread), which keeps the parallel and
/// sequential code paths byte-identical.
#[derive(Debug, Clone)]
pub struct ExecPool {
    workers: usize,
}

impl Default for ExecPool {
    fn default() -> Self {
        Self::serial()
    }
}

impl ExecPool {
    /// Single-worker pool: every shard runs inline, no threads spawned.
    pub fn serial() -> Self {
        Self { workers: 1 }
    }

    /// Pool with exactly `workers` workers (clamped to >= 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Pool sized to the machine: `MICROADAM_WORKERS` env override, else
    /// `std::thread::available_parallelism()`. Zero (in either source)
    /// means auto-detect, matching the `TrainConfig::workers` convention.
    pub fn auto() -> Self {
        let n = std::env::var("MICROADAM_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Self::new(n)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run one closure invocation per shard, in parallel across the pool.
    ///
    /// `shards` are the caller-built disjoint work units (typically structs
    /// of `&mut` sub-slices). The first shard runs on the calling thread;
    /// the rest get scoped threads. Returns after every shard completes
    /// (scope join). On a single-worker pool, or with 0/1 shards, everything
    /// runs inline and no thread is spawned — shard order is then the vec
    /// order, which (disjointness aside) keeps serial runs deterministic.
    pub fn run_shards<W, F>(&self, shards: Vec<W>, f: F)
    where
        W: Send,
        F: Fn(usize, W) + Sync,
    {
        let mut it = shards.into_iter().enumerate();
        let Some((i0, first)) = it.next() else { return };
        if self.workers == 1 || it.len() == 0 {
            f(i0, first);
            for (i, w) in it {
                f(i, w);
            }
            return;
        }
        std::thread::scope(|s| {
            let f = &f;
            for (i, w) in it {
                s.spawn(move || f(i, w));
            }
            f(i0, first);
        });
    }
}

/// Split `0..n` into at most `parts` contiguous, non-empty, near-equal
/// ranges (the first `n % parts` ranges get one extra item).
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.max(1).min(n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Per-worker scratch arena, reused across steps.
///
/// `z1`/`z2` are the dense per-block first/second AdamStats accumulators
/// (ADAMSTATS lines 5-6); `sel` is the Top-K quickselect index buffer.
#[derive(Debug, Clone, Default)]
pub struct Arena {
    pub z1: Vec<f32>,
    pub z2: Vec<f32>,
    pub sel: Vec<u16>,
}

impl Arena {
    /// Arena for Top-K/AdamStats blocks of length `block`.
    pub fn new(block: usize) -> Self {
        Self { z1: vec![0.0; block], z2: vec![0.0; block], sel: Vec::new() }
    }

    /// Grow (never shrink) to serve blocks of length `block`.
    pub fn ensure(&mut self, block: usize) {
        if self.z1.len() < block {
            self.z1.resize(block, 0.0);
            self.z2.resize(block, 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 2, 7, 16, 100, 101] {
            for parts in [1usize, 2, 3, 4, 8, 200] {
                let rs = chunk_ranges(n, parts);
                // contiguous, non-empty cover of 0..n
                let mut pos = 0;
                for r in &rs {
                    assert_eq!(r.start, pos);
                    assert!(!r.is_empty(), "n={n} parts={parts}");
                    pos = r.end;
                }
                assert_eq!(pos, n);
                assert!(rs.len() <= parts.max(1));
                if n > 0 {
                    assert_eq!(rs.len(), parts.max(1).min(n));
                    // balanced: sizes differ by at most one
                    let min = rs.iter().map(|r| r.len()).min().unwrap();
                    let max = rs.iter().map(|r| r.len()).max().unwrap();
                    assert!(max - min <= 1);
                }
            }
        }
    }

    #[test]
    fn run_shards_executes_every_shard_once() {
        let pool = ExecPool::new(4);
        let hits = AtomicUsize::new(0);
        let mut data = vec![0u32; 16];
        let shards: Vec<&mut [u32]> = data.chunks_mut(4).collect();
        pool.run_shards(shards, |i, chunk| {
            hits.fetch_add(1, Ordering::SeqCst);
            for v in chunk {
                *v = i as u32 + 1;
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        // every element written, shard index dense in 0..4
        assert!(data.iter().all(|&v| (1..=4).contains(&v)));
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ExecPool::serial();
        assert_eq!(pool.workers(), 1);
        let mut acc = vec![0u64; 3];
        let shards: Vec<&mut u64> = acc.iter_mut().collect();
        pool.run_shards(shards, |i, slot| *slot = i as u64 + 10);
        assert_eq!(acc.iter().sum::<u64>(), 10 + 11 + 12);
    }

    #[test]
    fn empty_shards_is_a_noop() {
        let pool = ExecPool::new(8);
        let shards: Vec<u8> = Vec::new();
        pool.run_shards(shards, |_, _| panic!("must not run"));
    }

    #[test]
    fn arena_ensure_grows_only() {
        let mut a = Arena::new(8);
        a.ensure(4);
        assert_eq!(a.z1.len(), 8);
        a.ensure(32);
        assert_eq!(a.z1.len(), 32);
        assert_eq!(a.z2.len(), 32);
    }
}
