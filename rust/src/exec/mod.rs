//! Block-sharded parallel execution engine.
//!
//! MicroAdam's step is embarrassingly parallel across the `NB` independent
//! parameter blocks (§3.2 "GPU-efficient implementation"): EF dequantize,
//! Top-K, re-quantize, AdamStats and the parameter update for block `b`
//! touch only block-`b` state. [`ExecPool`] exploits that on CPU: the caller
//! pre-splits its buffers into disjoint per-worker shards (plain `&mut`
//! slices — no locks on the data) and the pool fans them out over
//! **persistent** worker threads.
//!
//! The workers are spawned once at pool construction and then parked on a
//! condvar between steps; each `run_shards` call is one dispatch + one
//! join barrier, with shards claimed through an atomic cursor. The old
//! engine spawned fresh scoped threads per call, which costs tens of
//! microseconds per optimizer step — invisible at `d = 10M`, dominant for
//! small-`d` / high-step-rate workloads once the bf16 window halved the
//! step's memory traffic. Sequential execution is the `workers == 1`
//! special case (shards run inline on the caller's thread, no threads ever
//! spawned), which keeps the parallel and sequential code paths
//! byte-identical.
//!
//! [`Arena`] is the per-worker scratch arena: the dense per-block `z1`/`z2`
//! AdamStats accumulators and the Top-K selection buffer, pre-sized from
//! the layout's block length and reused every step so the hot path stays
//! allocation-free. Arenas travel with the *shard*, not the OS thread, so
//! they stay warm whichever worker picks the shard up.
//!
//! Placement ([`ExecPool::new_with`], `--pin-workers`): the pool can pin
//! each spawned worker to a cpu chosen by [`topology`] (NUMA nodes first,
//! cpus within a node second) via [`affinity`]'s raw `sched_setaffinity`.
//! A pinned pool claims shards by **static striping** (worker `w` takes
//! shards `w, w + workers, ...`) instead of the atomic cursor, so the
//! shard→worker mapping is the same every step — which is what makes the
//! optimizer's first-touch warm pass stick: the pages a worker touched at
//! step 1 are the pages it keeps touching. Pinning is best-effort
//! everywhere: an unsupported platform or a denied syscall just leaves
//! workers floating, and the achieved count is reported through the
//! `exec.pinned_workers` trace gauge.

use std::ops::Range;

pub mod affinity;
pub mod topology;

use self::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use self::sync::{Arc, Condvar, Mutex};

/// Sync-primitive shim for the loom model-checking lane.
///
/// Under a plain build this re-exports `std`; under `--cfg loom`
/// (`make loom`) it swaps in the scheduler-instrumented types from the
/// in-tree `minloom` crate so `rust/tests/loom/` can exhaustively
/// explore the dispatch/barrier protocol below. Production code paths
/// are identical either way — only the primitive types change.
pub(crate) mod sync {
    #[cfg(not(loom))]
    pub(crate) use std::sync::{Arc, Condvar, Mutex};
    #[cfg(not(loom))]
    pub(crate) mod atomic {
        pub(crate) use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    }
    #[cfg(not(loom))]
    pub(crate) type JoinHandle = std::thread::JoinHandle<()>;
    #[cfg(not(loom))]
    pub(crate) fn spawn_worker(name: String, f: impl FnOnce() + Send + 'static) -> JoinHandle {
        std::thread::Builder::new().name(name).spawn(f).expect("spawn exec worker")
    }

    #[cfg(loom)]
    pub(crate) use loom::sync::{Arc, Condvar, Mutex};
    #[cfg(loom)]
    pub(crate) mod atomic {
        pub(crate) use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    }
    #[cfg(loom)]
    pub(crate) type JoinHandle = loom::thread::JoinHandle<()>;
    #[cfg(loom)]
    pub(crate) fn spawn_worker(_name: String, f: impl FnOnce() + Send + 'static) -> JoinHandle {
        loom::thread::spawn(f)
    }

    /// Cooperative pause inside spin/poll loops: a real yield on std,
    /// a scheduling point under loom so polling cannot starve the
    /// model's other threads.
    #[allow(dead_code)]
    pub(crate) fn yield_now() {
        #[cfg(not(loom))]
        std::thread::yield_now();
        #[cfg(loom)]
        loom::thread::yield_now();
    }
}

/// A fixed-width pool of persistent, parked worker threads.
///
/// `workers == 1` (and [`ExecPool::serial`]) holds no threads at all;
/// `workers == n` holds `n - 1` parked threads plus the calling thread,
/// which always participates in the dispatch. Clones share the same
/// threads; the threads exit when the last clone drops.
#[derive(Clone)]
pub struct ExecPool {
    workers: usize,
    /// Placement-aware mode: workers were asked to pin and shard claiming
    /// uses static striping (see the module docs).
    pin: bool,
    handle: Option<Arc<PoolHandle>>,
}

impl std::fmt::Debug for ExecPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecPool")
            .field("workers", &self.workers)
            .field("pin", &self.pin)
            .field("persistent", &self.handle.is_some())
            .finish()
    }
}

impl Default for ExecPool {
    fn default() -> Self {
        Self::serial()
    }
}

/// One dispatched job: a type-erased pointer to the caller's stack-held
/// runner closure. Only valid while the dispatching `run_shards` call is
/// blocked on its completion barrier — which is exactly how long workers
/// may hold it.
#[derive(Clone, Copy)]
struct Job {
    task: *const (dyn Fn(usize) + Sync),
}
// SAFETY: the pointee is `Sync` (shared calls from several threads are
// fine) and the pointer is only dereferenced between dispatch and the
// completion barrier; `run_shards` pins the pointee's stack frame for
// exactly that window via `WaitGuard`, so sending the pointer to the
// workers cannot outlive the data.
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped once per dispatch; workers run a job exactly once per epoch.
    epoch: u64,
    /// Spawned workers still running the current epoch's job.
    remaining: usize,
    job: Option<Job>,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Workers park here between dispatches.
    work_cv: Condvar,
    /// The dispatching caller blocks here until `remaining == 0`.
    done_cv: Condvar,
}

struct PoolHandle {
    inner: Arc<PoolInner>,
    /// Serializes dispatches from clones sharing the threads.
    dispatch: Mutex<()>,
    threads: Vec<sync::JoinHandle>,
    /// Spawned workers whose `sched_setaffinity` succeeded. Plain std
    /// atomic (not the loom shim): it is telemetry, not synchronization,
    /// and pinning is compiled out under loom anyway.
    pinned: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    /// Workers the placement plan covered (0 when pinning was not asked).
    pin_target: usize,
}

impl Drop for PoolHandle {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn worker_loop(inner: Arc<PoolInner>, id: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("job published with its epoch");
                }
                st = inner.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: `job.task` was published under the state lock together
        // with this epoch, and the dispatching `run_shards` frame (which
        // owns the pointee) blocks in `WaitGuard::drop` until this worker
        // decrements `remaining` below — the pointee is alive for the
        // whole call.
        unsafe { (&*job.task)(id) };
        let mut st = inner.state.lock().unwrap();
        st.remaining -= 1;
        if st.remaining == 0 {
            inner.done_cv.notify_all();
        }
    }
}

/// Blocks until the spawned workers finish the current epoch — runs even
/// when the caller's own shard panics, so worker threads can never outlive
/// the stack frame whose buffers they borrow.
struct WaitGuard<'a> {
    inner: &'a PoolInner,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.inner.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }
}

impl ExecPool {
    /// Single-worker pool: every shard runs inline, no threads spawned.
    pub fn serial() -> Self {
        Self { workers: 1, pin: false, handle: None }
    }

    /// Pool with exactly `workers` workers (clamped to >= 1). For
    /// `workers > 1` this spawns `workers - 1` persistent threads now, so
    /// the steady-state step pays a wake + barrier instead of a spawn.
    pub fn new(workers: usize) -> Self {
        Self::new_with(workers, false)
    }

    /// [`ExecPool::new`] with optional placement-aware mode. With
    /// `pin == true` each spawned worker pins itself to the cpu
    /// [`topology::plan`] assigns it (best-effort — a refused
    /// `sched_setaffinity` leaves that worker floating) and shard claiming
    /// switches to static striping so the shard→worker mapping is stable
    /// across steps. The calling thread (worker 0) is never re-pinned: its
    /// affinity belongs to the embedding application. A `workers <= 1`
    /// pool has no threads to place, so `pin` is ignored there.
    pub fn new_with(workers: usize, pin: bool) -> Self {
        let workers = workers.max(1);
        if workers == 1 {
            return Self::serial();
        }
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState { epoch: 0, remaining: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let plan = if pin { topology::plan(workers) } else { Vec::new() };
        let pinned = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let threads = (1..workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                let pinned = std::sync::Arc::clone(&pinned);
                let cpu = plan.get(i).copied();
                sync::spawn_worker(format!("microadam-exec-{i}"), move || {
                    if let Some(c) = cpu {
                        if affinity::pin_to_cpu(c) {
                            pinned.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    worker_loop(inner, i)
                })
            })
            .collect();
        let pin_target = if pin { workers - 1 } else { 0 };
        Self {
            workers,
            pin,
            handle: Some(Arc::new(PoolHandle {
                inner,
                dispatch: Mutex::new(()),
                threads,
                pinned,
                pin_target,
            })),
        }
    }

    /// Pool sized to the machine: `MICROADAM_WORKERS` env override, else
    /// `std::thread::available_parallelism()`. Zero (in either source)
    /// means auto-detect, matching the `TrainConfig::workers` convention.
    pub fn auto() -> Self {
        Self::auto_with(false)
    }

    /// [`ExecPool::auto`] with optional placement-aware mode (see
    /// [`ExecPool::new_with`]).
    pub fn auto_with(pin: bool) -> Self {
        let n = std::env::var("MICROADAM_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Self::new_with(n, pin)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether this pool runs in placement-aware mode (pinning requested
    /// and worker threads exist). The optimizer keys its NUMA first-touch
    /// warm pass on this.
    pub fn pinned(&self) -> bool {
        self.pin && self.handle.is_some()
    }

    /// Spawned workers whose pin actually stuck — the achieved placement,
    /// `<=` [`ExecPool::pin_target`]. (Workers pin asynchronously at
    /// startup, so this can transiently undercount right after
    /// construction.)
    pub fn pinned_workers(&self) -> usize {
        self.handle
            .as_ref()
            .map(|h| h.pinned.load(std::sync::atomic::Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Workers the placement plan covered: `workers - 1` when pinning was
    /// requested (the caller's thread is never re-pinned), else 0.
    pub fn pin_target(&self) -> usize {
        self.handle.as_ref().map(|h| h.pin_target).unwrap_or(0)
    }

    /// Run one closure invocation per shard, fanned out across the pool.
    ///
    /// `shards` are the caller-built disjoint work units (typically structs
    /// of `&mut` sub-slices). Shards are claimed through an atomic cursor,
    /// so any shard count works (more shards than workers queue naturally);
    /// the calling thread always participates. Returns after every shard
    /// completes (barrier). On a single-worker pool, or with 0/1 shards,
    /// everything runs inline in vec order and no other thread is touched —
    /// which (disjointness aside) keeps serial runs deterministic.
    ///
    /// # Panics
    /// Propagates as a panic on the calling thread if any shard panics
    /// (after all other shards have been drained or finished).
    pub fn run_shards<W, F>(&self, shards: Vec<W>, f: F)
    where
        W: Send,
        F: Fn(usize, W) + Sync,
    {
        let n = shards.len();
        if n == 0 {
            return;
        }
        let handle = match &self.handle {
            Some(h) if n > 1 => h,
            _ => {
                for (i, w) in shards.into_iter().enumerate() {
                    let sp = crate::trace::begin();
                    f(i, w);
                    sp.end("exec", "shard", i as u32);
                }
                crate::trace::flush_local();
                return;
            }
        };

        if self.pin && crate::trace::enabled() {
            crate::trace::gauge("exec.pinned_workers", self.pinned_workers() as f64);
            crate::trace::gauge("exec.pin_target", self.pin_target() as f64);
        }

        // Shard claiming: unpinned pools share an atomic cursor (dynamic,
        // load-balancing); pinned pools stripe statically (worker w takes
        // w, w + workers, ...) so the shard→worker mapping — and therefore
        // the first-touch page placement — is identical every step. Either
        // way each slot is claimed exactly once and the Mutex is
        // uncontended by construction (one lock per shard lifetime).
        let stride = if self.pin { Some(self.workers) } else { None };
        let slots: Vec<Mutex<Option<W>>> = shards.into_iter().map(|w| Mutex::new(Some(w))).collect();
        let cursor = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let run = |worker: usize| {
            let mut next = match stride {
                Some(_) => worker,
                None => cursor.fetch_add(1, Ordering::Relaxed),
            };
            while next < n {
                let w = slots[next].lock().unwrap().take().expect("shard claimed once");
                let sp = crate::trace::begin();
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(next, w))).is_err() {
                    panicked.store(true, Ordering::SeqCst);
                }
                sp.end("exec", "shard", next as u32);
                next = match stride {
                    Some(s) => next + s,
                    None => cursor.fetch_add(1, Ordering::Relaxed),
                };
            }
            // Drain this worker's trace buffer once per dispatch, so the
            // collector sees every shard span without per-event locking.
            crate::trace::flush_local();
        };

        let task: &(dyn Fn(usize) + Sync) = &run;
        // SAFETY: erases the borrow's lifetime into a raw job pointer.
        // Sound because the `WaitGuard` below pins this stack frame (even
        // through an unwinding shard panic) until every worker checks in,
        // so no worker can dereference it after `run` is gone.
        let task: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(task) };
        let inner: &PoolInner = &handle.inner;
        // Poison-tolerant: a previous dispatch that re-panicked below must
        // not brick the pool for callers that recovered via catch_unwind.
        let dispatch = handle.dispatch.lock().unwrap_or_else(|e| e.into_inner());
        {
            let mut st = inner.state.lock().unwrap();
            st.job = Some(Job { task });
            st.epoch += 1;
            st.remaining = handle.threads.len();
        }
        inner.work_cv.notify_all();
        {
            // Barrier guard outlives the caller's own participation, so a
            // panicking shard still waits for the workers before unwinding
            // past the borrowed buffers.
            let _wait = WaitGuard { inner };
            run(0);
        }
        // Release the dispatch lock before re-raising so the propagated
        // panic cannot poison it out from under the pool's other users.
        drop(dispatch);
        if panicked.load(Ordering::SeqCst) {
            panic!("ExecPool: a shard panicked");
        }
    }
}

/// Split `0..n` into at most `parts` contiguous, non-empty, near-equal
/// ranges (the first `n % parts` ranges get one extra item).
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.max(1).min(n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Per-worker scratch arena, reused across steps.
///
/// `z1`/`z2` are the dense per-block first/second AdamStats accumulators
/// (ADAMSTATS lines 5-6); `sel` is the Top-K quickselect index buffer,
/// pre-sized from the layout's block length so the first step never
/// reallocates it mid-selection.
#[derive(Debug, Clone, Default)]
pub struct Arena {
    pub z1: Vec<f32>,
    pub z2: Vec<f32>,
    pub sel: Vec<u16>,
}

impl Arena {
    /// Arena for Top-K/AdamStats blocks of length `block`.
    pub fn new(block: usize) -> Self {
        Self { z1: vec![0.0; block], z2: vec![0.0; block], sel: Vec::with_capacity(block) }
    }

    /// Grow (never shrink) to serve blocks of length `block`.
    pub fn ensure(&mut self, block: usize) {
        if self.z1.len() < block {
            self.z1.resize(block, 0.0);
            self.z2.resize(block, 0.0);
        }
        if self.sel.capacity() < block {
            self.sel.reserve(block - self.sel.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for n in [0usize, 1, 2, 7, 16, 100, 101] {
            for parts in [1usize, 2, 3, 4, 8, 200] {
                let rs = chunk_ranges(n, parts);
                // contiguous, non-empty cover of 0..n
                let mut pos = 0;
                for r in &rs {
                    assert_eq!(r.start, pos);
                    assert!(!r.is_empty(), "n={n} parts={parts}");
                    pos = r.end;
                }
                assert_eq!(pos, n);
                assert!(rs.len() <= parts.max(1));
                if n > 0 {
                    assert_eq!(rs.len(), parts.max(1).min(n));
                    // balanced: sizes differ by at most one
                    let min = rs.iter().map(|r| r.len()).min().unwrap();
                    let max = rs.iter().map(|r| r.len()).max().unwrap();
                    assert!(max - min <= 1);
                }
            }
        }
    }

    #[test]
    fn run_shards_executes_every_shard_once() {
        let pool = ExecPool::new(4);
        let hits = AtomicUsize::new(0);
        let mut data = vec![0u32; 16];
        let shards: Vec<&mut [u32]> = data.chunks_mut(4).collect();
        pool.run_shards(shards, |i, chunk| {
            hits.fetch_add(1, Ordering::SeqCst);
            for v in chunk {
                *v = i as u32 + 1;
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        // every element written, shard index dense in 0..4
        assert!(data.iter().all(|&v| (1..=4).contains(&v)));
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ExecPool::serial();
        assert_eq!(pool.workers(), 1);
        let mut acc = vec![0u64; 3];
        let shards: Vec<&mut u64> = acc.iter_mut().collect();
        pool.run_shards(shards, |i, slot| *slot = i as u64 + 10);
        assert_eq!(acc.iter().sum::<u64>(), 10 + 11 + 12);
    }

    #[test]
    fn empty_shards_is_a_noop() {
        let pool = ExecPool::new(8);
        let shards: Vec<u8> = Vec::new();
        pool.run_shards(shards, |_, _| panic!("must not run"));
    }

    #[test]
    fn persistent_pool_survives_many_dispatches() {
        // The whole point of the rewrite: one pool, thousands of steps, no
        // spawn per step. Correctness leg: every dispatch sees every shard.
        let pool = ExecPool::new(4);
        let mut data = vec![0u64; 64];
        // Miri exercises the unsafe dispatch path just as well with a
        // handful of rounds and is ~100x slower per round.
        let rounds: u64 = if cfg!(miri) { 8 } else { 200 };
        for round in 0..rounds {
            let shards: Vec<&mut [u64]> = data.chunks_mut(16).collect();
            pool.run_shards(shards, |_, chunk| {
                for v in chunk {
                    *v += round + 1;
                }
            });
        }
        let expect = (1..=rounds).sum::<u64>();
        assert!(data.iter().all(|&v| v == expect), "{} != {expect}", data[0]);
    }

    #[test]
    fn more_shards_than_workers_all_run() {
        // The atomic cursor queues excess shards instead of oversubscribing.
        let pool = ExecPool::new(2);
        let hits = AtomicUsize::new(0);
        let shards: Vec<usize> = (0..37).collect();
        pool.run_shards(shards, |i, v| {
            assert_eq!(i, v);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 37);
    }

    #[test]
    fn clones_share_the_same_workers() {
        let pool = ExecPool::new(4);
        let clone = pool.clone();
        let mut a = vec![0u32; 8];
        let shards: Vec<&mut u32> = a.iter_mut().collect();
        clone.run_shards(shards, |i, v| *v = i as u32);
        assert_eq!(a, (0..8).collect::<Vec<u32>>());
        drop(clone);
        // original still dispatches after the clone is gone
        let mut b = vec![0u32; 4];
        let shards: Vec<&mut u32> = b.iter_mut().collect();
        pool.run_shards(shards, |i, v| *v = i as u32 + 1);
        assert_eq!(b, vec![1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "a shard panicked")]
    fn worker_panic_propagates_to_caller() {
        let pool = ExecPool::new(4);
        let shards: Vec<usize> = (0..8).collect();
        pool.run_shards(shards, |_, v| {
            if v == 5 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_caught_shard_panic() {
        // A recovered panic must not poison the dispatch path: the same
        // pool has to keep serving healthy dispatches afterwards.
        let pool = ExecPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_shards((0..8).collect::<Vec<usize>>(), |_, v| {
                if v == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        let mut data = vec![0u32; 8];
        let shards: Vec<&mut u32> = data.iter_mut().collect();
        pool.run_shards(shards, |i, v| *v = i as u32 + 1);
        assert_eq!(data.iter().sum::<u32>(), (1..=8).sum::<u32>());
    }

    #[test]
    fn every_shard_panicking_cannot_deadlock_the_barrier() {
        // Worst case for the barrier: *all* shards panic, including the
        // caller's own. The WaitGuard must still drain the workers, the
        // step must surface the panic, and the pool must stay usable.
        let pool = ExecPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_shards((0..12).collect::<Vec<usize>>(), |_, v| {
                panic!("shard {v} down");
            });
        }));
        assert!(r.is_err(), "the panic must propagate to the caller");
        let mut data = vec![0u32; 8];
        let shards: Vec<&mut u32> = data.iter_mut().collect();
        pool.run_shards(shards, |i, v| *v = i as u32 + 1);
        assert_eq!(data.iter().sum::<u32>(), (1..=8).sum::<u32>());
    }

    #[test]
    fn pinned_pool_runs_correctly_and_reports_placement() {
        let pool = ExecPool::new_with(4, true);
        assert!(pool.pinned());
        assert_eq!(pool.pin_target(), 3);
        // Achieved placement is best-effort (cpusets may refuse) and
        // workers pin asynchronously — only the bound is guaranteed.
        assert!(pool.pinned_workers() <= 3);
        let mut data = vec![0u32; 16];
        let shards: Vec<&mut [u32]> = data.chunks_mut(4).collect();
        pool.run_shards(shards, |i, chunk| {
            for v in chunk {
                *v = i as u32 + 1;
            }
        });
        assert!(data.iter().all(|&v| (1..=4).contains(&v)));
    }

    #[test]
    fn unpinned_pools_report_no_placement() {
        let pool = ExecPool::new(2);
        assert!(!pool.pinned());
        assert_eq!(pool.pin_target(), 0);
        assert_eq!(pool.pinned_workers(), 0);
        assert!(!ExecPool::serial().pinned());
        // a 1-worker pool has nothing to place: pin is ignored
        assert!(!ExecPool::new_with(1, true).pinned());
    }

    #[test]
    fn pinned_striping_covers_more_shards_than_workers() {
        // Static striping must still claim every shard exactly once when
        // shards outnumber workers.
        let pool = ExecPool::new_with(3, true);
        let hits = AtomicUsize::new(0);
        pool.run_shards((0..23).collect::<Vec<usize>>(), |i, v| {
            assert_eq!(i, v);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 23);
    }

    #[test]
    fn arena_ensure_grows_only() {
        let mut a = Arena::new(8);
        a.ensure(4);
        assert_eq!(a.z1.len(), 8);
        a.ensure(32);
        assert_eq!(a.z1.len(), 32);
        assert_eq!(a.z2.len(), 32);
        assert!(a.sel.capacity() >= 32);
    }

    #[test]
    fn arena_presizes_selection_scratch() {
        let a = Arena::new(4096);
        assert!(a.sel.capacity() >= 4096, "sel scratch must be pre-sized from the layout");
    }
}
