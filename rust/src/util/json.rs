//! Minimal JSON: parse + serialize (offline substitute for serde_json).
//!
//! Supports the full JSON grammar minus exotic escapes (\u is decoded for
//! BMP code points). Used for artifacts/manifest.json, train configs and
//! metrics JSONL.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(v: f64) -> Json {
    Json::Num(v)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u".to_string())?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("b").unwrap().get("d").unwrap().as_bool(), Some(true));
        // serialize then reparse: identical
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{e9}A"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
