//! bf16 <-> f32 conversion: the physical storage dtype of the sliding
//! window values and the dist engine's sparse wire slabs (paper §3.2
//! accounts `V` at 2 B/value).
//!
//! bf16 is the top 16 bits of an f32 (1 sign, 8 exponent, 7 mantissa), so
//! widening is a shift and narrowing is round-to-nearest-even on the
//! truncated half. Every bf16 bit pattern is exactly representable in f32,
//! which makes `bf16 -> f32 -> bf16` the identity — the property the
//! checkpoint round-trip relies on when window values travel through the
//! f32-typed snapshot format.

/// Round-to-nearest-even f32 -> bf16 bits.
///
/// NaNs are quieted explicitly: plain truncation of a NaN whose payload
/// lives only in the low 16 mantissa bits would otherwise collapse to an
/// infinity bit pattern.
///
/// Branchless on purpose: both the rounded and the quieted-NaN results
/// are computed from the bit pattern and selected without a data branch,
/// which is what lets [`round_into`] lane-parallelize under the
/// `target_feature` instantiations in [`crate::simd`]. The NaN predicate
/// `(bits & 0x7FFF_FFFF) > 0x7F80_0000` is exactly `v.is_nan()`.
#[inline(always)]
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    // One-add RNE: 0x7FFF plus the LSB of the kept half carries into the
    // kept bits exactly when (round bit) && (sticky bits || odd). Values
    // past the largest finite bf16 midpoint carry into the exponent and
    // land on the infinity encoding, which is the IEEE behaviour.
    let rounded = (bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) >> 16) as u16;
    let quieted = ((bits >> 16) as u16) | 0x0040;
    if (bits & 0x7FFF_FFFF) > 0x7F80_0000 {
        quieted
    } else {
        rounded
    }
}

/// bf16 bits -> f32 (exact).
#[inline(always)]
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// Widen a bf16 slab into an f32 buffer (`dst.len() == src.len()`).
///
/// Scalar twin of the vector instantiations in [`crate::simd`]
/// (`inline(always)` so the `target_feature` wrappers re-codegen this
/// exact body with wide registers enabled).
#[inline(always)]
pub fn widen_into(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = bf16_to_f32(s);
    }
}

/// Round an f32 slab into bf16 storage (`dst.len() == src.len()`).
///
/// Scalar twin of the vector instantiations in [`crate::simd`].
#[inline(always)]
pub fn round_into(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f32_to_bf16(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 65280.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v, "{v}");
        }
    }

    #[test]
    fn relative_error_within_bf16_ulp() {
        let mut x = 0.917f32;
        for _ in 0..100 {
            let r = bf16_to_f32(f32_to_bf16(x));
            assert!(((r - x) / x).abs() < 1.0 / 128.0, "{x} -> {r}");
            x *= 1.37;
        }
    }

    #[test]
    fn specials() {
        assert!(bf16_to_f32(f32_to_bf16(f32::INFINITY)).is_infinite());
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // NaN payloads confined to the truncated half must stay NaN, not
        // collapse to infinity (regression: the pre-bf16-storage converter
        // truncated them to 0x7F80).
        let sneaky = f32::from_bits(0x7F80_0001);
        assert!(sneaky.is_nan());
        assert!(bf16_to_f32(f32_to_bf16(sneaky)).is_nan());
    }

    #[test]
    fn slab_helpers_roundtrip() {
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.37).collect();
        let mut bits = vec![0u16; 64];
        round_into(&xs, &mut bits);
        let mut back = vec![0f32; 64];
        widen_into(&bits, &mut back);
        for (b, x) in back.iter().zip(&xs) {
            assert!(((b - x) / x.abs().max(1e-9)).abs() < 1.0 / 128.0);
        }
        // widening then re-rounding is the identity on the bit pattern
        let mut bits2 = vec![0u16; 64];
        round_into(&back, &mut bits2);
        assert_eq!(bits, bits2);
    }
}
