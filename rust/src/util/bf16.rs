//! bf16 <-> f32 conversion (paper-dtype storage for checkpoints and the
//! window value buffer accounting).

/// Round-to-nearest-even f32 -> bf16 bits.
pub fn f32_to_bf16(v: f32) -> u16 {
    let bits = v.to_bits();
    // round to nearest even on the truncated 16 bits
    let round_bit = (bits >> 15) & 1;
    let sticky = bits & 0x7FFF;
    let mut hi = (bits >> 16) as u16;
    if round_bit == 1 && (sticky != 0x0000 || (hi & 1) == 1) {
        // note: sticky includes the round bit position? standard approach:
        hi = hi.wrapping_add(((bits & 0xFFFF) > 0x8000 || ((bits & 0xFFFF) == 0x8000 && (hi & 1) == 1)) as u16);
        return hi;
    }
    hi
}

/// bf16 bits -> f32.
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 65280.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)), v, "{v}");
        }
    }

    #[test]
    fn relative_error_within_bf16_ulp() {
        let mut x = 0.917f32;
        for _ in 0..100 {
            let r = bf16_to_f32(f32_to_bf16(x));
            assert!(((r - x) / x).abs() < 1.0 / 128.0, "{x} -> {r}");
            x *= 1.37;
        }
    }

    #[test]
    fn specials() {
        assert!(bf16_to_f32(f32_to_bf16(f32::INFINITY)).is_infinite());
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }
}
