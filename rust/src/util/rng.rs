//! Deterministic seeded PRNG: xoshiro256** with splitmix64 seeding.
//!
//! Replaces `rand`/`rand_chacha` (not available offline). Statistical
//! quality is more than sufficient for data synthesis, Gaussian sketching
//! and stochastic rounding; determinism per seed is the hard requirement
//! (reproducible experiments, checkpoint-resume equivalence).

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    gauss_spare: Option<f32>,
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give independent streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], gauss_spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n).
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform i64 in [lo, hi).
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f32 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        let u1 = self.gen_f32().max(1e-7);
        let u2 = self.gen_f32();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices in [0, n), sorted.
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher-Yates over an index map
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            let vj = *map.get(&j).unwrap_or(&j);
            let vi = *map.get(&i).unwrap_or(&i);
            map.insert(j, vi);
            out.push(vj);
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(2);
        assert_ne!(Rng::seed_from_u64(1).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::seed_from_u64(3);
        let n = 20000;
        let mut sum = 0f64;
        for _ in 0..n {
            let v = r.gen_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 50000;
        let mut sum = 0f64;
        let mut sq = 0f64;
        for _ in 0..n {
            let v = r.gauss() as f64;
            sum += v;
            sq += v * v;
        }
        assert!((sum / n as f64).abs() < 0.02);
        assert!((sq / n as f64 - 1.0).abs() < 0.05);
    }

    #[test]
    fn choose_distinct_is_distinct_and_sorted() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..50 {
            let v = r.choose_distinct(20, 7);
            assert_eq!(v.len(), 7);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(v.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn gen_range_covers_domain() {
        let mut r = Rng::seed_from_u64(6);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[r.gen_range(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
