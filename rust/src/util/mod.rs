//! Self-contained utility substrates.
//!
//! The build is fully offline (only the `xla` crate closure is vendored in
//! this image), so the usual ecosystem crates are implemented here from
//! scratch: a seeded PRNG ([`rng`]), a minimal JSON parser/writer ([`json`])
//! for the artifact manifest / configs / metrics, and bf16 conversion
//! helpers ([`bf16`]) for paper-dtype storage.

pub mod bf16;
pub mod json;
pub mod rng;
