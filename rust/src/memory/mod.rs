//! Theoretical optimizer-state memory footprint model (§3.2 + Appendix D).
//!
//! Exact reproduction of the paper's accounting: bytes for the optimizer
//! states only (not weights/activations/gradients), using the same Llama-2
//! 7B constants as the Appendix-D python script. These formulas also feed
//! the measured-vs-theoretical columns of the table harnesses.

/// Actual parameter count of Llama-2 7B (Appendix D).
pub const LLAMA2_7B_PARAMS: u64 = 6_738_415_616;
/// `sum_i A_i` over Llama-2 7B weight matrices (Appendix D, GaLore).
pub const LLAMA2_7B_SUM_A: u64 = 1_423_872;
/// Total size of rank-1 layers kept dense under GaLore (Appendix D).
pub const LLAMA2_7B_EPS1: u64 = 266_240;
/// Parameter counts used for the ResNet table (torchvision models).
pub const RESNET18_PARAMS: u64 = 11_689_512;
pub const RESNET50_PARAMS: u64 = 25_557_032;

const GIB: f64 = (1u64 << 30) as f64;
const MIB: f64 = (1u64 << 20) as f64;

/// AdamW with fp32 states: `8d` bytes (§3.2, M_AW32).
pub fn adamw_fp32(d: u64) -> u64 {
    8 * d
}

/// AdamW with bf16 states: `4d` bytes (M_AW16).
pub fn adamw_bf16(d: u64) -> u64 {
    4 * d
}

/// AdamW-8bit: `2d` bytes (M_AW8).
pub fn adamw_8bit(d: u64) -> u64 {
    2 * d
}

/// SGD with fp32 momentum: `4d` bytes (ResNet table baseline).
pub fn sgd_momentum_fp32(d: u64) -> u64 {
    4 * d
}

/// MicroAdam: `0.5 d + 4 m k` bytes (M_muA) — 4-bit EF plus the sliding
/// window `G` holding `m*k` int16 indices and `m*k` bf16 values. Since the
/// bf16-storage change the native engine allocates the window at exactly
/// this accounting (2 B/value measured, see
/// `SlidingWindow::value_bytes_per_entry`), so this formula is the
/// *resident* window cost, not a paper-only fiction.
pub fn microadam(d: u64, m: u64, k: u64) -> u64 {
    d / 2 + 4 * m * k
}

/// MicroAdam at the paper's defaults (m = 10, k = d/100).
pub fn microadam_default(d: u64) -> u64 {
    microadam(d, crate::WINDOW as u64, d.div_ceil(100))
}

/// GaLore + bf16 AdamW states: `6 d_r + 2 eps_1` bytes, with
/// `d_r = r * sum_i A_i` (M_GLAW16).
pub fn galore_adamw_bf16(r: u64, sum_a: u64, eps1: u64) -> u64 {
    6 * r * sum_a + 2 * eps1
}

/// GaLore + 8-bit AdamW states: `4 d_r + 2 eps_1` bytes (M_GLAW8).
pub fn galore_adamw_8bit(r: u64, sum_a: u64, eps1: u64) -> u64 {
    4 * r * sum_a + 2 * eps1
}

/// Bytes -> GiB (the paper reports GB = GiB).
pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / GIB
}

/// Bytes -> MiB.
pub fn mib(bytes: u64) -> f64 {
    bytes as f64 / MIB
}

/// The window budget from the §3.2 discussion: largest window `m` at which
/// MicroAdam still beats AdamW-8bit for density `k = d/100`:
/// solve `0.5 d + 4 m k = 2 d` -> `m_max = 1.5 d / (4k) = 37.5`.
pub fn max_window_vs_adamw8bit(d: u64, k: u64) -> f64 {
    (2.0 * d as f64 - 0.5 * d as f64) / (4.0 * k as f64)
}

/// One row of the Appendix-D table.
#[derive(Debug, Clone, PartialEq)]
pub struct FootprintRow {
    pub name: &'static str,
    pub bytes: u64,
    pub gib: f64,
}

/// Regenerate the Appendix-D table for Llama-2 7B.
pub fn appendix_d_table() -> Vec<FootprintRow> {
    let d = LLAMA2_7B_PARAMS;
    let k = d.div_ceil(100);
    let rows = [
        ("M_AW32", adamw_fp32(d)),
        ("M_AW16", adamw_bf16(d)),
        ("M_AW8", adamw_8bit(d)),
        ("M_muA(m=10)", microadam(d, 10, k)),
        ("M_GLAW8_r256", galore_adamw_8bit(256, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)),
        ("M_GLAW8_r1024", galore_adamw_8bit(1024, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)),
        ("M_GLAW16_r256", galore_adamw_bf16(256, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)),
        ("M_GLAW16_r1024", galore_adamw_bf16(1024, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)),
    ];
    rows.iter().map(|&(name, bytes)| FootprintRow { name, bytes, gib: gib(bytes) }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn paper_section_3_2_numbers() {
        let d = LLAMA2_7B_PARAMS;
        // §3.2: 50.21 / 25.10 / 12.55 / 5.65 GB.
        assert!(close(gib(adamw_fp32(d)), 50.21, 0.01), "{}", gib(adamw_fp32(d)));
        assert!(close(gib(adamw_bf16(d)), 25.10, 0.01));
        assert!(close(gib(adamw_8bit(d)), 12.55, 0.01));
        assert!(close(gib(microadam_default(d)), 5.65, 0.01), "{}", gib(microadam_default(d)));
    }

    #[test]
    fn paper_galore_numbers() {
        // §3.2: GLAW8(256)=1.36, GLAW8(1024)=5.43, GLAW16(256)=2.04, GLAW16(1024)=8.15.
        assert!(close(gib(galore_adamw_8bit(256, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)), 1.36, 0.01));
        assert!(close(gib(galore_adamw_8bit(1024, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)), 5.43, 0.01));
        assert!(close(gib(galore_adamw_bf16(256, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)), 2.04, 0.01));
        assert!(close(gib(galore_adamw_bf16(1024, LLAMA2_7B_SUM_A, LLAMA2_7B_EPS1)), 8.15, 0.01));
    }

    #[test]
    fn discussion_m_max() {
        // §3.2 Discussion: m_max = 37.5 at k = d/100.
        let d = LLAMA2_7B_PARAMS;
        let m_max = max_window_vs_adamw8bit(d, d.div_ceil(100));
        assert!(close(m_max, 37.5, 0.01), "{m_max}");
    }

    #[test]
    fn microadam_is_half_of_adamw8bit_at_defaults() {
        let d = LLAMA2_7B_PARAMS;
        let ratio = microadam_default(d) as f64 / adamw_8bit(d) as f64;
        // 0.9d vs 2d -> 0.45.
        assert!(close(ratio, 0.45, 0.01), "{ratio}");
    }

    #[test]
    fn resnet_state_sizes_match_table4_shape() {
        // Table 4 reports SGD 44.59 MB / AdamW 89.18 MB / 8bit 22.30 MB /
        // MicroAdam 10.03 MB for ResNet-18 (and 2.19x that for ResNet-50).
        let d18 = RESNET18_PARAMS;
        assert!(close(mib(sgd_momentum_fp32(d18)), 44.59, 0.05));
        assert!(close(mib(adamw_fp32(d18)), 89.18, 0.1));
        assert!(close(mib(adamw_8bit(d18)), 22.30, 0.05));
        assert!(close(mib(microadam_default(d18)), 10.03, 0.05), "{}", mib(microadam_default(d18)));
        let d50 = RESNET50_PARAMS;
        assert!(close(mib(microadam_default(d50)), 21.94, 0.05), "{}", mib(microadam_default(d50)));
    }

    #[test]
    fn appendix_d_table_is_complete_and_ordered() {
        let table = appendix_d_table();
        assert_eq!(table.len(), 8);
        assert!(table[0].gib > table[1].gib && table[1].gib > table[2].gib);
        assert!(table[3].gib < table[2].gib); // MicroAdam under AdamW-8bit
    }
}
