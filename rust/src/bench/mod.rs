//! Table/figure harnesses: one entry point per experiment in DESIGN.md §4.
//!
//! Each `run_*` regenerates the corresponding paper artifact on the
//! synthetic substrate (substitutions documented in DESIGN.md), prints the
//! paper-style rows to stdout, and (where useful) writes CSV/JSONL under
//! `out_dir` for curve plotting. EXPERIMENTS.md records paper-vs-measured.

use std::fmt::Write as _;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::config::{optimizer_name, OptBackend, TrainConfig};
use crate::coordinator::metrics::MetricsLogger;
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::Trainer;
use crate::memory;
use crate::models::mlp::Mlp;
use crate::models::testfns::{self, IllConditioned, Rosenbrock, TestFn};
use crate::optim::microadam::{EfMode, MicroAdam, MicroAdamConfig};
use crate::optim::microadam_analytical::{AnalyticalConfig, MicroAdamAnalytical};
use crate::optim::{self, adamw, galore, Optimizer, OptimizerKind};

fn write_csv(out_dir: &str, name: &str, header: &str, rows: &[String]) -> Result<String> {
    std::fs::create_dir_all(out_dir)?;
    let path = format!("{out_dir}/{name}");
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Appendix D / §3.2: theoretical memory table
// ---------------------------------------------------------------------------

/// `repro memory`: optimizer-state footprints for Llama-2 7B (Appendix D).
pub fn run_memory() -> Result<()> {
    println!("Optimizer-state memory, Llama-2 7B (d = {}):", memory::LLAMA2_7B_PARAMS);
    println!("{:<16} {:>14} {:>9}", "state", "bytes", "GB");
    for row in memory::appendix_d_table() {
        println!("{:<16} {:>14} {:>9.2}", row.name, row.bytes, row.gib);
    }
    let d = memory::LLAMA2_7B_PARAMS;
    println!(
        "\nm_max vs AdamW-8bit at k=d/100 (§3.2 Discussion): {:.1}",
        memory::max_window_vs_adamw8bit(d, d.div_ceil(100))
    );
    // Measured (not accounted) resident window storage: the bf16 change
    // makes the paper's 2 B/value physical.
    let probe = MicroAdam::new(1 << 15, MicroAdamConfig::default());
    println!(
        "measured sliding-window value storage: {} B/value (window resident {} B at d=32768)",
        probe.window_value_bytes(),
        probe.window_state_bytes()
    );
    println!("\nResNet state sizes (Table 4 column):");
    for (name, dm) in [("ResNet-18", memory::RESNET18_PARAMS), ("ResNet-50", memory::RESNET50_PARAMS)] {
        println!(
            "{name}: SGD {:.2} MB | AdamW {:.2} MB | AdamW-8bit {:.2} MB | MicroAdam {:.2} MB",
            memory::mib(memory::sgd_momentum_fp32(dm)),
            memory::mib(memory::adamw_fp32(dm)),
            memory::mib(memory::adamw_8bit(dm)),
            memory::mib(memory::microadam_default(dm)),
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 1: Adam vs TopK-Adam vs TopK-Adam+EF on Rosenbrock
// ---------------------------------------------------------------------------

/// `repro fig1`: EF rescues TopK-Adam on the Rosenbrock function.
///
/// The paper's figure compresses to the single largest coordinate (50%
/// sparsity in 2-D) and compares plain Adam, TopK-Adam and TopK-Adam+EF.
/// The TopK variants here are Algorithm 3 with `C = Top-1`, dense error
/// (omega = 0), no AMSGrad/bias-correction asymmetries between them; the
/// practical 4-bit MicroAdam is added as a fourth line.
pub fn run_fig1(out_dir: &str, steps: usize) -> Result<()> {
    let lr = 0.01; // small constant lr as in the paper's illustration
    let f = Rosenbrock;
    let mk_topk = |error_feedback| -> Box<dyn Optimizer> {
        Box::new(MicroAdamAnalytical::new(2, AnalyticalConfig {
            k: 1,
            qbucket: None,
            amsgrad: false,
            error_feedback,
            ..Default::default()
        }))
    };
    let variants: Vec<(&str, Box<dyn Optimizer>)> = vec![
        (
            "adam",
            Box::new(adamw::AdamW::new(2, adamw::AdamWConfig {
                bias_correction: false, // match Algorithm 3's normalization
                ..Default::default()
            })),
        ),
        ("topk-adam", mk_topk(false)),
        ("topk-adam-ef", mk_topk(true)),
        (
            "microadam-q4",
            Box::new(MicroAdam::new(2, MicroAdamConfig { ef: EfMode::Quant4, ..Default::default() })),
        ),
    ];
    println!("Figure 1 — Rosenbrock from (-0.5, 1.0), lr={lr}, {steps} steps");
    println!(
        "{:<14} {:>10} {:>10} {:>12} {:>10} {:>12}",
        "optimizer", "x", "y", "f(x,y)", "path-len", "dist-to-adam"
    );
    let mut trajs: Vec<(&str, Vec<Vec<f32>>)> = Vec::new();
    for (name, mut opt) in variants {
        let traj = testfns::run_trajectory(&f, opt.as_mut(), lr, steps);
        trajs.push((name, traj));
    }
    let adam_traj = trajs[0].1.clone();
    let mut dists = Vec::new();
    for (name, traj) in &trajs {
        let end = traj.last().unwrap();
        let path_len: f32 = traj
            .windows(2)
            .map(|w| ((w[1][0] - w[0][0]).powi(2) + (w[1][1] - w[0][1]).powi(2)).sqrt())
            .sum();
        // mean pointwise distance to the Adam trajectory (the figure's
        // visual claim, quantified)
        let dist: f32 = traj
            .iter()
            .zip(&adam_traj)
            .map(|(a, b)| ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)).sqrt())
            .sum::<f32>()
            / traj.len() as f32;
        println!(
            "{:<14} {:>10.4} {:>10.4} {:>12.6} {:>10.3} {:>12.4}",
            name,
            end[0],
            end[1],
            f.eval(end),
            path_len,
            dist
        );
        dists.push((*name, dist));
        let rows: Vec<String> = traj.iter().map(|p| format!("{},{}", p[0], p[1])).collect();
        write_csv(out_dir, &format!("fig1_{name}.csv"), "x,y", &rows)?;
    }
    let d_noef = dists.iter().find(|(n, _)| *n == "topk-adam").unwrap().1;
    let d_ef = dists.iter().find(|(n, _)| *n == "topk-adam-ef").unwrap().1;
    println!(
        "\nEF recovers Adam's trajectory: mean deviation {:.4} with EF vs {:.4} without ({}x)",
        d_ef,
        d_noef,
        d_noef / d_ef.max(1e-9)
    );
    println!("trajectories written to {out_dir}/fig1_*.csv");
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 9: GaLore / GaLore-EF trajectories
// ---------------------------------------------------------------------------

/// `repro fig9`: Adam vs GaLore-Adam vs GaLore-Adam-EF on the
/// ill-conditioned trig function and on Rosenbrock.
pub fn run_fig9(out_dir: &str, steps: usize) -> Result<()> {
    // 2-D problems as 2x1 weight "matrices" with rank-1 projection: the
    // projection discards one direction per refresh interval, exactly the
    // regime Appendix F analyses.
    use crate::coordinator::layout::TensorSpec;
    let spec = vec![TensorSpec::new("w", &[2, 1], 0)];
    for (fname, f, lr) in [
        ("illcond", &IllConditioned as &dyn TestFn, 0.01),
        ("rosenbrock", &Rosenbrock as &dyn TestFn, 0.01),
    ] {
        println!("\nFigure 9 — {fname}, lr={lr}, {steps} steps");
        println!("{:<16} {:>10} {:>10} {:>12}", "optimizer", "x", "y", "f(x,y)");
        let variants: Vec<(&str, Box<dyn Optimizer>)> = vec![
            ("adam", Box::new(adamw::AdamW::new(2, adamw::AdamWConfig::default()))),
            (
                "galore-adam",
                Box::new(galore::GaLore::new(2, spec.clone(), galore::GaLoreConfig {
                    rank: 1,
                    update_every: 20,
                    error_feedback: false,
                    ..Default::default()
                })),
            ),
            (
                "galore-adam-ef",
                Box::new(galore::GaLore::new(2, spec.clone(), galore::GaLoreConfig {
                    rank: 1,
                    update_every: 20,
                    error_feedback: true,
                    ..Default::default()
                })),
            ),
        ];
        for (name, mut opt) in variants {
            let mut x = f.start();
            let mut g = vec![0.0; 2];
            let mut rows = Vec::with_capacity(steps + 1);
            rows.push(format!("{},{}", x[0], x[1]));
            for _ in 0..steps {
                f.grad(&x, &mut g);
                opt.step(&mut x, &g, lr);
                rows.push(format!("{},{}", x[0], x[1]));
            }
            println!("{:<16} {:>10.4} {:>10.4} {:>12.6}", name, x[0], x[1], f.eval(&x));
            write_csv(out_dir, &format!("fig9_{fname}_{name}.csv"), "x,y", &rows)?;
        }
    }
    println!("\ntrajectories written to {out_dir}/fig9_*.csv");
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 8: GaLore-EF error-norm growth
// ---------------------------------------------------------------------------

/// `repro fig8`: error-norm vs gradient-norm dynamics of GaLore+EF during
/// MLP fine-tuning (Appendix F: linear growth between subspace refreshes).
pub fn run_fig8(out_dir: &str, steps: usize) -> Result<()> {
    let vocab = 128;
    let mlp = Mlp::new(vec![vocab, 64, 32, 3]);
    let update_every = 50u64;
    let mut opt = galore::GaLore::new(mlp.dim(), mlp.specs().to_vec(), galore::GaLoreConfig {
        rank: 4,
        update_every,
        error_feedback: true,
        ..Default::default()
    });
    let mut flat = mlp.init(0);
    let mut ds = crate::data::NliDataset::new(vocab, 3, 1);
    let (mut toks, mut labs, mut feats) = (vec![], vec![], vec![]);
    let mut grads = vec![0f32; mlp.dim()];
    let mut rows = Vec::new();
    let mut max_ratio = 0f32;
    for step in 1..=steps {
        ds.next_batch(16, 24, &mut toks, &mut labs);
        Mlp::featurize_tokens(vocab, &toks, 24, &mut feats);
        let loss = mlp.loss_grad(&flat, &feats, &labs, &mut grads);
        opt.step(&mut flat, &grads, 1e-3);
        let norms = opt.layer_norms();
        let l0 = &norms[0];
        max_ratio = max_ratio.max(l0.error_norm / l0.grad_norm.max(1e-9));
        rows.push(format!("{step},{loss},{},{}", l0.grad_norm, l0.error_norm));
    }
    let path = write_csv(out_dir, "fig8_norms.csv", "step,loss,grad_norm,error_norm", &rows)?;
    println!("Figure 8 — GaLore-EF error/grad norms on MLP fine-tune ({steps} steps)");
    println!("subspace refresh interval T = {update_every}");
    println!("max ||e||/||g|| observed: {max_ratio:.1} (paper: error dominates gradient)");
    // growth-within-window summary: mean error norm right before refresh vs
    // right after
    let err_at = |s: usize| -> f32 {
        rows.get(s - 1)
            .and_then(|r| r.split(',').nth(3))
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0)
    };
    if steps as u64 > 2 * update_every {
        let before = err_at(2 * update_every as usize - 1);
        let after = err_at(update_every as usize + 5);
        println!(
            "error norm grows within a window: {:.3} (early) -> {:.3} (pre-refresh)",
            after, before
        );
    }
    println!("curve written to {path}");
    Ok(())
}

// ---------------------------------------------------------------------------
// Theory (Theorems 1-2): empirical rate study
// ---------------------------------------------------------------------------

/// `repro theory`: MicroAdam (analytical view) on a PL quadratic, sweeping
/// compression; checks the `(1+omega) q < 1` condition against observed
/// convergence and the O(1/sqrt(T)) gradient-norm decay.
pub fn run_theory(out_dir: &str) -> Result<()> {
    let d = 128;
    println!("Theory study — PL quadratic (d={d}, kappa=50), 4-bit stochastic EF");
    println!(
        "{:<22} {:>7} {:>9} {:>9} {:>12} {:>12} {:>9}",
        "config", "q", "omega", "(1+w)q", "E|g|^2@T/4", "E|g|^2@T", "converged"
    );
    let q = crate::models::testfns::QuadraticPL::new(d, 50.0);
    let mut rows = Vec::new();
    for (label, k, qbucket) in [
        ("dense-EF k=64", 64usize, None),
        ("dense-EF k=16", 16, None),
        ("Q4-EF k=64 Bq=16", 64, Some(16usize)),
        ("Q4-EF k=16 Bq=16", 16, Some(16)),
        ("Q4-EF k=4 Bq=128", 4, Some(128)), // violates (1+w)q < 1
    ] {
        let mut opt = MicroAdamAnalytical::new(d, AnalyticalConfig {
            k,
            qbucket,
            seed: 3,
            ..Default::default()
        });
        let qc = opt.q();
        let om = opt.omega_bound();
        let cond = opt.condition_holds();
        let total = 4000usize;
        let mut x = q.start();
        let mut g = vec![0f32; d];
        let mut sum_early = 0f64;
        let mut sum_late = 0f64;
        for t in 1..=total {
            q.grad(&x, &mut g);
            let gn: f64 = g.iter().map(|v| (v * v) as f64).sum();
            if t <= total / 4 {
                sum_early += gn;
            }
            sum_late += gn;
            opt.step(&mut x, &g, 0.01);
        }
        let early = sum_early / (total / 4) as f64;
        let late = sum_late / total as f64;
        let converged = q.eval(&x) < 0.05 * q.eval(&q.start());
        println!(
            "{:<22} {:>7.3} {:>9.3} {:>9.3} {:>12.4e} {:>12.4e} {:>9}",
            label,
            qc,
            om,
            (1.0 + om) * qc,
            early,
            late,
            converged
        );
        rows.push(format!("{label},{qc},{om},{cond},{early},{late},{converged}"));
    }
    let path = write_csv(
        out_dir,
        "theory_rates.csv",
        "config,q,omega,condition,grad2_early,grad2_late,converged",
        &rows,
    )?;
    println!("\n(avg grad^2 shrinking with horizon ~ the O(1/sqrt(T)) Theorem-1 rate; the");
    println!(" violated-condition row illustrates why (1+omega)q < 1 is needed)");
    println!("written {path}");
    Ok(())
}

// ---------------------------------------------------------------------------
// Tables 1-4
// ---------------------------------------------------------------------------

struct TableRow {
    name: String,
    train_loss: f32,
    accuracy: Option<f32>,
    state_bytes: usize,
    runtime_s: f64,
}

fn table_print(title: &str, rows: &[TableRow]) {
    println!("\n{title}");
    println!(
        "{:<22} {:>11} {:>9} {:>14} {:>9}",
        "optimizer", "train loss", "acc", "state bytes", "time (s)"
    );
    for r in rows {
        let acc = r.accuracy.map(|a| format!("{:.2}%", a * 100.0)).unwrap_or_else(|| "-".into());
        println!(
            "{:<22} {:>11.4} {:>9} {:>14} {:>9.1}",
            r.name, r.train_loss, acc, r.state_bytes, r.runtime_s
        );
    }
}

fn run_one(
    model: &str,
    kind: OptimizerKind,
    backend: OptBackend,
    steps: u64,
    lr: f32,
    seed: u64,
    artifacts_dir: &str,
    out_dir: &str,
    tag: &str,
) -> Result<(TableRow, Trainer)> {
    let cfg = TrainConfig {
        model: model.into(),
        optimizer: kind,
        backend,
        schedule: LrSchedule::Const { lr },
        steps,
        seed,
        out: format!("{out_dir}/{tag}_{}_{}.jsonl", model, optimizer_name(kind)),
        log_every: (steps / 4).max(1),
        artifacts_dir: artifacts_dir.into(),
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut trainer = Trainer::new(cfg)?;
    let mut logger = MetricsLogger::new(&trainer.cfg.out)?;
    trainer.train(&mut logger)?;
    let row = TableRow {
        name: format!("{} [{}]", optimizer_name(kind), match backend {
            OptBackend::Aot => "aot",
            OptBackend::Native => "native",
        }),
        train_loss: logger.tail_loss(10),
        accuracy: None,
        state_bytes: trainer.opt_state_bytes(),
        runtime_s: t0.elapsed().as_secs_f64(),
    };
    Ok((row, trainer))
}

/// `repro table1`: GLUE/MNLI stand-in — transformer classifier fine-tune
/// with the paper's five optimizers (MicroAdam, Adam, Adam-8bit, CAME,
/// GaLore).
pub fn run_table1(artifacts_dir: &str, out_dir: &str, model: &str, steps: u64) -> Result<()> {
    let mut rows = Vec::new();
    for (kind, backend, lr) in [
        (OptimizerKind::MicroAdam, OptBackend::Native, 3e-3),
        (OptimizerKind::Adam, OptBackend::Native, 1e-3),
        (OptimizerKind::AdamW8bit, OptBackend::Native, 1e-3),
        (OptimizerKind::Came, OptBackend::Native, 3e-4),
        (OptimizerKind::GaLore, OptBackend::Native, 3e-3),
    ] {
        let (mut row, mut trainer) =
            run_one(model, kind, backend, steps, lr, 7, artifacts_dir, out_dir, "table1")?;
        row.accuracy = Some(trainer.eval_accuracy(8)?);
        rows.push(row);
    }
    table_print(
        &format!("Table 1 (stand-in): {model} fine-tune on synthetic MNLI, {steps} steps"),
        &rows,
    );
    println!("\npaper shape to check: MicroAdam acc >= Adam-8bit ~ Adam > GaLore > CAME,");
    println!("with MicroAdam state well below Adam and ~half of Adam-8bit.");
    Ok(())
}

/// `repro table2`: GSM8k stand-in — LM fine-tune via AOT artifacts; the
/// paper-scale (7B/13B) state memory comes from the exact §3.2 model.
pub fn run_table2(artifacts_dir: &str, out_dir: &str, model: &str, steps: u64) -> Result<()> {
    let mut rows = Vec::new();
    for (kind, lr) in [
        (OptimizerKind::Adam, 1e-3),
        (OptimizerKind::AdamW8bit, 1e-3),
        (OptimizerKind::MicroAdam, 3e-3),
    ] {
        let (row, _) =
            run_one(model, kind, OptBackend::Aot, steps, lr, 7, artifacts_dir, out_dir, "table2")?;
        rows.push(row);
    }
    table_print(
        &format!("Table 2 (stand-in): {model} LM fine-tune (AOT path), {steps} steps"),
        &rows,
    );
    let d7 = memory::LLAMA2_7B_PARAMS;
    println!("\npaper-scale optimizer state (exact §3.2 accounting, Llama-2 7B):");
    println!("  Adam     {:>7.2} GB   (paper: 25.1 GB bf16)", memory::gib(memory::adamw_bf16(d7)));
    println!("  Adam-8b  {:>7.2} GB   (paper: 12.55 GB)", memory::gib(memory::adamw_8bit(d7)));
    println!("  MicroAdam{:>7.2} GB   (paper: 5.65 GB, m=10)", memory::gib(memory::microadam_default(d7)));
    println!(
        "  MicroAdam m=20 {:>7.2} GB (paper: 8.25 GB)",
        memory::gib(memory::microadam(d7, 20, d7.div_ceil(100)))
    );
    Ok(())
}

/// `repro table3`: Open-Platypus stand-in — instruction-tuning-shaped
/// classifier run evaluated on 4 synthetic "tasks" (fresh eval streams).
pub fn run_table3(artifacts_dir: &str, out_dir: &str, model: &str, steps: u64) -> Result<()> {
    println!("\nTable 3 (stand-in): {model}, 4-task synthetic eval suite, {steps} steps");
    println!(
        "{:<22} {:>14} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "optimizer", "state bytes", "avg", "task1", "task2", "task3", "task4"
    );
    for (kind, lr) in [
        (OptimizerKind::AdamW, 1e-3),
        (OptimizerKind::AdamW8bit, 1e-3),
        (OptimizerKind::MicroAdam, 3e-3),
    ] {
        let (row, mut trainer) =
            run_one(model, kind, OptBackend::Native, steps, lr, 11, artifacts_dir, out_dir, "table3")?;
        // four "tasks": independent eval batches
        let mut accs = Vec::new();
        for _ in 0..4 {
            accs.push(trainer.eval_accuracy(4)?);
        }
        let avg = accs.iter().sum::<f32>() / 4.0;
        println!(
            "{:<22} {:>14} {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}%",
            row.name,
            row.state_bytes,
            avg * 100.0,
            accs[0] * 100.0,
            accs[1] * 100.0,
            accs[2] * 100.0,
            accs[3] * 100.0
        );
    }
    println!("\npaper shape: MicroAdam >= AdamW > Adam-8b on average, with the lowest memory.");
    Ok(())
}

/// `repro table4`: ImageNet stand-in — CNN pre-train from scratch with
/// SGD / AdamW / AdamW-8bit / MicroAdam.
pub fn run_table4(artifacts_dir: &str, out_dir: &str, model: &str, steps: u64) -> Result<()> {
    let mut rows = Vec::new();
    for (kind, lr) in [
        (OptimizerKind::Sgd, 0.05),
        (OptimizerKind::AdamW, 1e-3),
        (OptimizerKind::AdamW8bit, 1e-3),
        (OptimizerKind::MicroAdam, 3e-3),
    ] {
        let (mut row, mut trainer) =
            run_one(model, kind, OptBackend::Native, steps, lr, 13, artifacts_dir, out_dir, "table4")?;
        row.accuracy = Some(trainer.eval_accuracy(8)?);
        rows.push(row);
    }
    table_print(
        &format!("Table 4 (stand-in): {model} pre-training on synthetic images, {steps} steps"),
        &rows,
    );
    println!("\npaper-scale state sizes (exact, Table 4 'State Size' column):");
    for (name, dm) in [("ResNet-18", memory::RESNET18_PARAMS), ("ResNet-50", memory::RESNET50_PARAMS)] {
        println!(
            "  {name}: SGD {:.2} / AdamW {:.2} / AdamW-8bit {:.2} / MicroAdam {:.2} MB",
            memory::mib(memory::sgd_momentum_fp32(dm)),
            memory::mib(memory::adamw_fp32(dm)),
            memory::mib(memory::adamw_8bit(dm)),
            memory::mib(memory::microadam_default(dm)),
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Data-parallel sweep: ranks x reducer, bytes-on-the-wire vs loss
// ---------------------------------------------------------------------------

/// `repro dist`: the compressed-all-reduce workload — every reducer at
/// ranks in {1, 2, 4, 8} on the native MLP substrate (artifact-free, so it
/// runs on the stub runtime), reporting final loss against the **measured
/// framed bytes** each configuration put on the wire. The loopback
/// transport serializes every frame through `dist::wire`, so "wire MB" is
/// what the uds/shm sockets would carry (payload + frame overhead), not a
/// formula — `frame B/r/s` is the per-rank-per-step framed cost.
pub fn run_dist_sweep(out_dir: &str, steps: u64) -> Result<()> {
    use crate::coordinator::config::TrainConfig;
    use crate::dist::{DistTrainer, ReducerKind, FRAME_OVERHEAD};

    println!("Data-parallel sweep — native mlp_tiny, micro-adam, {steps} steps/config");
    println!("(framed bytes = reducer payload + {FRAME_OVERHEAD} B frame overhead)");
    println!(
        "{:<6} {:<22} {:>12} {:>12} {:>11} {:>14} {:>9}",
        "ranks", "reducer", "final loss", "wire MB", "frame B/r/s", "residual B", "time (s)"
    );
    let mut rows = Vec::new();
    for &ranks in &[1usize, 2, 4, 8] {
        for &kind in &[ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let cfg = TrainConfig {
                model: "mlp_tiny".into(),
                optimizer: OptimizerKind::MicroAdam,
                schedule: LrSchedule::Const { lr: 3e-3 },
                steps,
                seed: 7,
                log_every: 10_000,
                ranks,
                reduce: kind,
                ..Default::default()
            };
            let t0 = Instant::now();
            let mut trainer = DistTrainer::new(cfg)?;
            let mut logger = MetricsLogger::new("")?;
            trainer.train(&mut logger)?;
            let dt = t0.elapsed().as_secs_f64();
            let loss = logger.tail_loss(10);
            let mb = trainer.wire_bytes_total() as f64 / (1u64 << 20) as f64;
            println!(
                "{:<6} {:<22} {:>12.4} {:>12.3} {:>11} {:>14} {:>9.1}",
                ranks,
                trainer.reducer_name(),
                loss,
                mb,
                trainer.frame_bytes_per_rank(),
                trainer.reducer_state_bytes(),
                dt
            );
            rows.push(format!(
                "{ranks},{},{loss},{},{},{},{dt}",
                crate::dist::reducer_name(kind),
                trainer.wire_bytes_total(),
                trainer.frame_bytes_per_rank(),
                trainer.reducer_state_bytes()
            ));
        }
    }
    let path = write_csv(
        out_dir,
        "dist_sweep.csv",
        "ranks,reducer,final_loss,framed_wire_bytes,frame_bytes_per_rank_step,residual_state_bytes,seconds",
        &rows,
    )?;
    println!("\nshape to check: eftopk tracks dense's loss at ~1-2% of its wire bytes,");
    println!("while plain topk drifts (no error correction); written {path}");
    Ok(())
}

/// What [`run_tcp_probe`] measured over the real socket.
pub struct TcpProbe {
    pub steps: u64,
    pub ranks: usize,
    /// Accounted framed bytes per rank per step (`wire_bytes_per_rank +
    /// FRAME_OVERHEAD`).
    pub frame_bytes_per_rank: u64,
    /// Bytes the worker endpoint physically wrote to its socket.
    pub worker_uplink_bytes: u64,
    /// What the accounting says the uplink should be (per-step frames +
    /// the one-time hello and config-digest handshakes).
    pub expected_uplink_bytes: u64,
    /// Bytes the coordinator physically read off its gather sockets.
    pub coordinator_received_bytes: u64,
    /// Gather/relay overlap the pipelined coordinator recorded (ms).
    pub overlap_ms: f64,
    /// Ranks in the order their frames completed the final gather.
    pub arrival_order: Vec<u16>,
    /// Arrival latency of each frame (ms since that gather opened),
    /// parallel to `arrival_order`.
    pub arrival_ms: Vec<f64>,
    pub final_loss: f32,
}

impl TcpProbe {
    /// Print the probe's rows (the bench_e2e / bench-smoke report).
    pub fn print(&self) {
        println!(
            "tcp probe ({} ranks x {} steps over 127.0.0.1, eftopk): \
             {} framed B/rank/step",
            self.ranks, self.steps, self.frame_bytes_per_rank
        );
        println!(
            "  worker uplink measured {} B vs accounted {} B ({})",
            self.worker_uplink_bytes,
            self.expected_uplink_bytes,
            if self.worker_uplink_bytes == self.expected_uplink_bytes { "MATCH" } else { "MISMATCH" }
        );
        println!(
            "  coordinator gathered {} B; gather/relay overlap {:.3} ms (>= 0: {}); \
             final loss {:.4}",
            self.coordinator_received_bytes,
            self.overlap_ms,
            if self.overlap_ms >= 0.0 { "ok" } else { "VIOLATED" },
            self.final_loss
        );
        if !self.arrival_order.is_empty() {
            let pairs: Vec<String> = self
                .arrival_order
                .iter()
                .zip(&self.arrival_ms)
                .map(|(r, ms)| format!("r{r}@{ms:.3}ms"))
                .collect();
            println!("  final-gather arrivals: {}", pairs.join(" "));
        }
    }
}

/// A real-socket TCP probe: a 3-rank eftopk training run over a
/// `127.0.0.1` ephemeral port (no external network), measuring the framed
/// socket bytes against the wire spec's accounting and the gather/relay
/// overlap the pipelined coordinator hides. Three ranks, not two: with a
/// single worker the ready-gated relay can only start once nothing is
/// missing, so overlap would be structurally zero; with two workers the
/// coordinator relays rank 0's frame to the earlier arriver while the
/// later one is still in flight. Run by `bench_e2e` and folded into the
/// `make bench-smoke` JSON record.
pub fn run_tcp_probe(steps: u64) -> Result<TcpProbe> {
    use crate::dist::wire::HELLO_DIGEST_BYTES;
    use crate::dist::{
        DistTrainer, ReducerKind, TcpPending, TcpTransport, TransportKind, FRAME_OVERHEAD,
    };

    let ranks = 3usize;
    let cfg = TrainConfig {
        model: "mlp_tiny".into(),
        optimizer: OptimizerKind::MicroAdam,
        schedule: LrSchedule::Const { lr: 3e-3 },
        steps,
        seed: 7,
        log_every: 10_000,
        workers: 2,
        ranks,
        reduce: ReducerKind::EfTopK,
        transport: TransportKind::Tcp,
        ..Default::default()
    };
    let pending = TcpPending::bind("127.0.0.1:0", ranks)?;
    let addr = pending.local_addr()?.to_string();
    let workers: Vec<_> = (1..ranks)
        .map(|r| {
            let addr = addr.clone();
            let wcfg = cfg.clone();
            std::thread::spawn(move || -> Result<u64> {
                let t = TcpTransport::connect(&addr, r, ranks)?;
                let mut tr = DistTrainer::with_transport(wcfg, Box::new(t), vec![r])?;
                let mut logger = MetricsLogger::new("")?;
                tr.train(&mut logger)?;
                Ok(tr.transport_bytes_sent())
            })
        })
        .collect();
    let coord_t = pending.accept()?;
    let mut tr = DistTrainer::with_transport(cfg, Box::new(coord_t), vec![0])?;
    let mut logger = MetricsLogger::new("")?;
    tr.train(&mut logger)?;
    let mut worker_sent = 0u64;
    for w in workers {
        let sent = w.join().map_err(|_| anyhow::anyhow!("tcp probe worker panicked"))??;
        if worker_sent == 0 {
            worker_sent = sent;
        } else if sent != worker_sent {
            return Err(anyhow::anyhow!(
                "tcp probe: workers measured different uplinks ({worker_sent} vs {sent} B)"
            ));
        }
    }
    let framed = tr.frame_bytes_per_rank() as u64;
    // per-step frames + the one-time rendezvous hello and config-digest
    let handshakes = (2 * FRAME_OVERHEAD + HELLO_DIGEST_BYTES) as u64;
    Ok(TcpProbe {
        steps,
        ranks,
        frame_bytes_per_rank: framed,
        worker_uplink_bytes: worker_sent,
        expected_uplink_bytes: steps * framed + handshakes,
        coordinator_received_bytes: tr.transport_bytes_received(),
        overlap_ms: tr.gather_overlap_ms(),
        arrival_order: tr.last_arrival_order().to_vec(),
        arrival_ms: tr.last_arrival_ms().to_vec(),
        final_loss: logger.tail_loss(10),
    })
}

/// One row of [`run_topology_probe`]'s topology × ranks sweep.
pub struct TopologyProbeRow {
    pub topology: &'static str,
    pub ranks: usize,
    /// Bytes the rank-0 endpoint physically wrote to its sockets.
    pub rank0_bytes_sent: u64,
    /// Bytes the rank-0 endpoint physically read off its sockets — the
    /// star→ring crossover signal: O(ranks) on star, O(1) on ring.
    pub rank0_bytes_received: u64,
    /// Gather/relay overlap rank 0 recorded (ms). Structurally 0 on ring,
    /// where rank 0 only ever sees the finished hop frame.
    pub overlap_ms: f64,
    /// Decode/gather overlap rank 0 recorded (ms; streaming slab decode
    /// under the gather tail).
    pub decode_overlap_ms: f64,
    pub final_loss: f32,
}

/// The topology × ranks sweep behind the `BENCH_*.json` `topology` key:
/// real-socket tcp runs over `127.0.0.1` ephemeral ports for each of
/// star/ring/tree at 2 and 4 ranks, recording what moves through rank 0
/// (the star bottleneck ring/tree exist to break) and the overlap the
/// pipelined endpoints hide. eftopk on the native mlp_tiny workload, so
/// the hop frames carry the same compressed slabs a real run would.
pub fn run_topology_probe(steps: u64) -> Result<Vec<TopologyProbeRow>> {
    use crate::dist::{
        ring_tcp_coordinator, ring_tcp_worker, tree_tcp_coordinator, tree_tcp_worker,
        DistTrainer, ReducerKind, TcpPending, TcpTransport, Topology, Transport, TransportKind,
    };

    let mut out = Vec::new();
    println!("\ntopology x ranks sweep (tcp over 127.0.0.1, eftopk, {steps} steps):");
    println!(
        "{:<6} {:<6} {:>14} {:>14} {:>12} {:>12} {:>10}",
        "topo", "ranks", "r0 sent B", "r0 recv B", "overlap ms", "decode ms", "loss"
    );
    for &topology in &[Topology::Star, Topology::Ring, Topology::Tree] {
        for &ranks in &[2usize, 4] {
            let cfg = TrainConfig {
                model: "mlp_tiny".into(),
                optimizer: OptimizerKind::MicroAdam,
                schedule: LrSchedule::Const { lr: 3e-3 },
                steps,
                seed: 7,
                log_every: 10_000,
                workers: 1,
                ranks,
                reduce: ReducerKind::EfTopK,
                transport: TransportKind::Tcp,
                topology,
                ..Default::default()
            };
            let pending = TcpPending::bind("127.0.0.1:0", ranks)?;
            let addr = pending.local_addr()?.to_string();
            let workers: Vec<_> = (1..ranks)
                .map(|r| {
                    let addr = addr.clone();
                    let wcfg = cfg.clone();
                    std::thread::spawn(move || -> Result<()> {
                        let t: Box<dyn Transport> = match topology {
                            Topology::Star => Box::new(TcpTransport::connect(&addr, r, ranks)?),
                            Topology::Ring => Box::new(ring_tcp_worker(&addr, r, ranks)?),
                            Topology::Tree => Box::new(tree_tcp_worker(&addr, r, ranks)?),
                        };
                        let mut tr = DistTrainer::with_transport(wcfg, t, vec![r])?;
                        let mut logger = MetricsLogger::new("")?;
                        tr.train(&mut logger)
                    })
                })
                .collect();
            let coord: Box<dyn Transport> = match topology {
                Topology::Star => Box::new(pending.accept()?),
                Topology::Ring => Box::new(ring_tcp_coordinator(pending)?),
                Topology::Tree => Box::new(tree_tcp_coordinator(pending)?),
            };
            let mut tr = DistTrainer::with_transport(cfg, coord, vec![0])?;
            let mut logger = MetricsLogger::new("")?;
            tr.train(&mut logger)?;
            for w in workers {
                w.join()
                    .map_err(|_| anyhow::anyhow!("topology probe worker panicked"))??;
            }
            let row = TopologyProbeRow {
                topology: crate::dist::topology_name(topology),
                ranks,
                rank0_bytes_sent: tr.transport_bytes_sent(),
                rank0_bytes_received: tr.transport_bytes_received(),
                overlap_ms: tr.gather_overlap_ms(),
                decode_overlap_ms: tr.decode_overlap_ms(),
                final_loss: logger.tail_loss(10),
            };
            println!(
                "{:<6} {:<6} {:>14} {:>14} {:>12.3} {:>12.3} {:>10.4}",
                row.topology,
                row.ranks,
                row.rank0_bytes_sent,
                row.rank0_bytes_received,
                row.overlap_ms,
                row.decode_overlap_ms,
                row.final_loss
            );
            out.push(row);
        }
    }
    println!(
        "  shape to check: rank-0 recv bytes grow with ranks on star but stay \
         one-hop-frame flat on ring"
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Micro-benchmarks (shared by the `benches/` targets)
// ---------------------------------------------------------------------------

/// Lightweight criterion substitute: median-of-runs wall time.
pub fn time_it<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    let mut line = String::new();
    let _ = write!(line, "{name:<46} median {:>10.3} ms", med * 1e3);
    let _ = write!(line, "  (min {:.3} ms, n={iters})", samples[0] * 1e3);
    println!("{line}");
    // Under a trace session the measurement also lands in the machine
    // sinks (gauges -> JSONL drain + Chrome counter track), so bench
    // numbers stop living only in stdout.
    if crate::trace::enabled() {
        crate::trace::gauge(&format!("bench.median_ms.{name}"), med * 1e3);
        crate::trace::gauge(&format!("bench.min_ms.{name}"), samples[0] * 1e3);
    }
    med
}

/// Measured cost of the *disabled* tracing instrumentation in one fused
/// MicroAdam step, as a percent of the step's wall time. CI-stable by
/// construction: rather than comparing two step timings across runs
/// (whose run-to-run jitter dwarfs 1%), it times the exact per-block
/// mark sequence a step executes with the gate off and divides by a
/// measured step time — an upper bound on what `--trace`-capable code
/// costs an untraced run. The `make trace-smoke` lane asserts < 1%.
/// Call with tracing disabled (no active session); an enabled gate would
/// measure the live-recording cost instead.
pub fn trace_overhead_pct(d: usize, iters: usize) -> f64 {
    use crate::exec::ExecPool;
    use crate::trace::PhaseAcc;

    let pool = ExecPool::new(1);
    let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
    let mut params = vec![0.1f32; d];
    let grads: Vec<f32> = (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
    let t_step = time_it("fused step (tracing disabled)", crate::WINDOW + 2, iters, || {
        opt.step_sharded(&mut params, &grads, 1e-3, &pool)
    });

    // The disabled instrumentation that step just paid: one PhaseAcc with
    // 5 marks per block. Re-run it alone, many times, behind black_box so
    // the dead `on == false` branches are not optimized away.
    let blocks = ((d + crate::BLOCK - 1) / crate::BLOCK).max(1);
    let reps = 64u32;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut acc = PhaseAcc::<5>::start();
        for _ in 0..blocks {
            for p in 0..5 {
                std::hint::black_box(&mut acc).mark(p);
            }
        }
        std::hint::black_box(acc).finish("bench.overhead", ["a", "b", "c", "d", "e"], 0);
    }
    let t_marks = t0.elapsed().as_secs_f64() / f64::from(reps);
    let pct = 100.0 * t_marks / t_step;
    println!(
        "disabled-tracing overhead: {:.3} us of marks per {:.3} ms step = {pct:.4}%",
        t_marks * 1e6,
        t_step * 1e3
    );
    pct
}

/// One measured (label, median seconds) row of the scaling benchmark.
pub type BenchRow = (String, f64);

/// One `(kernel, scalar median s, simd median s)` comparison row from
/// [`bench_kernel_rows`]. When the host resolves no vector level (simd
/// feature off, unsupported cpu, `MICROADAM_SIMD=scalar`) both columns
/// time the scalar kernels and the speedup is ~1.
pub type KernelRow = (String, f64, f64);

/// Time one kernel at [`Level::Scalar`](crate::simd::Level::Scalar) and at
/// the host's detected vector level.
fn kernel_pair<F: FnMut(crate::simd::Level)>(
    name: &str,
    iters: usize,
    vec_level: crate::simd::Level,
    mut f: F,
) -> KernelRow {
    use crate::simd::{level_name, Level};
    let ts = time_it(&format!("{name} [scalar]"), 2, iters, || f(Level::Scalar));
    let tv = time_it(&format!("{name} [{}]", level_name(vec_level)), 2, iters, || f(vec_level));
    (name.to_string(), ts, tv)
}

/// Per-kernel scalar-vs-simd medians over the fused step's hot kernels
/// (bf16 converters, Quant4 pack/unpack, Top-K select, AdamStats
/// accumulation, the update phase) plus the whole fused step under
/// [`Policy::Scalar`](crate::simd::Policy::Scalar) vs
/// [`Policy::Auto`](crate::simd::Policy::Auto). Feeds the `kernels`
/// section of the smoke lane's `BENCH_*.json` via [`smoke_json`]. Both
/// columns run the same math (the simd path is the scalar kernels
/// re-instantiated — see [`crate::simd`]), so the delta is pure codegen.
pub fn bench_kernel_rows(d: usize, iters: usize) -> Vec<KernelRow> {
    use crate::exec::ExecPool;
    use crate::quant::{BucketStats, Quant4};
    use crate::simd::{self, level_name, Policy};

    let d = crate::pad_up(d.max(crate::BLOCK), crate::BLOCK);
    let vec_level = simd::detected();
    println!("\nper-kernel scalar vs simd (detected: {}), d = {d}:", level_name(vec_level));
    let xs: Vec<f32> = (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 7.0).collect();
    let mut rows: Vec<KernelRow> = Vec::new();

    let mut bits = vec![0u16; d];
    rows.push(kernel_pair("kernel bf16_round", iters, vec_level, |lvl| {
        simd::bf16_round(lvl, &xs, &mut bits)
    }));
    let mut wide = vec![0f32; d];
    rows.push(kernel_pair("kernel bf16_widen", iters, vec_level, |lvl| {
        simd::bf16_widen(lvl, &bits, &mut wide)
    }));

    let q = Quant4::new(crate::QBUCKET);
    let mut packed = vec![0u8; d / 2];
    let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; d / crate::QBUCKET];
    rows.push(kernel_pair("kernel quant4_quantize", iters, vec_level, |lvl| {
        simd::quant4_quantize(lvl, &q, &xs, &mut packed, &mut stats)
    }));
    let mut acc = vec![0f32; d];
    rows.push(kernel_pair("kernel quant4_dequantize_add", iters, vec_level, |lvl| {
        simd::quant4_dequantize_add(lvl, &q, &packed, &stats, &mut acc)
    }));

    let kb = crate::kb_for_block(crate::BLOCK, crate::DENSITY);
    let mut idx = vec![0u16; kb];
    let mut vals = vec![0u16; kb];
    let mut scratch: Vec<u16> = Vec::with_capacity(crate::BLOCK);
    rows.push(kernel_pair("kernel topk_select", iters, vec_level, |lvl| {
        for b in 0..d / crate::BLOCK {
            crate::topk::topk_abs_block_bf16_with(
                lvl,
                &xs[b * crate::BLOCK..(b + 1) * crate::BLOCK],
                kb,
                &mut idx,
                &mut vals,
                &mut scratch,
            );
        }
    }));

    // One window row's worth of gathered indices per block, replayed
    // m x nb times — the shape the stats phase runs per step.
    let idx_w: Vec<u16> = (0..kb as u16).map(|i| i * 97 % crate::BLOCK as u16).collect();
    let val_bf: Vec<u16> = (0..kb).map(|i| crate::util::bf16::f32_to_bf16(xs[i])).collect();
    let val_f: Vec<f32> = xs[..kb].to_vec();
    let mut z1 = vec![0f32; crate::BLOCK];
    let mut z2 = vec![0f32; crate::BLOCK];
    let reps = crate::WINDOW * (d / crate::BLOCK);
    rows.push(kernel_pair("kernel stats_accum_bf16", iters, vec_level, |lvl| {
        for _ in 0..reps {
            simd::stats_accum_bf16(lvl, &idx_w, &val_bf, 0.5, 0.25, &mut z1, &mut z2);
        }
    }));
    rows.push(kernel_pair("kernel stats_accum_f32", iters, vec_level, |lvl| {
        for _ in 0..reps {
            simd::stats_accum_f32(lvl, &idx_w, &val_f, 0.5, 0.25, &mut z1, &mut z2);
        }
    }));

    let z1p: Vec<f32> = xs.iter().map(|v| v * 0.5).collect();
    let z2p: Vec<f32> = xs.iter().map(|v| v * v).collect();
    let mut params = vec![0.1f32; d];
    rows.push(kernel_pair("kernel adam_update", iters, vec_level, |lvl| {
        simd::adam_update(lvl, &mut params, &z1p, &z2p, 1e-3, 1e-8, 0.999)
    }));

    // Whole fused step, policy vs policy — the acceptance-gate row.
    let warmup = crate::WINDOW + 2;
    let pool = ExecPool::new(1);
    let mut fused = |policy: Policy, label: &str| -> f64 {
        let mut opt = MicroAdam::new(d, MicroAdamConfig { simd: policy, ..Default::default() });
        let mut p = vec![0.1f32; d];
        time_it(label, warmup, iters, || opt.step_sharded(&mut p, &xs, 1e-3, &pool))
    };
    let ts = fused(Policy::Scalar, "fused step [scalar]");
    let tv = fused(Policy::Auto, &format!("fused step [{}]", level_name(vec_level)));
    rows.push(("fused_step".to_string(), ts, tv));

    for (name, ts, tv) in &rows {
        println!("    {name:<34} speedup {:.2}x", ts / tv.max(1e-12));
    }
    rows
}

/// Sequential-vs-parallel step throughput for the block-sharded fused
/// engine (MicroAdam + the dense baselines routed through the same pool).
///
/// Prints the 4-pass reference, the fused single-pass at 1 worker, and the
/// fused engine at 2/4/8 workers (persistent zero-spawn pool), with
/// speedups against the sequential reference; returns the measured rows so
/// callers can serialize them (`BENCH_*.json`). Paper context: §3.2 claims
/// "similar running time to Adam"; the fused+sharded path is what closes
/// that gap on CPU.
pub fn bench_parallel_scaling(d: usize, iters: usize) -> Vec<BenchRow> {
    use crate::exec::ExecPool;
    use crate::optim::adamw::{AdamW, AdamWConfig};
    use crate::optim::adamw8bit::{AdamW8bit, AdamW8bitConfig};

    let mut rows: Vec<BenchRow> = Vec::new();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let grads: Vec<f32> = (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
    // warm every variant past the m-step window fill so steady-state
    // AdamStats cost is what gets timed
    let warmup = crate::WINDOW + 2;
    println!("\nblock-sharded fused step engine, d = {d} ({cores} cores):");

    let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
    let mut params = vec![0.1f32; d];
    let t_ref = time_it("microadam step_reference (4-pass sweep)", warmup, iters, || {
        opt.step_reference(&mut params, &grads, 1e-3)
    });
    rows.push(("microadam_reference".into(), t_ref));
    let mut speedup4 = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let pool = ExecPool::new(workers);
        let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
        let mut params = vec![0.1f32; d];
        let t = time_it(&format!("microadam fused ({workers} workers)"), warmup, iters, || {
            opt.step_sharded(&mut params, &grads, 1e-3, &pool)
        });
        if workers == 4 {
            speedup4 = t_ref / t;
        }
        rows.push((format!("microadam_fused_w{workers}"), t));
        println!("    -> {:.2}x vs sequential reference", t_ref / t);
    }

    let mut adamw = AdamW::new(d, AdamWConfig::default());
    let mut params = vec![0.1f32; d];
    let t_seq = time_it("adamw sequential", 2, iters, || adamw.step(&mut params, &grads, 1e-3));
    rows.push(("adamw_seq".into(), t_seq));
    let pool = ExecPool::auto();
    let t_par = time_it(
        &format!("adamw sharded ({} workers)", pool.workers()),
        2,
        iters,
        || adamw.step_sharded(&mut params, &grads, 1e-3, &pool),
    );
    rows.push((format!("adamw_sharded_w{}", pool.workers()), t_par));
    println!("    -> {:.2}x", t_seq / t_par);

    let mut adam8 = AdamW8bit::new(d, AdamW8bitConfig::default());
    let mut params = vec![0.1f32; d];
    let t_seq = time_it("adamw8bit sequential", 2, iters, || adam8.step(&mut params, &grads, 1e-3));
    rows.push(("adamw8bit_seq".into(), t_seq));
    let t_par = time_it(
        &format!("adamw8bit sharded ({} workers)", pool.workers()),
        2,
        iters,
        || adam8.step_sharded(&mut params, &grads, 1e-3, &pool),
    );
    rows.push((format!("adamw8bit_sharded_w{}", pool.workers()), t_par));
    println!("    -> {:.2}x", t_seq / t_par);

    println!(
        "\nmicroadam fused 4-worker speedup vs sequential reference: {speedup4:.2}x \
         (acceptance: >= 2x for d >= 1M on >= 4 cores; this machine has {cores})"
    );
    rows
}

/// Measured resident optimizer-state bytes/param for **every** registered
/// optimizer kind ([`OptimizerKind::all`], so a kind added to the registry
/// shows up here without touching this function) — allocated buffers, not
/// the paper accounting. Printed by `bench_e2e` and folded into the
/// smoke-lane JSON; returns `(name, resident bytes, paper bytes)` per
/// optimizer.
pub fn resident_state_report(d: usize) -> Vec<(String, usize, usize)> {
    use crate::coordinator::layout::TensorSpec;
    let side = (d as f64).sqrt() as usize;
    let specs = vec![TensorSpec::new("w", &[side, side], 0)];
    println!("\nresident optimizer-state bytes (measured allocations), d = {d}:");
    println!("{:<22} {:>14} {:>10} {:>14} {:>10}", "optimizer", "resident B", "B/param", "paper B", "B/param");
    let mut out = Vec::new();
    for &kind in OptimizerKind::all() {
        let opt = optim::build(kind, d, &specs, 0.0);
        let resident = opt.state_bytes();
        let paper = opt.paper_state_bytes();
        println!(
            "{:<22} {:>14} {:>10.3} {:>14} {:>10.3}",
            opt.name(),
            resident,
            optim::resident_bytes_per_param(opt.as_ref(), d),
            paper,
            paper as f64 / d as f64
        );
        out.push((opt.name(), resident, paper));
    }
    let probe = MicroAdam::new(d, MicroAdamConfig::default());
    println!(
        "microadam window: {} B resident, {} B/value (bf16)",
        probe.window_state_bytes(),
        probe.window_value_bytes()
    );
    out
}

/// One point on the bytes-vs-loss frontier ([`run_frontier`]).
pub struct FrontierRow {
    pub optimizer: String,
    pub resident_bytes_per_param: f64,
    pub paper_bytes_per_param: f64,
    pub final_loss: f32,
    pub seconds: f64,
}

/// The bytes-vs-loss frontier sweep: train the memory-accounting
/// headliners (micro-adam, adamw, adamw-8bit, ldadam, adammini) on the
/// native MLP substrate under identical schedules, and report final loss
/// against both the *measured* resident optimizer-state bytes/param and
/// the paper accounting. Runs through [`DistTrainer`] at `ranks = 1` +
/// dense — pinned bit-identical to single-process training — so the same
/// lane covers the dist wiring of every optimizer. Folded into the
/// smoke-lane `BENCH_*.json` under the `"frontier"` key.
pub fn run_frontier(steps: u64) -> Result<Vec<FrontierRow>> {
    use crate::coordinator::config::{optimizer_name, TrainConfig};
    use crate::dist::{DistTrainer, ReducerKind};

    println!("\nbytes-vs-loss frontier — native mlp_tiny, {steps} steps/optimizer:");
    println!(
        "{:<22} {:>12} {:>14} {:>12} {:>9}",
        "optimizer", "final loss", "resident B/p", "paper B/p", "time (s)"
    );
    let kinds = [
        OptimizerKind::MicroAdam,
        OptimizerKind::AdamW,
        OptimizerKind::AdamW8bit,
        OptimizerKind::LdAdam,
        OptimizerKind::AdamMini,
    ];
    let mut rows = Vec::new();
    for kind in kinds {
        let cfg = TrainConfig {
            model: "mlp_tiny".into(),
            optimizer: kind,
            schedule: LrSchedule::Const { lr: 3e-3 },
            steps,
            seed: 7,
            log_every: 10_000,
            ranks: 1,
            reduce: ReducerKind::Dense,
            ..Default::default()
        };
        let t0 = Instant::now();
        let mut trainer = DistTrainer::new(cfg)?;
        let mut logger = MetricsLogger::new("")?;
        trainer.train(&mut logger)?;
        let dt = t0.elapsed().as_secs_f64();
        let d = trainer.dim().max(1) as f64;
        let row = FrontierRow {
            optimizer: optimizer_name(kind).to_string(),
            resident_bytes_per_param: trainer.opt_resident_bytes() as f64 / d,
            paper_bytes_per_param: trainer.opt_state_bytes() as f64 / d,
            final_loss: logger.tail_loss(10),
            seconds: dt,
        };
        println!(
            "{:<22} {:>12.4} {:>14.3} {:>12.3} {:>9.1}",
            row.optimizer,
            row.final_loss,
            row.resident_bytes_per_param,
            row.paper_bytes_per_param,
            dt
        );
        rows.push(row);
    }
    Ok(rows)
}

/// Assemble the smoke-lane `BENCH_*.json` payload: steps/s from the
/// scaling rows, measured resident bytes/param, the bf16 window bytes per
/// value, the per-rank wire bytes of each reducer at this dimension, and
/// (when the caller ran one) the real-socket [`TcpProbe`] with its
/// gather/relay overlap ms and per-rank arrival latencies, plus the
/// measured [`trace_overhead_pct`] when the caller ran that check, and
/// the per-kernel scalar-vs-simd medians from [`bench_kernel_rows`], and
/// the bytes-vs-loss [`run_frontier`] rows under `"frontier"`, and the
/// [`run_topology_probe`] topology × ranks sweep under `"topology"`. Pure
/// assembly — the caller runs the probes and the benchmarks.
pub fn smoke_json(
    d: usize,
    rows: &[BenchRow],
    kernels: &[KernelRow],
    tcp: Option<&TcpProbe>,
    trace_overhead_pct: Option<f64>,
    frontier: &[FrontierRow],
    topology: &[TopologyProbeRow],
) -> crate::util::json::Json {
    use crate::dist::{build_reducer, ReducerKind, SparseReduceConfig};
    use crate::util::json::{self, Json};

    let steps: Vec<(&str, Json)> = rows
        .iter()
        .map(|(name, secs)| (name.as_str(), json::num(if *secs > 0.0 { 1.0 / secs } else { 0.0 })))
        .collect();
    let state = resident_state_report(d);
    let state_rows: Vec<Json> = state
        .iter()
        .map(|(name, bytes, paper)| {
            json::obj(vec![
                ("optimizer", json::s(name)),
                ("resident_bytes", json::num(*bytes as f64)),
                ("resident_bytes_per_param", json::num(*bytes as f64 / d as f64)),
                ("paper_bytes", json::num(*paper as f64)),
            ])
        })
        .collect();
    let mut wires = Vec::new();
    for kind in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
        let r = build_reducer(kind, d, 2, SparseReduceConfig::default());
        wires.push(json::obj(vec![
            ("reducer", json::s(crate::dist::reducer_name(kind))),
            ("wire_bytes_per_rank", json::num(r.wire_bytes_per_rank() as f64)),
            (
                "framed_bytes_per_rank",
                json::num((r.wire_bytes_per_rank() + crate::dist::FRAME_OVERHEAD) as f64),
            ),
        ]));
    }
    // Real-socket gather-overlap record (run by the caller): the smoke
    // lane's BENCH_*.json tracks the pipelined coordinator — overlap is
    // *recorded*, a timing measurement, deliberately not a speed claim.
    let tcp = match tcp {
        Some(p) => json::obj(vec![
            ("ranks", json::num(p.ranks as f64)),
            ("steps", json::num(p.steps as f64)),
            ("frame_bytes_per_rank", json::num(p.frame_bytes_per_rank as f64)),
            ("uplink_measured_bytes", json::num(p.worker_uplink_bytes as f64)),
            ("uplink_accounted_bytes", json::num(p.expected_uplink_bytes as f64)),
            ("gather_overlap_ms", json::num(p.overlap_ms)),
            (
                "arrival_order",
                Json::Arr(p.arrival_order.iter().map(|&r| json::num(r as f64)).collect()),
            ),
            (
                "arrival_ms",
                Json::Arr(p.arrival_ms.iter().map(|&ms| json::num(ms)).collect()),
            ),
        ]),
        None => json::obj(vec![("error", json::s("tcp probe not run"))]),
    };
    let kernel_rows: Vec<Json> = kernels
        .iter()
        .map(|(name, ts, tv)| {
            json::obj(vec![
                ("kernel", json::s(name)),
                ("scalar_ms", json::num(ts * 1e3)),
                ("simd_ms", json::num(tv * 1e3)),
                ("speedup", json::num(ts / tv.max(1e-12))),
            ])
        })
        .collect();
    let simd = json::obj(vec![
        ("level", json::s(crate::simd::level_name(crate::simd::detected()))),
        ("kernels", Json::Arr(kernel_rows)),
    ]);
    let frontier_rows: Vec<Json> = frontier
        .iter()
        .map(|r| {
            json::obj(vec![
                ("optimizer", json::s(&r.optimizer)),
                ("resident_bytes_per_param", json::num(r.resident_bytes_per_param)),
                ("paper_bytes_per_param", json::num(r.paper_bytes_per_param)),
                ("final_loss", json::num(r.final_loss as f64)),
                ("seconds", json::num(r.seconds)),
            ])
        })
        .collect();
    let topo_rows: Vec<Json> = topology
        .iter()
        .map(|r| {
            json::obj(vec![
                ("topology", json::s(r.topology)),
                ("ranks", json::num(r.ranks as f64)),
                ("rank0_bytes_sent", json::num(r.rank0_bytes_sent as f64)),
                ("rank0_bytes_received", json::num(r.rank0_bytes_received as f64)),
                ("gather_overlap_ms", json::num(r.overlap_ms)),
                ("decode_overlap_ms", json::num(r.decode_overlap_ms)),
                ("final_loss", json::num(r.final_loss as f64)),
            ])
        })
        .collect();
    let probe = MicroAdam::new(d, MicroAdamConfig::default());
    json::obj(vec![
        ("bench", json::s("smoke")),
        ("d", json::num(d as f64)),
        ("window_value_bytes", json::num(probe.window_value_bytes() as f64)),
        ("steps_per_s", json::obj(steps)),
        ("resident_state", Json::Arr(state_rows)),
        ("wire", Json::Arr(wires)),
        ("frontier", Json::Arr(frontier_rows)),
        ("topology", Json::Arr(topo_rows)),
        ("simd", simd),
        ("tcp_probe", tcp),
        (
            "trace_overhead_pct",
            trace_overhead_pct.map(json::num).unwrap_or(Json::Null),
        ),
    ])
}

/// Native optimizer step micro-benchmark (one row per optimizer at dim `d`).
pub fn bench_optimizer_steps(d: usize, iters: usize) {
    use crate::coordinator::layout::TensorSpec;
    let side = (d as f64).sqrt() as usize;
    let specs = vec![TensorSpec::new("w", &[side, side], 0)];
    println!("\nnative optimizer step, d = {d}:");
    for &kind in OptimizerKind::all() {
        let mut opt = optim::build(kind, d, &specs, 0.0);
        let mut params = vec![0.1f32; d];
        let grads: Vec<f32> = (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
        time_it(
            &format!("{:?}/d{}", kind, d),
            2,
            iters,
            || opt.step(&mut params, &grads, 1e-3),
        );
    }
}
