//! 2-D test functions from the paper's illustrative figures.
//!
//! * [`Rosenbrock`] — Figure 1 / Figure 9 second row:
//!   `f(x,y) = (1-x)^2 + 100 (y - x^2)^2`, start `(-1/2, 1)`.
//! * [`IllConditioned`] — Figure 9 first row:
//!   `f(x,y) = cos(5pi/4 x) + sin(7pi/4 y)`, start `(-1/4, 1/4)`.
//! * [`QuadraticPL`] — a strongly-convex quadratic (hence PL) used by the
//!   Theorem-2 empirical rate study (`repro theory`).

/// A differentiable scalar objective over R^d.
pub trait TestFn {
    fn dim(&self) -> usize;
    fn eval(&self, x: &[f32]) -> f32;
    fn grad(&self, x: &[f32], g: &mut [f32]);
    fn start(&self) -> Vec<f32>;
    /// Global minimum value (for convergence assertions), if known.
    fn f_star(&self) -> Option<f32>;
}

/// Rosenbrock banana function (Figure 1).
pub struct Rosenbrock;

impl TestFn for Rosenbrock {
    fn dim(&self) -> usize {
        2
    }
    fn eval(&self, x: &[f32]) -> f32 {
        (1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2)
    }
    fn grad(&self, x: &[f32], g: &mut [f32]) {
        let (a, b) = (x[0], x[1]);
        g[0] = -2.0 * (1.0 - a) - 400.0 * a * (b - a * a);
        g[1] = 200.0 * (b - a * a);
    }
    fn start(&self) -> Vec<f32> {
        vec![-0.5, 1.0] // paper's (x0, y0)
    }
    fn f_star(&self) -> Option<f32> {
        Some(0.0) // at (1, 1)
    }
}

/// Ill-conditioned trigonometric function (Figure 9, first row).
pub struct IllConditioned;

impl TestFn for IllConditioned {
    fn dim(&self) -> usize {
        2
    }
    fn eval(&self, x: &[f32]) -> f32 {
        let c = 5.0 * std::f32::consts::PI / 4.0;
        let s = 7.0 * std::f32::consts::PI / 4.0;
        (c * x[0]).cos() + (s * x[1]).sin()
    }
    fn grad(&self, x: &[f32], g: &mut [f32]) {
        let c = 5.0 * std::f32::consts::PI / 4.0;
        let s = 7.0 * std::f32::consts::PI / 4.0;
        g[0] = -c * (c * x[0]).sin();
        g[1] = s * (s * x[1]).cos();
    }
    fn start(&self) -> Vec<f32> {
        vec![-0.25, 0.25] // paper's (x0, y0)
    }
    fn f_star(&self) -> Option<f32> {
        Some(-2.0)
    }
}

/// `f(x) = 1/2 x^T diag(h) x`, h_i > 0: mu-PL with mu = min h (Theorem 2 study).
pub struct QuadraticPL {
    pub h: Vec<f32>,
    pub x0: Vec<f32>,
}

impl QuadraticPL {
    /// Condition-number-`kappa` quadratic in dimension d.
    pub fn new(d: usize, kappa: f32) -> Self {
        let h = (0..d)
            .map(|i| 1.0 + (kappa - 1.0) * i as f32 / (d.max(2) - 1) as f32)
            .collect();
        let x0 = (0..d).map(|i| ((i as f32 * 0.73).sin() + 1.2) / 2.0).collect();
        Self { h, x0 }
    }
}

impl TestFn for QuadraticPL {
    fn dim(&self) -> usize {
        self.h.len()
    }
    fn eval(&self, x: &[f32]) -> f32 {
        0.5 * x.iter().zip(&self.h).map(|(&xi, &hi)| hi * xi * xi).sum::<f32>()
    }
    fn grad(&self, x: &[f32], g: &mut [f32]) {
        for ((gi, &xi), &hi) in g.iter_mut().zip(x).zip(&self.h) {
            *gi = hi * xi;
        }
    }
    fn start(&self) -> Vec<f32> {
        self.x0.clone()
    }
    fn f_star(&self) -> Option<f32> {
        Some(0.0)
    }
}

/// Run `opt` on `f` for `steps` steps; returns the iterate trajectory
/// (including the start point). Used by the figure harnesses.
pub fn run_trajectory<F: TestFn>(
    f: &F,
    opt: &mut dyn crate::optim::Optimizer,
    lr: f32,
    steps: usize,
) -> Vec<Vec<f32>> {
    let mut x = f.start();
    let mut g = vec![0.0; f.dim()];
    let mut traj = vec![x.clone()];
    for _ in 0..steps {
        f.grad(&x, &mut g);
        opt.step(&mut x, &g, lr);
        traj.push(x.clone());
    }
    traj
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_grad<F: TestFn>(f: &F, x: &[f32]) {
        let mut g = vec![0.0; f.dim()];
        f.grad(x, &mut g);
        let eps = 1e-3;
        for i in 0..f.dim() {
            let mut xp = x.to_vec();
            let mut xm = x.to_vec();
            xp[i] += eps;
            xm[i] -= eps;
            let fd = (f.eval(&xp) - f.eval(&xm)) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 2e-2 * (1.0 + fd.abs()), "coord {i}: {fd} vs {}", g[i]);
        }
    }

    #[test]
    fn rosenbrock_gradient_matches_fd() {
        check_grad(&Rosenbrock, &[-0.5, 1.0]);
        check_grad(&Rosenbrock, &[0.3, -0.2]);
    }

    #[test]
    fn rosenbrock_minimum() {
        assert_eq!(Rosenbrock.eval(&[1.0, 1.0]), 0.0);
        let mut g = vec![0.0; 2];
        Rosenbrock.grad(&[1.0, 1.0], &mut g);
        assert_eq!(g, vec![0.0, 0.0]);
    }

    #[test]
    fn illconditioned_gradient_matches_fd() {
        check_grad(&IllConditioned, &[-0.25, 0.25]);
        check_grad(&IllConditioned, &[0.6, -0.9]);
    }

    #[test]
    fn quadratic_pl_inequality_holds() {
        // ||grad||^2 >= 2 mu (f - f*) with mu = min h.
        let q = QuadraticPL::new(8, 50.0);
        let mu = q.h.iter().cloned().fold(f32::INFINITY, f32::min);
        let x = q.start();
        let mut g = vec![0.0; 8];
        q.grad(&x, &mut g);
        let gn: f32 = g.iter().map(|v| v * v).sum();
        assert!(gn >= 2.0 * mu * q.eval(&x) - 1e-5);
    }
}
