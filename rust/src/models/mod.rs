//! Native (artifact-free) models: 2-D test functions for the trajectory
//! figures and a pure-rust MLP classifier used by optimizer-comparison
//! experiments that don't need the AOT transformer.

pub mod mlp;
pub mod testfns;
