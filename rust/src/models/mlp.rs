//! Pure-rust MLP classifier with exact backprop — the artifact-free model
//! substrate for optimizer-comparison experiments (Figure 8, ablations,
//! proptest-driven training invariants).
//!
//! Bag-of-tokens featurization + 2 hidden layers + softmax CE. Small enough
//! to train in milliseconds, structured enough (real 2-D weight matrices)
//! that shaped optimizers (GaLore/AdaFactor/CAME) exercise their factorized
//! paths via the exported [`Mlp::specs`].

use crate::coordinator::layout::TensorSpec;

/// MLP: input -> hidden (tanh) -> hidden (tanh) -> classes (softmax CE).
pub struct Mlp {
    pub sizes: Vec<usize>,
    specs: Vec<TensorSpec>,
    d: usize,
}

impl Mlp {
    /// `sizes = [input, h1, ..., classes]`.
    pub fn new(sizes: Vec<usize>) -> Self {
        assert!(sizes.len() >= 2);
        let mut specs = Vec::new();
        let mut off = 0;
        for l in 0..sizes.len() - 1 {
            let (a, b) = (sizes[l], sizes[l + 1]);
            specs.push(TensorSpec::new(&format!("w{l}"), &[a, b], off));
            off += a * b;
            specs.push(TensorSpec::new(&format!("b{l}"), &[b], off));
            off += b;
        }
        Self { sizes, specs, d: off }
    }

    /// Flat parameter dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Tensor layout for shaped optimizers.
    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    /// He-style init into a fresh flat vector.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let mut flat = vec![0f32; self.d];
        for l in 0..self.sizes.len() - 1 {
            let (a, b) = (self.sizes[l], self.sizes[l + 1]);
            let spec = &self.specs[2 * l];
            let std = (2.0 / a as f32).sqrt();
            for v in flat[spec.offset..spec.offset + a * b].iter_mut() {
                *v = (rng.gen_f32() - 0.5) * 2.0 * std;
            }
        }
        flat
    }

    fn w<'a>(&self, flat: &'a [f32], l: usize) -> &'a [f32] {
        let s = &self.specs[2 * l];
        &flat[s.offset..s.offset + s.size()]
    }

    fn b<'a>(&self, flat: &'a [f32], l: usize) -> &'a [f32] {
        let s = &self.specs[2 * l + 1];
        &flat[s.offset..s.offset + s.size()]
    }

    /// Forward + backward over one batch; returns mean CE loss and writes
    /// gradients into `grads` (same flat layout).
    ///
    /// `x`: (batch, input) row-major; `labels`: (batch,).
    pub fn loss_grad(&self, flat: &[f32], x: &[f32], labels: &[i32], grads: &mut [f32]) -> f32 {
        assert_eq!(flat.len(), self.d);
        assert_eq!(grads.len(), self.d);
        let nl = self.sizes.len() - 1;
        let batch = labels.len();
        assert_eq!(x.len(), batch * self.sizes[0]);
        grads.fill(0.0);

        // forward, keeping activations per layer
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        for l in 0..nl {
            let (a, b) = (self.sizes[l], self.sizes[l + 1]);
            let w = self.w(flat, l);
            let bias = self.b(flat, l);
            let prev = &acts[l];
            let mut out = vec![0f32; batch * b];
            for n in 0..batch {
                for j in 0..b {
                    let mut acc = bias[j];
                    for i in 0..a {
                        acc += prev[n * a + i] * w[i * b + j];
                    }
                    out[n * b + j] = if l + 1 < nl { acc.tanh() } else { acc };
                }
            }
            acts.push(out);
        }

        // softmax CE + output delta
        let classes = self.sizes[nl];
        let logits = &acts[nl];
        let mut delta = vec![0f32; batch * classes];
        let mut loss = 0f32;
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|&v| (v - maxv).exp()).collect();
            let z: f32 = exps.iter().sum();
            let label = labels[n] as usize;
            loss += -(exps[label] / z).ln();
            for c in 0..classes {
                let p = exps[c] / z;
                delta[n * classes + c] = (p - if c == label { 1.0 } else { 0.0 }) / batch as f32;
            }
        }
        loss /= batch as f32;

        // backward
        let mut cur_delta = delta;
        for l in (0..nl).rev() {
            let (a, b) = (self.sizes[l], self.sizes[l + 1]);
            let w = self.w(flat, l);
            let (ws, bs) = (&self.specs[2 * l], &self.specs[2 * l + 1]);
            let prev = &acts[l];
            // grads
            for n in 0..batch {
                for j in 0..b {
                    let dj = cur_delta[n * b + j];
                    if dj == 0.0 {
                        continue;
                    }
                    grads[bs.offset + j] += dj;
                    for i in 0..a {
                        grads[ws.offset + i * b + j] += prev[n * a + i] * dj;
                    }
                }
            }
            if l > 0 {
                // delta_prev = (delta @ W^T) * tanh'(pre) with tanh' = 1 - act^2
                let mut next = vec![0f32; batch * a];
                for n in 0..batch {
                    for i in 0..a {
                        let mut acc = 0f32;
                        for j in 0..b {
                            acc += cur_delta[n * b + j] * w[i * b + j];
                        }
                        let act = prev[n * a + i];
                        next[n * a + i] = acc * (1.0 - act * act);
                    }
                }
                cur_delta = next;
            }
        }
        loss
    }

    /// Classification accuracy on one batch.
    pub fn accuracy(&self, flat: &[f32], x: &[f32], labels: &[i32]) -> f32 {
        let nl = self.sizes.len() - 1;
        let batch = labels.len();
        let mut act = x.to_vec();
        for l in 0..nl {
            let (a, b) = (self.sizes[l], self.sizes[l + 1]);
            let w = self.w(flat, l);
            let bias = self.b(flat, l);
            let mut out = vec![0f32; batch * b];
            for n in 0..batch {
                for j in 0..b {
                    let mut acc = bias[j];
                    for i in 0..a {
                        acc += act[n * a + i] * w[i * b + j];
                    }
                    out[n * b + j] = if l + 1 < nl { acc.tanh() } else { acc };
                }
            }
            act = out;
        }
        let classes = self.sizes[nl];
        let mut correct = 0;
        for n in 0..batch {
            let row = &act[n * classes..(n + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == labels[n] as usize {
                correct += 1;
            }
        }
        correct as f32 / batch as f32
    }

    /// Bag-of-tokens featurization matching [`crate::data::NliDataset`]
    /// batches: token histogram normalized by sequence length.
    pub fn featurize_tokens(vocab: usize, tokens: &[i32], seq: usize, out: &mut Vec<f32>) {
        out.clear();
        for row in tokens.chunks(seq) {
            let mut hist = vec![0f32; vocab];
            for &t in row {
                hist[t as usize] += 1.0 / seq as f32;
            }
            out.extend_from_slice(&hist);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradients_match_finite_differences() {
        let mlp = Mlp::new(vec![6, 5, 3]);
        let flat = mlp.init(0);
        let x: Vec<f32> = (0..12).map(|i| (i as f32 * 0.31).sin()).collect();
        let labels = vec![0, 2];
        let mut grads = vec![0f32; mlp.dim()];
        let loss = mlp.loss_grad(&flat, &x, &labels, &mut grads);
        assert!(loss.is_finite());
        let eps = 1e-3;
        for &i in &[0usize, 7, 20, mlp.dim() - 1] {
            let mut fp = flat.clone();
            fp[i] += eps;
            let mut fm = flat.clone();
            fm[i] -= eps;
            let mut scratch = vec![0f32; mlp.dim()];
            let lp = mlp.loss_grad(&fp, &x, &labels, &mut scratch);
            let lm = mlp.loss_grad(&fm, &x, &labels, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grads[i]).abs() < 1e-2 * (1.0 + fd.abs()),
                "param {i}: fd {fd} vs {}",
                grads[i]
            );
        }
    }

    #[test]
    fn training_with_adamw_learns_nli_task() {
        use crate::data::NliDataset;
        use crate::optim::{adamw::AdamW, adamw::AdamWConfig, Optimizer};
        let vocab = 64;
        let mlp = Mlp::new(vec![vocab, 32, 3]);
        let mut flat = mlp.init(1);
        let mut opt = AdamW::new(mlp.dim(), AdamWConfig::default());
        let mut ds = NliDataset::new(vocab, 3, 0);
        let (mut toks, mut labs, mut feats) = (vec![], vec![], vec![]);
        let mut grads = vec![0f32; mlp.dim()];
        let mut last_loss = 0.0;
        for _ in 0..200 {
            ds.next_batch(16, 24, &mut toks, &mut labs);
            Mlp::featurize_tokens(vocab, &toks, 24, &mut feats);
            last_loss = mlp.loss_grad(&flat, &feats, &labs, &mut grads);
            opt.step(&mut flat, &grads, 3e-3);
        }
        assert!(last_loss < 0.7, "loss did not drop: {last_loss}");
        ds.next_batch(64, 24, &mut toks, &mut labs);
        Mlp::featurize_tokens(vocab, &toks, 24, &mut feats);
        let acc = mlp.accuracy(&flat, &feats, &labs);
        assert!(acc > 0.6, "accuracy {acc}");
    }

    #[test]
    fn specs_cover_dim_exactly() {
        let mlp = Mlp::new(vec![10, 8, 4]);
        let total: usize = mlp.specs().iter().map(|s| s.size()).sum();
        assert_eq!(total, mlp.dim());
        assert_eq!(mlp.dim(), 10 * 8 + 8 + 8 * 4 + 4);
    }
}
