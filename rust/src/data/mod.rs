//! Synthetic data substrates (DESIGN.md substitutions for GLUE/GSM8k/
//! Open-Platypus/ImageNet).
//!
//! Every generator is a deterministic function of a seed, produces batches
//! shaped exactly like the corresponding artifact inputs, and has enough
//! learnable structure that optimizer quality differences show up in the
//! loss/accuracy curves (the property the paper's tables measure).

use crate::util::rng::Rng;

/// Zipf-distributed token sampler with first-order Markov structure: makes
/// next-token prediction learnable (bigram statistics) so LM loss curves
/// separate optimizers, unlike i.i.d. noise.
pub struct MarkovCorpus {
    vocab: usize,
    /// Per-state candidate successors (dense transition would be V^2).
    successors: Vec<[u32; 4]>,
    rng: Rng,
    state: u32,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let successors = (0..vocab)
            .map(|_| {
                [
                    zipf(&mut rng, vocab),
                    zipf(&mut rng, vocab),
                    zipf(&mut rng, vocab),
                    zipf(&mut rng, vocab),
                ]
            })
            .collect();
        Self { vocab, successors, rng: Rng::seed_from_u64(seed ^ 0x9e3779b9), state: 0 }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_token(&mut self) -> u32 {
        // 85% follow the Markov chain, 15% jump to a zipf draw.
        let t = if self.rng.gen_f32() < 0.85 {
            let cands = &self.successors[self.state as usize];
            cands[self.rng.gen_range(cands.len())]
        } else {
            zipf(&mut self.rng, self.vocab)
        };
        self.state = t;
        t
    }

    /// Fill a (batch, seq) token batch and its next-token targets.
    pub fn next_batch(&mut self, batch: usize, seq: usize, tokens: &mut Vec<i32>, targets: &mut Vec<i32>) {
        tokens.clear();
        targets.clear();
        for _ in 0..batch {
            let mut prev = self.next_token();
            for _ in 0..seq {
                let cur = self.next_token();
                tokens.push(prev as i32);
                targets.push(cur as i32);
                prev = cur;
            }
        }
    }
}

fn zipf(rng: &mut Rng, n: usize) -> u32 {
    // Inverse-CDF approximation of zipf(s=1.1) over [0, n).
    let u: f64 = rng.gen_f64().max(1e-12);
    let v = (n as f64).powf(1.0 - 0.1) * u;
    (v.powf(1.0 / 0.9) as u32).min(n as u32 - 1)
}

/// Synthetic NLI-style classification set (GLUE/MNLI stand-in): each of the
/// 3 labels is a distribution over "signal" tokens; sequences mix signal
/// with zipf background noise. Linear separability is partial, so training
/// dynamics matter.
pub struct NliDataset {
    vocab: usize,
    n_classes: usize,
    signal_tokens: Vec<Vec<u32>>,
    rng: Rng,
}

impl NliDataset {
    pub fn new(vocab: usize, n_classes: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let signal_tokens = (0..n_classes)
            .map(|_| (0..8).map(|_| rng.gen_range(vocab) as u32).collect())
            .collect();
        Self { vocab, n_classes, signal_tokens, rng }
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Sample a (batch, seq) token batch and labels.
    pub fn next_batch(&mut self, batch: usize, seq: usize, tokens: &mut Vec<i32>, labels: &mut Vec<i32>) {
        tokens.clear();
        labels.clear();
        for _ in 0..batch {
            let label = self.rng.gen_range(self.n_classes);
            labels.push(label as i32);
            let sig = &self.signal_tokens[label];
            for _ in 0..seq {
                let tok = if self.rng.gen_f32() < 0.35 {
                    sig[self.rng.gen_range(sig.len())]
                } else {
                    zipf(&mut self.rng, self.vocab)
                };
                tokens.push(tok as i32);
            }
        }
    }
}

/// Synthetic image classification set (ImageNet stand-in): each class has a
/// characteristic low-frequency template; samples are template + noise.
pub struct ImageDataset {
    image: usize,
    channels: usize,
    n_classes: usize,
    templates: Vec<Vec<f32>>,
    rng: Rng,
    /// signal-to-noise ratio of the class template.
    pub snr: f32,
}

impl ImageDataset {
    pub fn new(image: usize, channels: usize, n_classes: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let n = image * image * channels;
        let templates = (0..n_classes)
            .map(|c| {
                (0..n)
                    .map(|i| {
                        let (y, x) = ((i / channels) / image, (i / channels) % image);
                        let fx = (c % 7 + 1) as f32;
                        let fy = (c % 5 + 1) as f32;
                        ((x as f32 * fx * 0.3).sin() + (y as f32 * fy * 0.23).cos()
                            + rng.gen_f32() * 0.3)
                            * 0.5
                    })
                    .collect()
            })
            .collect();
        Self { image, channels, n_classes, templates, rng: Rng::seed_from_u64(seed ^ 0xabcdef), snr: 1.0 }
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Sample a NHWC f32 batch and labels.
    pub fn next_batch(&mut self, batch: usize, images: &mut Vec<f32>, labels: &mut Vec<i32>) {
        images.clear();
        labels.clear();
        let n = self.image * self.image * self.channels;
        for _ in 0..batch {
            let label = self.rng.gen_range(self.n_classes);
            labels.push(label as i32);
            let tpl = &self.templates[label];
            for i in 0..n {
                images.push(self.snr * tpl[i] + (self.rng.gen_f32() - 0.5));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_per_seed() {
        let mut a = MarkovCorpus::new(256, 7);
        let mut b = MarkovCorpus::new(256, 7);
        let (mut ta, mut ga, mut tb, mut gb) = (vec![], vec![], vec![], vec![]);
        a.next_batch(2, 16, &mut ta, &mut ga);
        b.next_batch(2, 16, &mut tb, &mut gb);
        assert_eq!(ta, tb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn corpus_tokens_in_range_and_shaped() {
        let mut c = MarkovCorpus::new(100, 0);
        let (mut t, mut g) = (vec![], vec![]);
        c.next_batch(4, 32, &mut t, &mut g);
        assert_eq!(t.len(), 128);
        assert_eq!(g.len(), 128);
        assert!(t.iter().chain(&g).all(|&x| (0..100).contains(&x)));
    }

    #[test]
    fn corpus_has_learnable_bigram_structure() {
        // Markov chain: successor entropy must be far below uniform.
        let mut c = MarkovCorpus::new(64, 1);
        let (mut t, mut g) = (vec![], vec![]);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..50 {
            c.next_batch(4, 64, &mut t, &mut g);
            for (a, b) in t.iter().zip(&g) {
                *counts.entry((*a, *b)).or_insert(0u32) += 1;
            }
        }
        // 64*64 = 4096 possible bigrams; the chain concentrates on far fewer.
        assert!(counts.len() < 2500, "{} distinct bigrams", counts.len());
    }

    #[test]
    fn nli_labels_balanced_and_tokens_in_range() {
        let mut ds = NliDataset::new(256, 3, 0);
        let (mut t, mut l) = (vec![], vec![]);
        let mut counts = [0usize; 3];
        for _ in 0..50 {
            ds.next_batch(8, 16, &mut t, &mut l);
            for &lab in &l {
                counts[lab as usize] += 1;
            }
            assert!(t.iter().all(|&x| (0..256).contains(&x)));
        }
        for &c in &counts {
            assert!(c > 60, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn nli_classes_have_distinct_token_statistics() {
        let mut ds = NliDataset::new(256, 3, 3);
        let (mut t, mut l) = (vec![], vec![]);
        let mut hist = vec![vec![0f64; 256]; 3];
        for _ in 0..200 {
            ds.next_batch(8, 32, &mut t, &mut l);
            for (row, &lab) in t.chunks(32).zip(&l) {
                for &tok in row {
                    hist[lab as usize][tok as usize] += 1.0;
                }
            }
        }
        // L1 distance between class histograms must be significant.
        let norm: f64 = hist[0].iter().sum();
        let dist: f64 = hist[0].iter().zip(&hist[1]).map(|(a, b)| (a - b).abs()).sum();
        assert!(dist / norm > 0.2, "classes indistinguishable: {}", dist / norm);
    }

    #[test]
    fn images_shaped_and_finite() {
        let mut ds = ImageDataset::new(32, 3, 10, 0);
        let (mut imgs, mut labs) = (vec![], vec![]);
        ds.next_batch(4, &mut imgs, &mut labs);
        assert_eq!(imgs.len(), 4 * 32 * 32 * 3);
        assert_eq!(labs.len(), 4);
        assert!(imgs.iter().all(|v| v.is_finite()));
    }
}
