//! AdamW with 8-bit block-quantized state (Dettmers et al. 2021 baseline).
//!
//! Stores `m` (signed) and `v` (unsigned) as u8 codes indexing a log-spaced
//! "dynamic" table with per-bucket absmax scales: 2 bytes/param + negligible
//! metadata, the `M_AW8 = 2d` row of §3.2. The log table mirrors the
//! original's dynamic-tree map (relative precision across ~7 orders of
//! magnitude); a trust-region clip on the update guards the residual
//! v-underflow corner (DESIGN.md substitutions).

use super::Optimizer;
use crate::exec::{self, ExecPool};
use crate::quant::Dynamic8;

#[derive(Debug, Clone, Copy)]
pub struct AdamW8bitConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Quantization bucket for the state blocks.
    pub bucket: usize,
}

impl Default for AdamW8bitConfig {
    fn default() -> Self {
        Self { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, bucket: 256 }
    }
}

/// 8-bit-state AdamW.
pub struct AdamW8bit {
    cfg: AdamW8bitConfig,
    d: usize,
    d_pad: usize,
    mq: Dynamic8,
    vq: Dynamic8,
    m_codes: Vec<u8>,
    m_scales: Vec<f32>,
    v_codes: Vec<u8>,
    v_scales: Vec<f32>,
    /// fp32 scratch for the dequantized moments (not persistent state).
    m_f: Vec<f32>,
    v_f: Vec<f32>,
    t: u64,
}

impl AdamW8bit {
    pub fn new(d: usize, cfg: AdamW8bitConfig) -> Self {
        let bucket = cfg.bucket.min(crate::pad_up(d, 2));
        let cfg = AdamW8bitConfig { bucket, ..cfg };
        let d_pad = crate::pad_up(d, bucket);
        let nq = d_pad / bucket;
        let mq = Dynamic8::signed();
        let vq = Dynamic8::unsigned();
        Self {
            cfg,
            d,
            d_pad,
            mq,
            vq,
            m_codes: vec![128; d_pad], // code 128 == 0.0 signed
            m_scales: vec![0.0; nq],
            v_codes: vec![0; d_pad],
            v_scales: vec![0.0; nq],
            m_f: vec![0.0; d_pad],
            v_f: vec![0.0; d_pad],
            t: 0,
        }
    }
}

/// Per-step scalar factors (bias corrections, decoupled decay).
fn factors(cfg: &AdamW8bitConfig, t: u64, lr: f32) -> (f32, f32, f32) {
    (
        1.0 - cfg.beta1.powi(t as i32),
        1.0 - cfg.beta2.powi(t as i32),
        1.0 - lr * cfg.weight_decay,
    )
}

/// Dequantize -> update -> re-quantize over one bucket-aligned chunk.
/// `params`/`grads` may be shorter than the state slices (the padded tail);
/// the surplus state decays to zero exactly as in the sequential path.
/// Shared by the sequential and sharded steps so both produce identical bits.
#[allow(clippy::too_many_arguments)]
fn update_chunk(
    cfg: &AdamW8bitConfig,
    mq: &Dynamic8,
    vq: &Dynamic8,
    bc1: f32,
    bc2: f32,
    decay: f32,
    lr: f32,
    params: &mut [f32],
    grads: &[f32],
    m_codes: &mut [u8],
    m_scales: &mut [f32],
    v_codes: &mut [u8],
    v_scales: &mut [f32],
    m_f: &mut [f32],
    v_f: &mut [f32],
) {
    mq.dequantize(m_codes, cfg.bucket, m_scales, m_f);
    vq.dequantize(v_codes, cfg.bucket, v_scales, v_f);
    let n = params.len();
    for i in 0..n {
        let g = grads[i];
        m_f[i] = cfg.beta1 * m_f[i] + (1.0 - cfg.beta1) * g;
        v_f[i] = cfg.beta2 * v_f[i] + (1.0 - cfg.beta2) * g * g;
        let m_hat = m_f[i] / bc1;
        let v_hat = v_f[i] / bc2;
        // Trust-region clip: a v code that decays to zero while m stays
        // nonzero would otherwise produce an m/eps-scale explosion.
        let u = (m_hat / (v_hat.sqrt() + cfg.eps)).clamp(-10.0, 10.0);
        params[i] = decay * params[i] - lr * u;
    }
    for i in n..m_f.len() {
        m_f[i] = 0.0;
        v_f[i] = 0.0;
    }
    mq.quantize(m_f, cfg.bucket, m_codes, m_scales);
    vq.quantize(v_f, cfg.bucket, v_codes, v_scales);
}

impl Optimizer for AdamW8bit {
    fn name(&self) -> String {
        "AdamW-8bit".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.d);
        self.t += 1;
        let (bc1, bc2, decay) = factors(&self.cfg, self.t, lr);
        update_chunk(
            &self.cfg,
            &self.mq,
            &self.vq,
            bc1,
            bc2,
            decay,
            lr,
            params,
            grads,
            &mut self.m_codes,
            &mut self.m_scales,
            &mut self.v_codes,
            &mut self.v_scales,
            &mut self.m_f,
            &mut self.v_f,
        );
    }

    fn step_sharded(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: &ExecPool) {
        assert_eq!(params.len(), self.d);
        self.t += 1;
        let (bc1, bc2, decay) = factors(&self.cfg, self.t, lr);
        // Shard on quantization-bucket boundaries so every worker owns whole
        // buckets of codes + scales.
        let nq = self.m_scales.len();
        let ranges = exec::chunk_ranges(nq, pool.workers());
        let bucket = self.cfg.bucket;
        let cfg = &self.cfg;
        let (mq, vq) = (&self.mq, &self.vq);
        let mut shards = Vec::with_capacity(ranges.len());
        let (mut p_rest, mut g_rest) = (params, grads);
        let (mut mc_rest, mut ms_rest) = (&mut self.m_codes[..], &mut self.m_scales[..]);
        let (mut vc_rest, mut vs_rest) = (&mut self.v_codes[..], &mut self.v_scales[..]);
        let (mut mf_rest, mut vf_rest) = (&mut self.m_f[..], &mut self.v_f[..]);
        let mut pstart = 0usize;
        for r in &ranges {
            let elems = r.len() * bucket;
            let pend = (r.end * bucket).min(self.d);
            let (p, pr) = p_rest.split_at_mut(pend - pstart);
            p_rest = pr;
            let (g, gr) = g_rest.split_at(pend - pstart);
            g_rest = gr;
            pstart = pend;
            let (mc, mcr) = mc_rest.split_at_mut(elems);
            mc_rest = mcr;
            let (ms, msr) = ms_rest.split_at_mut(r.len());
            ms_rest = msr;
            let (vc, vcr) = vc_rest.split_at_mut(elems);
            vc_rest = vcr;
            let (vs, vsr) = vs_rest.split_at_mut(r.len());
            vs_rest = vsr;
            let (mf, mfr) = mf_rest.split_at_mut(elems);
            mf_rest = mfr;
            let (vf, vfr) = vf_rest.split_at_mut(elems);
            vf_rest = vfr;
            shards.push((p, g, mc, ms, vc, vs, mf, vf));
        }
        pool.run_shards(shards, |_, (p, g, mc, ms, vc, vs, mf, vf)| {
            update_chunk(cfg, mq, vq, bc1, bc2, decay, lr, p, g, mc, ms, vc, vs, mf, vf);
        });
    }

    fn state_bytes(&self) -> usize {
        self.m_codes.len() + self.v_codes.len() + 4 * (self.m_scales.len() + self.v_scales.len())
    }

    fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adamw::{AdamW, AdamWConfig};
    use crate::optim::testutil::randvec;

    #[test]
    fn tracks_fp32_adamw() {
        let d = 512;
        let mut opt8 = AdamW8bit::new(d, AdamW8bitConfig::default());
        let mut opt32 = AdamW::new(d, AdamWConfig::default());
        let mut p8 = randvec(0, d, 1.0);
        let mut p32 = p8.clone();
        for s in 0..20 {
            let g = randvec(10 + s, d, 1.0);
            opt8.step(&mut p8, &g, 1e-3);
            opt32.step(&mut p32, &g, 1e-3);
        }
        let diff: f32 = p8.iter().zip(&p32).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt();
        let norm: f32 = p32.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(diff / norm < 0.01, "rel {}", diff / norm);
    }

    #[test]
    fn state_is_quarter_of_fp32() {
        let d = 4096;
        let opt8 = AdamW8bit::new(d, AdamW8bitConfig::default());
        let opt32 = AdamW::new(d, AdamWConfig::default());
        let ratio = opt8.state_bytes() as f64 / opt32.state_bytes() as f64;
        assert!(ratio < 0.27, "{ratio}");
    }

    #[test]
    fn converges_on_quadratic() {
        let d = 512;
        let mut opt = AdamW8bit::new(d, AdamW8bitConfig::default());
        let mut x = randvec(5, d, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..300 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.02);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        // 8-bit state quantization has a noise floor; 0.25x contraction in
        // 300 steps is the fp32 trajectory up to that floor.
        assert!(n1 < 0.25 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn sharded_step_matches_sequential_bitwise() {
        let d = 1000; // padded to 1024: last shard owns the padded tail
        for workers in [1usize, 2, 3, 4] {
            let mut seq = AdamW8bit::new(d, AdamW8bitConfig::default());
            let mut par = AdamW8bit::new(d, AdamW8bitConfig::default());
            let pool = ExecPool::new(workers);
            let mut ps = randvec(40, d, 1.0);
            let mut pp = ps.clone();
            for s in 0..5 {
                let g = randvec(50 + s, d, 1.0);
                seq.step(&mut ps, &g, 1e-2);
                par.step_sharded(&mut pp, &g, 1e-2, &pool);
            }
            assert_eq!(ps, pp, "workers={workers}");
        }
    }

    #[test]
    fn handles_non_bucket_multiple_dimension() {
        let mut opt = AdamW8bit::new(300, AdamW8bitConfig::default());
        let mut x = randvec(6, 300, 1.0);
        for _ in 0..10 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.01);
        }
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
