//! AdaFactor baseline (Shazeer & Stern 2018): factorized second moments.
//!
//! 2-D tensors keep row/column statistics `R`/`C` instead of a dense `v`
//! (sublinear state); 1-D tensors fall back to a dense second moment. No
//! first moment (the memory-saving configuration), RMS update clipping.

use super::Optimizer;
use crate::coordinator::layout::TensorSpec;

#[derive(Debug, Clone, Copy)]
pub struct AdaFactorConfig {
    pub beta2: f32,
    pub eps1: f32,
    /// RMS clip threshold `d` from the paper.
    pub clip: f32,
}

impl Default for AdaFactorConfig {
    fn default() -> Self {
        Self { beta2: 0.999, eps1: 1e-30, clip: 1.0 }
    }
}

enum State {
    Factored { rows: usize, cols: usize, offset: usize, r: Vec<f32>, c: Vec<f32> },
    Dense { offset: usize, len: usize, v: Vec<f32> },
}

/// AdaFactor over a flat vector with tensor shape metadata.
pub struct AdaFactor {
    cfg: AdaFactorConfig,
    d: usize,
    states: Vec<State>,
    t: u64,
}

impl AdaFactor {
    pub fn new(d: usize, specs: Vec<TensorSpec>, cfg: AdaFactorConfig) -> Self {
        let mut states = Vec::new();
        let mut covered = 0usize;
        for s in &specs {
            if let Some((rows, cols)) = s.as_matrix() {
                states.push(State::Factored {
                    rows,
                    cols,
                    offset: s.offset,
                    r: vec![0.0; rows],
                    c: vec![0.0; cols],
                });
            } else {
                states.push(State::Dense { offset: s.offset, len: s.size(), v: vec![0.0; s.size()] });
            }
            covered = covered.max(s.offset + s.size());
        }
        // Parameters not covered by any spec (e.g. padding) get one dense
        // tail state so the optimizer is total over the flat vector.
        if covered < d {
            states.push(State::Dense { offset: covered, len: d - covered, v: vec![0.0; d - covered] });
        }
        Self { cfg, d, states, t: 0 }
    }
}

impl Optimizer for AdaFactor {
    fn name(&self) -> String {
        "AdaFactor".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.d);
        self.t += 1;
        let cfg = self.cfg;
        for st in &mut self.states {
            match st {
                State::Factored { rows, cols, offset, r, c } => {
                    let (rows, cols, offset) = (*rows, *cols, *offset);
                    let g = &grads[offset..offset + rows * cols];
                    // update row/col stats of g^2 + eps1
                    for i in 0..rows {
                        let mut acc = 0f32;
                        for j in 0..cols {
                            let v = g[i * cols + j];
                            acc += v * v + cfg.eps1;
                        }
                        r[i] = cfg.beta2 * r[i] + (1.0 - cfg.beta2) * (acc / cols as f32);
                    }
                    for j in 0..cols {
                        let mut acc = 0f32;
                        for i in 0..rows {
                            let v = g[i * cols + j];
                            acc += v * v + cfg.eps1;
                        }
                        c[j] = cfg.beta2 * c[j] + (1.0 - cfg.beta2) * (acc / rows as f32);
                    }
                    let r_mean = r.iter().sum::<f32>() / rows as f32;
                    // u = g / sqrt(R C / mean R); then RMS clip
                    let mut rms = 0f32;
                    let mut u = vec![0f32; rows * cols];
                    for i in 0..rows {
                        for j in 0..cols {
                            let v = (r[i] * c[j] / r_mean.max(cfg.eps1)).max(cfg.eps1);
                            let ui = g[i * cols + j] / v.sqrt();
                            rms += ui * ui;
                            u[i * cols + j] = ui;
                        }
                    }
                    let rms = (rms / (rows * cols) as f32).sqrt();
                    let scale = 1.0 / (rms / cfg.clip).max(1.0);
                    let p = &mut params[offset..offset + rows * cols];
                    for (pi, ui) in p.iter_mut().zip(&u) {
                        *pi -= lr * scale * ui;
                    }
                }
                State::Dense { offset, len, v } => {
                    let (offset, len) = (*offset, *len);
                    let g = &grads[offset..offset + len];
                    let p = &mut params[offset..offset + len];
                    for i in 0..len {
                        v[i] = cfg.beta2 * v[i] + (1.0 - cfg.beta2) * (g[i] * g[i] + cfg.eps1);
                        p[i] -= lr * g[i] / v[i].sqrt().max(cfg.eps1);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| match s {
                State::Factored { r, c, .. } => 4 * (r.len() + c.len()),
                State::Dense { v, .. } => 4 * v.len(),
            })
            .sum()
    }

    fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::randvec;

    #[test]
    fn factored_state_is_sublinear() {
        let specs = vec![TensorSpec::new("w", &[64, 64], 0)];
        let opt = AdaFactor::new(4096, specs, AdaFactorConfig::default());
        // 64 + 64 floats instead of 4096
        assert_eq!(opt.state_bytes(), 4 * 128);
    }

    #[test]
    fn converges_on_quadratic_matrix() {
        let specs = vec![TensorSpec::new("w", &[16, 16], 0)];
        let mut opt = AdaFactor::new(256, specs, AdaFactorConfig::default());
        let mut x = randvec(0, 256, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..300 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.05);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.3 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn uncovered_tail_is_still_optimized() {
        // spec covers only first 64 of 128 params
        let specs = vec![TensorSpec::new("w", &[8, 8], 0)];
        let mut opt = AdaFactor::new(128, specs, AdaFactorConfig::default());
        let mut x = vec![1.0f32; 128];
        for _ in 0..100 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.05);
        }
        assert!(x[100].abs() < 0.9, "tail coord did not move: {}", x[100]);
    }
}
