//! LDAdam baseline (Robert et al. 2024): Adam with low-rank projected
//! moments, projection-aware moment rotation, and generalized error
//! feedback.
//!
//! Where GaLore projects per-tensor and discards what the subspace misses,
//! LDAdam (a) refreshes the subspace every `update_every` steps and
//! *rotates* the existing moments into the new subspace (`m <- m·C`,
//! `v <- v·(C∘C)` with `C = P_oldᵀ P_new`), so optimizer memory survives the
//! refresh, and (b) keeps a generalized error-feedback accumulator of
//! everything the projection dropped, folded into the next gradient.
//!
//! This implementation instantiates LDAdam on the repo's block-major
//! substrate: the flat vector is cut into `block`-sized blocks (padded
//! tail, same convention as MicroAdam), each block is viewed as a
//! `rows × cols` matrix, and a per-block projector `P (cols × r)`
//! compresses each row to rank `r`. The EF residual `e = a − (aP)Pᵀ`
//! reuses the paper's [`Quant4`] compressor — 4 bits per parameter, the
//! same kernels and bucket layout as MicroAdam's EF — so the resident cost
//! is `0.5·d` EF bytes plus `4·d·r·(1/rows + 2/cols)` bytes of
//! projector + projected moments (≈ 1.25 B/param at the defaults).
//!
//! Sharding: blocks are fully independent within a step and the projector
//! refresh draws from a per-`(block, t)` seeded RNG stream, so the fused
//! path carves whole blocks across workers and is bit-identical to the
//! sequential oracle at every worker count.

use super::{OptSnapshot, Optimizer};
use crate::exec::{self, ExecPool};
use crate::linalg;
use crate::quant::{BucketStats, Quant4};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy)]
pub struct LdAdamConfig {
    /// Projection rank `r` per block-row.
    pub rank: usize,
    /// Subspace refresh interval (the paper interleaves the subspace update
    /// with descent every step; 1 reproduces that).
    pub update_every: u64,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Block size (flat-vector partition; padded tail like MicroAdam).
    pub block: usize,
    /// Row width inside a block: each block is a `(block/cols) × cols`
    /// matrix and the projector compresses `cols -> rank` per row.
    pub cols: usize,
    /// Quant4 bucket for the EF residual store.
    pub qbucket: usize,
    /// Base seed for the per-(block, step) refresh sketch streams.
    pub seed: u64,
}

impl Default for LdAdamConfig {
    fn default() -> Self {
        Self {
            rank: 4,
            update_every: 1,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            block: crate::BLOCK,
            cols: 64,
            qbucket: crate::QBUCKET,
            seed: 0x1dada,
        }
    }
}

/// Host-side copy of the LDAdam state (checkpoint payload). Per-block
/// projector/moment matrices are flattened in block order.
#[derive(Debug, Clone, PartialEq)]
pub struct LdAdamSnapshot {
    /// Concatenated per-block projectors (`nb · cols · r` values).
    pub proj: Vec<f32>,
    /// Concatenated projected first moments (`nb · rows · r`).
    pub m: Vec<f32>,
    /// Concatenated projected second moments (`nb · rows · r`).
    pub v: Vec<f32>,
    /// Packed 4-bit EF residual codes (`d_pad / 2` bytes).
    pub ef: Vec<u8>,
    /// EF bucket minima (one per Quant4 bucket).
    pub qlo: Vec<f32>,
    /// EF bucket maxima (same length as `qlo`).
    pub qhi: Vec<f32>,
    /// Step counter.
    pub t: u64,
}

/// Resolved block geometry (what the constructor clamped the config to).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LdGeometry {
    pub block: usize,
    pub cols: usize,
    pub rows: usize,
    pub rank: usize,
    pub n_blocks: usize,
    pub qbucket: usize,
}

struct BlockState {
    /// Projector, row-major `cols × r`, orthonormal columns (zero columns
    /// where the sketch was rank-deficient).
    p: Vec<f32>,
    /// Projected Adam moments, row-major `rows × r`.
    m: Vec<f32>,
    v: Vec<f32>,
}

/// LDAdam over a flat vector, block-major.
pub struct LdAdam {
    cfg: LdAdamConfig,
    d: usize,
    geom: LdGeometry,
    blocks: Vec<BlockState>,
    quant: Quant4,
    /// Packed EF codes, `d_pad/2` bytes, block-aligned.
    ef_packed: Vec<u8>,
    /// EF bucket stats (buckets never straddle a block: qbucket | block).
    ef_stats: Vec<BucketStats>,
    /// Padded accumulator scratch (`a = g + Q⁻¹(e)`), `d_pad` elements.
    acc: Vec<f32>,
    t: u64,
}

/// Per-step immutable context handed to the block kernel.
#[derive(Clone, Copy)]
struct StepCtx {
    beta1: f32,
    beta2: f32,
    eps: f32,
    bc1: f32,
    bc2: f32,
    decay: f32,
    lr: f32,
    t: u64,
    update_every: u64,
    seed: u64,
    geom: LdGeometry,
}

/// Per-worker scratch; every buffer is fully overwritten per block, so
/// reuse across blocks cannot leak state between them.
struct Scratch {
    /// Block accumulator transposed (`cols × rows`) for the range finder.
    at: Vec<f32>,
    /// Projected gradient `R = A·P` (`rows × r`).
    rproj: Vec<f32>,
    /// Normalized update in the subspace (`rows × r`).
    nproj: Vec<f32>,
    /// Back-projected update (`rows × cols`).
    upd: Vec<f32>,
    /// Rotation `C = P_oldᵀ·P_new` and its elementwise square (`r × r`).
    c: Vec<f32>,
    csq: Vec<f32>,
    /// Rotated-moment temporary (`rows × r`).
    tmp: Vec<f32>,
}

impl Scratch {
    fn new(g: &LdGeometry) -> Self {
        Self {
            at: vec![0.0; g.cols * g.rows],
            rproj: vec![0.0; g.rows * g.rank],
            nproj: vec![0.0; g.rows * g.rank],
            upd: vec![0.0; g.rows * g.cols],
            c: vec![0.0; g.rank * g.rank],
            csq: vec![0.0; g.rank * g.rank],
            tmp: vec![0.0; g.rows * g.rank],
        }
    }
}

/// One worker's carve: a contiguous run of whole blocks plus the matching
/// element spans of every per-element buffer.
struct LdShard<'a> {
    /// Global index of this shard's first block (refresh RNG stream key).
    gb0: usize,
    blocks: &'a mut [BlockState],
    params: &'a mut [f32],
    grads: &'a [f32],
    acc: &'a mut [f32],
    packed: &'a mut [u8],
    stats: &'a mut [BucketStats],
}

impl LdAdam {
    pub fn new(d: usize, cfg: LdAdamConfig) -> Self {
        assert!(d > 0, "ldadam: empty parameter vector");
        let cols_req = cfg.cols.clamp(1, cfg.block.max(1));
        // Small problems collapse to a single block padded to a row
        // boundary; big ones keep the configured block size.
        let block =
            if d >= cfg.block { cfg.block } else { crate::pad_up(d, cols_req) };
        let mut cols = cols_req.min(block);
        while block % cols != 0 {
            cols -= 1;
        }
        let rows = block / cols;
        let rank = cfg.rank.clamp(1, rows.min(cols));
        assert!(block % 2 == 0, "ldadam: block must be even for 4-bit packing, got {block}");
        let mut qbucket = cfg.qbucket.clamp(2, block);
        if qbucket % 2 != 0 {
            qbucket += 1;
        }
        while block % qbucket != 0 {
            qbucket -= 2;
            assert!(qbucket >= 2, "ldadam: no even qbucket divides block {block}");
        }
        let d_pad = crate::pad_up(d, block);
        let nb = d_pad / block;
        let geom = LdGeometry { block, cols, rows, rank, n_blocks: nb, qbucket };
        let blocks = (0..nb)
            .map(|_| BlockState {
                p: vec![0.0; cols * rank],
                m: vec![0.0; rows * rank],
                v: vec![0.0; rows * rank],
            })
            .collect();
        Self {
            cfg,
            d,
            geom,
            blocks,
            quant: Quant4::new(qbucket),
            ef_packed: vec![0; d_pad / 2],
            ef_stats: vec![BucketStats { lo: 0.0, hi: 0.0 }; d_pad / qbucket],
            acc: vec![0.0; d_pad],
            t: 0,
        }
    }

    /// The geometry the constructor resolved (after clamping).
    pub fn geometry(&self) -> LdGeometry {
        self.geom
    }

    /// Per-block projector, row-major `cols × r`.
    pub fn projector(&self, b: usize) -> &[f32] {
        &self.blocks[b].p
    }

    /// L2 norm of the dequantized EF residual (bookkeeping diagnostic).
    pub fn ef_norm(&self) -> f32 {
        self.quant.l2_norm(&self.ef_packed, &self.ef_stats)
    }

    /// `‖E·P‖_F / ‖E‖_F` over all blocks: how much of the stored residual
    /// leaks back into the learning subspace. The exact residual is
    /// orthogonal to `P` by construction, so this measures pure Quant4
    /// noise and stays well below 1.
    pub fn ef_projection_ratio(&self) -> f32 {
        let g = self.geom;
        let mut e = vec![0f32; self.acc.len()];
        self.quant.dequantize(&self.ef_packed, &self.ef_stats, &mut e);
        let mut num = 0f64;
        let mut den = 0f64;
        let mut ep = vec![0f32; g.rows * g.rank];
        for (b, st) in self.blocks.iter().enumerate() {
            let eb = &e[b * g.block..(b + 1) * g.block];
            linalg::matmul(eb, &st.p, &mut ep, g.rows, g.cols, g.rank);
            num += ep.iter().map(|v| (v * v) as f64).sum::<f64>();
            den += eb.iter().map(|v| (v * v) as f64).sum::<f64>();
        }
        (num.sqrt() / den.sqrt().max(1e-12)) as f32
    }

    /// Copy the state out for checkpointing (flattened in block order).
    pub fn snapshot(&self) -> LdAdamSnapshot {
        let mut proj = Vec::with_capacity(self.blocks.len() * self.geom.cols * self.geom.rank);
        let mut m = Vec::with_capacity(self.blocks.len() * self.geom.rows * self.geom.rank);
        let mut v = Vec::with_capacity(m.capacity());
        for b in &self.blocks {
            proj.extend_from_slice(&b.p);
            m.extend_from_slice(&b.m);
            v.extend_from_slice(&b.v);
        }
        LdAdamSnapshot {
            proj,
            m,
            v,
            ef: self.ef_packed.clone(),
            qlo: self.ef_stats.iter().map(|s| s.lo).collect(),
            qhi: self.ef_stats.iter().map(|s| s.hi).collect(),
            t: self.t,
        }
    }

    /// Load a snapshot back. Fails (typed, no panic) on geometry mismatch.
    pub fn restore(&mut self, s: &LdAdamSnapshot) -> Result<()> {
        let g = self.geom;
        let (plen, mlen) = (g.n_blocks * g.cols * g.rank, g.n_blocks * g.rows * g.rank);
        if s.proj.len() != plen || s.m.len() != mlen || s.v.len() != mlen {
            bail!(
                "ldadam snapshot geometry mismatch: proj {} vs {plen}, m {} / v {} vs {mlen}",
                s.proj.len(),
                s.m.len(),
                s.v.len()
            );
        }
        if s.ef.len() != self.ef_packed.len()
            || s.qlo.len() != self.ef_stats.len()
            || s.qhi.len() != self.ef_stats.len()
        {
            bail!(
                "ldadam snapshot EF geometry mismatch: ef {} vs {}, stats {}/{} vs {}",
                s.ef.len(),
                self.ef_packed.len(),
                s.qlo.len(),
                s.qhi.len(),
                self.ef_stats.len()
            );
        }
        for (b, st) in self.blocks.iter_mut().enumerate() {
            let (pl, ml) = (g.cols * g.rank, g.rows * g.rank);
            st.p.copy_from_slice(&s.proj[b * pl..(b + 1) * pl]);
            st.m.copy_from_slice(&s.m[b * ml..(b + 1) * ml]);
            st.v.copy_from_slice(&s.v[b * ml..(b + 1) * ml]);
        }
        self.ef_packed.copy_from_slice(&s.ef);
        for (st, (&lo, &hi)) in self.ef_stats.iter_mut().zip(s.qlo.iter().zip(&s.qhi)) {
            *st = BucketStats { lo, hi };
        }
        self.t = s.t;
        Ok(())
    }

    /// The one step path: sequential when `pool` is `None` or the carve is
    /// a single range, sharded otherwise. Both run the identical per-block
    /// kernel over the identical carve, so the bits cannot diverge.
    fn fused(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: Option<&ExecPool>) {
        assert_eq!(params.len(), self.d);
        assert_eq!(grads.len(), self.d);
        self.t += 1;
        let cfg = self.cfg;
        let geom = self.geom;
        let ctx = StepCtx {
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            bc1: 1.0 - cfg.beta1.powi(self.t as i32),
            bc2: 1.0 - cfg.beta2.powi(self.t as i32),
            decay: 1.0 - lr * cfg.weight_decay,
            lr,
            t: self.t,
            update_every: cfg.update_every.max(1),
            seed: cfg.seed,
            geom,
        };
        let workers = pool.map_or(1, |p| p.workers());
        let ranges = exec::chunk_ranges(geom.n_blocks, workers);
        let quant = self.quant.clone();
        let (block, qb) = (geom.block, geom.qbucket);
        let mut shards = Vec::with_capacity(ranges.len());
        let (mut p_rest, mut g_rest) = (params, grads);
        let mut b_rest = &mut self.blocks[..];
        let mut a_rest = &mut self.acc[..];
        let mut k_rest = &mut self.ef_packed[..];
        let mut s_rest = &mut self.ef_stats[..];
        let mut elem_off = 0usize;
        for r in &ranges {
            let elem_end = (r.end * block).min(self.d);
            let n = elem_end - elem_off;
            let (p, pr) = p_rest.split_at_mut(n);
            p_rest = pr;
            let (gs, gr) = g_rest.split_at(n);
            g_rest = gr;
            let (bs, br) = b_rest.split_at_mut(r.len());
            b_rest = br;
            let (a, ar) = a_rest.split_at_mut(r.len() * block);
            a_rest = ar;
            let (kk, kr) = k_rest.split_at_mut(r.len() * block / 2);
            k_rest = kr;
            let (ss, sr) = s_rest.split_at_mut(r.len() * block / qb);
            s_rest = sr;
            shards.push(LdShard {
                gb0: r.start,
                blocks: bs,
                params: p,
                grads: gs,
                acc: a,
                packed: kk,
                stats: ss,
            });
            elem_off = elem_end;
        }
        match pool {
            Some(pool) if shards.len() > 1 => {
                pool.run_shards(shards, |_, sh| run_shard(ctx, &quant, sh));
            }
            _ => {
                for sh in shards {
                    run_shard(ctx, &quant, sh);
                }
            }
        }
    }
}

/// Deterministic per-(block, refresh-step) sketch stream: independent of
/// worker count and shard assignment, so refreshes cannot couple blocks.
fn refresh_seed(seed: u64, gb: usize, t: u64) -> u64 {
    seed ^ (gb as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ t.wrapping_mul(0xd1b5_4a32_d192_ed03)
}

fn run_shard(ctx: StepCtx, quant: &Quant4, mut sh: LdShard<'_>) {
    let g = ctx.geom;
    let mut scr = Scratch::new(&g);
    let mut off = 0usize;
    for (k, st) in sh.blocks.iter_mut().enumerate() {
        let end = (off + g.block).min(sh.params.len());
        step_block(
            ctx,
            quant,
            sh.gb0 + k,
            st,
            &mut sh.params[off..end],
            &sh.grads[off..end],
            &mut sh.acc[k * g.block..(k + 1) * g.block],
            &mut sh.packed[k * g.block / 2..(k + 1) * g.block / 2],
            &mut sh.stats[k * g.block / g.qbucket..(k + 1) * g.block / g.qbucket],
            &mut scr,
        );
        off = end;
    }
}

/// One block's full LDAdam step: EF accumulate, (optional) subspace refresh
/// with moment rotation, project, Adam in the subspace, back-project, and
/// re-compress the new residual. Entirely sequential and self-contained —
/// the unit of bit-exact sharding.
#[allow(clippy::too_many_arguments)]
fn step_block(
    ctx: StepCtx,
    quant: &Quant4,
    gb: usize,
    st: &mut BlockState,
    params: &mut [f32],
    grads: &[f32],
    acc: &mut [f32],
    packed: &mut [u8],
    stats: &mut [BucketStats],
    scr: &mut Scratch,
) {
    let g = ctx.geom;
    let (rows, cols, r) = (g.rows, g.cols, g.rank);
    // a = g + Q⁻¹(e); padded-tail coords carry zero gradient.
    acc.fill(0.0);
    acc[..grads.len()].copy_from_slice(grads);
    quant.dequantize_add(packed, stats, acc);
    if (ctx.t - 1) % ctx.update_every == 0 {
        // Refresh the subspace from the accumulator: P spans the top-r row
        // space of A (range of Aᵀ).
        for i in 0..rows {
            for j in 0..cols {
                scr.at[j * rows + i] = acc[i * cols + j];
            }
        }
        let mut rng = Rng::seed_from_u64(refresh_seed(ctx.seed, gb, ctx.t));
        let pnew = linalg::randomized_range_finder(&scr.at, cols, rows, r, 1, &mut rng);
        // Projection-aware moment rotation (the LDAdam step that GaLore
        // lacks): carry m into the new subspace via C = P_oldᵀ P_new, and
        // v via C∘C (the paper's nonnegative second-moment surrogate).
        linalg::matmul_tn(&st.p, &pnew, &mut scr.c, cols, r, r);
        for (cs, &cv) in scr.csq.iter_mut().zip(&scr.c) {
            *cs = cv * cv;
        }
        linalg::matmul(&st.m, &scr.c, &mut scr.tmp, rows, r, r);
        st.m.copy_from_slice(&scr.tmp);
        linalg::matmul(&st.v, &scr.csq, &mut scr.tmp, rows, r, r);
        st.v.copy_from_slice(&scr.tmp);
        st.p.copy_from_slice(&pnew);
    }
    // Project: R = A·P (rows × r).
    linalg::matmul(acc, &st.p, &mut scr.rproj, rows, cols, r);
    // Adam in the subspace.
    for i in 0..rows * r {
        st.m[i] = ctx.beta1 * st.m[i] + (1.0 - ctx.beta1) * scr.rproj[i];
        st.v[i] = ctx.beta2 * st.v[i] + (1.0 - ctx.beta2) * scr.rproj[i] * scr.rproj[i];
        scr.nproj[i] = (st.m[i] / ctx.bc1) / ((st.v[i] / ctx.bc2).sqrt() + ctx.eps);
    }
    // Back-project the update U = N·Pᵀ and the reconstruction R·Pᵀ in one
    // pass; the accumulator becomes the new residual e = a − (aP)Pᵀ.
    for i in 0..rows {
        for j in 0..cols {
            let mut u = 0f32;
            let mut rec = 0f32;
            for k in 0..r {
                u += scr.nproj[i * r + k] * st.p[j * r + k];
                rec += scr.rproj[i * r + k] * st.p[j * r + k];
            }
            scr.upd[i * cols + j] = u;
            acc[i * cols + j] -= rec;
        }
    }
    // Apply to the real (unpadded) coordinates only.
    for (pi, &ui) in params.iter_mut().zip(scr.upd.iter()) {
        *pi = ctx.decay * *pi - ctx.lr * ui;
    }
    // Compress the residual back into the 4-bit EF store.
    quant.quantize(acc, packed, stats);
}

impl Optimizer for LdAdam {
    fn name(&self) -> String {
        format!("LDAdam(r={})", self.geom.rank)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.fused(params, grads, lr, None);
    }

    fn step_sharded(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: &ExecPool) {
        self.fused(params, grads, lr, Some(pool));
    }

    /// Resident bytes: f32 projectors + projected moments, packed EF codes,
    /// and the f32 EF bucket stats. The padded accumulator is step scratch
    /// (like the gradient buffer), not persistent state.
    fn state_bytes(&self) -> usize {
        let dense: usize = self.blocks.iter().map(|b| b.p.len() + b.m.len() + b.v.len()).sum();
        4 * dense + self.ef_packed.len() + self.ef_stats.len() * BucketStats::BYTES
    }

    /// Paper accounting: `0.5·d_pad` EF bytes + f32 projector/moments —
    /// `d/2 + 4·d·r·(1/rows + 2/cols)` bytes. The f32 bucket stats are
    /// honest implementation overhead, as in MicroAdam's accounting.
    fn paper_state_bytes(&self) -> usize {
        let dense: usize = self.blocks.iter().map(|b| b.p.len() + b.m.len() + b.v.len()).sum();
        4 * dense + self.ef_packed.len()
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn snapshot_state(&self) -> Option<OptSnapshot> {
        Some(OptSnapshot::LdAdam(self.snapshot()))
    }

    fn restore_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        match snap {
            OptSnapshot::LdAdam(s) => self.restore(s),
            other => bail!("ldadam cannot restore a {} snapshot", other.kind_name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::randvec;

    /// Genuinely low-rank geometry: 8×8 blocks at rank 2, so the EF
    /// residual carries real mass.
    fn small_cfg() -> LdAdamConfig {
        LdAdamConfig {
            rank: 2,
            block: 64,
            cols: 8,
            qbucket: 16,
            update_every: 4,
            ..Default::default()
        }
    }

    #[test]
    fn geometry_resolves_and_clamps() {
        let opt = LdAdam::new(1000, small_cfg());
        let g = opt.geometry();
        assert_eq!(g, LdGeometry { block: 64, cols: 8, rows: 8, rank: 2, n_blocks: 16, qbucket: 16 });
        // small d collapses to one padded block
        let tiny = LdAdam::new(10, LdAdamConfig::default());
        let tg = tiny.geometry();
        assert_eq!(tg.n_blocks, 1);
        assert_eq!(tg.block % tg.cols, 0);
        assert!(tg.rank <= tg.rows.min(tg.cols));
    }

    #[test]
    fn sharded_step_matches_sequential_bitwise() {
        let d = 1000; // padded tail: 15 full blocks + 40 real elements in the last
        for workers in [1usize, 2, 4, 8] {
            let mut seq = LdAdam::new(d, small_cfg());
            let mut par = LdAdam::new(d, small_cfg());
            let pool = ExecPool::new(workers);
            let mut ps = randvec(20, d, 1.0);
            let mut pp = ps.clone();
            for s in 0..6 {
                let g = randvec(30 + s, d, 1.0);
                seq.step(&mut ps, &g, 1e-2);
                par.step_sharded(&mut pp, &g, 1e-2, &pool);
            }
            assert_eq!(ps, pp, "workers={workers}");
            assert_eq!(seq.snapshot(), par.snapshot(), "workers={workers}");
        }
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = LdAdam::new(256, LdAdamConfig::default());
        let mut x = randvec(1, 256, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..400 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.02);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.2 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn projector_columns_are_orthonormal() {
        let d = 256;
        let mut opt = LdAdam::new(d, small_cfg());
        let mut x = randvec(2, d, 1.0);
        for s in 0..5 {
            let g = randvec(40 + s, d, 1.0);
            opt.step(&mut x, &g, 1e-2);
        }
        let geo = opt.geometry();
        for b in 0..geo.n_blocks {
            let p = opt.projector(b);
            assert_eq!(p.len(), geo.cols * geo.rank);
            for j in 0..geo.rank {
                for k in 0..=j {
                    let mut dot = 0f32;
                    for i in 0..geo.cols {
                        dot += p[i * geo.rank + j] * p[i * geo.rank + k];
                    }
                    let expect = if j == k { 1.0 } else { 0.0 };
                    assert!((dot - expect).abs() < 1e-3, "block {b} col {j}x{k}: {dot}");
                }
            }
        }
    }

    #[test]
    fn ef_residual_is_nearly_orthogonal_to_subspace() {
        // The exact residual is orthogonal to P by construction; what is
        // stored is its Quant4 image, so only quantization noise can leak
        // into the subspace. The leak ratio must stay far below 1.
        let d = 512;
        let mut opt = LdAdam::new(d, small_cfg());
        let mut x = randvec(3, d, 1.0);
        for s in 0..8 {
            let g = randvec(60 + s, d, 1.0);
            opt.step(&mut x, &g, 1e-2);
        }
        assert!(opt.ef_norm() > 0.0, "rank-2 of 8 rows must leave residual mass");
        let ratio = opt.ef_projection_ratio();
        assert!(ratio < 0.5, "subspace leak {ratio}");
    }

    #[test]
    fn state_bytes_match_documented_formula() {
        // d = 4096 at the defaults: one 64×64 block, r=4.
        let opt = LdAdam::new(4096, LdAdamConfig::default());
        let g = opt.geometry();
        assert_eq!((g.rows, g.cols, g.rank), (64, 64, 4));
        let dense_f32 = g.n_blocks * (g.cols * g.rank + 2 * g.rows * g.rank);
        assert_eq!(opt.state_bytes(), 4 * dense_f32 + 4096 / 2 + (4096 / 64) * 8);
        assert_eq!(opt.paper_state_bytes(), 4 * dense_f32 + 4096 / 2);
        // ≈ 1.25 B/param at the defaults
        assert_eq!(opt.paper_state_bytes(), 5120);
    }

    #[test]
    fn snapshot_restore_continues_bit_exactly() {
        let d = 300;
        let mut a = LdAdam::new(d, small_cfg());
        let mut xa = randvec(4, d, 1.0);
        for s in 0..5 {
            let g = randvec(70 + s, d, 1.0);
            a.step(&mut xa, &g, 1e-2);
        }
        let snap = a.snapshot();
        let mut b = LdAdam::new(d, small_cfg());
        b.restore(&snap).unwrap();
        let mut xb = xa.clone();
        for s in 5..10 {
            let g = randvec(70 + s, d, 1.0);
            a.step(&mut xa, &g, 1e-2);
            b.step(&mut xb, &g, 1e-2);
        }
        assert_eq!(xa, xb);
        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn restore_rejects_mismatched_geometry() {
        let a = LdAdam::new(1000, small_cfg());
        let mut b = LdAdam::new(500, small_cfg());
        assert!(b.restore(&a.snapshot()).is_err());
        let mut c = LdAdam::new(1000, LdAdamConfig { rank: 3, ..small_cfg() });
        assert!(c.restore(&a.snapshot()).is_err());
    }
}
