//! MicroAdam — the paper's contribution, practical form (Algorithm 1).
//!
//! Per step `t`:
//! 1. `a <- g + Q^-1(e)` — decompress the 4-bit error feedback straight
//!    into the gradient accumulator (no extra dense buffer, §3.1);
//! 2. block-wise Top-K on `|a|` -> `(I_t, V_t)`; zero the selected entries;
//! 3. quantize the remainder back into the 4-bit EF (`Q`, Algorithm 2);
//! 4. write `(I_t, V_t)` into row `(t-1) % m` of the sliding window `G`,
//!    with `V` stored physically in **bf16** (the paper's 2 B/value
//!    accounting made real — selection still ranks on f32 magnitudes,
//!    see [`crate::topk::topk_abs_block_bf16`]);
//! 5. recompute `m_hat`/`v_hat` densely *per block* from the window
//!    (ADAMSTATS, widening each stored value back to f32) and update
//!    `theta <- (1 - lr*wd) theta - lr m_hat / (eps + sqrt(v_hat))`.
//!
//! Every stage is independent across the `NB` parameter blocks, which the
//! paper exploits for its GPU-efficient CUDA implementation (§3.2). The
//! step here is the CPU analogue: a **fused single pass per block** —
//! stages 1-5 run back-to-back while the block is hot in cache — executed
//! by the [`crate::exec`] engine either sequentially ([`Optimizer::step`])
//! or sharded across a persistent worker pool
//! ([`Optimizer::step_sharded`]). Both paths, at any worker count, are
//! bit-identical: blocks never share state, so partitioning them cannot
//! reassociate a single float op. The pre-fusion four-sweep implementation
//! survives as [`MicroAdam::step_reference`] for cross-checking and
//! benchmarking; it shares the window's store/accumulate kernels, so
//! reference-vs-fused stays bit-exact at **equal** window dtype, while
//! f32-vs-bf16 comparisons are tolerance-bounded (see
//! `rust/tests/test_parallel_parity.rs` and `rust/src/optim/README.md`
//! for the two parity tiers).
//!
//! Persistent state: `d/2` EF bytes + per-bucket stats + the `m x k`
//! window — the `0.5 d + 4 m k` bytes of §3.2, now in physical paper
//! dtypes (bf16 values, u16 indices).
//!
//! This implementation is cross-validated against the AOT-compiled L2 graph
//! (which routes the same math through the Pallas kernels) in
//! `rust/tests/test_artifact_parity.rs`, and the fused engine against the
//! reference sweep in `rust/tests/test_parallel_parity.rs`.

use anyhow::{bail, Result};

use super::Optimizer;
use crate::coordinator::state::MicroAdamSnapshot;
use crate::exec::{self, Arena, ExecPool};
use crate::quant::{BucketStats, Quant4};
use crate::simd::{self, Level, Policy};
use crate::topk::{topk_abs_block_bf16_with, topk_abs_block_with, SlidingWindow, WinDtype};
use crate::trace;

/// How the error-feedback accumulator is stored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EfMode {
    /// No error feedback at all ("TopK-Adam", Figure 1 middle).
    Off,
    /// Dense f32 error buffer (the Figure-1 "TopK-Adam + EF" surrogate;
    /// also the `omega = 0` / Comp-AMS setting of the theory).
    Dense,
    /// 4-bit block-quantized EF — real MicroAdam.
    Quant4,
}

#[derive(Debug, Clone, Copy)]
pub struct MicroAdamConfig {
    /// Sliding window length `m`.
    pub m: usize,
    /// Top-K block size `B_d` (clamped to the problem dimension).
    pub block: usize,
    /// Gradient density `k/d` (paper: 0.01).
    pub density: f64,
    /// EF quantization bucket `B_q`.
    pub qbucket: usize,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f32,
    pub weight_decay: f32,
    pub ef: EfMode,
    /// Physical storage dtype of the window values. [`WinDtype::Bf16`]
    /// (default) is the paper dtype; [`WinDtype::F32`] keeps the
    /// full-precision baseline for the tolerance-bounded parity tier.
    pub win_dtype: WinDtype,
    /// Kernel dispatch policy. [`Policy::Auto`] (default) resolves once at
    /// construction to the widest compiled instruction set the host
    /// supports; [`Policy::Scalar`] pins the always-compiled scalar
    /// kernels. Both produce identical bits (see [`crate::simd`]), so
    /// this is a speed knob, never a numerics knob.
    pub simd: Policy,
}

impl Default for MicroAdamConfig {
    fn default() -> Self {
        Self {
            m: crate::WINDOW,
            block: crate::BLOCK,
            density: crate::DENSITY,
            qbucket: crate::QBUCKET,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            ef: EfMode::Quant4,
            win_dtype: WinDtype::Bf16,
            simd: Policy::Auto,
        }
    }
}

/// The MicroAdam optimizer state + step logic.
pub struct MicroAdam {
    cfg: MicroAdamConfig,
    d: usize,
    /// Internally padded dimension (multiple of `block`).
    d_pad: usize,
    block: usize,
    kb: usize,
    nb: usize,
    /// Quantization buckets per block.
    bpb: usize,
    window: SlidingWindow,
    quant: Quant4,
    /// Packed 4-bit EF codes (`d_pad / 2` bytes) — Quant4 mode.
    ef_packed: Vec<u8>,
    ef_stats: Vec<BucketStats>,
    /// Dense EF — Dense mode.
    ef_dense: Vec<f32>,
    /// Accumulator `a` (padded); workers own disjoint per-shard sub-slices.
    acc: Vec<f32>,
    /// Per-worker scratch arenas (z1/z2 + Top-K select), pre-sized from
    /// the block length and kept warm across steps.
    arenas: Vec<Arena>,
    /// Kernel instruction-set level, resolved once from `cfg.simd`.
    level: Level,
    t: u64,
}

impl MicroAdam {
    pub fn new(d: usize, cfg: MicroAdamConfig) -> Self {
        assert!(d > 0);
        // Clamp block to the (even-rounded) dimension; small problems like
        // the 2-D test functions then use a single block.
        let block = cfg.block.min(crate::pad_up(d, 2));
        let d_pad = crate::pad_up(d, block);
        let nb = d_pad / block;
        let kb = crate::kb_for_block(block, cfg.density);
        // Bucket must be even, divide block.
        let mut qbucket = cfg.qbucket.min(block);
        while block % qbucket != 0 || qbucket % 2 != 0 {
            qbucket -= 1;
            assert!(qbucket >= 2, "no valid quantization bucket for block {block}");
        }
        let quant = Quant4::new(qbucket);
        let nq = d_pad / qbucket;
        let (ef_packed, ef_stats, ef_dense) = match cfg.ef {
            EfMode::Quant4 => (vec![0u8; d_pad / 2], vec![BucketStats { lo: 0.0, hi: 0.0 }; nq], Vec::new()),
            EfMode::Dense => (Vec::new(), Vec::new(), vec![0f32; d_pad]),
            EfMode::Off => (Vec::new(), Vec::new(), Vec::new()),
        };
        Self {
            cfg,
            d,
            d_pad,
            block,
            kb,
            nb,
            bpb: block / qbucket,
            window: SlidingWindow::with_dtype(cfg.m, nb, kb, cfg.win_dtype),
            quant,
            ef_packed,
            ef_stats,
            ef_dense,
            acc: vec![0.0; d_pad],
            arenas: Vec::new(),
            level: simd::resolve(cfg.simd),
            t: 0,
        }
    }

    /// The kernel instruction-set level this optimizer dispatches to
    /// (resolved once from the configured [`Policy`]).
    pub fn simd_level(&self) -> Level {
        self.level
    }

    /// Effective Top-K entries per block.
    pub fn kb(&self) -> usize {
        self.kb
    }

    /// Norm of the (dequantized) error-feedback accumulator. Streams per
    /// quantization bucket — no `O(d)` allocation per call.
    pub fn error_norm(&self) -> f32 {
        match self.cfg.ef {
            EfMode::Off => 0.0,
            EfMode::Dense => self.ef_dense.iter().map(|v| v * v).sum::<f32>().sqrt(),
            EfMode::Quant4 => self.quant.l2_norm(&self.ef_packed, &self.ef_stats),
        }
    }

    /// Fraction of coordinates moved by the last update (paper §3
    /// "Properties and Limitations" — at most `m * k / d`).
    pub fn max_update_density(&self) -> f64 {
        (self.cfg.m * self.kb * self.nb) as f64 / self.d as f64
    }

    /// Measured resident bytes of the sliding window (indices + values,
    /// from the actual buffers — 2 B/value in the default bf16 mode).
    pub fn window_state_bytes(&self) -> usize {
        self.window.state_bytes()
    }

    /// Measured resident bytes per stored window value: 2 (bf16) or 4
    /// (f32 baseline mode).
    pub fn window_value_bytes(&self) -> usize {
        self.window.value_bytes_per_entry()
    }

    /// Host-side copy of the full optimizer state for checkpointing.
    /// The window values travel as f32 — exact for bf16 storage, so the
    /// save/load round trip is bit-preserving. Only the paper
    /// configuration ([`EfMode::Quant4`]) is checkpointable.
    pub fn snapshot(&self) -> Result<MicroAdamSnapshot> {
        if self.cfg.ef != EfMode::Quant4 {
            bail!("MicroAdam snapshot covers the paper configuration (EfMode::Quant4) only");
        }
        Ok(MicroAdamSnapshot {
            ef: self.ef_packed.clone(),
            qlo: self.ef_stats.iter().map(|s| s.lo).collect(),
            qhi: self.ef_stats.iter().map(|s| s.hi).collect(),
            w_idx: self.window.idx.iter().map(|&i| i as i32).collect(),
            w_val: self.window.values_to_f32(),
            w_bf16: self.window.dtype == WinDtype::Bf16,
            t: self.t,
        })
    }

    /// Restore a [`MicroAdam::snapshot`] (checkpoint resume): the next
    /// step continues bit-exactly where the saved run left off.
    pub fn restore(&mut self, s: &MicroAdamSnapshot) -> Result<()> {
        if self.cfg.ef != EfMode::Quant4 {
            bail!("MicroAdam restore covers the paper configuration (EfMode::Quant4) only");
        }
        if s.ef.len() != self.ef_packed.len()
            || s.qlo.len() != self.ef_stats.len()
            || s.qhi.len() != self.ef_stats.len()
            || s.w_idx.len() != self.window.idx.len()
            || s.w_val.len() != self.window.entries()
        {
            bail!(
                "snapshot does not match this optimizer's geometry \
                 (d={}, m={}, k_b={})",
                self.d,
                self.cfg.m,
                self.kb
            );
        }
        if s.w_bf16 != (self.window.dtype == WinDtype::Bf16) {
            // A dtype switch would pass every length check and then round
            // (or stop rounding) the window values — a silently perturbed
            // trajectory instead of the promised bit-exact resume.
            bail!(
                "snapshot window dtype ({}) does not match this optimizer ({:?})",
                if s.w_bf16 { "bf16" } else { "f32" },
                self.window.dtype
            );
        }
        self.ef_packed.copy_from_slice(&s.ef);
        for (st, (&lo, &hi)) in self.ef_stats.iter_mut().zip(s.qlo.iter().zip(&s.qhi)) {
            *st = BucketStats { lo, hi };
        }
        for (d, &i) in self.window.idx.iter_mut().zip(&s.w_idx) {
            *d = i as u16;
        }
        self.window.set_values_from_f32(&s.w_val);
        self.window.written = s.t;
        self.t = s.t;
        Ok(())
    }

    /// The pre-fusion reference step: four full-vector sweeps (EF
    /// decompress, Top-K, re-quantize, AdamStats+update) sharing the dense
    /// accumulator. Kept verbatim-in-math as the ground truth the fused
    /// engine is tested against, and as the sequential baseline in
    /// `bench_optimizer_step`. Stores/reads the window through the same
    /// dtype-aware kernels as the fused engine, so the two are bit-exact
    /// at every window dtype.
    pub fn step_reference(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.d);
        assert_eq!(grads.len(), self.d);
        self.t += 1;
        let t = self.t;
        if self.arenas.is_empty() {
            self.arenas.push(Arena::new(self.block));
        }
        let arena = &mut self.arenas[0];
        arena.ensure(self.block);

        // Line 5: a <- g + Q^-1(e).
        self.acc[..self.d].copy_from_slice(grads);
        self.acc[self.d..].fill(0.0);
        match self.cfg.ef {
            EfMode::Off => {}
            EfMode::Dense => {
                for (a, e) in self.acc.iter_mut().zip(&self.ef_dense) {
                    *a += *e;
                }
            }
            EfMode::Quant4 => {
                self.quant.dequantize_add(&self.ef_packed, &self.ef_stats, &mut self.acc);
            }
        }

        // Lines 6-7 + 10: per-block Top-K into the window row (rounded to
        // the window dtype on store); zero outliers at full precision.
        let row = self.window.row_for_step(t);
        for b in 0..self.nb {
            let blk = b * self.block..(b + 1) * self.block;
            self.window.select_into(row, b, &self.acc[blk.clone()], &mut arena.sel);
            let accb = &mut self.acc[blk];
            for &i in self.window.idx_at(row, b) {
                accb[i as usize] = 0.0;
            }
        }
        self.window.commit_row();

        // Lines 8-9: compress what is left into the EF store.
        match self.cfg.ef {
            EfMode::Off => {}
            EfMode::Dense => self.ef_dense.copy_from_slice(&self.acc),
            EfMode::Quant4 => {
                self.quant.quantize(&self.acc, &mut self.ef_packed, &mut self.ef_stats)
            }
        }

        // Lines 11-13: dynamic AdamStats per block + parameter update. Only
        // the `valid_rows()` window rows hold data; rows beyond carry
        // weight zero anyway.
        let w1 = self.window.folded_weights(t, self.cfg.beta1);
        let w2 = self.window.folded_weights(t, self.cfg.beta2);
        let decay = 1.0 - lr * self.cfg.weight_decay;
        let valid = self.window.valid_rows();
        for b in 0..self.nb {
            let z1 = &mut arena.z1[..self.block];
            let z2 = &mut arena.z2[..self.block];
            z1.fill(0.0);
            z2.fill(0.0);
            for i in 0..valid {
                self.window.accumulate_stats(i, b, w1[i], w2[i], z1, z2);
            }
            let base = b * self.block;
            let n = self.block.min(self.d.saturating_sub(base));
            for j in 0..n {
                let u = lr * z1[j] / (self.cfg.eps + z2[j].sqrt());
                params[base + j] = decay * params[base + j] - u;
            }
        }
    }

    /// The fused engine: one pass per block (stage 1-5 back-to-back),
    /// sharded over `pool`. Bit-identical to [`MicroAdam::step_reference`]
    /// at every worker count.
    fn step_fused(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: &ExecPool) {
        assert_eq!(params.len(), self.d);
        assert_eq!(grads.len(), self.d);
        self.t += 1;
        let t = self.t;
        let row = self.window.row_for_step(t);
        // Commit up front: each worker fills the row for its own blocks
        // before reading it back in the same fused pass.
        self.window.commit_row();
        let valid = self.window.valid_rows();
        let w1 = self.window.folded_weights(t, self.cfg.beta1);
        let w2 = self.window.folded_weights(t, self.cfg.beta2);

        if trace::enabled() {
            trace::gauge(
                "optim.window_bytes_per_value",
                match self.window.dtype {
                    WinDtype::Bf16 => 2.0,
                    WinDtype::F32 => 4.0,
                },
            );
            trace::gauge("optim.state_bytes", self.state_bytes() as f64);
        }

        let nshards = pool.workers().min(self.nb);
        while self.arenas.len() < nshards {
            self.arenas.push(Arena::new(self.block));
        }
        for a in &mut self.arenas {
            a.ensure(self.block);
        }
        let ranges = exec::chunk_ranges(self.nb, nshards);

        let ctx = StepCtx {
            block: self.block,
            kb: self.kb,
            m: self.cfg.m,
            bpb: self.bpb,
            row,
            valid,
            lr,
            decay: 1.0 - lr * self.cfg.weight_decay,
            eps: self.cfg.eps,
            level: self.level,
            w1: &w1,
            w2: &w2,
            quant: &self.quant,
        };

        // The per-shard window spans come from the layout's own offset
        // math so they can never drift from the window's own indexing.
        let wspans: Vec<usize> =
            ranges.iter().map(|r| self.window.block_range(r.clone()).len()).collect();
        let geom = CarveGeom {
            block: self.block,
            bpb: self.bpb,
            d: self.d,
            ef: self.cfg.ef,
            ranges: &ranges,
            wspans: &wspans,
        };

        // NUMA first touch: when workers are pinned, have each worker
        // write every page of its own shard's state slabs once before the
        // first real pass, so the kernel's first-touch policy places those
        // pages on the owning worker's node. At t == 1 the buffers are
        // freshly allocated all-zeros (restore at t = 0 is also all-zero
        // state), so the fill never changes a value; the static shard
        // striping `run_shards` uses under pinning keeps the shard→worker
        // mapping identical between this pass and every later step.
        if t == 1 && pool.pinned() {
            let warm = carve_shards(
                geom,
                &mut *params,
                grads,
                &mut self.acc,
                &mut self.window.idx,
                match self.window.dtype {
                    WinDtype::Bf16 => WinVals::Bf16(&mut self.window.val[..]),
                    WinDtype::F32 => WinVals::F32(&mut self.window.val_f32[..]),
                },
                &mut self.ef_packed,
                &mut self.ef_stats,
                &mut self.ef_dense,
                &mut self.arenas[..nshards],
            );
            pool.run_shards(warm, |_i, sh| warm_shard(sh));
        }

        let shards = carve_shards(
            geom,
            params,
            grads,
            &mut self.acc,
            &mut self.window.idx,
            match self.window.dtype {
                WinDtype::Bf16 => WinVals::Bf16(&mut self.window.val[..]),
                WinDtype::F32 => WinVals::F32(&mut self.window.val_f32[..]),
            },
            &mut self.ef_packed,
            &mut self.ef_stats,
            &mut self.ef_dense,
            &mut self.arenas[..nshards],
        );
        pool.run_shards(shards, |i, sh| run_shard(ctx, i, sh));
    }
}

/// The carve geometry: everything [`carve_shards`] needs besides the
/// buffers themselves.
#[derive(Clone, Copy)]
struct CarveGeom<'a> {
    block: usize,
    bpb: usize,
    /// Unpadded parameter dimension.
    d: usize,
    ef: EfMode,
    /// Contiguous block ranges, one per shard.
    ranges: &'a [std::ops::Range<usize>],
    /// Window span (idx/val entries) per shard, from the layout's offset math.
    wspans: &'a [usize],
}

/// Carve every state buffer into disjoint per-shard `&mut` sub-slices.
/// Free function (not a method) so a step can carve twice — once for the
/// NUMA first-touch pass, once for the real pass — without fighting the
/// borrow checker over `&mut self`.
#[allow(clippy::too_many_arguments)]
fn carve_shards<'a>(
    geom: CarveGeom<'_>,
    params: &'a mut [f32],
    grads: &'a [f32],
    acc: &'a mut [f32],
    win_idx: &'a mut [u16],
    win_val: WinVals<'a>,
    ef_packed: &'a mut [u8],
    ef_stats: &'a mut [BucketStats],
    ef_dense: &'a mut [f32],
    arenas: &'a mut [Arena],
) -> Vec<Shard<'a>> {
    let mut p_rest = params;
    let mut g_rest = grads;
    let mut acc_rest = acc;
    let mut wi_rest = win_idx;
    let mut wv_rest = win_val;
    let mut efp_rest = ef_packed;
    let mut efs_rest = ef_stats;
    let mut efd_rest = ef_dense;
    let mut arenas = arenas.iter_mut();
    let mut shards = Vec::with_capacity(geom.ranges.len());
    let mut pstart = 0usize;
    for (r, &wspan) in geom.ranges.iter().zip(geom.wspans) {
        let nblk = r.len();
        let pend = (r.end * geom.block).min(geom.d);
        let (p, pr) = p_rest.split_at_mut(pend - pstart);
        p_rest = pr;
        let (g, gr) = g_rest.split_at(pend - pstart);
        g_rest = gr;
        pstart = pend;
        let (a, ar) = acc_rest.split_at_mut(nblk * geom.block);
        acc_rest = ar;
        let (wi, wir) = wi_rest.split_at_mut(wspan);
        wi_rest = wir;
        let (wv, wvr) = wv_rest.split_at_mut(wspan);
        wv_rest = wvr;
        let ef = match geom.ef {
            EfMode::Off => EfShard::Off,
            EfMode::Dense => {
                let (e, er) = efd_rest.split_at_mut(nblk * geom.block);
                efd_rest = er;
                EfShard::Dense(e)
            }
            EfMode::Quant4 => {
                let (pk, pkr) = efp_rest.split_at_mut(nblk * geom.block / 2);
                efp_rest = pkr;
                let (st, str_) = efs_rest.split_at_mut(nblk * geom.bpb);
                efs_rest = str_;
                EfShard::Quant4 { packed: pk, stats: st }
            }
        };
        shards.push(Shard {
            params: p,
            grads: g,
            acc: a,
            win_idx: wi,
            win_val: wv,
            ef,
            arena: arenas.next().expect("one arena per shard"),
        });
    }
    shards
}

/// NUMA first-touch pass body: write every page of the shard's mutable
/// state slabs from the worker that owns the shard. Values are untouched
/// in effect — this only runs at t == 1, when every slab is all-zeros.
fn warm_shard(sh: Shard) {
    let Shard { params: _, grads: _, acc, win_idx, win_val, ef, arena } = sh;
    acc.fill(0.0);
    win_idx.fill(0);
    match win_val {
        WinVals::Bf16(wv) => wv.fill(0),
        WinVals::F32(wv) => wv.fill(0.0),
    }
    match ef {
        EfShard::Off => {}
        EfShard::Dense(e) => e.fill(0.0),
        EfShard::Quant4 { packed, stats } => {
            packed.fill(0);
            stats.fill(BucketStats { lo: 0.0, hi: 0.0 });
        }
    }
    arena.z1.fill(0.0);
    arena.z2.fill(0.0);
}

/// Span names of the five fused stages, in pass order — the `optim.phase`
/// trace category emits exactly these per shard per step.
pub const PHASE_NAMES: [&str; 5] = ["ef_dequant", "topk", "requant", "stats", "update"];

/// Step-invariant context shared (read-only) by every worker.
#[derive(Clone, Copy)]
struct StepCtx<'a> {
    block: usize,
    kb: usize,
    m: usize,
    bpb: usize,
    row: usize,
    valid: usize,
    lr: f32,
    decay: f32,
    eps: f32,
    level: Level,
    w1: &'a [f32],
    w2: &'a [f32],
    quant: &'a Quant4,
}

/// A worker's dtype-resolved view of its window value span. Resolved once
/// per step (the dtype is fixed at construction), matched once per block
/// inside the fused pass — no per-element branching.
enum WinVals<'a> {
    Bf16(&'a mut [u16]),
    F32(&'a mut [f32]),
}

impl<'a> WinVals<'a> {
    fn split_at_mut(self, n: usize) -> (WinVals<'a>, WinVals<'a>) {
        match self {
            WinVals::Bf16(s) => {
                let (a, b) = s.split_at_mut(n);
                (WinVals::Bf16(a), WinVals::Bf16(b))
            }
            WinVals::F32(s) => {
                let (a, b) = s.split_at_mut(n);
                (WinVals::F32(a), WinVals::F32(b))
            }
        }
    }
}

/// One worker's disjoint view of the optimizer state: a contiguous run of
/// blocks across every buffer.
struct Shard<'a> {
    /// Unpadded parameter slice (the last shard may be shorter than its
    /// padded block span).
    params: &'a mut [f32],
    grads: &'a [f32],
    /// Padded accumulator slice: `n_blocks * block`.
    acc: &'a mut [f32],
    /// Block-major window history for these blocks: `n_blocks * m * kb`.
    win_idx: &'a mut [u16],
    win_val: WinVals<'a>,
    ef: EfShard<'a>,
    arena: &'a mut Arena,
}

enum EfShard<'a> {
    Off,
    Dense(&'a mut [f32]),
    Quant4 { packed: &'a mut [u8], stats: &'a mut [BucketStats] },
}

/// The fused per-block pass: for each block in the shard, run EF
/// decompress + Top-K + re-quantize + AdamStats + parameter update
/// back-to-back while the block's working set is cache-resident.
///
/// Per-phase timing goes through [`trace::PhaseAcc`]: one clock read per
/// stage boundary when tracing is on, none at all when it is off, and
/// exactly [`PHASE_NAMES`]`.len()` spans per shard per step (per-block
/// stage costs accumulate into the shard's five phase totals).
fn run_shard(ctx: StepCtx, shard_id: usize, sh: Shard) {
    let Shard { params, grads, acc, win_idx, mut win_val, mut ef, arena } = sh;
    let nb_local = acc.len() / ctx.block;
    let mut phases = trace::PhaseAcc::<5>::start();
    for bl in 0..nb_local {
        let base = bl * ctx.block;
        // valid (unpadded) element count of this block
        let n = ctx.block.min(params.len().saturating_sub(base));
        let acc_b = &mut acc[base..base + ctx.block];

        // Stage grads; pad tail with zeros (line 5, first half).
        acc_b[..n].copy_from_slice(&grads[base..base + n]);
        acc_b[n..].fill(0.0);

        // a += Q^-1(e) (line 5, second half).
        match &mut ef {
            EfShard::Off => {}
            EfShard::Dense(e) => {
                for (a, ev) in acc_b.iter_mut().zip(&e[base..base + ctx.block]) {
                    *a += *ev;
                }
            }
            EfShard::Quant4 { packed, stats } => {
                let pb = &packed[base / 2..(base + ctx.block) / 2];
                let sb = &stats[bl * ctx.bpb..(bl + 1) * ctx.bpb];
                simd::quant4_dequantize_add(ctx.level, ctx.quant, pb, sb, acc_b);
            }
        }
        phases.mark(0);

        // Top-K into the window row (rounded to the storage dtype); zero
        // the selected entries at full precision (6-7, 10).
        let wo = (bl * ctx.m + ctx.row) * ctx.kb;
        match &mut win_val {
            WinVals::Bf16(wv) => topk_abs_block_bf16_with(
                ctx.level,
                acc_b,
                ctx.kb,
                &mut win_idx[wo..wo + ctx.kb],
                &mut wv[wo..wo + ctx.kb],
                &mut arena.sel,
            ),
            WinVals::F32(wv) => topk_abs_block_with(
                ctx.level,
                acc_b,
                ctx.kb,
                &mut win_idx[wo..wo + ctx.kb],
                &mut wv[wo..wo + ctx.kb],
                &mut arena.sel,
            ),
        }
        for &i in win_idx[wo..wo + ctx.kb].iter() {
            acc_b[i as usize] = 0.0;
        }
        phases.mark(1);

        // Compress the remainder back into the EF store (8-9).
        match &mut ef {
            EfShard::Off => {}
            EfShard::Dense(e) => e[base..base + ctx.block].copy_from_slice(acc_b),
            EfShard::Quant4 { packed, stats } => {
                let pb = &mut packed[base / 2..(base + ctx.block) / 2];
                let sb = &mut stats[bl * ctx.bpb..(bl + 1) * ctx.bpb];
                simd::quant4_quantize(ctx.level, ctx.quant, acc_b, pb, sb);
            }
        }
        phases.mark(2);

        // AdamStats over this block's contiguous window history (11-12),
        // widening each stored value back to f32. These are the same
        // kernels SlidingWindow::accumulate_stats runs for the reference
        // sweep — bit-exact by construction.
        let z1 = &mut arena.z1[..ctx.block];
        let z2 = &mut arena.z2[..ctx.block];
        z1.fill(0.0);
        z2.fill(0.0);
        match &win_val {
            WinVals::Bf16(wv) => {
                for i in 0..ctx.valid {
                    let o = (bl * ctx.m + i) * ctx.kb;
                    simd::stats_accum_bf16(ctx.level, &win_idx[o..o + ctx.kb], &wv[o..o + ctx.kb], ctx.w1[i], ctx.w2[i], z1, z2);
                }
            }
            WinVals::F32(wv) => {
                for i in 0..ctx.valid {
                    let o = (bl * ctx.m + i) * ctx.kb;
                    simd::stats_accum_f32(ctx.level, &win_idx[o..o + ctx.kb], &wv[o..o + ctx.kb], ctx.w1[i], ctx.w2[i], z1, z2);
                }
            }
        }
        phases.mark(3);

        // Parameter update (13) — lane-parallel `m̂/(ε+√v̂)` under the
        // vector instantiations, same float-op chain at every level.
        simd::adam_update(ctx.level, &mut params[base..base + n], &z1[..n], &z2[..n], ctx.lr, ctx.eps, ctx.decay);
        phases.mark(4);
    }
    phases.finish("optim.phase", PHASE_NAMES, shard_id as u32);
}

impl Optimizer for MicroAdam {
    fn name(&self) -> String {
        match self.cfg.ef {
            EfMode::Off => "TopK-Adam".into(),
            EfMode::Dense => "TopK-Adam+EF".into(),
            EfMode::Quant4 => format!("MicroAdam(m={})", self.cfg.m),
        }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.step_fused(params, grads, lr, &ExecPool::serial());
    }

    fn step_sharded(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: &ExecPool) {
        self.step_fused(params, grads, lr, pool);
    }

    fn state_bytes(&self) -> usize {
        let ef = match self.cfg.ef {
            EfMode::Off => 0,
            EfMode::Dense => self.ef_dense.len() * 4,
            EfMode::Quant4 => self.ef_packed.len() + self.ef_stats.len() * BucketStats::BYTES,
        };
        ef + self.window.state_bytes()
    }

    fn paper_state_bytes(&self) -> usize {
        // 0.5 B/param EF + (int16 + bf16) * m * k window = 0.5d + 4mk
        // (§3.2). In the default bf16 mode the window term now equals the
        // measured resident bytes.
        let ef = match self.cfg.ef {
            EfMode::Off => 0,
            EfMode::Dense => self.d_pad * 4,
            EfMode::Quant4 => self.d_pad / 2,
        };
        ef + self.window.entries() * 4
    }

    fn t(&self) -> u64 {
        self.t
    }

    fn snapshot_state(&self) -> Option<super::OptSnapshot> {
        // Snapshot is only defined for the paper's Quant4 EF mode; the
        // diagnostic Off/Dense modes save params-only checkpoints.
        self.snapshot().ok().map(super::OptSnapshot::MicroAdam)
    }

    fn restore_state(&mut self, snap: &super::OptSnapshot) -> Result<()> {
        match snap {
            super::OptSnapshot::MicroAdam(s) => self.restore(s),
            other => bail!("micro-adam cannot restore a {} snapshot", other.kind_name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::randvec;

    fn small_cfg() -> MicroAdamConfig {
        MicroAdamConfig { m: 4, block: 64, density: 0.05, qbucket: 16, ..Default::default() }
    }

    #[test]
    fn converges_on_quadratic() {
        let d = 256;
        let mut opt = MicroAdam::new(d, small_cfg());
        let mut x = randvec(0, d, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..300 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.05);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.25 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn fused_step_matches_reference_bitwise() {
        // The fused single-pass engine and the four-sweep reference must
        // produce the same bits, step after step, at either window dtype
        // (see also tests/test_parallel_parity.rs for the full
        // EfMode x dtype x workers grid).
        for win in [WinDtype::Bf16, WinDtype::F32] {
            for ef in [EfMode::Off, EfMode::Dense, EfMode::Quant4] {
                let d = 300; // non-multiple of block: exercises the padded tail
                let cfg = MicroAdamConfig { ef, win_dtype: win, ..small_cfg() };
                let mut fused = MicroAdam::new(d, cfg);
                let mut refr = MicroAdam::new(d, cfg);
                let mut xf = randvec(9, d, 1.0);
                let mut xr = xf.clone();
                for s in 0..12 {
                    let g = randvec(500 + s, d, 1.0);
                    fused.step(&mut xf, &g, 0.01);
                    refr.step_reference(&mut xr, &g, 0.01);
                    assert_eq!(xf, xr, "{win:?} {ef:?} step {s}");
                    assert_eq!(fused.error_norm(), refr.error_norm(), "{win:?} {ef:?} step {s}");
                }
            }
        }
    }

    #[test]
    fn scalar_policy_matches_auto_bitwise() {
        // Policy is a speed knob, never a numerics knob: the Auto path
        // (whatever level the host resolves to, including the Top-K
        // prefilter, which engages at block >= 128) must produce the same
        // bits as the pinned scalar oracle, step after step.
        let d = 2048; // 8 blocks of 256
        let cfg = MicroAdamConfig { m: 4, block: 256, density: 0.05, qbucket: 16, ..Default::default() };
        let mut auto_opt = MicroAdam::new(d, cfg);
        let mut scalar_opt = MicroAdam::new(d, MicroAdamConfig { simd: Policy::Scalar, ..cfg });
        assert_eq!(scalar_opt.simd_level(), Level::Scalar);
        let mut xa = randvec(17, d, 1.0);
        let mut xs = xa.clone();
        for s in 0..10 {
            let g = randvec(600 + s, d, 1.0);
            auto_opt.step(&mut xa, &g, 0.01);
            scalar_opt.step(&mut xs, &g, 0.01);
            assert_eq!(xa, xs, "step {s} ({:?})", auto_opt.simd_level());
            assert_eq!(auto_opt.error_norm(), scalar_opt.error_norm(), "step {s}");
        }
    }

    #[test]
    fn ef_off_diverges_from_ef_on() {
        // Error feedback must change the trajectory (Figure 1).
        let d = 128;
        let mk = |ef| {
            MicroAdam::new(d, MicroAdamConfig { ef, ..small_cfg() })
        };
        let mut a = mk(EfMode::Quant4);
        let mut b = mk(EfMode::Off);
        let mut xa = randvec(1, d, 1.0);
        let mut xb = xa.clone();
        for s in 0..20 {
            let g = randvec(100 + s, d, 1.0);
            a.step(&mut xa, &g, 0.01);
            b.step(&mut xb, &g, 0.01);
        }
        assert_ne!(xa, xb);
    }

    #[test]
    fn quant4_tracks_dense_ef() {
        // 4-bit EF must stay close to the dense-EF surrogate (the paper's
        // central claim: EF can be compressed without losing convergence).
        let d = 256;
        let mut a = MicroAdam::new(d, MicroAdamConfig { ef: EfMode::Quant4, ..small_cfg() });
        let mut b = MicroAdam::new(d, MicroAdamConfig { ef: EfMode::Dense, ..small_cfg() });
        let mut xa = randvec(2, d, 1.0);
        let mut xb = xa.clone();
        for s in 0..30 {
            let g = randvec(200 + s, d, 1.0);
            a.step(&mut xa, &g, 0.01);
            b.step(&mut xb, &g, 0.01);
        }
        let diff: f32 = xa.iter().zip(&xb).map(|(p, q)| (p - q).powi(2)).sum::<f32>().sqrt();
        let norm: f32 = xb.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(diff / norm < 0.05, "rel diff {}", diff / norm);
    }

    #[test]
    fn update_density_bounded_by_m_k() {
        let d = 256;
        let cfg = small_cfg();
        let mut opt = MicroAdam::new(d, cfg);
        let mut x = vec![0.0f32; d];
        let mut moved = vec![false; d];
        for s in 0..3 {
            let g = randvec(300 + s, d, 1.0);
            let before = x.clone();
            opt.step(&mut x, &g, 0.01);
            for i in 0..d {
                moved[i] |= x[i] != before[i];
            }
        }
        let density = moved.iter().filter(|&&m| m).count() as f64 / d as f64;
        assert!(density <= opt.max_update_density() + 1e-9, "{density}");
    }

    #[test]
    fn handles_non_multiple_dimension() {
        // d = 100 with block 64 -> padded to 128 internally.
        let mut opt = MicroAdam::new(100, small_cfg());
        let mut x = randvec(3, 100, 1.0);
        for _ in 0..50 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.05);
        }
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn handles_2d_problem() {
        // Figure-1 regime: d=2, one block, k_b=1 (50% sparsity).
        let mut opt = MicroAdam::new(2, MicroAdamConfig::default());
        assert_eq!(opt.kb(), 1);
        let mut x = vec![-0.5f32, 1.0];
        for _ in 0..10 {
            let g = vec![x[0], x[1]];
            opt.step(&mut x, &g, 0.01);
        }
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn weight_decay_contracts() {
        let mut opt = MicroAdam::new(64, MicroAdamConfig {
            weight_decay: 0.5,
            ..small_cfg()
        });
        let mut x = vec![1.0f32; 64];
        opt.step(&mut x, &vec![0.0; 64], 0.1);
        // zero grads: pure (1 - lr*wd) contraction
        assert!(x.iter().all(|&v| (v - 0.95).abs() < 1e-6));
    }

    #[test]
    fn paper_state_bytes_formula() {
        // 0.5 d + 4 m k with m=10, k = d/100.
        let d = 409600;
        let opt = MicroAdam::new(d, MicroAdamConfig::default());
        let expect = d / 2 + 4 * 10 * (d / 4096) * 41;
        assert_eq!(opt.paper_state_bytes(), expect);
    }

    #[test]
    fn resident_window_is_paper_dtype() {
        // The bf16-storage acceptance target: measured resident window
        // bytes/value is 2, and the *allocated* state now matches the
        // paper window accounting instead of doubling it.
        let d = 409600;
        let opt = MicroAdam::new(d, MicroAdamConfig::default());
        assert_eq!(opt.window_value_bytes(), 2);
        let mk = 10 * (d / 4096) * 41;
        assert_eq!(opt.window_state_bytes(), 4 * mk);
        // f32 baseline mode still reports its real (doubled) footprint
        let f32_opt = MicroAdam::new(d, MicroAdamConfig {
            win_dtype: WinDtype::F32,
            ..Default::default()
        });
        assert_eq!(f32_opt.window_value_bytes(), 4);
        assert_eq!(f32_opt.window_state_bytes(), 6 * mk);
        assert_eq!(f32_opt.paper_state_bytes(), opt.paper_state_bytes());
    }

    #[test]
    fn snapshot_restore_continues_bit_exactly() {
        let d = 300;
        let cfg = small_cfg();
        let mut a = MicroAdam::new(d, cfg);
        let mut xa = randvec(31, d, 1.0);
        for s in 0..7 {
            let g = randvec(700 + s, d, 1.0);
            a.step(&mut xa, &g, 0.01);
        }
        let snap = a.snapshot().unwrap();
        assert_eq!(snap.t, 7);
        let mut b = MicroAdam::new(d, cfg);
        b.restore(&snap).unwrap();
        let mut xb = xa.clone();
        for s in 0..5 {
            let g = randvec(900 + s, d, 1.0);
            a.step(&mut xa, &g, 0.01);
            b.step(&mut xb, &g, 0.01);
            assert_eq!(xa, xb, "step {s} after restore");
        }
        assert_eq!(a.error_norm(), b.error_norm());
    }

    #[test]
    fn restore_rejects_mismatched_geometry() {
        let a = MicroAdam::new(256, small_cfg());
        let snap = a.snapshot().unwrap();
        let mut b = MicroAdam::new(512, small_cfg());
        assert!(b.restore(&snap).is_err());
    }

    #[test]
    fn restore_rejects_window_dtype_switch() {
        // Same geometry, different window dtype: every length check passes,
        // so without the dtype marker this would silently round (or stop
        // rounding) the restored values instead of resuming bit-exactly.
        let a = MicroAdam::new(256, MicroAdamConfig { win_dtype: WinDtype::F32, ..small_cfg() });
        let snap = a.snapshot().unwrap();
        let mut b = MicroAdam::new(256, small_cfg()); // bf16 default
        assert!(b.restore(&snap).is_err());
        let mut c = MicroAdam::new(256, MicroAdamConfig { win_dtype: WinDtype::F32, ..small_cfg() });
        assert!(c.restore(&snap).is_ok());
    }

    #[test]
    fn error_norm_is_bounded_over_time() {
        // Lemma 3: ||e_t|| stays bounded when (1+omega) q < 1.
        let d = 256;
        let mut opt = MicroAdam::new(d, small_cfg());
        let mut x = vec![0.0f32; d];
        let mut max_norm = 0f32;
        for s in 0..100 {
            let g = randvec(400 + s, d, 1.0);
            opt.step(&mut x, &g, 0.001);
            max_norm = max_norm.max(opt.error_norm());
        }
        // gradients are bounded by ~sqrt(d); e must not blow up past a few
        // multiples of that.
        let gbound = (d as f32).sqrt();
        assert!(max_norm < 10.0 * gbound, "{max_norm} vs {gbound}");
    }
}
