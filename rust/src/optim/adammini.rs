//! Adam-mini baseline (Zhang et al. 2024): dense first moment, one shared
//! second-moment scalar per parameter block.
//!
//! Adam-mini's observation is that within a well-chosen parameter block the
//! per-coordinate Adam learning rates are nearly identical, so the second
//! moment can be a *single EMA of the block-mean squared gradient* instead
//! of a dense vector. State drops from Adam's 8 B/param to
//! `4·(1 + 1/B)` B/param — the memory goes almost entirely to the first
//! moment. This implementation rides the repo's block-major layout: blocks
//! are consecutive `block`-sized spans of the flat vector (the same
//! partition MicroAdam's Top-K uses), with a shorter final block when `d`
//! is not a multiple.
//!
//! Sharding: blocks are independent given the gradient, and the in-block
//! mean is a fixed-order sequential fold, so the fused path carves whole
//! blocks across workers and is bit-identical to [`AdamMini::step`] at
//! every worker count (partitioned, never reassociated).

use super::{OptSnapshot, Optimizer};
use crate::exec::{self, ExecPool};
use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy)]
pub struct AdamMiniConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Block size `B`: one shared second-moment scalar per `B` consecutive
    /// parameters. The final block is shorter when `d % B != 0`.
    pub block: usize,
}

impl Default for AdamMiniConfig {
    fn default() -> Self {
        Self {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            block: crate::BLOCK,
        }
    }
}

/// Host-side copy of the Adam-mini state (checkpoint payload).
#[derive(Debug, Clone, PartialEq)]
pub struct AdamMiniSnapshot {
    /// Dense first moment (`d` values).
    pub m: Vec<f32>,
    /// Per-block second-moment means (`ceil(d/B)` values).
    pub v: Vec<f32>,
    /// Step counter.
    pub t: u64,
}

/// Adam-mini: dense `m`, per-block scalar `v`.
pub struct AdamMini {
    cfg: AdamMiniConfig,
    m: Vec<f32>,
    /// One EMA of `mean(g^2)` per block.
    v: Vec<f32>,
    t: u64,
}

impl AdamMini {
    pub fn new(d: usize, cfg: AdamMiniConfig) -> Self {
        assert!(cfg.block >= 1, "block must be >= 1");
        let nb = d.div_ceil(cfg.block);
        Self { cfg, m: vec![0.0; d], v: vec![0.0; nb], t: 0 }
    }

    /// Number of second-moment blocks.
    pub fn n_blocks(&self) -> usize {
        self.v.len()
    }

    /// Per-step scalar factors (bias corrections, decoupled decay).
    fn factors(&self, lr: f32) -> (f32, f32, f32) {
        let c = &self.cfg;
        (
            1.0 - c.beta1.powi(self.t as i32),
            1.0 - c.beta2.powi(self.t as i32),
            1.0 - lr * c.weight_decay,
        )
    }

    /// Copy the state out for checkpointing.
    pub fn snapshot(&self) -> AdamMiniSnapshot {
        AdamMiniSnapshot { m: self.m.clone(), v: self.v.clone(), t: self.t }
    }

    /// Load a snapshot back. Fails (typed, no panic) on geometry mismatch.
    pub fn restore(&mut self, s: &AdamMiniSnapshot) -> Result<()> {
        if s.m.len() != self.m.len() || s.v.len() != self.v.len() {
            bail!(
                "adam-mini snapshot geometry mismatch: m {} vs {}, v {} vs {}",
                s.m.len(),
                self.m.len(),
                s.v.len(),
                self.v.len()
            );
        }
        self.m.copy_from_slice(&s.m);
        self.v.copy_from_slice(&s.v);
        self.t = s.t;
        Ok(())
    }
}

/// The Adam-mini update over a span of whole blocks: `v` holds this span's
/// block scalars; `params`/`grads`/`m` hold the matching elements. Shared by
/// the sequential and sharded paths so both produce identical bits. The
/// in-block `mean(g^2)` is a fixed-order sequential fold — never
/// reassociated — which is what makes whole-block sharding bit-exact.
fn update_span(
    cfg: &AdamMiniConfig,
    bc1: f32,
    bc2: f32,
    decay: f32,
    lr: f32,
    params: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
) {
    let mut off = 0usize;
    for vb in v.iter_mut() {
        let end = (off + cfg.block).min(grads.len());
        let g = &grads[off..end];
        let mut sum = 0f32;
        for &gi in g {
            sum += gi * gi;
        }
        let mean = sum / g.len() as f32;
        *vb = cfg.beta2 * *vb + (1.0 - cfg.beta2) * mean;
        let v_hat = *vb / bc2;
        let denom = v_hat.sqrt() + cfg.eps;
        for i in off..end {
            m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * grads[i];
            let m_hat = m[i] / bc1;
            params[i] = decay * params[i] - lr * m_hat / denom;
        }
        off = end;
    }
}

impl Optimizer for AdamMini {
    fn name(&self) -> String {
        format!("Adam-mini(B={})", self.cfg.block)
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let (bc1, bc2, decay) = self.factors(lr);
        update_span(&self.cfg, bc1, bc2, decay, lr, params, grads, &mut self.m, &mut self.v);
    }

    fn step_sharded(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: &ExecPool) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let (bc1, bc2, decay) = self.factors(lr);
        let nb = self.v.len();
        let ranges = exec::chunk_ranges(nb, pool.workers());
        if ranges.len() <= 1 {
            update_span(&self.cfg, bc1, bc2, decay, lr, params, grads, &mut self.m, &mut self.v);
            return;
        }
        // Carve whole blocks per shard: block b owns elements
        // [b*block, min((b+1)*block, d)), so a block-range shard owns a
        // contiguous element span and the split_at_mut chain stays linear.
        let cfg = &self.cfg;
        let d = self.m.len();
        let mut shards = Vec::with_capacity(ranges.len());
        let (mut p_rest, mut g_rest) = (params, grads);
        let (mut m_rest, mut v_rest) = (&mut self.m[..], &mut self.v[..]);
        let mut elem_off = 0usize;
        for r in &ranges {
            let elem_end = (r.end * cfg.block).min(d);
            let n = elem_end - elem_off;
            let (p, pr) = p_rest.split_at_mut(n);
            p_rest = pr;
            let (g, gr) = g_rest.split_at(n);
            g_rest = gr;
            let (m, mr) = m_rest.split_at_mut(n);
            m_rest = mr;
            let (v, vr) = v_rest.split_at_mut(r.len());
            v_rest = vr;
            shards.push((p, g, m, v));
            elem_off = elem_end;
        }
        pool.run_shards(shards, |_, (p, g, m, v)| {
            update_span(cfg, bc1, bc2, decay, lr, p, g, m, v);
        });
    }

    /// Resident bytes: f32 dense `m` + one f32 per block.
    fn state_bytes(&self) -> usize {
        4 * (self.m.len() + self.v.len())
    }

    // paper_state_bytes: the default (== state_bytes) IS the paper formula,
    // 4·(d + ceil(d/B)) — Adam-mini stores fp32 state natively.

    fn t(&self) -> u64 {
        self.t
    }

    fn snapshot_state(&self) -> Option<OptSnapshot> {
        Some(OptSnapshot::AdamMini(self.snapshot()))
    }

    fn restore_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        match snap {
            OptSnapshot::AdamMini(s) => self.restore(s),
            other => bail!("adam-mini cannot restore a {} snapshot", other.kind_name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::adamw::{AdamW, AdamWConfig};
    use crate::optim::testutil::randvec;

    fn cfg(block: usize) -> AdamMiniConfig {
        AdamMiniConfig { block, ..Default::default() }
    }

    #[test]
    fn block_one_degenerates_to_adam() {
        // With B=1 the block mean is g^2 itself, so Adam-mini degenerates to
        // bias-corrected Adam (up to one rounding in the v EMA:
        // (1-b2)*(g*g) here vs ((1-b2)*g)*g in the dense kernel).
        let d = 97;
        let mut mini = AdamMini::new(d, cfg(1));
        let mut adam = AdamW::new(d, AdamWConfig::default());
        let mut pm = randvec(11, d, 1.0);
        let mut pa = pm.clone();
        for s in 0..20 {
            let g = randvec(40 + s, d, 1.0);
            mini.step(&mut pm, &g, 1e-2);
            adam.step(&mut pa, &g, 1e-2);
        }
        for i in 0..d {
            let tol = 1e-5 * pa[i].abs().max(1.0);
            assert!((pm[i] - pa[i]).abs() <= tol, "coord {i}: {} vs {}", pm[i], pa[i]);
        }
    }

    #[test]
    fn v_is_shared_within_a_block() {
        // Constant gradient within a block => every coordinate in the block
        // receives the bit-identical update (one shared denominator).
        let block = 8;
        let d = 3 * block;
        let mut opt = AdamMini::new(d, cfg(block));
        let mut p = vec![0f32; d];
        let mut g = vec![0f32; d];
        for b in 0..3 {
            for i in 0..block {
                g[b * block + i] = (b as f32 + 1.0) * 0.3;
            }
        }
        opt.step(&mut p, &g, 0.1);
        for b in 0..3 {
            for i in 1..block {
                assert_eq!(p[b * block + i], p[b * block], "block {b} coord {i}");
            }
        }
        // different block means => different updates across blocks
        assert_ne!(p[0], p[block]);
        assert_ne!(p[block], p[2 * block]);
    }

    #[test]
    fn sharded_step_matches_sequential_bitwise() {
        let d = 1003; // 15 full blocks of 64 + a 43-element tail block
        for workers in [1usize, 2, 4, 8] {
            let mut seq = AdamMini::new(d, cfg(64));
            let mut par = AdamMini::new(d, cfg(64));
            let pool = ExecPool::new(workers);
            let mut ps = randvec(20, d, 1.0);
            let mut pp = ps.clone();
            for s in 0..5 {
                let g = randvec(30 + s, d, 1.0);
                seq.step(&mut ps, &g, 1e-2);
                par.step_sharded(&mut pp, &g, 1e-2, &pool);
            }
            assert_eq!(ps, pp, "workers={workers}");
            assert_eq!(seq.t(), par.t());
        }
    }

    #[test]
    fn state_bytes_is_paper_formula() {
        // 4·(d + ceil(d/B))
        let opt = AdamMini::new(1000, cfg(64));
        assert_eq!(opt.n_blocks(), 16);
        assert_eq!(opt.state_bytes(), 4 * (1000 + 16));
        assert_eq!(opt.paper_state_bytes(), opt.state_bytes());
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = AdamMini::new(256, cfg(32));
        let mut x = randvec(1, 256, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..400 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.02);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.05 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn snapshot_restore_continues_bit_exactly() {
        let d = 300;
        let mut a = AdamMini::new(d, cfg(64));
        let mut xa = randvec(2, d, 1.0);
        for s in 0..5 {
            let g = randvec(50 + s, d, 1.0);
            a.step(&mut xa, &g, 1e-2);
        }
        let snap = a.snapshot();
        let mut b = AdamMini::new(d, cfg(64));
        b.restore(&snap).unwrap();
        let mut xb = xa.clone();
        for s in 5..10 {
            let g = randvec(50 + s, d, 1.0);
            a.step(&mut xa, &g, 1e-2);
            b.step(&mut xb, &g, 1e-2);
        }
        assert_eq!(xa, xb);
        assert_eq!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn restore_rejects_mismatched_geometry() {
        let a = AdamMini::new(300, cfg(64));
        let mut b = AdamMini::new(301, cfg(64));
        assert!(b.restore(&a.snapshot()).is_err());
    }
}
