//! GaLore baseline (Zhao et al. 2024) + the Appendix-F error-feedback
//! variant.
//!
//! For each eligible 2-D tensor `W` (both dims > rank), the gradient is
//! projected onto a rank-`r` subspace recomputed every `update_every` steps
//! (randomized range finder instead of full SVD — same subspace property,
//! see [`crate::linalg`]); Adam moments live in the projected space.
//! Ineligible tensors fall back to dense Adam.
//!
//! With `error_feedback = true` the Appendix-F surrogate is enabled: a dense
//! per-tensor error accumulator `e <- a - proj(a)` with `a = g + e`. The
//! appendix shows this error lives in the *orthogonal complement* of the
//! learning subspace and grows linearly between subspace refreshes —
//! reproduced by `repro fig8` via [`GaLore::layer_norms`].

use super::Optimizer;
use crate::coordinator::layout::TensorSpec;
use crate::linalg;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct GaLoreConfig {
    /// Projection rank `r`.
    pub rank: usize,
    /// SVD/subspace refresh interval `T` (paper default 200).
    pub update_every: u64,
    /// GaLore scale `alpha`.
    pub scale: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Enable the Appendix-F error-feedback surrogate.
    pub error_feedback: bool,
    pub seed: u64,
}

impl Default for GaLoreConfig {
    fn default() -> Self {
        Self {
            rank: 4,
            update_every: 200,
            scale: 1.0,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            error_feedback: false,
            seed: 0,
        }
    }
}

struct Projected {
    rows: usize,
    cols: usize,
    offset: usize,
    /// Projection matrix: (rows x r) when `left`, else (cols x r).
    p: Vec<f32>,
    left: bool,
    r: usize,
    /// Adam moments in the projected space.
    m: Vec<f32>,
    v: Vec<f32>,
    /// Dense EF accumulator (error_feedback mode only).
    e: Vec<f32>,
    /// Diagnostics for Figure 8.
    last_grad_norm: f32,
    last_err_norm: f32,
}

enum State {
    Proj(Projected),
    Dense { offset: usize, len: usize, m: Vec<f32>, v: Vec<f32> },
}

/// Per-layer norm diagnostics (Figure 8).
#[derive(Debug, Clone)]
pub struct LayerNorms {
    pub name: String,
    pub grad_norm: f32,
    pub error_norm: f32,
}

/// GaLore optimizer over a flat vector with tensor metadata.
pub struct GaLore {
    cfg: GaLoreConfig,
    d: usize,
    names: Vec<String>,
    states: Vec<State>,
    rng: Rng,
    t: u64,
}

impl GaLore {
    pub fn new(d: usize, specs: Vec<TensorSpec>, cfg: GaLoreConfig) -> Self {
        let mut states = Vec::new();
        let mut names = Vec::new();
        let mut covered = 0usize;
        for s in &specs {
            names.push(s.name.clone());
            match s.as_matrix() {
                // Project (compress) the larger dimension; eligible when it
                // exceeds the rank. This also covers the paper's 2-D toy
                // problems (a (2,1) "matrix" with rank-1 projection).
                Some((rows, cols)) if rows.max(cols) > cfg.rank => {
                    let left = rows >= cols;
                    // Rank cannot exceed the short dimension (the range
                    // finder returns at most min(rows, cols) directions).
                    let r = cfg.rank.min(rows).min(cols);
                    let proj_len = if left { rows * r } else { cols * r };
                    let state_len = if left { r * cols } else { rows * r };
                    states.push(State::Proj(Projected {
                        rows,
                        cols,
                        offset: s.offset,
                        p: vec![0.0; proj_len],
                        left,
                        r,
                        m: vec![0.0; state_len],
                        v: vec![0.0; state_len],
                        e: if cfg.error_feedback { vec![0.0; rows * cols] } else { Vec::new() },
                        last_grad_norm: 0.0,
                        last_err_norm: 0.0,
                    }));
                }
                _ => states.push(State::Dense {
                    offset: s.offset,
                    len: s.size(),
                    m: vec![0.0; s.size()],
                    v: vec![0.0; s.size()],
                }),
            }
            covered = covered.max(s.offset + s.size());
        }
        if covered < d {
            names.push("<tail>".into());
            states.push(State::Dense {
                offset: covered,
                len: d - covered,
                m: vec![0.0; d - covered],
                v: vec![0.0; d - covered],
            });
        }
        Self { cfg, d, names, states, rng: Rng::seed_from_u64(cfg.seed), t: 0 }
    }

    /// Figure-8 diagnostics: last-step gradient/error norms per projected layer.
    pub fn layer_norms(&self) -> Vec<LayerNorms> {
        self.states
            .iter()
            .zip(&self.names)
            .filter_map(|(s, n)| match s {
                State::Proj(p) => Some(LayerNorms {
                    name: n.clone(),
                    grad_norm: p.last_grad_norm,
                    error_norm: p.last_err_norm,
                }),
                _ => None,
            })
            .collect()
    }
}

impl Optimizer for GaLore {
    fn name(&self) -> String {
        if self.cfg.error_feedback {
            format!("GaLore-EF(r={})", self.cfg.rank)
        } else {
            format!("GaLore(r={})", self.cfg.rank)
        }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.d);
        self.t += 1;
        let t = self.t;
        let cfg = self.cfg;
        let bc1 = 1.0 - cfg.beta1.powi(t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(t as i32);
        for st in &mut self.states {
            match st {
                State::Proj(pj) => {
                    let (rows, cols) = (pj.rows, pj.cols);
                    let g = &grads[pj.offset..pj.offset + rows * cols];
                    pj.last_grad_norm = linalg::fro_norm(g);
                    // accumulator a = g + e (EF mode) or a = g
                    let a: Vec<f32> = if cfg.error_feedback {
                        g.iter().zip(&pj.e).map(|(&gi, &ei)| gi + ei).collect()
                    } else {
                        g.to_vec()
                    };
                    // refresh projection every T steps from the accumulator
                    if (t - 1) % cfg.update_every == 0 {
                        let p = if pj.left {
                            linalg::randomized_range_finder(&a, rows, cols, pj.r, 1, &mut self.rng)
                        } else {
                            // right projection: range of a^T (cols x rows)
                            let mut at = vec![0f32; rows * cols];
                            for i in 0..rows {
                                for j in 0..cols {
                                    at[j * rows + i] = a[i * cols + j];
                                }
                            }
                            linalg::randomized_range_finder(&at, cols, rows, pj.r, 1, &mut self.rng)
                        };
                        pj.p = p;
                    }
                    // project: left -> R = P^T a (r x cols); right -> R = a P (rows x r)
                    let state_len = pj.m.len();
                    let mut rproj = vec![0f32; state_len];
                    if pj.left {
                        linalg::matmul_tn(&pj.p, &a, &mut rproj, rows, pj.r, cols);
                    } else {
                        linalg::matmul(&a, &pj.p, &mut rproj, rows, cols, pj.r);
                    }
                    // Adam in the projected space
                    let mut nproj = vec![0f32; state_len];
                    for i in 0..state_len {
                        pj.m[i] = cfg.beta1 * pj.m[i] + (1.0 - cfg.beta1) * rproj[i];
                        pj.v[i] = cfg.beta2 * pj.v[i] + (1.0 - cfg.beta2) * rproj[i] * rproj[i];
                        nproj[i] = (pj.m[i] / bc1) / ((pj.v[i] / bc2).sqrt() + cfg.eps);
                    }
                    // project back: left -> U = P N (rows x cols); right -> U = N P^T
                    let mut upd = vec![0f32; rows * cols];
                    if pj.left {
                        linalg::matmul(&pj.p, &nproj, &mut upd, rows, pj.r, cols);
                    } else {
                        // N (rows x r) * P^T (r x cols): P stored (cols x r)
                        for i in 0..rows {
                            for j in 0..cols {
                                let mut acc = 0f32;
                                for k in 0..pj.r {
                                    acc += nproj[i * pj.r + k] * pj.p[j * pj.r + k];
                                }
                                upd[i * cols + j] = acc;
                            }
                        }
                    }
                    let p = &mut params[pj.offset..pj.offset + rows * cols];
                    for (pi, &ui) in p.iter_mut().zip(&upd) {
                        *pi -= lr * cfg.scale * ui;
                    }
                    // EF update: e = a - proj_L(a) (reconstruction residual)
                    if cfg.error_feedback {
                        let mut recon = vec![0f32; rows * cols];
                        if pj.left {
                            linalg::matmul(&pj.p, &rproj, &mut recon, rows, pj.r, cols);
                        } else {
                            for i in 0..rows {
                                for j in 0..cols {
                                    let mut acc = 0f32;
                                    for k in 0..pj.r {
                                        acc += rproj[i * pj.r + k] * pj.p[j * pj.r + k];
                                    }
                                    recon[i * cols + j] = acc;
                                }
                            }
                        }
                        for i in 0..rows * cols {
                            pj.e[i] = a[i] - recon[i];
                        }
                        pj.last_err_norm = linalg::fro_norm(&pj.e);
                    }
                }
                State::Dense { offset, len, m, v } => {
                    let (offset, len) = (*offset, *len);
                    let g = &grads[offset..offset + len];
                    let p = &mut params[offset..offset + len];
                    for i in 0..len {
                        m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * g[i];
                        v[i] = cfg.beta2 * v[i] + (1.0 - cfg.beta2) * g[i] * g[i];
                        p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + cfg.eps);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| match s {
                State::Proj(p) => 4 * (p.p.len() + p.m.len() + p.v.len() + p.e.len()),
                State::Dense { m, v, .. } => 4 * (m.len() + v.len()),
            })
            .sum()
    }

    fn paper_state_bytes(&self) -> usize {
        // bf16 storage: 2 B per projection + state component (§3.2 GaLore
        // accounting); the EF surrogate is a diagnostics-only add-on and
        // excluded, as in the appendix.
        self.states
            .iter()
            .map(|s| match s {
                State::Proj(p) => 2 * (p.p.len() + p.m.len() + p.v.len()),
                State::Dense { m, v, .. } => 2 * (m.len() + v.len()),
            })
            .sum()
    }

    fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::randvec;

    fn spec_16x16() -> Vec<TensorSpec> {
        vec![TensorSpec::new("w", &[16, 16], 0)]
    }

    #[test]
    fn projected_state_is_low_rank() {
        let opt = GaLore::new(256, spec_16x16(), GaLoreConfig { rank: 4, ..Default::default() });
        // P: 16x4, m/v: 4x16 each => (64 + 64 + 64) f32
        assert_eq!(opt.state_bytes(), 4 * (64 + 64 + 64));
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = GaLore::new(256, spec_16x16(), GaLoreConfig {
            rank: 8,
            update_every: 20,
            ..Default::default()
        });
        let mut x = randvec(0, 256, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..600 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.02);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.6 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn ef_error_lives_in_orthogonal_complement() {
        // Appendix F: e is orthogonal to the learning subspace, so
        // projecting e onto P must give ~0.
        let mut opt = GaLore::new(256, spec_16x16(), GaLoreConfig {
            rank: 4,
            update_every: 1000, // never refresh during the test
            error_feedback: true,
            ..Default::default()
        });
        let mut x = randvec(1, 256, 1.0);
        for s in 0..10 {
            let g = randvec(10 + s, 256, 1.0);
            opt.step(&mut x, &g, 0.01);
        }
        if let State::Proj(p) = &opt.states[0] {
            // ||P^T e|| << ||e||
            let mut pte = vec![0f32; p.r * p.cols];
            linalg::matmul_tn(&p.p, &p.e, &mut pte, p.rows, p.r, p.cols);
            let ratio = linalg::fro_norm(&pte) / linalg::fro_norm(&p.e).max(1e-9);
            assert!(ratio < 1e-3, "projection leak {ratio}");
        } else {
            panic!("expected projected state");
        }
    }

    #[test]
    fn ef_error_grows_between_refreshes() {
        // Appendix F / Figure 8: error norm grows roughly linearly while the
        // subspace is fixed.
        let mut opt = GaLore::new(256, spec_16x16(), GaLoreConfig {
            rank: 2,
            update_every: 1000,
            error_feedback: true,
            ..Default::default()
        });
        let mut x = randvec(2, 256, 1.0);
        let mut norms = Vec::new();
        for s in 0..30 {
            let g = randvec(100 + s, 256, 1.0);
            opt.step(&mut x, &g, 0.001);
            norms.push(opt.layer_norms()[0].error_norm);
        }
        assert!(norms[29] > 2.0 * norms[2], "no growth: {norms:?}");
    }

    #[test]
    fn small_tensors_fall_back_to_dense_adam() {
        let specs = vec![TensorSpec::new("b", &[8], 0)];
        let mut opt = GaLore::new(8, specs, GaLoreConfig { rank: 4, ..Default::default() });
        let mut x = randvec(3, 8, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..200 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.05);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.1 * n0);
    }
}
