//! MicroAdam, analytical view (Algorithm 3) — the object of Theorems 1/2.
//!
//! Differences from the practical Algorithm 1 implementation:
//! * `C` is a *global* Top-K contraction (`q = sqrt(1 - k/d)`, Assumption 1);
//! * `Q` is the unbiased stochastic-rounding quantizer of Lemma 1
//!   (Assumption 2), applied to the *residual* `g + e - C(g+e)`;
//! * moments are dense EMAs of the compressed gradients with AMSGrad
//!   normalization `v_hat = max(v_hat, v)`, no bias correction.
//!
//! This variant is used by the `repro theory` harness to study the
//! convergence rates and the `(1 + omega) q < 1` condition empirically; it
//! is *not* memory-efficient (dense state) and exists purely as the
//! theory-facing twin of [`super::microadam::MicroAdam`].

use super::Optimizer;
use crate::quant::{BucketStats, Quant4};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct AnalyticalConfig {
    /// Global Top-K count `k` (contraction factor `q = sqrt(1 - k/d)`).
    pub k: usize,
    /// EF quantization bucket; `None` stores the error uncompressed
    /// (`omega = 0` — the Comp-AMS special case of the theory).
    pub qbucket: Option<usize>,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub seed: u64,
    /// AMSGrad normalization (the analysed variant). Off gives plain Adam
    /// normalization for ablations.
    pub amsgrad: bool,
    /// Disable error feedback entirely ("TopK-Adam", Figure 1 middle).
    pub error_feedback: bool,
}

impl Default for AnalyticalConfig {
    fn default() -> Self {
        Self {
            k: 1,
            qbucket: Some(crate::QBUCKET),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            seed: 0,
            amsgrad: true,
            error_feedback: true,
        }
    }
}

/// Algorithm 3 with dense bookkeeping.
pub struct MicroAdamAnalytical {
    cfg: AnalyticalConfig,
    d: usize,
    e: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    v_hat: Vec<f32>,
    rng: Rng,
    quant: Option<Quant4>,
    t: u64,
    /// scratch
    acc: Vec<f32>,
    order: Vec<u32>,
}

impl MicroAdamAnalytical {
    pub fn new(d: usize, cfg: AnalyticalConfig) -> Self {
        let quant = cfg.qbucket.map(|b| {
            let mut b = b.min(crate::pad_up(d, 2));
            while d % b != 0 || b % 2 != 0 {
                b -= 1;
                assert!(b >= 2);
            }
            Quant4::new(b)
        });
        Self {
            cfg,
            d,
            e: vec![0.0; d],
            m: vec![0.0; d],
            v: vec![0.0; d],
            v_hat: vec![0.0; d],
            rng: Rng::seed_from_u64(cfg.seed),
            quant,
            t: 0,
            acc: vec![0.0; d],
            order: Vec::new(),
        }
    }

    /// Contraction factor `q = sqrt(1 - k/d)` of the Top-K compressor.
    pub fn q(&self) -> f64 {
        (1.0 - self.cfg.k as f64 / self.d as f64).sqrt()
    }

    /// Lemma-1 omega bound of the EF quantizer (worst case over inputs):
    /// `omega <= sqrt(d-2) / (2^b - 1)` since `(Delta-delta)/sqrt(Delta^2+delta^2) <= sqrt(2)`.
    pub fn omega_bound(&self) -> f64 {
        match self.quant {
            None => 0.0,
            Some(ref q) => {
                let db = q.bucket as f64;
                (db - 2.0).max(0.0).sqrt() * std::f64::consts::SQRT_2 / 15.0
            }
        }
    }

    /// The theory's compression condition `(1 + omega) q < 1`.
    pub fn condition_holds(&self) -> bool {
        (1.0 + self.omega_bound()) * self.q() < 1.0
    }

    pub fn error_norm(&self) -> f32 {
        self.e.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

impl MicroAdamAnalytical {
    fn finish_update(&mut self, params: &mut [f32], lr: f32) {
        let c = self.cfg;
        // AMSGrad normalization + update.
        for i in 0..self.d {
            if c.amsgrad {
                self.v_hat[i] = self.v_hat[i].max(self.v[i]);
            } else {
                self.v_hat[i] = self.v[i];
            }
            params[i] -= lr * self.m[i] / (self.v_hat[i].sqrt() + c.eps);
        }
    }
}

impl Optimizer for MicroAdamAnalytical {
    fn name(&self) -> String {
        format!("MicroAdam-A(k={},{})", self.cfg.k,
                if self.quant.is_some() { "Q4" } else { "dense" })
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.d);
        self.t += 1;
        let c = self.cfg;

        // acc = g + e
        for i in 0..self.d {
            self.acc[i] = grads[i] + self.e[i];
        }
        // tilde_g = C(acc): global top-k by |.|; residual stays in acc.
        self.order.clear();
        self.order.extend(0..self.d as u32);
        let k = c.k.min(self.d);
        if k < self.d {
            let acc = &self.acc;
            self.order.select_nth_unstable_by(k - 1, |&a, &b| {
                let fa = acc[a as usize].abs();
                let fb = acc[b as usize].abs();
                fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal)
            });
        }
        // moments updated on the sparse compressed gradient:
        for i in 0..self.d {
            self.m[i] *= c.beta1;
            self.v[i] *= c.beta2;
        }
        for &i in &self.order[..k] {
            let i = i as usize;
            let g = self.acc[i];
            self.m[i] += (1.0 - c.beta1) * g;
            self.v[i] += (1.0 - c.beta2) * g * g;
            self.acc[i] = 0.0; // residual = acc - C(acc)
        }
        // e' = Q(residual)
        if !self.cfg.error_feedback {
            // Figure-1 "TopK-Adam": discard the residual entirely.
            return self.finish_update(params, lr);
        }
        match self.quant {
            None => self.e.copy_from_slice(&self.acc),
            Some(ref q) => {
                let nq = self.d / q.bucket;
                let mut packed = vec![0u8; self.d / 2];
                let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; nq];
                q.quantize_stochastic(&self.acc, &mut packed, &mut stats, &mut self.rng);
                q.dequantize(&packed, &stats, &mut self.e);
            }
        }
        self.finish_update(params, lr);
    }

    fn state_bytes(&self) -> usize {
        4 * (self.e.len() + self.m.len() + self.v.len() + self.v_hat.len())
    }

    fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::randvec;

    #[test]
    fn q_matches_assumption1() {
        let opt = MicroAdamAnalytical::new(100, AnalyticalConfig { k: 1, ..Default::default() });
        assert!((opt.q() - (0.99f64).sqrt()).abs() < 1e-12);
        let full = MicroAdamAnalytical::new(100, AnalyticalConfig { k: 100, ..Default::default() });
        assert_eq!(full.q(), 0.0);
    }

    #[test]
    fn condition_detects_excessive_compression() {
        // Tiny k on a huge d with coarse quantization violates (1+w)q < 1.
        let bad = MicroAdamAnalytical::new(10_000, AnalyticalConfig {
            k: 1,
            qbucket: Some(64),
            ..Default::default()
        });
        assert!(!bad.condition_holds());
        // Dense error (omega = 0) with large k satisfies it.
        let good = MicroAdamAnalytical::new(100, AnalyticalConfig {
            k: 60,
            qbucket: None,
            ..Default::default()
        });
        assert!(good.condition_holds());
    }

    #[test]
    fn converges_on_quadratic() {
        let d = 64;
        let mut opt = MicroAdamAnalytical::new(d, AnalyticalConfig {
            k: 16,
            qbucket: Some(16),
            ..Default::default()
        });
        let mut x = randvec(0, d, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..500 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.02);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.2 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn amsgrad_vhat_is_monotone() {
        let d = 32;
        let mut opt = MicroAdamAnalytical::new(d, AnalyticalConfig { k: 8, ..Default::default() });
        let mut x = randvec(1, d, 1.0);
        let mut prev = vec![0f32; d];
        for s in 0..20 {
            let g = randvec(50 + s, d, 1.0);
            opt.step(&mut x, &g, 0.01);
            for i in 0..d {
                assert!(opt.v_hat[i] >= prev[i]);
            }
            prev.copy_from_slice(&opt.v_hat);
        }
    }

    #[test]
    fn error_norm_bounded_lemma3() {
        // With (1+w)q < 1, ||e_t||^2 <= 4 q_w^2 / (1-q_w^2)^2 G^2.
        let d = 64;
        let k = 32;
        let mut opt = MicroAdamAnalytical::new(d, AnalyticalConfig {
            k,
            qbucket: None, // omega = 0 so q_w = q, bound is exact
            ..Default::default()
        });
        let q_w = opt.q();
        assert!(opt.condition_holds());
        let g_bound = (d as f64).sqrt(); // coords in [-1,1]
        let bound = 2.0 * q_w / (1.0 - q_w * q_w) * g_bound;
        let mut x = vec![0.0f32; d];
        for s in 0..200 {
            let g = randvec(900 + s, d, 1.0);
            opt.step(&mut x, &g, 0.001);
            assert!(
                (opt.error_norm() as f64) <= bound * 1.01,
                "step {s}: {} > {bound}",
                opt.error_norm()
            );
        }
    }
}
