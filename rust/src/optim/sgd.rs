//! SGD with momentum (the ResNet/ImageNet table baseline).

use super::Optimizer;

#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    pub momentum: f32,
    /// Classic L2 regularization folded into the gradient (FFCV recipe).
    pub weight_decay: f32,
    pub nesterov: bool,
}

impl Default for SgdConfig {
    fn default() -> Self {
        Self { momentum: 0.9, weight_decay: 0.0, nesterov: false }
    }
}

/// SGD + momentum with fp32 buffer: 4 bytes/param state.
pub struct Sgd {
    cfg: SgdConfig,
    buf: Vec<f32>,
    t: u64,
}

impl Sgd {
    pub fn new(d: usize, cfg: SgdConfig) -> Self {
        Self { cfg, buf: vec![0.0; d], t: 0 }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "SGD".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.t += 1;
        let c = &self.cfg;
        for i in 0..params.len() {
            let g = grads[i] + c.weight_decay * params[i];
            self.buf[i] = c.momentum * self.buf[i] + g;
            let d = if c.nesterov { g + c.momentum * self.buf[i] } else { self.buf[i] };
            params[i] -= lr * d;
        }
    }

    fn state_bytes(&self) -> usize {
        self.buf.len() * 4
    }

    fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_matches_formula() {
        let mut opt = Sgd::new(2, SgdConfig { momentum: 0.0, ..Default::default() });
        let mut p = vec![1.0f32, 2.0];
        opt.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, 2.05]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(1, SgdConfig { momentum: 0.9, ..Default::default() });
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 0.1); // buf=1, p=-0.1
        opt.step(&mut p, &[1.0], 0.1); // buf=1.9, p=-0.29
        assert!((p[0] + 0.29).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn l2_weight_decay_pulls_to_zero() {
        let mut opt = Sgd::new(1, SgdConfig { momentum: 0.0, weight_decay: 1.0, ..Default::default() });
        let mut p = vec![1.0f32];
        for _ in 0..100 {
            opt.step(&mut p, &[0.0], 0.1);
        }
        assert!(p[0].abs() < 1e-3);
    }
}
