//! CAME baseline (Luo et al. 2023): confidence-guided, memory-efficient.
//!
//! Keeps a full first moment `m` but factorizes both the second moment and
//! the *instability* statistic `(u - m)^2` into row/column factors. 1-D
//! tensors fall back to dense Adam-style moments.

use super::Optimizer;
use crate::coordinator::layout::TensorSpec;

#[derive(Debug, Clone, Copy)]
pub struct CameConfig {
    pub beta1: f32,
    pub beta2: f32,
    /// beta3 for the instability factors (paper default 0.9999).
    pub beta3: f32,
    pub eps1: f32,
    pub eps2: f32,
    pub clip: f32,
}

impl Default for CameConfig {
    fn default() -> Self {
        Self { beta1: 0.9, beta2: 0.999, beta3: 0.9999, eps1: 1e-30, eps2: 1e-16, clip: 1.0 }
    }
}

enum State {
    Factored {
        rows: usize,
        cols: usize,
        offset: usize,
        m: Vec<f32>,
        vr: Vec<f32>,
        vc: Vec<f32>,
        ur: Vec<f32>,
        uc: Vec<f32>,
    },
    Dense { offset: usize, len: usize, m: Vec<f32>, v: Vec<f32> },
}

/// CAME over a flat vector with tensor shape metadata.
pub struct Came {
    cfg: CameConfig,
    d: usize,
    states: Vec<State>,
    t: u64,
}

impl Came {
    pub fn new(d: usize, specs: Vec<TensorSpec>, cfg: CameConfig) -> Self {
        let mut states = Vec::new();
        let mut covered = 0usize;
        for s in &specs {
            if let Some((rows, cols)) = s.as_matrix() {
                states.push(State::Factored {
                    rows,
                    cols,
                    offset: s.offset,
                    m: vec![0.0; rows * cols],
                    vr: vec![0.0; rows],
                    vc: vec![0.0; cols],
                    ur: vec![0.0; rows],
                    uc: vec![0.0; cols],
                });
            } else {
                states.push(State::Dense {
                    offset: s.offset,
                    len: s.size(),
                    m: vec![0.0; s.size()],
                    v: vec![0.0; s.size()],
                });
            }
            covered = covered.max(s.offset + s.size());
        }
        if covered < d {
            states.push(State::Dense {
                offset: covered,
                len: d - covered,
                m: vec![0.0; d - covered],
                v: vec![0.0; d - covered],
            });
        }
        Self { cfg, d, states, t: 0 }
    }
}

impl Optimizer for Came {
    fn name(&self) -> String {
        "CAME".into()
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.d);
        self.t += 1;
        let cfg = self.cfg;
        for st in &mut self.states {
            match st {
                State::Factored { rows, cols, offset, m, vr, vc, ur, uc } => {
                    let (rows, cols, offset) = (*rows, *cols, *offset);
                    let g = &grads[offset..offset + rows * cols];
                    // second-moment factors of g^2 + eps1
                    for i in 0..rows {
                        let mut acc = 0f32;
                        for j in 0..cols {
                            let v = g[i * cols + j];
                            acc += v * v + cfg.eps1;
                        }
                        vr[i] = cfg.beta2 * vr[i] + (1.0 - cfg.beta2) * (acc / cols as f32);
                    }
                    for j in 0..cols {
                        let mut acc = 0f32;
                        for i in 0..rows {
                            let v = g[i * cols + j];
                            acc += v * v + cfg.eps1;
                        }
                        vc[j] = cfg.beta2 * vc[j] + (1.0 - cfg.beta2) * (acc / rows as f32);
                    }
                    let vr_mean = (vr.iter().sum::<f32>() / rows as f32).max(cfg.eps1);
                    // u = g / sqrt(V); RMS clip; momentum
                    let mut u = vec![0f32; rows * cols];
                    let mut rms = 0f32;
                    for i in 0..rows {
                        for j in 0..cols {
                            let v = (vr[i] * vc[j] / vr_mean).max(cfg.eps1);
                            let ui = g[i * cols + j] / v.sqrt();
                            rms += ui * ui;
                            u[i * cols + j] = ui;
                        }
                    }
                    let rms = (rms / (rows * cols) as f32).sqrt();
                    let scale = 1.0 / (rms / cfg.clip).max(1.0);
                    for (mi, &ui) in m.iter_mut().zip(&u) {
                        *mi = cfg.beta1 * *mi + (1.0 - cfg.beta1) * scale * ui;
                    }
                    // instability U = (u_hat - m)^2, factorized with beta3
                    for i in 0..rows {
                        let mut acc = 0f32;
                        for j in 0..cols {
                            let diff = scale * u[i * cols + j] - m[i * cols + j];
                            acc += diff * diff + cfg.eps2;
                        }
                        ur[i] = cfg.beta3 * ur[i] + (1.0 - cfg.beta3) * (acc / cols as f32);
                    }
                    for j in 0..cols {
                        let mut acc = 0f32;
                        for i in 0..rows {
                            let diff = scale * u[i * cols + j] - m[i * cols + j];
                            acc += diff * diff + cfg.eps2;
                        }
                        uc[j] = cfg.beta3 * uc[j] + (1.0 - cfg.beta3) * (acc / rows as f32);
                    }
                    let ur_mean = (ur.iter().sum::<f32>() / rows as f32).max(cfg.eps2);
                    let p = &mut params[offset..offset + rows * cols];
                    for i in 0..rows {
                        for j in 0..cols {
                            let s = (ur[i] * uc[j] / ur_mean).max(cfg.eps2);
                            p[i * cols + j] -= lr * m[i * cols + j] / s.sqrt().max(cfg.eps2);
                        }
                    }
                }
                State::Dense { offset, len, m, v } => {
                    let (offset, len) = (*offset, *len);
                    let g = &grads[offset..offset + len];
                    let p = &mut params[offset..offset + len];
                    for i in 0..len {
                        m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * g[i];
                        v[i] = cfg.beta2 * v[i] + (1.0 - cfg.beta2) * g[i] * g[i];
                        p[i] -= lr * m[i] / (v[i].sqrt() + 1e-8);
                    }
                }
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| match s {
                State::Factored { m, vr, vc, ur, uc, .. } => {
                    4 * (m.len() + vr.len() + vc.len() + ur.len() + uc.len())
                }
                State::Dense { m, v, .. } => 4 * (m.len() + v.len()),
            })
            .sum()
    }

    fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::randvec;

    #[test]
    fn state_between_sgd_and_adam() {
        // m is full (4 B/param) + small factors: more than SGD momentum,
        // less than dense Adam's 8 B/param.
        let specs = vec![TensorSpec::new("w", &[64, 64], 0)];
        let opt = Came::new(4096, specs, CameConfig::default());
        let bytes = opt.state_bytes();
        assert!(bytes > 4 * 4096);
        assert!(bytes < 8 * 4096);
    }

    #[test]
    fn converges_on_quadratic_matrix() {
        let specs = vec![TensorSpec::new("w", &[16, 16], 0)];
        let mut opt = Came::new(256, specs, CameConfig::default());
        let mut x = randvec(0, 256, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..400 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.02);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.5 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn updates_stay_finite_with_tiny_gradients() {
        // CAME's known instability regime: near-zero gradients.
        let specs = vec![TensorSpec::new("w", &[8, 8], 0)];
        let mut opt = Came::new(64, specs, CameConfig::default());
        let mut x = randvec(1, 64, 1.0);
        for _ in 0..50 {
            let g = vec![1e-20f32; 64];
            opt.step(&mut x, &g, 0.01);
            assert!(x.iter().all(|v| v.is_finite()));
        }
    }
}
