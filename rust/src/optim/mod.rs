//! Native optimizer implementations (L3).
//!
//! Everything the paper compares against, implemented from scratch so every
//! table/figure harness runs without external dependencies:
//!
//! | module | paper role |
//! |---|---|
//! | [`microadam`] | the contribution (Algorithm 1, practical form) |
//! | [`microadam_analytical`] | Algorithm 3 (AMSGrad normalization) for the theory experiments |
//! | [`adamw`] | Adam / AdamW baseline |
//! | [`adamw8bit`] | Dettmers-style 8-bit state baseline |
//! | [`sgd`] | SGD + momentum (ResNet table) |
//! | [`adafactor`] | factorized second-moment baseline |
//! | [`came`] | confidence-guided factorized baseline |
//! | [`galore`] | low-rank projection baseline (+ the Appendix-F EF variant) |
//! | [`ldadam`] | LDAdam: low-rank projected moments + EF (shares the Quant4 kernels) |
//! | [`adammini`] | Adam-mini: per-block shared second moment (shares the block partition) |
//!
//! All optimizers share [`Optimizer`]: a flat-vector `step`, an accurate
//! accounting of allocated state bytes, and the "paper bytes" the same state
//! would occupy with the paper's storage dtypes (bf16/int16/4-bit).
//! See `rust/src/optim/README.md` for the per-optimizer state-layout /
//! bytes-per-param / reducer-compatibility table.

pub mod adafactor;
pub mod adammini;
pub mod adamw;
pub mod adamw8bit;
pub mod came;
pub mod galore;
pub mod ldadam;
pub mod microadam;
pub mod microadam_analytical;
pub mod sgd;

use crate::coordinator::layout::TensorSpec;
use crate::coordinator::state::MicroAdamSnapshot;
use crate::exec::ExecPool;
use anyhow::{bail, Result};

/// Typed optimizer-state checkpoint payload: one variant per optimizer
/// that supports bit-exact snapshot/restore through the checkpoint format.
/// Carried by [`crate::coordinator::checkpoint::Checkpoint`] (format v3).
#[derive(Debug, Clone, PartialEq)]
pub enum OptSnapshot {
    /// MicroAdam window + Quant4 EF state.
    MicroAdam(MicroAdamSnapshot),
    /// LDAdam projectors, projected moments, and Quant4 EF state.
    LdAdam(ldadam::LdAdamSnapshot),
    /// Adam-mini dense first moment + per-block second-moment means.
    AdamMini(adammini::AdamMiniSnapshot),
}

impl OptSnapshot {
    /// Stable variant label for error messages and the checkpoint tag.
    pub fn kind_name(&self) -> &'static str {
        match self {
            OptSnapshot::MicroAdam(_) => "micro-adam",
            OptSnapshot::LdAdam(_) => "ldadam",
            OptSnapshot::AdamMini(_) => "adammini",
        }
    }
}

/// One tensor's (parameter, gradient) pair for the multi-tensor step entry
/// point. Chunks are consecutive segments of the optimizer's flat vector;
/// their concatenation must have the dimension the optimizer was built with.
pub struct TensorChunk<'a> {
    /// This tensor's mutable slice of the flat parameter vector.
    pub params: &'a mut [f32],
    /// The matching gradient slice (same length as `params`).
    pub grads: &'a [f32],
}

/// A stateful first-order optimizer over a flat f32 parameter vector.
///
/// ```
/// use microadam::exec::ExecPool;
/// use microadam::optim::{self, Optimizer, OptimizerKind, TensorChunk};
///
/// let mut opt = optim::build(OptimizerKind::MicroAdam, 128, &[], 0.0);
/// let mut params = vec![0.5f32; 128];
/// let grads = vec![0.1f32; 128];
/// // one multi-tensor step over a single flat chunk (the zero-copy path)
/// let mut chunks = [TensorChunk { params: &mut params[..], grads: &grads }];
/// opt.step_multi(&mut chunks, 1e-3, &ExecPool::serial());
/// assert_eq!(opt.t(), 1);
/// assert!(opt.state_bytes() > 0);
/// ```
pub trait Optimizer {
    /// Optimizer display name (table row label).
    fn name(&self) -> String;
    /// Apply one update step. `params` and `grads` have the dimension the
    /// optimizer was constructed with; the internal step counter advances.
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32);
    /// Block-sharded step: like [`Optimizer::step`] but free to fan the
    /// update out across `pool`'s workers. Implementations that override
    /// this MUST produce bit-identical results to `step` for every worker
    /// count (the update is partitioned, never reassociated). The default
    /// ignores the pool and runs sequentially.
    fn step_sharded(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: &ExecPool) {
        let _ = pool;
        self.step(params, grads, lr);
    }
    /// Multi-tensor step: one update over a list of consecutive flat-vector
    /// segments (e.g. the per-tensor views of a model's parameter layout).
    /// The single-chunk case is zero-copy; the general case gathers into a
    /// flat buffer, steps, and scatters back.
    fn step_multi(&mut self, chunks: &mut [TensorChunk<'_>], lr: f32, pool: &ExecPool) {
        if let [c] = chunks {
            self.step_sharded(c.params, c.grads, lr, pool);
            return;
        }
        let total: usize = chunks.iter().map(|c| c.params.len()).sum();
        let mut p = Vec::with_capacity(total);
        let mut g = Vec::with_capacity(total);
        for c in chunks.iter() {
            p.extend_from_slice(&c.params[..]);
            g.extend_from_slice(c.grads);
        }
        self.step_sharded(&mut p, &g, lr, pool);
        let mut o = 0;
        for c in chunks.iter_mut() {
            let n = c.params.len();
            c.params.copy_from_slice(&p[o..o + n]);
            o += n;
        }
    }
    /// Bytes of persistent optimizer state actually allocated — measured
    /// from the resident buffers, in their physical dtypes (MicroAdam's
    /// window, for instance, counts 2 B/value now that it stores bf16).
    fn state_bytes(&self) -> usize;
    /// Bytes the same state occupies with the paper's storage dtypes.
    /// Post bf16-window this agrees with [`Optimizer::state_bytes`] for
    /// the window term; remaining gaps (e.g. f32 quantization stats) are
    /// honest implementation overhead.
    fn paper_state_bytes(&self) -> usize {
        self.state_bytes()
    }
    /// Current step count (number of `step` calls so far).
    fn t(&self) -> u64;
    /// Copy the optimizer state out as a typed checkpoint payload.
    /// `None` means this optimizer does not (yet) support state
    /// checkpointing; trainers then save params-only checkpoints.
    fn snapshot_state(&self) -> Option<OptSnapshot> {
        None
    }
    /// Restore state from a typed checkpoint payload. The default is a
    /// typed error (never a panic): unsupported optimizers and mismatched
    /// snapshot variants both refuse loudly.
    fn restore_state(&mut self, snap: &OptSnapshot) -> Result<()> {
        bail!(
            "optimizer {} cannot restore a {} state snapshot (unsupported)",
            self.name(),
            snap.kind_name()
        )
    }
}

/// Carve a flat (padded) parameter/gradient pair into consecutive
/// [`TensorChunk`]s at the layout's real tensor boundaries, plus one tail
/// chunk for the block padding beyond `d_model`. The chunks concatenate
/// back to exactly `d_padded` elements, as [`Optimizer::step_multi`]
/// requires. `params`/`grads` must both have length `d_padded`.
pub fn layout_chunks<'a>(
    tensors: &[TensorSpec],
    d_padded: usize,
    mut params: &'a mut [f32],
    mut grads: &'a [f32],
) -> Vec<TensorChunk<'a>> {
    assert_eq!(params.len(), d_padded);
    assert_eq!(grads.len(), d_padded);
    let mut chunks = Vec::with_capacity(tensors.len() + 1);
    let mut off = 0usize;
    for t in tensors {
        // The sequential carve is only correct for contiguous, in-order
        // layouts; a gap or reorder would silently mislabel every chunk.
        assert_eq!(t.offset, off, "tensor {} not contiguous at offset {off}", t.name);
        let n = t.size();
        let (p, pr) = params.split_at_mut(n);
        params = pr;
        let (g, gr) = grads.split_at(n);
        grads = gr;
        chunks.push(TensorChunk { params: p, grads: g });
        off += n;
    }
    if off < d_padded {
        chunks.push(TensorChunk { params, grads });
    }
    chunks
}

/// Step `opt` over a flat padded parameter/gradient pair using the
/// layout's real tensor boundaries. Single-tensor layouts keep the
/// zero-copy flat-chunk fast path; multi-tensor layouts route through
/// [`layout_chunks`]. Shared by the single-process trainer and the
/// data-parallel [`crate::dist::DistTrainer`], so the routing policy
/// cannot diverge between them.
pub fn step_with_layout(
    opt: &mut dyn Optimizer,
    tensors: &[TensorSpec],
    d_padded: usize,
    params: &mut [f32],
    grads: &[f32],
    lr: f32,
    pool: &ExecPool,
) {
    if tensors.len() <= 1 {
        let mut chunks = [TensorChunk { params, grads }];
        opt.step_multi(&mut chunks, lr, pool);
    } else {
        let mut chunks = layout_chunks(tensors, d_padded, params, grads);
        opt.step_multi(&mut chunks, lr, pool);
    }
}

/// Measured resident optimizer-state bytes per parameter (allocated
/// buffers, not the paper accounting) — the honest column of the bench
/// reports.
pub fn resident_bytes_per_param(opt: &dyn Optimizer, d: usize) -> f64 {
    opt.state_bytes() as f64 / d as f64
}

/// Which optimizers a harness can instantiate by name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// The paper's contribution ([`microadam::MicroAdam`]).
    MicroAdam,
    /// Adam (AdamW with zero decoupled weight decay).
    Adam,
    /// AdamW baseline ([`adamw::AdamW`]).
    AdamW,
    /// Dettmers-style 8-bit-state baseline ([`adamw8bit::AdamW8bit`]).
    AdamW8bit,
    /// SGD + momentum ([`sgd::Sgd`]).
    Sgd,
    /// Factorized second-moment baseline ([`adafactor::AdaFactor`]).
    AdaFactor,
    /// Confidence-guided factorized baseline ([`came::Came`]).
    Came,
    /// Low-rank projection baseline ([`galore::GaLore`]).
    GaLore,
    /// GaLore with the Appendix-F error-feedback variant.
    GaLoreEf,
    /// LDAdam: low-rank projected moments + EF ([`ldadam::LdAdam`]).
    LdAdam,
    /// Adam-mini: per-block shared second moment ([`adammini::AdamMini`]).
    AdamMini,
}

impl OptimizerKind {
    /// Every instantiable kind, in the order the benches sweep them.
    pub fn all() -> &'static [OptimizerKind] {
        use OptimizerKind::*;
        &[MicroAdam, Adam, AdamW, AdamW8bit, Sgd, AdaFactor, Came, GaLore, GaLoreEf, LdAdam, AdamMini]
    }
}

/// Build an optimizer by kind with library defaults. `specs` is required by
/// the shaped optimizers (GaLore/AdaFactor/CAME) and ignored by the rest.
pub fn build(
    kind: OptimizerKind,
    d: usize,
    specs: &[TensorSpec],
    weight_decay: f32,
) -> Box<dyn Optimizer> {
    match kind {
        OptimizerKind::MicroAdam => {
            let cfg = microadam::MicroAdamConfig { weight_decay, ..Default::default() };
            Box::new(microadam::MicroAdam::new(d, cfg))
        }
        OptimizerKind::Adam => Box::new(adamw::AdamW::new(d, adamw::AdamWConfig {
            weight_decay: 0.0,
            ..Default::default()
        })),
        OptimizerKind::AdamW => Box::new(adamw::AdamW::new(d, adamw::AdamWConfig {
            weight_decay,
            ..Default::default()
        })),
        OptimizerKind::AdamW8bit => Box::new(adamw8bit::AdamW8bit::new(d, adamw8bit::AdamW8bitConfig {
            weight_decay,
            ..Default::default()
        })),
        OptimizerKind::Sgd => Box::new(sgd::Sgd::new(d, sgd::SgdConfig {
            weight_decay,
            ..Default::default()
        })),
        OptimizerKind::AdaFactor => Box::new(adafactor::AdaFactor::new(d, specs.to_vec(), Default::default())),
        OptimizerKind::Came => Box::new(came::Came::new(d, specs.to_vec(), Default::default())),
        OptimizerKind::GaLore => Box::new(galore::GaLore::new(d, specs.to_vec(), galore::GaLoreConfig {
            error_feedback: false,
            ..Default::default()
        })),
        OptimizerKind::GaLoreEf => Box::new(galore::GaLore::new(d, specs.to_vec(), galore::GaLoreConfig {
            error_feedback: true,
            ..Default::default()
        })),
        OptimizerKind::LdAdam => Box::new(ldadam::LdAdam::new(d, ldadam::LdAdamConfig {
            weight_decay,
            ..Default::default()
        })),
        OptimizerKind::AdamMini => Box::new(adammini::AdamMini::new(d, adammini::AdamMiniConfig {
            weight_decay,
            ..Default::default()
        })),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::util::rng::Rng;

    /// Random vector in [-s, s].
    pub fn randvec(seed: u64, n: usize, s: f32) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
    }

    /// Run `steps` optimizer steps on the quadratic f(x)=||x||^2/2 and
    /// return (initial_norm, final_norm).
    pub fn quadratic_descent(opt: &mut dyn super::Optimizer, d: usize, lr: f32, steps: usize) -> (f32, f32) {
        let mut x = randvec(42, d, 1.0);
        let n0 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..steps {
            let g = x.clone();
            opt.step(&mut x, &g, lr);
        }
        let n1 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        (n0, n1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_constructs_every_kind() {
        let specs = vec![TensorSpec::new("w", &[16, 16], 0)];
        for &k in OptimizerKind::all() {
            let mut opt = build(k, 256, &specs, 0.0);
            let mut p = vec![0.5f32; 256];
            let g = vec![0.1f32; 256];
            opt.step(&mut p, &g, 1e-3);
            assert_eq!(opt.t(), 1, "{k:?}");
            assert!(!opt.name().is_empty());
        }
    }

    #[test]
    fn every_kind_descends_quadratic() {
        let specs = vec![TensorSpec::new("w", &[16, 16], 0)];
        for &k in OptimizerKind::all() {
            let mut opt = build(k, 256, &specs, 0.0);
            let lr = if k == OptimizerKind::Sgd { 0.05 } else { 0.04 };
            // MicroAdam at default 1% density updates few coords per step;
            // give every optimizer the same generous budget.
            let (n0, n1) = testutil::quadratic_descent(opt.as_mut(), 256, lr, 800);
            assert!(n1 < 0.5 * n0, "{k:?}: {n0} -> {n1}");
        }
    }

    #[test]
    fn step_multi_matches_flat_step_for_every_kind() {
        // Chunked (multi-tensor) stepping must reproduce the flat trajectory
        // exactly, whatever the chunk boundaries.
        let specs = vec![TensorSpec::new("w", &[16, 16], 0)];
        let d = 256;
        let pool = ExecPool::new(3);
        for &k in OptimizerKind::all() {
            let mut flat = build(k, d, &specs, 0.0);
            let mut multi = build(k, d, &specs, 0.0);
            let mut p_flat = testutil::randvec(50, d, 1.0);
            let mut p_multi = p_flat.clone();
            for s in 0..5 {
                let g = testutil::randvec(60 + s, d, 1.0);
                flat.step(&mut p_flat, &g, 1e-2);
                // uneven split: 100 + 56 + 100
                let (a, rest) = p_multi.split_at_mut(100);
                let (b, c) = rest.split_at_mut(56);
                let mut chunks = [
                    TensorChunk { params: a, grads: &g[..100] },
                    TensorChunk { params: b, grads: &g[100..156] },
                    TensorChunk { params: c, grads: &g[156..] },
                ];
                multi.step_multi(&mut chunks, 1e-2, &pool);
            }
            assert_eq!(p_flat, p_multi, "{k:?}");
            assert_eq!(flat.t(), multi.t(), "{k:?}");
        }
    }

    #[test]
    fn single_chunk_step_multi_is_step_sharded() {
        let specs = vec![TensorSpec::new("w", &[16, 16], 0)];
        let d = 256;
        let pool = ExecPool::new(4);
        let mut a = build(OptimizerKind::MicroAdam, d, &specs, 0.0);
        let mut b = build(OptimizerKind::MicroAdam, d, &specs, 0.0);
        let mut pa = testutil::randvec(70, d, 1.0);
        let mut pb = pa.clone();
        let g = testutil::randvec(71, d, 1.0);
        a.step(&mut pa, &g, 1e-2);
        let mut chunks = [TensorChunk { params: &mut pb[..], grads: &g }];
        b.step_multi(&mut chunks, 1e-2, &pool);
        assert_eq!(pa, pb);
    }

    #[test]
    fn layout_chunks_cover_padded_vector_and_match_flat_step() {
        // Three tensors (56 params) padded to 64: chunks must cover all 64
        // and stepping through them must equal the flat trajectory.
        use crate::coordinator::layout::ParamLayout;
        use crate::coordinator::layout::Init;
        let layout = ParamLayout::new(
            vec![
                TensorSpec::new("w1", &[4, 8], 0),
                TensorSpec::new("b1", &[8], 32),
                TensorSpec::new("w2", &[8, 2], 40),
            ],
            vec![(Init::Normal, 0.02), (Init::Zeros, 0.0), (Init::Normal, 0.1)],
            64,
        );
        let pool = ExecPool::new(2);
        let mut flat = build(OptimizerKind::MicroAdam, 64, &layout.tensors, 0.0);
        let mut multi = build(OptimizerKind::MicroAdam, 64, &layout.tensors, 0.0);
        let mut p_flat = testutil::randvec(80, 64, 1.0);
        let mut p_multi = p_flat.clone();
        for s in 0..6 {
            let g = testutil::randvec(90 + s, 64, 1.0);
            flat.step(&mut p_flat, &g, 1e-2);
            let mut chunks = layout_chunks(&layout.tensors, 64, &mut p_multi, &g);
            assert_eq!(chunks.len(), 4); // 3 tensors + padding tail
            assert_eq!(chunks.iter().map(|c| c.params.len()).sum::<usize>(), 64);
            multi.step_multi(&mut chunks, 1e-2, &pool);
        }
        assert_eq!(p_flat, p_multi);
    }

    #[test]
    fn microadam_state_is_smallest_adaptive() {
        let specs = vec![TensorSpec::new("w", &[64, 64], 0)];
        let d = 4096;
        let micro = build(OptimizerKind::MicroAdam, d, &specs, 0.0);
        let adamw = build(OptimizerKind::AdamW, d, &specs, 0.0);
        let adam8 = build(OptimizerKind::AdamW8bit, d, &specs, 0.0);
        assert!(micro.paper_state_bytes() < adam8.paper_state_bytes());
        assert!(adam8.paper_state_bytes() < adamw.paper_state_bytes());
    }
}
