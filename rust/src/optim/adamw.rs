//! Dense Adam / AdamW baseline (Kingma & Ba 2014; Loshchilov & Hutter 2019).
//!
//! fp32 `m`/`v` state: 8 bytes per parameter — the `M_AW32` row of §3.2.

use super::Optimizer;
use crate::exec::{self, ExecPool};

#[derive(Debug, Clone, Copy)]
pub struct AdamWConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Apply bias correction (standard Adam). Off matches Algorithm 3.
    pub bias_correction: bool,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        Self { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0, bias_correction: true }
    }
}

/// Dense AdamW with fp32 moments.
pub struct AdamW {
    cfg: AdamWConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl AdamW {
    pub fn new(d: usize, cfg: AdamWConfig) -> Self {
        Self { cfg, m: vec![0.0; d], v: vec![0.0; d], t: 0 }
    }

    /// Per-step scalar factors (bias corrections, decoupled decay).
    fn factors(&self, lr: f32) -> (f32, f32, f32) {
        let c = &self.cfg;
        let (bc1, bc2) = if c.bias_correction {
            (1.0 - c.beta1.powi(self.t as i32), 1.0 - c.beta2.powi(self.t as i32))
        } else {
            (1.0, 1.0)
        };
        (bc1, bc2, 1.0 - lr * c.weight_decay)
    }
}

/// The element-wise AdamW update over one contiguous chunk. Shared by the
/// sequential and sharded paths so both produce identical bits.
fn update_chunk(
    cfg: &AdamWConfig,
    bc1: f32,
    bc2: f32,
    decay: f32,
    lr: f32,
    params: &mut [f32],
    grads: &[f32],
    m: &mut [f32],
    v: &mut [f32],
) {
    for i in 0..params.len() {
        let g = grads[i];
        m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * g;
        v[i] = cfg.beta2 * v[i] + (1.0 - cfg.beta2) * g * g;
        let m_hat = m[i] / bc1;
        let v_hat = v[i] / bc2;
        params[i] = decay * params[i] - lr * m_hat / (v_hat.sqrt() + cfg.eps);
    }
}

impl Optimizer for AdamW {
    fn name(&self) -> String {
        if self.cfg.weight_decay > 0.0 { "AdamW".into() } else { "Adam".into() }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let (bc1, bc2, decay) = self.factors(lr);
        update_chunk(&self.cfg, bc1, bc2, decay, lr, params, grads, &mut self.m, &mut self.v);
    }

    fn step_sharded(&mut self, params: &mut [f32], grads: &[f32], lr: f32, pool: &ExecPool) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let (bc1, bc2, decay) = self.factors(lr);
        let ranges = exec::chunk_ranges(params.len(), pool.workers());
        if ranges.len() <= 1 {
            update_chunk(&self.cfg, bc1, bc2, decay, lr, params, grads, &mut self.m, &mut self.v);
            return;
        }
        // Element-wise update: any contiguous partition yields the same bits.
        let cfg = &self.cfg;
        let mut shards = Vec::with_capacity(ranges.len());
        let (mut p_rest, mut g_rest) = (params, grads);
        let (mut m_rest, mut v_rest) = (&mut self.m[..], &mut self.v[..]);
        for r in &ranges {
            let (p, pr) = p_rest.split_at_mut(r.len());
            p_rest = pr;
            let (g, gr) = g_rest.split_at(r.len());
            g_rest = gr;
            let (m, mr) = m_rest.split_at_mut(r.len());
            m_rest = mr;
            let (v, vr) = v_rest.split_at_mut(r.len());
            v_rest = vr;
            shards.push((p, g, m, v));
        }
        pool.run_shards(shards, |_, (p, g, m, v)| {
            update_chunk(cfg, bc1, bc2, decay, lr, p, g, m, v);
        });
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    fn t(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::randvec;

    #[test]
    fn first_step_moves_by_lr_signs() {
        // With bias correction, |update_1| ~= lr * g/|g| = lr.
        let mut opt = AdamW::new(4, AdamWConfig::default());
        let mut p = vec![0.0f32; 4];
        let g = vec![1.0, -2.0, 0.5, -0.1];
        opt.step(&mut p, &g, 0.1);
        for (pi, gi) in p.iter().zip(&g) {
            assert!((pi.abs() - 0.1).abs() < 1e-3, "{pi}");
            assert!(pi.signum() == -gi.signum());
        }
    }

    #[test]
    fn weight_decay_is_decoupled() {
        let mut opt = AdamW::new(2, AdamWConfig { weight_decay: 0.5, ..Default::default() });
        let mut p = vec![1.0f32, -1.0];
        let g = vec![0.0f32, 0.0];
        opt.step(&mut p, &g, 0.1);
        // zero grad: params only shrink by (1 - lr*wd) = 0.95
        assert!((p[0] - 0.95).abs() < 1e-6);
        assert!((p[1] + 0.95).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = AdamW::new(64, AdamWConfig::default());
        let mut x = randvec(1, 64, 1.0);
        let n0: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        for _ in 0..400 {
            let g = x.clone();
            opt.step(&mut x, &g, 0.02);
        }
        let n1: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(n1 < 0.05 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn sharded_step_matches_sequential_bitwise() {
        let d = 1003; // non-divisible: uneven chunk sizes
        for workers in [1usize, 2, 4, 8] {
            let mut seq = AdamW::new(d, AdamWConfig::default());
            let mut par = AdamW::new(d, AdamWConfig::default());
            let pool = ExecPool::new(workers);
            let mut ps = randvec(20, d, 1.0);
            let mut pp = ps.clone();
            for s in 0..5 {
                let g = randvec(30 + s, d, 1.0);
                seq.step(&mut ps, &g, 1e-2);
                par.step_sharded(&mut pp, &g, 1e-2, &pool);
            }
            assert_eq!(ps, pp, "workers={workers}");
        }
    }

    #[test]
    fn state_bytes_is_8d() {
        let opt = AdamW::new(1000, AdamWConfig::default());
        assert_eq!(opt.state_bytes(), 8000);
    }
}
