//! trace — dependency-free tracing/metrics for the whole stack.
//!
//! The paper's convergence story rests on quantities the step loop never
//! used to surface: the EF residual norm ‖e_t‖, the Top-K captured mass,
//! the Quant4 quantization error. This module is the instrumentation
//! layer that makes them (and the per-phase step timing the perf work
//! optimizes) first-class, in the same spirit as `minloom`/`repolint`:
//! no new dependencies, and **zero cost when disabled**.
//!
//! Design, hot path first:
//!
//! * A single global `AtomicBool` gate ([`enabled`], relaxed load). Every
//!   recording entry point checks it first; when it is off, no clock is
//!   read, nothing allocates, nothing locks.
//! * Events are pushed into **thread-local** buffers (plain `RefCell<Vec>`
//!   — no atomics, no locks per event). Workers drain their buffer into
//!   the global collector once per dispatch ([`flush_local`]), so the
//!   fused inner loops never contend.
//! * [`PhaseAcc`] times the N phases of a sharded kernel with one clock
//!   read per phase boundary and emits exactly N spans per shard — the
//!   per-block stage costs are accumulated, not recorded individually.
//!
//! Two sinks:
//!
//! * **JSONL records** (schema-versioned `{"kind":"trace","v":1,...}`
//!   lines) drained once per step via [`drain_step_records`] and written
//!   by the caller alongside the ordinary step records — see the
//!   "Observability" section of the repo README for the schema.
//! * **Chrome trace-event JSON** ([`chrome_trace_json`], written by
//!   [`TraceSession::finish`] when a path was given) — loadable in
//!   Perfetto / `chrome://tracing` for flame-level evidence.
//!
//! A [`TraceSession`] guard owns the global gate; sessions serialize on a
//! process-wide lock so concurrent tests cannot interleave their events.
//!
//! ```
//! use microadam::trace;
//! let session = trace::session();
//! let g = trace::begin();
//! // ... timed work ...
//! g.end("demo", "work", 0);
//! trace::gauge("demo.residual_norm", 0.25);
//! let records = trace::drain_step_records(1);
//! assert!(records.iter().any(|r| {
//!     r.get("kind").and_then(|k| k.as_str()) == Some("trace")
//! }));
//! session.finish().unwrap();
//! ```

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::util::json::{self, Json};

/// Version stamped into every JSONL trace record (`"v"` key). Bump when a
/// record's key set changes shape.
pub const SCHEMA_VERSION: u64 = 1;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
/// Sessions serialize here so parallel tests can't interleave events.
static SESSION: Mutex<()> = Mutex::new(());
static COLLECTOR: Mutex<Collector> = Mutex::new(Collector::new());

/// Is tracing on? Relaxed atomic load — the only cost instrumentation
/// pays on the hot path when tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds since the process trace epoch (first clock use).
#[inline]
fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// One recorded event. Spans carry `'static` category/name so recording
/// never allocates; gauges are per-step (not per-block) and may own their
/// name.
#[derive(Debug, Clone)]
pub enum Event {
    /// A completed duration: `[ts_ns, ts_ns + dur_ns)` on lane `tid`.
    Span { cat: &'static str, name: &'static str, tid: u32, ts_ns: u64, dur_ns: u64 },
    /// A monotonic-ish count contribution (summed per step in the JSONL
    /// sink).
    Counter { name: &'static str, value: f64, ts_ns: u64 },
    /// A point-in-time measurement (EF residual norm, captured mass, …).
    Gauge { name: String, value: f64, ts_ns: u64 },
}

impl Event {
    fn ts_ns(&self) -> u64 {
        match self {
            Event::Span { ts_ns, .. } | Event::Counter { ts_ns, .. } | Event::Gauge { ts_ns, .. } => {
                *ts_ns
            }
        }
    }
}

struct Collector {
    events: Vec<Event>,
    /// Index up to which [`drain_step_records`] has consumed events. The
    /// events themselves are retained for the Chrome export.
    cursor: usize,
}

impl Collector {
    const fn new() -> Self {
        Self { events: Vec::new(), cursor: 0 }
    }
}

fn lock_collector() -> MutexGuard<'static, Collector> {
    COLLECTOR.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    static LOCAL: RefCell<Vec<Event>> = const { RefCell::new(Vec::new()) };
}

#[inline]
fn push(ev: Event) {
    LOCAL.with(|b| b.borrow_mut().push(ev));
}

/// Move this thread's buffered events into the global collector. Called
/// once per worker per dispatch by `exec::run_shards` and once per step
/// by [`drain_step_records`]; cheap no-op when the buffer is empty.
pub fn flush_local() {
    LOCAL.with(|b| {
        let mut buf = b.borrow_mut();
        if buf.is_empty() {
            return;
        }
        // `append` moves the elements and keeps the local capacity, so a
        // steady-state worker never reallocates its buffer.
        lock_collector().events.append(&mut buf);
    });
}

// ---------------------------------------------------------------------
// Recording API
// ---------------------------------------------------------------------

/// Start timing a span. Reads the clock only when tracing is enabled;
/// call [`SpanGuard::end`] to record it.
#[inline]
pub fn begin() -> SpanGuard {
    if !enabled() {
        return SpanGuard { start_ns: 0, on: false };
    }
    SpanGuard { start_ns: now_ns(), on: true }
}

/// An open span from [`begin`]. Copyable so a caller can both end it and
/// anchor sub-spans at its start time ([`SpanGuard::start_ns`]).
#[derive(Clone, Copy)]
#[must_use = "call .end(cat, name, tid) to record the span"]
pub struct SpanGuard {
    start_ns: u64,
    on: bool,
}

impl SpanGuard {
    /// Record the span `[start, now)`. No-op when tracing was off at
    /// [`begin`] time.
    #[inline]
    pub fn end(self, cat: &'static str, name: &'static str, tid: u32) {
        if !self.on {
            return;
        }
        let dur = now_ns().saturating_sub(self.start_ns);
        push(Event::Span { cat, name, tid, ts_ns: self.start_ns, dur_ns: dur });
    }

    /// Epoch-relative start of this span (0 when recorded disabled).
    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }

    /// Whether this guard is live (tracing was on at [`begin`] time).
    pub fn is_on(&self) -> bool {
        self.on
    }
}

/// Record a span whose extent was measured externally (e.g. the
/// transport's accumulated relay-overlap interval).
pub fn span_at(cat: &'static str, name: &'static str, tid: u32, ts_ns: u64, dur_ns: u64) {
    if enabled() {
        push(Event::Span { cat, name, tid, ts_ns, dur_ns });
    }
}

/// Add `value` to the per-step sum of counter `name`.
pub fn counter(name: &'static str, value: f64) {
    if enabled() {
        push(Event::Counter { name, value, ts_ns: now_ns() });
    }
}

/// Record a point-in-time gauge (EF residual norm, captured mass, …).
pub fn gauge(name: &str, value: f64) {
    if enabled() {
        push(Event::Gauge { name: name.to_string(), value, ts_ns: now_ns() });
    }
}

/// Per-phase time accumulator for a sharded kernel with `N` phases.
///
/// One clock read per phase boundary, zero clock reads (and zero
/// allocations) when tracing is disabled; [`PhaseAcc::finish`] emits
/// exactly `N` spans laid out back-to-back from the shard's start, so a
/// step over `S` shards contributes exactly `S * N` phase spans.
///
/// ```
/// use microadam::trace::{self, PhaseAcc};
/// let session = trace::session();
/// let mut acc = PhaseAcc::<2>::start();
/// // ... phase 0 work (possibly over many blocks) ...
/// acc.mark(0);
/// // ... phase 1 work ...
/// acc.mark(1);
/// acc.finish("demo.phase", ["first", "second"], 0);
/// trace::flush_local();
/// assert_eq!(trace::span_count("demo.phase"), 2);
/// session.finish().unwrap();
/// ```
pub struct PhaseAcc<const N: usize> {
    on: bool,
    start_ns: u64,
    mark_ns: u64,
    acc: [u64; N],
}

impl<const N: usize> PhaseAcc<N> {
    /// Begin timing a shard. Inert (no clock read) when tracing is off.
    #[inline]
    pub fn start() -> Self {
        if !enabled() {
            return Self { on: false, start_ns: 0, mark_ns: 0, acc: [0; N] };
        }
        let t = now_ns();
        Self { on: true, start_ns: t, mark_ns: t, acc: [0; N] }
    }

    /// Attribute the time since the previous mark to `phase`. Call after
    /// each phase of each block; costs accumulate across blocks.
    #[inline]
    pub fn mark(&mut self, phase: usize) {
        if !self.on {
            return;
        }
        let t = now_ns();
        self.acc[phase] += t - self.mark_ns;
        self.mark_ns = t;
    }

    /// Emit the `N` accumulated phase spans (sequential from the shard's
    /// start) under category `cat` on lane `tid`.
    pub fn finish(self, cat: &'static str, names: [&'static str; N], tid: u32) {
        if !self.on {
            return;
        }
        let mut ts = self.start_ns;
        for (i, name) in names.iter().enumerate() {
            push(Event::Span { cat, name, tid, ts_ns: ts, dur_ns: self.acc[i] });
            ts += self.acc[i];
        }
    }

    /// Whether this accumulator is live (tracing was on at start).
    pub fn is_on(&self) -> bool {
        self.on
    }
}

/// A tiny local histogram: accumulate values on the caller's stack, then
/// [`Histogram::emit`] the summary as gauges (count/mean/min/max). Never
/// touches the trace buffers until `emit`.
pub struct Histogram {
    name: &'static str,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new(name: &'static str) -> Self {
        Self { name, count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Emit `<name>.count/.mean/.min/.max` gauges (no-op when empty or
    /// tracing is off).
    pub fn emit(&self) {
        if self.count == 0 || !enabled() {
            return;
        }
        gauge(&format!("{}.count", self.name), self.count as f64);
        gauge(&format!("{}.mean", self.name), self.sum / self.count as f64);
        gauge(&format!("{}.min", self.name), self.min);
        gauge(&format!("{}.max", self.name), self.max);
    }
}

// ---------------------------------------------------------------------
// Sessions
// ---------------------------------------------------------------------

/// Owns the global tracing gate. Created by [`session`] /
/// [`session_to`]; dropping (or [`TraceSession::finish`]ing) disables
/// tracing and, when a path was given, writes the Chrome trace file.
/// Sessions serialize on a process-wide lock, so holding one guarantees
/// the collector contains only this session's events.
pub struct TraceSession {
    _lock: MutexGuard<'static, ()>,
    chrome_path: Option<String>,
}

/// Start a trace session with no Chrome-trace file (JSONL drain only).
pub fn session() -> TraceSession {
    session_impl(None, true)
}

/// Start a trace session that writes a Chrome trace-event JSON file to
/// `path` when finished (the `--trace <path>` CLI flag lands here).
pub fn session_to(path: &str) -> TraceSession {
    session_impl(Some(path.to_string()), true)
}

/// Test support: hold the session lock with tracing left **disabled**,
/// so a disabled-mode workload can run without another test enabling the
/// gate mid-flight.
pub fn session_disabled() -> TraceSession {
    session_impl(None, false)
}

fn session_impl(chrome_path: Option<String>, enable: bool) -> TraceSession {
    let lock = SESSION.lock().unwrap_or_else(|e| e.into_inner());
    {
        let mut c = lock_collector();
        c.events.clear();
        c.cursor = 0;
    }
    // Drop events a previous session left in this thread's buffer.
    LOCAL.with(|b| b.borrow_mut().clear());
    ENABLED.store(enable, Ordering::Relaxed);
    TraceSession { _lock: lock, chrome_path }
}

impl TraceSession {
    /// Disable tracing, flush this thread, and write the Chrome trace
    /// file if a path was configured.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.close()
    }

    fn close(&mut self) -> std::io::Result<()> {
        ENABLED.store(false, Ordering::Relaxed);
        flush_local();
        if let Some(path) = self.chrome_path.take() {
            std::fs::write(&path, chrome_trace_json().to_string())?;
        }
        Ok(())
    }

    /// The Chrome trace-event document for everything collected so far.
    pub fn chrome_json(&self) -> Json {
        flush_local();
        chrome_trace_json()
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        let _ = self.close();
    }
}

// ---------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------

/// Drain everything collected since the previous drain into
/// schema-versioned JSONL records for step `step`: spans are aggregated
/// per `(cat, name)` into `{count, total_us}` summaries, counters are
/// summed per name, gauges pass through individually. The events stay in
/// the collector for the Chrome export.
pub fn drain_step_records(step: u64) -> Vec<Json> {
    flush_local();
    let mut c = lock_collector();
    let start = c.cursor;
    c.cursor = c.events.len();
    let mut spans: Vec<(&'static str, &'static str, u64, u64)> = Vec::new();
    let mut counters: Vec<(&'static str, f64)> = Vec::new();
    let mut out = Vec::new();
    for ev in &c.events[start..] {
        match ev {
            Event::Span { cat, name, dur_ns, .. } => {
                let (cat, name, dur) = (*cat, *name, *dur_ns);
                match spans.iter_mut().find(|e| e.0 == cat && e.1 == name) {
                    Some(e) => {
                        e.2 += 1;
                        e.3 += dur;
                    }
                    None => spans.push((cat, name, 1, dur)),
                }
            }
            Event::Counter { name, value, .. } => {
                let (name, value) = (*name, *value);
                match counters.iter_mut().find(|e| e.0 == name) {
                    Some(e) => e.1 += value,
                    None => counters.push((name, value)),
                }
            }
            Event::Gauge { name, value, .. } => out.push(json::obj(vec![
                ("kind", json::s("trace")),
                ("v", json::num(SCHEMA_VERSION as f64)),
                ("type", json::s("gauge")),
                ("step", json::num(step as f64)),
                ("name", json::s(name)),
                ("value", json::num(*value)),
            ])),
        }
    }
    for (cat, name, count, total_ns) in spans {
        out.push(json::obj(vec![
            ("kind", json::s("trace")),
            ("v", json::num(SCHEMA_VERSION as f64)),
            ("type", json::s("spans")),
            ("step", json::num(step as f64)),
            ("cat", json::s(cat)),
            ("name", json::s(name)),
            ("count", json::num(count as f64)),
            ("total_us", json::num(total_ns as f64 / 1e3)),
        ]));
    }
    for (name, value) in counters {
        out.push(json::obj(vec![
            ("kind", json::s("trace")),
            ("v", json::num(SCHEMA_VERSION as f64)),
            ("type", json::s("counter")),
            ("step", json::num(step as f64)),
            ("name", json::s(name)),
            ("value", json::num(value)),
        ]));
    }
    out
}

/// Build the Chrome trace-event document (the `--trace` file contents):
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}` with complete
/// (`"ph":"X"`) events for spans and counter (`"ph":"C"`) events for
/// gauges/counters, sorted so `ts` is monotonic. Timestamps are
/// microseconds from the process trace epoch.
pub fn chrome_trace_json() -> Json {
    let c = lock_collector();
    let mut order: Vec<usize> = (0..c.events.len()).collect();
    order.sort_by_key(|&i| c.events[i].ts_ns());
    let mut arr = Vec::with_capacity(order.len());
    for i in order {
        match &c.events[i] {
            Event::Span { cat, name, tid, ts_ns, dur_ns } => arr.push(json::obj(vec![
                ("ph", json::s("X")),
                ("pid", json::num(1.0)),
                ("tid", json::num(*tid as f64)),
                ("ts", json::num(*ts_ns as f64 / 1e3)),
                ("dur", json::num(*dur_ns as f64 / 1e3)),
                ("cat", json::s(cat)),
                ("name", json::s(name)),
            ])),
            Event::Counter { name, value, ts_ns } => arr.push(counter_event(name, *value, *ts_ns)),
            Event::Gauge { name, value, ts_ns } => arr.push(counter_event(name, *value, *ts_ns)),
        }
    }
    json::obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

fn counter_event(name: &str, value: f64, ts_ns: u64) -> Json {
    json::obj(vec![
        ("ph", json::s("C")),
        ("pid", json::num(1.0)),
        ("tid", json::num(0.0)),
        ("ts", json::num(ts_ns as f64 / 1e3)),
        ("name", json::s(name)),
        ("args", json::obj(vec![("value", json::num(value))])),
    ])
}

// ---------------------------------------------------------------------
// Introspection (tests + the trace-smoke lane)
// ---------------------------------------------------------------------

/// Number of collected spans in category `cat` (flushes this thread
/// first; pool workers flush at each dispatch end).
pub fn span_count(cat: &str) -> usize {
    flush_local();
    lock_collector()
        .events
        .iter()
        .filter(|e| matches!(e, Event::Span { cat: c, .. } if *c == cat))
        .count()
}

/// Total number of collected events (flushes this thread first).
pub fn collected_len() -> usize {
    flush_local();
    lock_collector().events.len()
}

/// (len, capacity) of this thread's local event buffer — the
/// disabled-mode zero-cost test asserts both stay 0.
#[doc(hidden)]
pub fn local_buffer_stats() -> (usize, usize) {
    LOCAL.with(|b| {
        let buf = b.borrow();
        (buf.len(), buf.capacity())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_phase_acc_is_inert() {
        // No session: the gate is (at least initially) off in this
        // process; an inert accumulator records nothing and reads no
        // clock (start_ns stays 0).
        let mut acc = PhaseAcc::<3>::start();
        if acc.is_on() {
            return; // another test binary quirk; covered by test_trace.rs
        }
        acc.mark(0);
        acc.mark(2);
        assert_eq!(acc.start_ns, 0);
        assert_eq!(acc.acc, [0; 3]);
        acc.finish("never", ["a", "b", "c"], 0);
    }

    #[test]
    fn disabled_span_guard_is_inert() {
        let g = begin();
        if g.is_on() {
            return;
        }
        assert_eq!(g.start_ns(), 0);
        g.end("never", "x", 0);
    }

    #[test]
    fn counter_event_shape() {
        let ev = counter_event("m", 2.5, 3_000);
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("C"));
        assert_eq!(ev.get("ts").and_then(Json::as_f64), Some(3.0));
        assert_eq!(
            ev.get("args").and_then(|a| a.get("value")).and_then(Json::as_f64),
            Some(2.5)
        );
    }
}
