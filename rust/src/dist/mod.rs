//! Multi-replica data-parallel training engine with a real wire layer.
//!
//! MicroAdam's core trick — error feedback whose correction buffer is
//! itself compressed — was lifted from distributed optimization. This
//! module puts the mechanism back in its native habitat: `N` replicas
//! each draw their **own** seeded data shard, compute local gradients,
//! and exchange them through a pluggable [`GradReducer`] before every
//! process applies the same optimizer step. Replicas can share one
//! address space (loopback) or live in separate processes connected by
//! Unix-domain sockets or shared-memory mailboxes — same math, same
//! bytes, bit-identical trajectories.
//!
//! Layer map:
//! * [`reducer`] — the exchange math: [`DenseAllReduce`] (exact mean
//!   baseline), [`TopKReduce`] (per-rank block Top-K sparsification), and
//!   [`EfTopKReduce`] (Top-K + per-rank 4-bit-quantized error-feedback
//!   residuals, reusing [`crate::quant::Quant4`] and the optimizer's
//!   [`crate::optim::microadam::EfMode`]). Each reducer exposes both the
//!   in-core `reduce` and the split compress-payload / aggregate-payloads
//!   phases the transports run. All are deterministic and bit-identical
//!   at any [`crate::exec::ExecPool`] worker count.
//! * [`wire`] — the serialization layer: a versioned, little-endian,
//!   CRC-32-guarded frame per rank per step, carrying exactly the slab
//!   the reducer holds resident. The normative byte-level spec lives in
//!   `rust/src/dist/README.md`; `wire.rs` implements that document.
//! * [`transport`] — how frames move: [`Loopback`] (in-process, still
//!   encode/decode round-tripped so framing is always exercised),
//!   [`UdsTransport`] (Unix-domain sockets with a rank-0 rendezvous),
//!   [`TcpTransport`] (the multi-host twin: the same session over
//!   `host:port` TCP with `TCP_NODELAY`), and [`ShmTransport`]
//!   (file-backed shared-memory mailboxes, page-cache only on tmpfs).
//!   All implement the same gather-to-all [`Transport`] collective,
//!   split into `post_send`/`collect` phases so the rank-0 coordinator
//!   pipelines its relay with the still-arriving worker frames. The
//!   uds/tcp transports additionally re-wire into ring or tree
//!   topologies (`--topology ring|tree`): [`RingDriver`] forwards
//!   partially-aggregated hop frames to the successor rank,
//!   [`TreeDriver`] gathers from binary-tree children and relays the
//!   bundle down — both bit-identical to the star collective.
//! * [`replica`] — per-rank state: rank-seeded `MarkovCorpus` /
//!   `NliDataset` / `ImageDataset` streams (artifact engine) or a
//!   pure-rust MLP shard (native engine, runs on the stub runtime), with
//!   rank 0 reproducing the single-process trainer's stream exactly.
//! * [`trainer`] — [`DistTrainer`]: one process's endpoint of the
//!   synchronous data-parallel loop, wrapping the coordinator's
//!   config/metrics/checkpoint stack and feeding the aggregated gradient
//!   into the ordinary [`crate::optim::Optimizer::step_multi`] hot path.
//!
//! Wire/bytes accounting is **physical**: the sparse reducers hold real
//! `(u16 index, bf16 value)` slabs in RAM (4 B per entry), a frame is
//! exactly those payload bytes plus the fixed
//! [`wire::FRAME_OVERHEAD`] — asserted every step and measured over the
//! real socket/mailbox in the transport parity tests. Dense f32 costs
//! 4 B/param; the EF residual costs what
//! [`Quant4::state_bytes`] reports (0.5 B/param + bucket stats) per rank.
//!
//! Entry points: `microadam train --ranks N --reduce eftopk` (loopback),
//! plus `--transport uds|tcp|shm` for the multi-process launcher (rank 0
//! spawns workers, or `--rendezvous PATH|host:port` to join by hand —
//! tcp is how a run spans real hosts).
//!
//! [`DenseAllReduce`]: reducer::DenseAllReduce
//! [`TopKReduce`]: reducer::TopKReduce
//! [`EfTopKReduce`]: reducer::EfTopKReduce
//! [`GradReducer`]: reducer::GradReducer
//! [`DistTrainer`]: trainer::DistTrainer
//! [`Loopback`]: transport::Loopback
//! [`UdsTransport`]: transport::UdsTransport
//! [`TcpTransport`]: transport::TcpTransport
//! [`ShmTransport`]: transport::ShmTransport
//! [`Transport`]: transport::Transport
//! [`RingDriver`]: transport::RingDriver
//! [`TreeDriver`]: transport::TreeDriver
//! [`Quant4::state_bytes`]: crate::quant::Quant4::state_bytes

pub mod reducer;
pub mod replica;
pub mod trainer;
pub mod transport;
pub mod wire;

pub use reducer::{
    build_reducer, parse_reducer, reducer_name, DenseAllReduce, EfTopKReduce, GradReducer,
    ReducerKind, SparseReduceConfig, TopKReduce,
};
pub use replica::{
    is_native_model, native_model_spec, rank_data_seed, NativeModelSpec, NativeReplica,
};
pub use trainer::DistTrainer;
pub use transport::{
    default_rendezvous, parse_topology, parse_transport, ring_tcp_coordinator, ring_tcp_worker,
    ring_uds_coordinator, ring_uds_worker, topology_name, transport_name, tree_tcp_coordinator,
    tree_tcp_worker, tree_uds_coordinator, tree_uds_worker, GatherStream, Loopback, RingDriver,
    ShmTransport, TcpPending, TcpTransport, Topology, Transport, TransportKind, TreeDriver,
    UdsPending, UdsTransport,
};
pub use wire::{Frame, FrameReader, PayloadTag, WireError, FLAG_HOP, FRAME_OVERHEAD};
