//! In-process multi-replica data-parallel training engine.
//!
//! MicroAdam's core trick — error feedback whose correction buffer is
//! itself compressed — was lifted from distributed optimization. This
//! module puts the mechanism back in its native habitat: `N` simulated
//! replicas each draw their **own** seeded data shard, compute local
//! gradients against the shared parameters, and exchange them through a
//! pluggable [`GradReducer`] before one shared optimizer step.
//!
//! Layer map:
//! * [`reducer`] — the exchange: [`DenseAllReduce`] (exact mean baseline),
//!   [`TopKReduce`] (per-rank block-wise Top-K sparsification), and
//!   [`EfTopKReduce`] (Top-K + per-rank 4-bit-quantized error-feedback
//!   residuals, reusing [`crate::quant::Quant4`] and the optimizer's
//!   [`crate::optim::microadam::EfMode`]). All are deterministic and
//!   bit-identical at any [`crate::exec::ExecPool`] worker count.
//! * [`replica`] — per-rank state: rank-seeded `MarkovCorpus` /
//!   `NliDataset` / `ImageDataset` streams (artifact engine) or a
//!   pure-rust MLP shard (native engine, runs on the stub runtime), with
//!   rank 0 reproducing the single-process trainer's stream exactly.
//! * [`trainer`] — [`DistTrainer`]: the synchronous data-parallel loop,
//!   wrapping the coordinator's config/metrics/checkpoint stack and
//!   feeding the aggregated gradient into the ordinary
//!   [`crate::optim::Optimizer::step_multi`] hot path with real
//!   per-tensor chunk boundaries.
//!
//! Wire/bytes accounting is **physical**: the sparse reducers hold real
//! `(u16 index, bf16 value)` slabs in RAM (4 B per entry, derived from
//! the resident buffer lengths and asserted against the formula), dense
//! f32 costs 4 B/param, and the EF residual costs what
//! [`Quant4::state_bytes`] reports (0.5 B/param + bucket stats) per rank.
//!
//! This is a *simulation* of the transport (replicas share one address
//! space; "bytes on the wire" are accounted, not moved through sockets) —
//! a real multi-process transport is a ROADMAP follow-up. The compression
//! math, EF state, and trajectory semantics are the real thing.
//!
//! [`DenseAllReduce`]: reducer::DenseAllReduce
//! [`TopKReduce`]: reducer::TopKReduce
//! [`EfTopKReduce`]: reducer::EfTopKReduce
//! [`GradReducer`]: reducer::GradReducer
//! [`DistTrainer`]: trainer::DistTrainer
//! [`Quant4::state_bytes`]: crate::quant::Quant4::state_bytes

pub mod reducer;
pub mod replica;
pub mod trainer;

pub use reducer::{
    build_reducer, parse_reducer, reducer_name, DenseAllReduce, EfTopKReduce, GradReducer,
    ReducerKind, SparseReduceConfig, TopKReduce,
};
pub use replica::{
    is_native_model, native_model_spec, rank_data_seed, NativeModelSpec, NativeReplica,
};
pub use trainer::DistTrainer;
