//! Simulated data-parallel replicas: each rank owns its own seeded data
//! shard and gradient buffer; parameters are shared (read-only) during the
//! local gradient phase, exactly like synchronous data-parallel training.
//!
//! Two replica flavours match the two gradient backends:
//!
//! * [`NativeReplica`] — pure-rust [`Mlp`] fwd/bwd over a per-rank
//!   [`NliDataset`] stream. Runs everywhere (stub runtime included) and
//!   fans out across the [`crate::exec::ExecPool`], since `Mlp::loss_grad`
//!   takes `&self`.
//! * [`ArtifactReplica`] — the shared AOT artifact computes the gradient;
//!   per-rank [`crate::coordinator::trainer::Data`] streams (MarkovCorpus /
//!   NliDataset / ImageDataset, per the artifact's input signature) feed
//!   it. Execution is sequential across ranks: there is one PJRT client.
//!
//! Seeding: [`rank_data_seed`] mixes the rank into the run seed with a
//! golden-ratio stride; **rank 0 reproduces the single-process
//! [`crate::coordinator::trainer::Trainer`] data stream exactly**, which is
//! what makes the `ranks=1` + dense-reduce parity guarantee testable
//! bit-for-bit.

use anyhow::{bail, Result};

use crate::coordinator::trainer::Data;
use crate::data::NliDataset;
use crate::models::mlp::Mlp;
use crate::runtime::{self, ArtifactMeta, Literal, Runtime};

/// Per-rank data seed: rank 0 equals the single-process trainer's
/// `seed ^ 0xda7a`; higher ranks stride by the 64-bit golden ratio so
/// shards are decorrelated but reproducible.
pub fn rank_data_seed(seed: u64, rank: usize) -> u64 {
    (seed ^ 0xda7a).wrapping_add((rank as u64).wrapping_mul(0x9e37_79b9_97f4_a7c5))
}

/// Geometry of a native (artifact-free) MLP workload.
#[derive(Debug, Clone)]
pub struct NativeModelSpec {
    /// Layer sizes `[input, hidden.., classes]`; input = vocab for the
    /// bag-of-tokens featurization.
    pub sizes: Vec<usize>,
    pub vocab: usize,
    pub n_classes: usize,
    pub seq: usize,
    pub batch: usize,
}

/// Whether `name` is one of the known native model presets.
pub fn is_native_model(name: &str) -> bool {
    matches!(name, "mlp_tiny" | "mlp_small")
}

/// Resolve a native model preset by name. Unknown names get the `mlp_tiny`
/// geometry — the fallback workload when no artifact runtime is available.
/// (Explicitly-requested `mlp*` names are validated upstream via
/// [`is_native_model`], so a typo doesn't silently train the wrong model.)
pub fn native_model_spec(name: &str) -> NativeModelSpec {
    match name {
        "mlp_small" => NativeModelSpec {
            sizes: vec![128, 64, 32, 3],
            vocab: 128,
            n_classes: 3,
            seq: 32,
            batch: 16,
        },
        _ => NativeModelSpec {
            sizes: vec![64, 32, 16, 3],
            vocab: 64,
            n_classes: 3,
            seq: 24,
            batch: 16,
        },
    }
}

/// One rank of the native (pure-rust MLP) engine.
pub struct NativeReplica {
    pub rank: usize,
    ds: NliDataset,
    toks: Vec<i32>,
    labels: Vec<i32>,
    feats: Vec<f32>,
    /// Local gradient of the last step (length `mlp.dim()`).
    pub grads: Vec<f32>,
    /// Local loss of the last step.
    pub last_loss: f32,
}

impl NativeReplica {
    pub fn new(rank: usize, spec: &NativeModelSpec, seed: u64, d: usize) -> Self {
        Self {
            rank,
            ds: NliDataset::new(spec.vocab, spec.n_classes, rank_data_seed(seed, rank)),
            toks: Vec::new(),
            labels: Vec::new(),
            feats: Vec::new(),
            grads: vec![0.0; d],
            last_loss: f32::NAN,
        }
    }

    /// Draw this rank's next batch and compute the local gradient on the
    /// shared `params`. Safe to run concurrently across replicas: `mlp`
    /// and `params` are read-only, all written state is rank-local.
    pub fn local_step(&mut self, mlp: &Mlp, spec: &NativeModelSpec, params: &[f32]) {
        self.ds.next_batch(spec.batch, spec.seq, &mut self.toks, &mut self.labels);
        Mlp::featurize_tokens(spec.vocab, &self.toks, spec.seq, &mut self.feats);
        self.last_loss = mlp.loss_grad(params, &self.feats, &self.labels, &mut self.grads);
    }
}

/// One rank of the artifact (AOT runtime) engine.
pub struct ArtifactReplica {
    pub rank: usize,
    data: Data,
    /// Local gradient of the last step (length `d_padded`).
    pub grads: Vec<f32>,
    pub last_loss: f32,
}

impl ArtifactReplica {
    pub fn new(rank: usize, meta: &ArtifactMeta, seed: u64, d_padded: usize) -> Result<Self> {
        Ok(Self {
            rank,
            data: Data::from_meta(meta, rank_data_seed(seed, rank))?,
            grads: vec![0.0; d_padded],
            last_loss: f32::NAN,
        })
    }

    /// Draw this rank's next batch and run the shared fwd/bwd artifact.
    /// Sequential across ranks (single PJRT client).
    pub fn local_step(
        &mut self,
        rt: &mut Runtime,
        model: &str,
        params: &Literal,
    ) -> Result<()> {
        let mut inputs = vec![params.clone()];
        inputs.extend(self.data.next_batch_literals()?);
        let mut outs = rt.execute_named(model, &inputs)?;
        if outs.len() < 2 {
            bail!("dist: fwd/bwd artifact returned {} outputs, expected loss + grads", outs.len());
        }
        let Some(g) = outs.pop() else {
            bail!("dist: fwd/bwd artifact returned no gradient output");
        };
        let Some(loss) = outs.pop() else {
            bail!("dist: fwd/bwd artifact returned no loss output");
        };
        self.last_loss = runtime::scalar_f32(&loss)?;
        self.grads = runtime::to_f32(&g)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank0_seed_matches_single_process_trainer() {
        // The single-process Trainer seeds its data with `seed ^ 0xda7a`;
        // rank 0 must reproduce that stream exactly.
        assert_eq!(rank_data_seed(7, 0), 7 ^ 0xda7a);
        assert_eq!(rank_data_seed(0, 0), 0xda7a);
    }

    #[test]
    fn rank_seeds_are_distinct() {
        let seeds: Vec<u64> = (0..16).map(|r| rank_data_seed(42, r)).collect();
        for i in 0..seeds.len() {
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j]);
            }
        }
    }

    #[test]
    fn native_replicas_draw_distinct_shards() {
        let spec = native_model_spec("mlp_tiny");
        let mlp = Mlp::new(spec.sizes.clone());
        let params = mlp.init(3);
        let mut r0 = NativeReplica::new(0, &spec, 7, mlp.dim());
        let mut r1 = NativeReplica::new(1, &spec, 7, mlp.dim());
        r0.local_step(&mlp, &spec, &params);
        r1.local_step(&mlp, &spec, &params);
        assert!(r0.last_loss.is_finite());
        assert!(r1.last_loss.is_finite());
        assert_ne!(r0.grads, r1.grads, "ranks saw the same batch");
    }

    #[test]
    fn same_rank_same_seed_is_deterministic() {
        let spec = native_model_spec("mlp_tiny");
        let mlp = Mlp::new(spec.sizes.clone());
        let params = mlp.init(3);
        let mut a = NativeReplica::new(2, &spec, 7, mlp.dim());
        let mut b = NativeReplica::new(2, &spec, 7, mlp.dim());
        a.local_step(&mlp, &spec, &params);
        b.local_step(&mlp, &spec, &params);
        assert_eq!(a.grads, b.grads);
        assert_eq!(a.last_loss, b.last_loss);
    }
}
