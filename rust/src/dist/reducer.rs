//! Gradient reducers: how N replicas' local gradients become the one
//! aggregated gradient the optimizer steps on.
//!
//! Three implementations of [`GradReducer`], mirroring the compressed
//! all-reduce families MicroAdam's error-feedback mechanism comes from:
//!
//! * [`DenseAllReduce`] — the exact baseline: coordinate-wise mean of the
//!   full f32 gradients (4 B/param on the wire per rank).
//! * [`TopKReduce`] — each rank sparsifies its gradient with the same
//!   block-wise Top-K as the optimizer ([`crate::topk::topk_abs_block`])
//!   and only the selected `(index, value)` pairs travel; the coordinator
//!   densely aggregates the sparse contributions. Biased, no correction —
//!   the "TopK-SGD without EF" failure mode of Figure 1, at the
//!   communication layer.
//! * [`EfTopKReduce`] — Top-K plus a **per-rank error-feedback residual**:
//!   what the compressor dropped is carried to the next step
//!   (`a_r = g_r + Q^{-1}(e_r)`), and the residual itself is stored 4-bit
//!   via [`crate::quant::Quant4`] — the optimizer's own EF compressor
//!   ([`crate::optim::microadam::EfMode`]), now in its native distributed
//!   habitat. `EfMode::Dense` keeps the residual in f32 for the
//!   omega = 0 theory setting.
//!
//! All reducers produce the **mean** gradient, are deterministic, and are
//! bit-identical at every [`ExecPool`] worker count: the per-rank compress
//! phase shards by rank, the aggregation phase shards by block, and no
//! float op is ever reassociated across a shard boundary.
//!
//! The sparse reducers exchange **physical** `(u16 idx, bf16 val)` slabs:
//! each rank's selected values are rounded to bf16 on write (selection
//! still ranks on f32 magnitudes) and widened back on aggregation, so a
//! sparse entry costs 2 B + 2 B = 4 B *in RAM and on the accounted wire
//! alike* — the accounting is derived from the resident slab lengths and
//! asserted against the formula, not assumed. Dense f32 costs 4 B/param.
//! The bf16 rounding residual of a *selected* entry is dropped (mirroring
//! the optimizer's window semantics); the EF residual carries exactly the
//! unselected mass.
//!
//! Every reducer exposes the exchange in two equivalent shapes:
//!
//! * [`GradReducer::reduce`] — the in-core path: compress every rank
//!   (phase A, sharded by rank) and aggregate the resident slabs
//!   (phase B, sharded by block range).
//! * [`GradReducer::compress_payload`] / [`GradReducer::aggregate_payloads`]
//!   — the split-phase path the [`crate::dist::transport`] layer uses: a
//!   process compresses only the ranks it hosts into wire payloads
//!   (serialized exactly as `rust/src/dist/README.md` specifies), and
//!   aggregation decodes the gathered payloads into the same resident
//!   slabs before running the identical phase B. Both shapes run the same
//!   kernels on the same bytes, so loopback and multi-process training
//!   are bit-identical by construction.
//!
//! ```
//! use microadam::dist::{build_reducer, GradReducer, ReducerKind, SparseReduceConfig};
//! use microadam::exec::ExecPool;
//!
//! // two ranks, 256 params, paper-default compression geometry
//! let mut r = build_reducer(ReducerKind::EfTopK, 256, 2, SparseReduceConfig::default());
//! let g0 = vec![0.1f32; 256];
//! let g1 = vec![0.3f32; 256];
//! let mut mean = vec![0f32; 256];
//! r.reduce(&[&g0[..], &g1[..]], &mut mean, &ExecPool::serial());
//! // far below the dense 4 B/param exchange
//! assert!(r.wire_bytes_per_rank() < 4 * 256);
//! ```

use anyhow::{anyhow, bail, Result};

use super::wire::{self, PayloadTag};
use crate::exec::{self, ExecPool};
use crate::optim::microadam::EfMode;
use crate::quant::{BucketStats, Quant4};
use crate::topk::topk_abs_block_bf16;
use crate::util::bf16::bf16_to_f32;

/// Which gradient reducer a config/CLI names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReducerKind {
    Dense,
    TopK,
    EfTopK,
}

/// Parse a reducer name (kebab-case, as in the CLI and config files).
pub fn parse_reducer(s: &str) -> Result<ReducerKind> {
    Ok(match s {
        "dense" | "allreduce" => ReducerKind::Dense,
        "topk" => ReducerKind::TopK,
        "eftopk" | "ef-topk" => ReducerKind::EfTopK,
        other => bail!("unknown reducer {other} (expected dense|topk|eftopk)"),
    })
}

/// Canonical name of a reducer kind.
pub fn reducer_name(k: ReducerKind) -> &'static str {
    match k {
        ReducerKind::Dense => "dense",
        ReducerKind::TopK => "topk",
        ReducerKind::EfTopK => "eftopk",
    }
}

/// Whether `reduce` is mathematically sound under `optimizer`.
///
/// Dense is exact and EF-Top-K is self-correcting (the communication-side
/// residual re-injects whatever a step dropped), so both compose with any
/// optimizer. Plain Top-K permanently discards gradient mass; MicroAdam and
/// the Adam family tolerate that bias on this workload and stay supported
/// for the sweep tables, but LDAdam and Adam-mini maintain their own
/// compressed state downstream of the exchange (LDAdam's low-rank EF
/// accumulator, Adam-mini's per-block second moment) and compounding an
/// uncorrected communication bias into that state is exactly the
/// silently-wrong-numbers failure the typed error exists to prevent.
pub fn reducer_supported(optimizer: crate::optim::OptimizerKind, reduce: ReducerKind) -> bool {
    use crate::optim::OptimizerKind;
    match reduce {
        ReducerKind::Dense | ReducerKind::EfTopK => true,
        ReducerKind::TopK => {
            !matches!(optimizer, OptimizerKind::LdAdam | OptimizerKind::AdamMini)
        }
    }
}

/// Combine per-rank gradients into the mean aggregated gradient.
pub trait GradReducer: Send {
    /// Display name (bench table row label).
    fn name(&self) -> String;
    /// Aggregate `grads` (one length-`d` slice per rank, in rank order)
    /// into `out` (length `d`): the mean of the ranks' — possibly
    /// compressed — contributions. Deterministic and bit-identical at any
    /// `pool` worker count.
    fn reduce(&mut self, grads: &[&[f32]], out: &mut [f32], pool: &ExecPool);
    /// Wire tag this reducer's payloads carry (frame type checking).
    fn payload_tag(&self) -> PayloadTag;
    /// Phase A for one hosted rank: fold `grad` through the rank's
    /// compressor state (updating its error-feedback residual, if any) and
    /// return the serialized wire payload — exactly
    /// [`GradReducer::wire_bytes_per_rank`] bytes, laid out as the wire
    /// spec (`rust/src/dist/README.md`) defines for
    /// [`GradReducer::payload_tag`].
    fn compress_payload(&mut self, rank: usize, grad: &[f32]) -> Vec<u8>;
    /// Phase B from gathered payloads (one per rank, rank order): decode
    /// them into the resident slabs and aggregate the mean into `out`.
    /// Runs the same aggregation kernel as [`GradReducer::reduce`], so for
    /// payloads produced by [`GradReducer::compress_payload`] the result
    /// is bit-identical to the in-core path.
    fn aggregate_payloads(
        &mut self,
        payloads: &[Vec<u8>],
        out: &mut [f32],
        pool: &ExecPool,
    ) -> Result<()>;
    /// Phase B, streaming entry point: decode one gathered rank's payload
    /// into its resident slot. Decoding rank `r` touches only rank `r`'s
    /// state, so frames can be decoded in *arrival* order while later
    /// frames are still in flight; once every rank is loaded,
    /// [`GradReducer::aggregate_loaded`] runs the identical phase-B kernel
    /// as [`GradReducer::aggregate_payloads`], so the streaming and batch
    /// paths are bit-identical by construction.
    fn load_payload(&mut self, rank: usize, payload: &[u8]) -> Result<()>;
    /// Aggregate the slots populated by [`GradReducer::load_payload`] into
    /// `out` (the mean). Bit-identical to
    /// [`GradReducer::aggregate_payloads`] over the same payloads.
    fn aggregate_loaded(&mut self, out: &mut [f32], pool: &ExecPool) -> Result<()>;
    /// The associative partial-aggregate over one wire payload — the ring
    /// hop kernel: parse `payload`'s bytes directly (no resident slab is
    /// touched, hence `&self`) and add its contribution into the running
    /// per-coordinate sum `acc` (length `d`). Zero-initializing `acc`,
    /// folding every rank's payload in **ascending rank order**, then
    /// calling [`GradReducer::finalize_partial`] reproduces
    /// [`GradReducer::aggregate_payloads`] bit-for-bit: both paths start
    /// each coordinate's sum at 0.0 and apply the same additions in the
    /// same (rank, slab-entry) order, ending on the one multiply by `1/n`.
    fn accumulate_payload(&self, payload: &[u8], acc: &mut [f32]) -> Result<()>;
    /// Turn the rank-ascending partial sum built by
    /// [`GradReducer::accumulate_payload`] folds into the mean — the single
    /// `* 1/ranks` the phase-B kernels end on.
    fn finalize_partial(&self, acc: &mut [f32]);
    /// Paper-dtype bytes one rank puts on the wire per step.
    fn wire_bytes_per_rank(&self) -> usize;
    /// Persistent compressor/residual state across all ranks, paper dtypes
    /// (0 for stateless reducers).
    fn residual_state_bytes(&self) -> usize;
    /// L2 norm of rank `r`'s dequantized EF residual (0 for stateless).
    fn residual_norm(&self, rank: usize) -> f32 {
        let _ = rank;
        0.0
    }
    /// Fraction of rank `r`'s `|a_r|` mass the last Top-K selection
    /// captured (1.0 for lossless reducers). EF-health telemetry: only
    /// refreshed while [`crate::trace::enabled`] — stale otherwise.
    fn topk_mass(&self, rank: usize) -> f32 {
        let _ = rank;
        1.0
    }
    /// Mean absolute Quant4 error of rank `r`'s last residual
    /// re-quantization (0 when the residual is unquantized). EF-health
    /// telemetry: only refreshed while [`crate::trace::enabled`].
    fn quant_abs_err(&self, rank: usize) -> f32 {
        let _ = rank;
        0.0
    }
    /// Fraction of coordinates each rank communicates per step (the slab
    /// density `nb*kb/d`; 1.0 for dense exchange).
    fn slab_density(&self) -> f64 {
        1.0
    }
}

/// Shared compression geometry for the sparse reducers (defaults follow the
/// optimizer's paper constants).
#[derive(Debug, Clone, Copy)]
pub struct SparseReduceConfig {
    /// Top-K block size `B_d` (clamped to the problem dimension).
    pub block: usize,
    /// Communicated gradient density `k/d`.
    pub density: f64,
    /// EF quantization bucket `B_q` (EfTopK only).
    pub qbucket: usize,
    /// Residual storage mode (EfTopK only; `Off` turns EfTopK into TopK).
    pub ef: EfMode,
}

impl Default for SparseReduceConfig {
    fn default() -> Self {
        Self {
            block: crate::BLOCK,
            density: crate::DENSITY,
            qbucket: crate::QBUCKET,
            ef: EfMode::Quant4,
        }
    }
}

/// Build a reducer by kind for `ranks` replicas over dimension `d`.
pub fn build_reducer(
    kind: ReducerKind,
    d: usize,
    ranks: usize,
    cfg: SparseReduceConfig,
) -> Box<dyn GradReducer> {
    match kind {
        ReducerKind::Dense => Box::new(DenseAllReduce::new(d, ranks)),
        ReducerKind::TopK => Box::new(TopKReduce::new(d, ranks, cfg)),
        ReducerKind::EfTopK => Box::new(EfTopKReduce::new(d, ranks, cfg)),
    }
}

// ---------------------------------------------------------------------------
// DenseAllReduce
// ---------------------------------------------------------------------------

/// Exact mean of full-precision gradients (the no-compression baseline).
pub struct DenseAllReduce {
    d: usize,
    ranks: usize,
    /// Payload-decode scratch (`ranks * d`, rank-major), allocated on
    /// first use so the per-step aggregate path stays allocation-free.
    rx: Vec<f32>,
}

impl DenseAllReduce {
    pub fn new(d: usize, ranks: usize) -> Self {
        assert!(d > 0 && ranks > 0);
        Self { d, ranks, rx: Vec::new() }
    }
}

/// The dense aggregation kernel, shared verbatim by the in-core and
/// payload-decoded paths so the two cannot diverge by a float op:
/// coordinate-sharded, rank-ascending summation, one multiply by `1/n`.
fn dense_mean(d: usize, ranks: usize, grads: &[&[f32]], out: &mut [f32], pool: &ExecPool) {
    assert_eq!(grads.len(), ranks);
    assert_eq!(out.len(), d);
    if ranks == 1 {
        // single-rank fast path: the mean IS the gradient, bit-for-bit
        out.copy_from_slice(grads[0]);
        return;
    }
    let inv = 1.0f32 / ranks as f32;
    let ranges = exec::chunk_ranges(d, pool.workers());
    let mut shards = Vec::with_capacity(ranges.len());
    let mut rest = out;
    let mut start = 0usize;
    for r in &ranges {
        let (chunk, next) = rest.split_at_mut(r.len());
        rest = next;
        shards.push((start, chunk));
        start = r.end;
    }
    pool.run_shards(shards, |_, (base, chunk)| {
        for (i, o) in chunk.iter_mut().enumerate() {
            // fixed rank-ascending summation: the result cannot depend
            // on how coordinates were sharded
            let mut s = 0f32;
            for g in grads {
                s += g[base + i];
            }
            *o = s * inv;
        }
    });
}

/// The shared `* 1/ranks` epilogue of every phase-B kernel, reused by the
/// ring partial path so the final multiply cannot diverge between them.
fn scale_mean(acc: &mut [f32], ranks: usize) {
    let inv = 1.0f32 / ranks as f32;
    for a in acc.iter_mut() {
        *a *= inv;
    }
}

impl GradReducer for DenseAllReduce {
    fn name(&self) -> String {
        "dense-allreduce".into()
    }

    fn reduce(&mut self, grads: &[&[f32]], out: &mut [f32], pool: &ExecPool) {
        dense_mean(self.d, self.ranks, grads, out, pool);
    }

    fn payload_tag(&self) -> PayloadTag {
        PayloadTag::Dense
    }

    fn compress_payload(&mut self, rank: usize, grad: &[f32]) -> Vec<u8> {
        assert!(rank < self.ranks);
        assert_eq!(grad.len(), self.d);
        wire::dense_payload(grad)
    }

    fn aggregate_payloads(
        &mut self,
        payloads: &[Vec<u8>],
        out: &mut [f32],
        pool: &ExecPool,
    ) -> Result<()> {
        if payloads.len() != self.ranks {
            bail!("dense aggregate: {} payloads for {} ranks", payloads.len(), self.ranks);
        }
        // f32 bit patterns round-trip the payload codec exactly, so this
        // path is bit-identical to `reduce` on the original gradients.
        self.rx.resize(self.ranks * self.d, 0.0);
        for (r, (buf, p)) in self.rx.chunks_mut(self.d).zip(payloads).enumerate() {
            wire::dense_from_payload(p, buf).map_err(|e| anyhow!("rank {r} payload: {e}"))?;
        }
        let refs: Vec<&[f32]> = self.rx.chunks(self.d).collect();
        dense_mean(self.d, self.ranks, &refs, out, pool);
        Ok(())
    }

    fn load_payload(&mut self, rank: usize, payload: &[u8]) -> Result<()> {
        if rank >= self.ranks {
            bail!("dense load: rank {rank} out of range ({} ranks)", self.ranks);
        }
        self.rx.resize(self.ranks * self.d, 0.0);
        wire::dense_from_payload(payload, &mut self.rx[rank * self.d..(rank + 1) * self.d])
            .map_err(|e| anyhow!("rank {rank} payload: {e}"))
    }

    fn aggregate_loaded(&mut self, out: &mut [f32], pool: &ExecPool) -> Result<()> {
        if self.rx.len() != self.ranks * self.d {
            bail!("dense aggregate: no payloads loaded");
        }
        let refs: Vec<&[f32]> = self.rx.chunks(self.d).collect();
        dense_mean(self.d, self.ranks, &refs, out, pool);
        Ok(())
    }

    fn accumulate_payload(&self, payload: &[u8], acc: &mut [f32]) -> Result<()> {
        if acc.len() != self.d {
            bail!("dense accumulate: partial length {} != d {}", acc.len(), self.d);
        }
        if payload.len() != 4 * self.d {
            bail!("dense accumulate: payload {} B != {} B", payload.len(), 4 * self.d);
        }
        // Bit-preserving f32 reads added in coordinate order — per
        // coordinate this is exactly one term of dense_mean's
        // rank-ascending `s += g[i]` chain.
        for (a, b) in acc.iter_mut().zip(payload.chunks_exact(4)) {
            // repolint: allow(no-panic): chunks_exact(4) yields 4-byte slices.
            *a += f32::from_bits(u32::from_le_bytes(b.try_into().expect("4-byte chunk")));
        }
        Ok(())
    }

    fn finalize_partial(&self, acc: &mut [f32]) {
        scale_mean(acc, self.ranks);
    }

    fn wire_bytes_per_rank(&self) -> usize {
        4 * self.d
    }

    fn residual_state_bytes(&self) -> usize {
        0
    }
}

// ---------------------------------------------------------------------------
// Sparse core shared by TopKReduce / EfTopKReduce
// ---------------------------------------------------------------------------

/// Per-rank Top-K compression state + the dense aggregation scratch. The
/// two public sparse reducers are thin wrappers selecting the EF mode.
///
/// The core is **world-sized** on every endpoint: the `idx`/`val` slabs
/// must hold all ranks for phase B, and `residual_state_bytes` reports
/// the job-wide paper accounting. A multi-process endpoint therefore also
/// carries (unused) `acc`/EF buffers for remote ranks — per-process
/// overhead of `(ranks-1) * ~1.5 d_pad` bytes, negligible for the native
/// MLP workloads the multi-process transports drive today. Lazily
/// allocating only the hosted rank's compressor state is the obvious
/// refinement if multi-process ever hosts large-`d` models.
struct SparseCore {
    d: usize,
    d_pad: usize,
    block: usize,
    nb: usize,
    kb: usize,
    ranks: usize,
    ef: EfMode,
    quant: Quant4,
    /// Quantization buckets per rank (`d_pad / qbucket`).
    nq: usize,
    /// Per-rank padded accumulator `a_r = g_r + Q^{-1}(e_r)`: `ranks * d_pad`.
    acc: Vec<f32>,
    /// Selected block-relative indices, rank-major `[rank][block][k]`.
    idx: Vec<u16>,
    /// Selected values as bf16 bits (signed), same layout — the physical
    /// wire payload.
    val: Vec<u16>,
    /// 4-bit packed EF residual per rank (`ranks * d_pad / 2`), Quant4 mode.
    ef_packed: Vec<u8>,
    ef_stats: Vec<BucketStats>,
    /// Dense f32 residual per rank (`ranks * d_pad`), Dense mode.
    ef_dense: Vec<f32>,
    /// Per-rank Top-K quickselect scratch.
    sels: Vec<Vec<u16>>,
    /// Per-rank EF-health snapshot from the last compress — refreshed only
    /// while [`crate::trace::enabled`] (the extra `O(d)` passes are skipped
    /// otherwise, so the hot path stays untouched).
    health: Vec<RankHealth>,
}

/// One rank's EF-health sample (see [`GradReducer::topk_mass`] /
/// [`GradReducer::quant_abs_err`]).
#[derive(Debug, Clone, Copy, Default)]
struct RankHealth {
    topk_mass: f32,
    quant_abs_err: f32,
}

impl SparseCore {
    fn new(d: usize, ranks: usize, cfg: SparseReduceConfig) -> Self {
        assert!(d > 0 && ranks > 0);
        // Same geometry derivation as MicroAdam::new: clamp the block to the
        // (even-rounded) dimension, shrink the bucket until it is even and
        // divides the block.
        let block = cfg.block.min(crate::pad_up(d, 2));
        let d_pad = crate::pad_up(d, block);
        let nb = d_pad / block;
        let kb = crate::kb_for_block(block, cfg.density);
        let mut qbucket = cfg.qbucket.min(block);
        while block % qbucket != 0 || qbucket % 2 != 0 {
            qbucket -= 1;
            assert!(qbucket >= 2, "no valid quantization bucket for block {block}");
        }
        let quant = Quant4::new(qbucket);
        let nq = d_pad / qbucket;
        let (ef_packed, ef_stats, ef_dense) = match cfg.ef {
            EfMode::Quant4 => (
                vec![0u8; ranks * d_pad / 2],
                vec![BucketStats { lo: 0.0, hi: 0.0 }; ranks * nq],
                Vec::new(),
            ),
            EfMode::Dense => (Vec::new(), Vec::new(), vec![0f32; ranks * d_pad]),
            EfMode::Off => (Vec::new(), Vec::new(), Vec::new()),
        };
        Self {
            d,
            d_pad,
            block,
            nb,
            kb,
            ranks,
            ef: cfg.ef,
            quant,
            nq,
            acc: vec![0.0; ranks * d_pad],
            idx: vec![0; ranks * nb * kb],
            val: vec![0; ranks * nb * kb],
            ef_packed,
            ef_stats,
            ef_dense,
            // quickselect scratch pre-sized from the layout's block length
            sels: (0..ranks).map(|_| Vec::with_capacity(block)).collect(),
            health: vec![RankHealth::default(); ranks],
        }
    }

    /// The in-core exchange: phase A over every rank, then phase B.
    fn reduce(&mut self, grads: &[&[f32]], out: &mut [f32], pool: &ExecPool) {
        self.compress_all(grads, pool);
        self.aggregate(out, pool);
    }

    /// Phase A (sharded by rank): compress every rank's gradient into its
    /// `(idx, val)` slab, updating the rank's EF residual.
    fn compress_all(&mut self, grads: &[&[f32]], pool: &ExecPool) {
        assert_eq!(grads.len(), self.ranks);
        let (d, d_pad, block, nb, kb) = (self.d, self.d_pad, self.block, self.nb, self.kb);
        let ef_mode = self.ef;
        let quant = &self.quant;
        let nq = self.nq;
        {
            let mut rank_shards = Vec::with_capacity(self.ranks);
            let mut acc_rest = &mut self.acc[..];
            let mut idx_rest = &mut self.idx[..];
            let mut val_rest = &mut self.val[..];
            let mut efp_rest = &mut self.ef_packed[..];
            let mut efs_rest = &mut self.ef_stats[..];
            let mut efd_rest = &mut self.ef_dense[..];
            let mut sel_iter = self.sels.iter_mut();
            for (&g, health) in grads.iter().zip(&mut self.health) {
                let (acc, ar) = acc_rest.split_at_mut(d_pad);
                acc_rest = ar;
                let (idx, ir) = idx_rest.split_at_mut(nb * kb);
                idx_rest = ir;
                let (val, vr) = val_rest.split_at_mut(nb * kb);
                val_rest = vr;
                let ef = match ef_mode {
                    EfMode::Off => RankEf::Off,
                    EfMode::Dense => {
                        let (e, er) = efd_rest.split_at_mut(d_pad);
                        efd_rest = er;
                        RankEf::Dense(e)
                    }
                    EfMode::Quant4 => {
                        let (p, pr) = efp_rest.split_at_mut(d_pad / 2);
                        efp_rest = pr;
                        let (s, sr) = efs_rest.split_at_mut(nq);
                        efs_rest = sr;
                        RankEf::Quant4 { packed: p, stats: s }
                    }
                };
                rank_shards.push(RankShard {
                    grad: g,
                    acc,
                    idx,
                    val,
                    ef,
                    // repolint: allow(no-panic): sels was sized to one scratch per rank above.
                    sel: sel_iter.next().expect("one scratch per rank"),
                    health,
                });
            }
            // Group ranks so at most `workers` threads run (the ExecPool
            // convention: callers build <= workers shards). Grouping cannot
            // change results: ranks never share state in this phase.
            let groups = exec::chunk_ranges(rank_shards.len(), pool.workers());
            let mut shards: Vec<Vec<RankShard>> = Vec::with_capacity(groups.len());
            for gr in &groups {
                shards.push(rank_shards.drain(..gr.len()).collect());
            }
            pool.run_shards(shards, |_, group| {
                for sh in group {
                    compress_rank(d, block, kb, quant, sh);
                }
            });
        }
    }

    /// Phase A for a single rank (the split-phase path: a process
    /// compresses only the ranks it hosts). Exactly the per-rank work of
    /// [`SparseCore::compress_all`], so the resulting slab and EF state
    /// are bit-identical whichever entry point ran.
    fn compress_one(&mut self, rank: usize, grad: &[f32]) {
        assert!(rank < self.ranks);
        assert_eq!(grad.len(), self.d);
        let (d_pad, nbkb, nq) = (self.d_pad, self.nb * self.kb, self.nq);
        let ef = match self.ef {
            EfMode::Off => RankEf::Off,
            EfMode::Dense => {
                RankEf::Dense(&mut self.ef_dense[rank * d_pad..(rank + 1) * d_pad])
            }
            EfMode::Quant4 => RankEf::Quant4 {
                packed: &mut self.ef_packed[rank * d_pad / 2..(rank + 1) * d_pad / 2],
                stats: &mut self.ef_stats[rank * nq..(rank + 1) * nq],
            },
        };
        let sh = RankShard {
            grad,
            acc: &mut self.acc[rank * d_pad..(rank + 1) * d_pad],
            idx: &mut self.idx[rank * nbkb..(rank + 1) * nbkb],
            val: &mut self.val[rank * nbkb..(rank + 1) * nbkb],
            ef,
            sel: &mut self.sels[rank],
            health: &mut self.health[rank],
        };
        compress_rank(self.d, self.block, self.kb, &self.quant, sh);
    }

    /// Serialize `rank`'s resident `(idx, val)` slab as its wire payload.
    fn rank_payload(&self, rank: usize) -> Vec<u8> {
        let nbkb = self.nb * self.kb;
        wire::slab_payload(
            &self.idx[rank * nbkb..(rank + 1) * nbkb],
            &self.val[rank * nbkb..(rank + 1) * nbkb],
        )
    }

    /// Decode one rank's gathered wire payload into its resident slab
    /// slot. Per-rank (rather than batch) so the trainer can start
    /// decoding as soon as a pipelined `collect` hands over a frame — the
    /// decode of rank `r` touches only rank `r`'s slab, so arrival order
    /// cannot matter. For the ranks this process compressed itself, the
    /// decode rewrites the identical bytes.
    fn load_payload(&mut self, rank: usize, payload: &[u8]) -> Result<()> {
        assert!(rank < self.ranks);
        let nbkb = self.nb * self.kb;
        wire::slab_from_payload(
            payload,
            &mut self.idx[rank * nbkb..(rank + 1) * nbkb],
            &mut self.val[rank * nbkb..(rank + 1) * nbkb],
        )
        .map_err(|e| anyhow!("rank {rank} slab payload: {e}"))
    }

    /// Decode gathered wire payloads (rank order) into the resident slabs.
    fn load_payloads(&mut self, payloads: &[Vec<u8>]) -> Result<()> {
        if payloads.len() != self.ranks {
            bail!("sparse aggregate: {} payloads for {} ranks", payloads.len(), self.ranks);
        }
        for (r, p) in payloads.iter().enumerate() {
            self.load_payload(r, p)?;
        }
        Ok(())
    }

    /// The ring hop kernel: parse one rank's `(u16 idx, bf16 val)` slab
    /// straight out of the wire bytes and add every entry into the dense
    /// running sum `acc`, in [`SparseCore::aggregate`]'s block/entry
    /// order. `&self`: no resident slab is touched, so a hop endpoint can
    /// fold payloads of ranks it never compressed or decoded.
    fn accumulate_payload(&self, payload: &[u8], acc: &mut [f32]) -> Result<()> {
        if acc.len() != self.d {
            bail!("sparse accumulate: partial length {} != d {}", acc.len(), self.d);
        }
        let nbkb = self.nb * self.kb;
        if payload.len() != 4 * nbkb {
            bail!("sparse accumulate: payload {} B != {} B", payload.len(), 4 * nbkb);
        }
        // Wire slab layout (see wire::slab_payload): all u16 indices, then
        // all bf16 values; entry (block b, slot k) sits at flat position
        // `b*kb + k` in both halves.
        let half = 2 * nbkb;
        for b in 0..self.nb {
            let base = b * self.block;
            // Same bound as aggregate()'s per-shard chunk length: only real
            // (unpadded) coordinates are writable, so padded-tail entries
            // (value 0 by construction) and corrupt indices alike fall to
            // the same guard star-aggregation applies. `base < d` always:
            // the last block starts below `d` by the padding construction.
            let chunk_len = self.block.min(self.d - base);
            for k in 0..self.kb {
                let e = 2 * (b * self.kb + k);
                let i = u16::from_le_bytes([payload[e], payload[e + 1]]) as usize;
                let v = u16::from_le_bytes([payload[half + e], payload[half + e + 1]]);
                if i < chunk_len {
                    acc[base + i] += bf16_to_f32(v);
                }
            }
        }
        Ok(())
    }

    /// Phase B (sharded by block range): densely aggregate the resident
    /// sparse slabs into `out` as the mean.
    fn aggregate(&self, out: &mut [f32], pool: &ExecPool) {
        assert_eq!(out.len(), self.d);
        let (d, block, nb, kb) = (self.d, self.block, self.nb, self.kb);
        let inv = 1.0f32 / self.ranks as f32;
        let ranks = self.ranks;
        let idx = &self.idx[..];
        let val = &self.val[..];
        let ranges = exec::chunk_ranges(nb, pool.workers());
        let mut shards = Vec::with_capacity(ranges.len());
        let mut rest = out;
        let mut pstart = 0usize;
        for r in &ranges {
            let pend = (r.end * block).min(d);
            let (chunk, next) = rest.split_at_mut(pend - pstart);
            rest = next;
            shards.push((r.clone(), chunk));
            pstart = pend;
        }
        pool.run_shards(shards, |_, (blocks, chunk)| {
            chunk.fill(0.0);
            let cbase = blocks.start * block;
            for b in blocks {
                let base = b * block - cbase;
                // rank-ascending accumulation per coordinate: deterministic
                // whatever the block sharding
                for r in 0..ranks {
                    let o = (r * nb + b) * kb;
                    for (&i, &v) in idx[o..o + kb].iter().zip(&val[o..o + kb]) {
                        let at = base + i as usize;
                        // Padded-tail entries land past the chunk; the tail
                        // is re-zeroed before Top-K (see compress_rank), so
                        // anything selected there carries value 0 — the
                        // guard only prevents the out-of-bounds write.
                        if at < chunk.len() {
                            chunk[at] += bf16_to_f32(v);
                        }
                    }
                }
            }
            for o in chunk.iter_mut() {
                *o *= inv;
            }
        });
    }

    /// Physical bytes of one rank's serialized `(idx, val)` slab, measured
    /// from the resident buffers (u16 indices + bf16 values).
    fn slab_bytes_per_rank(&self) -> usize {
        (std::mem::size_of_val(&self.idx[..]) + std::mem::size_of_val(&self.val[..])) / self.ranks
    }

    fn wire_bytes_per_rank(&self) -> usize {
        self.slab_bytes_per_rank()
    }

    fn residual_state_bytes(&self) -> usize {
        match self.ef {
            EfMode::Off => 0,
            EfMode::Dense => self.ranks * self.d_pad * 4,
            EfMode::Quant4 => self.ranks * self.quant.state_bytes(self.d_pad),
        }
    }

    fn slab_density(&self) -> f64 {
        (self.nb * self.kb) as f64 / self.d as f64
    }

    fn residual_norm(&self, rank: usize) -> f32 {
        assert!(rank < self.ranks);
        match self.ef {
            EfMode::Off => 0.0,
            EfMode::Dense => self.ef_dense[rank * self.d_pad..(rank + 1) * self.d_pad]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
                .sqrt(),
            EfMode::Quant4 => self.quant.l2_norm(
                &self.ef_packed[rank * self.d_pad / 2..(rank + 1) * self.d_pad / 2],
                &self.ef_stats[rank * self.nq..(rank + 1) * self.nq],
            ),
        }
    }
}

/// One rank's disjoint compression state for phase A.
struct RankShard<'a> {
    grad: &'a [f32],
    /// Padded accumulator, length `d_pad`.
    acc: &'a mut [f32],
    /// This rank's `nb * kb` selected indices / bf16 values.
    idx: &'a mut [u16],
    val: &'a mut [u16],
    ef: RankEf<'a>,
    sel: &'a mut Vec<u16>,
    health: &'a mut RankHealth,
}

enum RankEf<'a> {
    Off,
    Dense(&'a mut [f32]),
    Quant4 { packed: &'a mut [u8], stats: &'a mut [BucketStats] },
}

/// Compress one rank: `a = g + Q^{-1}(e)`, block-wise Top-K into the rank's
/// `(u16 idx, bf16 val)` slab (selection on f32 magnitudes, bf16 on the
/// wire), zero the selected entries, re-quantize the remainder into the
/// residual.
fn compress_rank(d: usize, block: usize, kb: usize, quant: &Quant4, sh: RankShard) {
    let RankShard { grad, acc, idx, val, mut ef, sel, health } = sh;
    acc[..d].copy_from_slice(grad);
    acc[d..].fill(0.0);
    match &mut ef {
        RankEf::Off => {}
        RankEf::Dense(e) => {
            for (a, ev) in acc.iter_mut().zip(e.iter()) {
                *a += *ev;
            }
        }
        RankEf::Quant4 { packed, stats } => quant.dequantize_add(packed, stats, acc),
    }
    // Re-zero the padded tail: 4-bit dequantization of a mixed real/padding
    // bucket leaves noise on padding coordinates, and near convergence
    // Top-K would select that noise — wasting wire slots and dropping real
    // gradient mass from the EF contract. No real gradient ever lives
    // beyond `d`, so clearing is exact.
    acc[d..].fill(0.0);
    // EF-health sampling has to happen inline: the remainder is overwritten
    // into the residual below, so the captured-mass fraction is measurable
    // only between selection and re-quantization. The extra O(d) passes run
    // only while tracing is on.
    let tracing = crate::trace::enabled();
    let total_abs: f64 =
        if tracing { acc.iter().map(|a| a.abs() as f64).sum() } else { 0.0 };
    let nb = acc.len() / block;
    for b in 0..nb {
        let blk = b * block..(b + 1) * block;
        let (bi, bv) = (&mut idx[b * kb..(b + 1) * kb], &mut val[b * kb..(b + 1) * kb]);
        topk_abs_block_bf16(&acc[blk.clone()], kb, bi, bv, sel);
        let accb = &mut acc[blk];
        for &i in bi.iter() {
            accb[i as usize] = 0.0;
        }
    }
    if tracing {
        let rem_abs: f64 = acc.iter().map(|a| a.abs() as f64).sum();
        health.topk_mass =
            if total_abs > 0.0 { ((total_abs - rem_abs) / total_abs) as f32 } else { 1.0 };
    }
    match &mut ef {
        RankEf::Off => {}
        RankEf::Dense(e) => e.copy_from_slice(acc),
        RankEf::Quant4 { packed, stats } => quant.quantize(acc, packed, stats),
    }
    if tracing {
        // `acc` still holds the pre-quantization remainder: compare it to
        // the residual the next step will actually dequantize.
        health.quant_abs_err = match &ef {
            RankEf::Quant4 { packed, stats } => quant.mean_abs_err(packed, stats, acc),
            _ => 0.0,
        };
    }
}

// ---------------------------------------------------------------------------
// Public sparse reducers
// ---------------------------------------------------------------------------

/// Per-rank block-wise Top-K sparsification, no error correction.
pub struct TopKReduce {
    core: SparseCore,
}

impl TopKReduce {
    pub fn new(d: usize, ranks: usize, cfg: SparseReduceConfig) -> Self {
        Self { core: SparseCore::new(d, ranks, SparseReduceConfig { ef: EfMode::Off, ..cfg }) }
    }

    /// Effective entries communicated per block.
    pub fn kb(&self) -> usize {
        self.core.kb
    }
}

impl GradReducer for TopKReduce {
    fn name(&self) -> String {
        format!("topk(k/d={:.3})", (self.core.nb * self.core.kb) as f64 / self.core.d as f64)
    }

    fn reduce(&mut self, grads: &[&[f32]], out: &mut [f32], pool: &ExecPool) {
        self.core.reduce(grads, out, pool);
    }

    fn payload_tag(&self) -> PayloadTag {
        PayloadTag::TopK
    }

    fn compress_payload(&mut self, rank: usize, grad: &[f32]) -> Vec<u8> {
        self.core.compress_one(rank, grad);
        self.core.rank_payload(rank)
    }

    fn aggregate_payloads(
        &mut self,
        payloads: &[Vec<u8>],
        out: &mut [f32],
        pool: &ExecPool,
    ) -> Result<()> {
        self.core.load_payloads(payloads)?;
        self.core.aggregate(out, pool);
        Ok(())
    }

    fn load_payload(&mut self, rank: usize, payload: &[u8]) -> Result<()> {
        if rank >= self.core.ranks {
            bail!("sparse load: rank {rank} out of range ({} ranks)", self.core.ranks);
        }
        self.core.load_payload(rank, payload)
    }

    fn aggregate_loaded(&mut self, out: &mut [f32], pool: &ExecPool) -> Result<()> {
        self.core.aggregate(out, pool);
        Ok(())
    }

    fn accumulate_payload(&self, payload: &[u8], acc: &mut [f32]) -> Result<()> {
        self.core.accumulate_payload(payload, acc)
    }

    fn finalize_partial(&self, acc: &mut [f32]) {
        scale_mean(acc, self.core.ranks);
    }

    fn wire_bytes_per_rank(&self) -> usize {
        self.core.wire_bytes_per_rank()
    }

    fn residual_state_bytes(&self) -> usize {
        0
    }

    fn topk_mass(&self, rank: usize) -> f32 {
        self.core.health[rank].topk_mass
    }

    fn slab_density(&self) -> f64 {
        self.core.slab_density()
    }
}

impl TopKReduce {
    /// Accounted wire formula (`4 B * NB * k_b`), for cross-checks.
    pub fn accounted_wire_bytes_per_rank(&self) -> usize {
        4 * self.core.nb * self.core.kb
    }
}

/// Top-K with per-rank (4-bit-quantized) error-feedback residuals — the
/// distributed setting MicroAdam's EF mechanism is native to.
pub struct EfTopKReduce {
    core: SparseCore,
}

impl EfTopKReduce {
    /// `cfg.ef` selects the residual storage; `EfMode::Off` degenerates to
    /// plain Top-K (use [`TopKReduce`] for that directly).
    pub fn new(d: usize, ranks: usize, cfg: SparseReduceConfig) -> Self {
        Self { core: SparseCore::new(d, ranks, cfg) }
    }

    pub fn kb(&self) -> usize {
        self.core.kb
    }
}

impl GradReducer for EfTopKReduce {
    fn name(&self) -> String {
        let ef = match self.core.ef {
            EfMode::Off => "off",
            EfMode::Dense => "f32",
            EfMode::Quant4 => "q4",
        };
        format!("eftopk(ef={ef})")
    }

    fn reduce(&mut self, grads: &[&[f32]], out: &mut [f32], pool: &ExecPool) {
        self.core.reduce(grads, out, pool);
    }

    fn payload_tag(&self) -> PayloadTag {
        PayloadTag::EfTopK
    }

    fn compress_payload(&mut self, rank: usize, grad: &[f32]) -> Vec<u8> {
        self.core.compress_one(rank, grad);
        self.core.rank_payload(rank)
    }

    fn aggregate_payloads(
        &mut self,
        payloads: &[Vec<u8>],
        out: &mut [f32],
        pool: &ExecPool,
    ) -> Result<()> {
        self.core.load_payloads(payloads)?;
        self.core.aggregate(out, pool);
        Ok(())
    }

    fn load_payload(&mut self, rank: usize, payload: &[u8]) -> Result<()> {
        if rank >= self.core.ranks {
            bail!("sparse load: rank {rank} out of range ({} ranks)", self.core.ranks);
        }
        self.core.load_payload(rank, payload)
    }

    fn aggregate_loaded(&mut self, out: &mut [f32], pool: &ExecPool) -> Result<()> {
        self.core.aggregate(out, pool);
        Ok(())
    }

    fn accumulate_payload(&self, payload: &[u8], acc: &mut [f32]) -> Result<()> {
        self.core.accumulate_payload(payload, acc)
    }

    fn finalize_partial(&self, acc: &mut [f32]) {
        scale_mean(acc, self.core.ranks);
    }

    fn wire_bytes_per_rank(&self) -> usize {
        // The accounted formula (2 B u16 idx + 2 B bf16 val
        // per entry) and the physically resident slab must agree — if they
        // ever drift the accounting has gone fictional again.
        let accounted = 4 * self.core.nb * self.core.kb;
        let physical = self.core.slab_bytes_per_rank();
        assert_eq!(
            accounted, physical,
            "eftopk wire accounting ({accounted} B) drifted from the physical slab ({physical} B)"
        );
        physical
    }

    fn residual_state_bytes(&self) -> usize {
        self.core.residual_state_bytes()
    }

    fn residual_norm(&self, rank: usize) -> f32 {
        self.core.residual_norm(rank)
    }

    fn topk_mass(&self, rank: usize) -> f32 {
        self.core.health[rank].topk_mass
    }

    fn quant_abs_err(&self, rank: usize) -> f32 {
        self.core.health[rank].quant_abs_err
    }

    fn slab_density(&self) -> f64 {
        self.core.slab_density()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
    }

    fn rank_grads(seed: u64, ranks: usize, d: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..ranks).map(|_| randvec(&mut rng, d, 1.0)).collect()
    }

    fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
        v.iter().map(|g| g.as_slice()).collect()
    }

    fn small_cfg() -> SparseReduceConfig {
        SparseReduceConfig { block: 64, density: 0.1, qbucket: 16, ef: EfMode::Quant4 }
    }

    #[test]
    fn dense_allreduce_is_the_mean() {
        let d = 100;
        let ranks = 4;
        let grads = rank_grads(0, ranks, d);
        let mut r = DenseAllReduce::new(d, ranks);
        let mut out = vec![9f32; d];
        r.reduce(&refs(&grads), &mut out, &ExecPool::serial());
        for i in 0..d {
            let mean: f32 = grads.iter().map(|g| g[i]).sum::<f32>() / ranks as f32;
            assert!((out[i] - mean).abs() < 1e-6, "coord {i}");
        }
    }

    #[test]
    fn dense_single_rank_is_bitwise_identity() {
        let d = 257;
        let grads = rank_grads(1, 1, d);
        let mut r = DenseAllReduce::new(d, 1);
        let mut out = vec![0f32; d];
        r.reduce(&refs(&grads), &mut out, &ExecPool::new(4));
        assert_eq!(out, grads[0]);
    }

    #[test]
    fn reducers_are_worker_count_invariant() {
        let d = 300; // non-multiple of block: padded tail
        let ranks = 3;
        for kind in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let mut outs = Vec::new();
            for workers in [1usize, 2, 4, 8] {
                let pool = ExecPool::new(workers);
                let mut r = build_reducer(kind, d, ranks, small_cfg());
                let mut out = vec![0f32; d];
                // several rounds so EF state evolves
                for round in 0..5 {
                    let grads = rank_grads(100 + round, ranks, d);
                    r.reduce(&refs(&grads), &mut out, &pool);
                }
                outs.push(out);
            }
            for o in &outs[1..] {
                assert_eq!(&outs[0], o, "{kind:?}");
            }
        }
    }

    #[test]
    fn topk_keeps_only_selected_coordinates() {
        let d = 128;
        let cfg = small_cfg();
        let mut r = TopKReduce::new(d, 1, cfg);
        let grads = rank_grads(7, 1, d);
        let mut out = vec![0f32; d];
        r.reduce(&refs(&grads), &mut out, &ExecPool::serial());
        let nonzero = out.iter().filter(|v| **v != 0.0).count();
        // 2 blocks of 64 at density 0.1 -> kb = 7 per block, 14 total
        assert_eq!(r.kb(), 7);
        assert!(nonzero <= 14, "{nonzero} nonzero");
        // selected coordinates carry the gradient value rounded through the
        // bf16 wire (single rank); everything else is exactly zero
        for (o, g) in out.iter().zip(&grads[0]) {
            let wire = crate::util::bf16::bf16_to_f32(crate::util::bf16::f32_to_bf16(*g));
            assert!(*o == 0.0 || *o == wire, "{o} vs wire {wire} (g {g})");
        }
    }

    #[test]
    fn wire_accounting_matches_physical_slab() {
        // The EfTopK accounting is asserted against the resident slab
        // inside wire_bytes_per_rank itself; exercise it across geometries,
        // including a padded tail.
        for d in [64usize, 300, 1 << 14] {
            for ranks in [1usize, 3, 8] {
                let ef = EfTopKReduce::new(d, ranks, small_cfg());
                let topk = TopKReduce::new(d, ranks, small_cfg());
                assert_eq!(ef.wire_bytes_per_rank(), topk.wire_bytes_per_rank(), "d={d}");
                assert_eq!(topk.wire_bytes_per_rank(), topk.accounted_wire_bytes_per_rank());
            }
        }
    }

    #[test]
    fn eftopk_carries_dropped_mass_forward() {
        // With a constant gradient, EF must eventually communicate
        // coordinates plain Top-K starves forever.
        let d = 64;
        let cfg = SparseReduceConfig { block: 64, density: 0.05, qbucket: 16, ef: EfMode::Dense };
        let mut ef = EfTopKReduce::new(d, 1, cfg);
        let mut topk = TopKReduce::new(d, 1, cfg);
        let g: Vec<f32> = (0..d).map(|i| 1.0 + (i as f32) / d as f32).collect();
        let grads = vec![g.clone()];
        let pool = ExecPool::serial();
        let mut touched_ef = vec![false; d];
        let mut touched_topk = vec![false; d];
        let mut out = vec![0f32; d];
        for _ in 0..40 {
            ef.reduce(&refs(&grads), &mut out, &pool);
            for (t, o) in touched_ef.iter_mut().zip(&out) {
                *t |= *o != 0.0;
            }
            topk.reduce(&refs(&grads), &mut out, &pool);
            for (t, o) in touched_topk.iter_mut().zip(&out) {
                *t |= *o != 0.0;
            }
        }
        let n_ef = touched_ef.iter().filter(|t| **t).count();
        let n_topk = touched_topk.iter().filter(|t| **t).count();
        // a constant gradient pins plain TopK to the same kb coordinates
        assert_eq!(n_topk, topk.kb());
        assert!(n_ef > 2 * n_topk, "EF reached only {n_ef} coords");
        assert!(ef.residual_norm(0) > 0.0);
    }

    #[test]
    fn wire_and_residual_accounting() {
        let d = 1 << 16;
        let ranks = 4;
        let cfg = SparseReduceConfig::default(); // paper geometry
        let dense = DenseAllReduce::new(d, ranks);
        let topk = TopKReduce::new(d, ranks, cfg);
        let ef = EfTopKReduce::new(d, ranks, cfg);
        assert_eq!(dense.wire_bytes_per_rank(), 4 * d);
        // 16 blocks of 4096, kb = 41 -> 4 B per entry
        assert_eq!(topk.wire_bytes_per_rank(), 4 * 16 * 41);
        assert_eq!(ef.wire_bytes_per_rank(), topk.wire_bytes_per_rank());
        // paper-dtype residual: 4-bit codes + per-bucket f32 stats, per rank
        let q = Quant4::new(crate::QBUCKET);
        assert_eq!(ef.residual_state_bytes(), ranks * q.state_bytes(d));
        assert_eq!(topk.residual_state_bytes(), 0);
        assert_eq!(dense.residual_state_bytes(), 0);
        assert!(ef.wire_bytes_per_rank() < dense.wire_bytes_per_rank() / 20);
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for k in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            assert_eq!(parse_reducer(reducer_name(k)).unwrap(), k);
        }
        assert!(parse_reducer("frobnicate").is_err());
    }

    #[test]
    fn split_phase_payload_path_matches_in_core_bitwise() {
        // The transport path (compress_payload per rank -> serialized slab
        // -> aggregate_payloads) must reproduce the in-core reduce() to the
        // bit, EF state evolution included, for every reducer kind.
        let d = 300; // padded tail
        let ranks = 3;
        let pool = ExecPool::new(2);
        for kind in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let mut core = build_reducer(kind, d, ranks, small_cfg());
            let mut split = build_reducer(kind, d, ranks, small_cfg());
            let mut out_core = vec![0f32; d];
            let mut out_split = vec![0f32; d];
            for round in 0..6 {
                let grads = rank_grads(40 + round, ranks, d);
                core.reduce(&refs(&grads), &mut out_core, &pool);
                let payloads: Vec<Vec<u8>> = (0..ranks)
                    .map(|r| split.compress_payload(r, &grads[r]))
                    .collect();
                for p in &payloads {
                    assert_eq!(p.len(), split.wire_bytes_per_rank(), "{kind:?}");
                }
                split.aggregate_payloads(&payloads, &mut out_split, &pool).unwrap();
                assert_eq!(out_core, out_split, "{kind:?} round {round}");
                for r in 0..ranks {
                    assert_eq!(core.residual_norm(r), split.residual_norm(r), "{kind:?}");
                }
            }
        }
    }

    #[test]
    fn rank_ascending_partial_fold_matches_phase_b_bitwise() {
        // The ring invariant: zero acc -> fold every rank's payload in
        // ascending order via accumulate_payload -> finalize_partial must
        // equal aggregate_payloads to the bit, every reducer kind, EF
        // evolution included.
        let d = 300; // padded tail
        let ranks = 4;
        let pool = ExecPool::new(2);
        for kind in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let mut r = build_reducer(kind, d, ranks, small_cfg());
            let mut out = vec![0f32; d];
            for round in 0..4 {
                let grads = rank_grads(70 + round, ranks, d);
                let payloads: Vec<Vec<u8>> =
                    (0..ranks).map(|k| r.compress_payload(k, &grads[k])).collect();
                let mut acc = vec![0f32; d];
                for p in &payloads {
                    r.accumulate_payload(p, &mut acc).unwrap();
                }
                r.finalize_partial(&mut acc);
                r.aggregate_payloads(&payloads, &mut out, &pool).unwrap();
                let same = out.iter().zip(&acc).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "{kind:?} round {round}: partial fold diverged from phase B");
            }
        }
    }

    #[test]
    fn streaming_load_path_matches_batch_aggregate_bitwise() {
        // load_payload in out-of-order arrival + aggregate_loaded ==
        // aggregate_payloads over the same payloads (the streaming-decode
        // contract the pipelined collect relies on).
        let d = 300;
        let ranks = 3;
        let pool = ExecPool::serial();
        for kind in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let mut batch = build_reducer(kind, d, ranks, small_cfg());
            let mut stream = build_reducer(kind, d, ranks, small_cfg());
            let grads = rank_grads(55, ranks, d);
            let pb: Vec<Vec<u8>> =
                (0..ranks).map(|r| batch.compress_payload(r, &grads[r])).collect();
            let ps: Vec<Vec<u8>> =
                (0..ranks).map(|r| stream.compress_payload(r, &grads[r])).collect();
            assert_eq!(pb, ps, "{kind:?}: same grads must serialize identically");
            let mut out_batch = vec![0f32; d];
            batch.aggregate_payloads(&pb, &mut out_batch, &pool).unwrap();
            let mut out_stream = vec![0f32; d];
            for r in [2usize, 0, 1] {
                stream.load_payload(r, &ps[r]).unwrap();
            }
            stream.aggregate_loaded(&mut out_stream, &pool).unwrap();
            assert_eq!(out_batch, out_stream, "{kind:?}");
        }
    }

    #[test]
    fn partial_fold_paths_reject_malformed_input() {
        let d = 128;
        let pool = ExecPool::serial();
        for kind in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let mut r = build_reducer(kind, d, 2, small_cfg());
            let good = r.compress_payload(0, &vec![0.5f32; d]);
            // wrong partial length
            let mut short = vec![0f32; d - 1];
            assert!(r.accumulate_payload(&good, &mut short).is_err(), "{kind:?}");
            // wrong payload length
            let mut acc = vec![0f32; d];
            assert!(r.accumulate_payload(&good[..good.len() - 1], &mut acc).is_err());
            // out-of-range rank on the streaming path
            assert!(r.load_payload(9, &good).is_err(), "{kind:?}");
        }
        // dense aggregate_loaded before any load is a typed error
        let mut dense = build_reducer(ReducerKind::Dense, d, 2, small_cfg());
        let mut out = vec![0f32; d];
        assert!(dense.aggregate_loaded(&mut out, &pool).is_err());
    }

    #[test]
    fn aggregate_payloads_rejects_malformed_input() {
        let d = 128;
        let pool = ExecPool::serial();
        let mut out = vec![0f32; d];
        for kind in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let mut r = build_reducer(kind, d, 2, small_cfg());
            // wrong payload count
            let one = vec![r.compress_payload(0, &vec![0.5f32; d])];
            assert!(r.aggregate_payloads(&one, &mut out, &pool).is_err(), "{kind:?}");
            // wrong payload size
            let bad = vec![vec![0u8; 3], vec![0u8; 3]];
            assert!(r.aggregate_payloads(&bad, &mut out, &pool).is_err(), "{kind:?}");
        }
    }
}
