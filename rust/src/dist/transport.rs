//! Inter-process transports for the data-parallel engine: how the
//! per-rank wire frames of [`crate::dist::wire`] physically move.
//!
//! Every transport implements the same collective, a **gather-to-all
//! through rank 0**: each process submits the frames of the ranks it
//! hosts, and receives the full rank-ordered set of every rank's frame.
//! All ranks then aggregate identically (the reducers are deterministic),
//! so parameters and optimizer state stay in lockstep without any
//! parameter broadcast — the only per-step traffic is one gradient frame
//! up per worker and one relay bundle down.
//!
//! Three implementations:
//!
//! * [`Loopback`] — the single-process path ([`crate::dist::DistTrainer`]
//!   hosts every rank). Frames still round-trip through
//!   [`Frame::encode`]/[`Frame::decode`], so the serialization layer is
//!   exercised — and the framed byte counts measured — even when nothing
//!   leaves the address space.
//! * [`UdsTransport`] — Unix-domain stream sockets. Rank 0 binds the
//!   rendezvous socket ([`UdsPending::bind`]), workers connect and
//!   identify themselves with a [`FLAG_HELLO`] frame, and
//!   [`UdsPending::accept`] resolves them into rank-indexed streams.
//! * [`ShmTransport`] — file-backed shared memory: one single-writer /
//!   single-reader mailbox file per direction per worker under the
//!   rendezvous directory (tmpfs paths like `/dev/shm/...` make this a
//!   page-cache-only exchange). The mailbox protocol is documented in
//!   `rust/src/dist/README.md` §8.
//!
//! A worker's uplink per step is exactly one frame, so its
//! [`Transport::bytes_sent`] grows by `FRAME_OVERHEAD +
//! wire_bytes_per_rank()` per step — the equality the transport parity
//! tests measure over the real socket/mailbox.
//!
//! [`FLAG_HELLO`]: crate::dist::wire::FLAG_HELLO
//! [`FRAME_OVERHEAD`]: crate::dist::wire::FRAME_OVERHEAD

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::wire::{Frame, WireError, FLAG_HELLO, MAX_SECTION_BYTES};

/// How long a transport waits for a peer mid-run before giving up.
/// Generous: a step on the native workloads takes milliseconds; a
/// two-minute silence means a peer died.
pub const PEER_TIMEOUT: Duration = Duration::from_secs(120);
/// How long a worker retries the rendezvous (rank 0 may still be setting
/// up, or the operator starts workers by hand before the coordinator).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(60);

/// Which transport a config/CLI names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process exchange (the default; `ranks` replicas in one address
    /// space).
    Loopback,
    /// Unix-domain stream sockets via a rendezvous socket path.
    Uds,
    /// File-backed shared-memory mailboxes under a rendezvous directory.
    Shm,
}

/// Parse a transport name (kebab-case, as in the CLI and config files).
pub fn parse_transport(s: &str) -> Result<TransportKind> {
    Ok(match s {
        "loopback" | "local" => TransportKind::Loopback,
        "uds" | "unix" => TransportKind::Uds,
        "shm" => TransportKind::Shm,
        other => bail!("unknown transport {other} (expected loopback|uds|shm)"),
    })
}

/// Canonical name of a transport kind.
pub fn transport_name(k: TransportKind) -> &'static str {
    match k {
        TransportKind::Loopback => "loopback",
        TransportKind::Uds => "uds",
        TransportKind::Shm => "shm",
    }
}

/// Default rendezvous path for a launcher-started run: a socket path
/// (uds) or directory (shm) under the system temp dir, unique per
/// process.
pub fn default_rendezvous(kind: TransportKind) -> PathBuf {
    let tag = match kind {
        TransportKind::Loopback => "loop",
        TransportKind::Uds => "uds",
        TransportKind::Shm => "shm",
    };
    std::env::temp_dir().join(format!("microadam-rdv-{tag}-{}", std::process::id()))
}

/// The per-step frame collective every rank runs: submit the frames of
/// the locally-hosted ranks, receive every rank's frame in rank order.
///
/// Implementations must be deterministic relays — they move bytes, never
/// reorder ranks, and never touch payloads (the CRC in every frame pins
/// that down).
pub trait Transport: Send {
    /// Transport display name (`loopback` / `uds` / `shm`).
    fn name(&self) -> &'static str;
    /// World size (total rank count across all processes).
    fn ranks(&self) -> usize;
    /// Perform one gather-to-all: `local` holds this process's frames
    /// (one per hosted rank, rank-ascending); the result holds all
    /// `ranks()` frames, rank-ascending. Blocks until every peer has
    /// contributed or [`PEER_TIMEOUT`] expires.
    fn exchange(&mut self, local: Vec<Frame>) -> Result<Vec<Frame>>;
    /// Framed bytes this endpoint has serialized and sent so far (for
    /// [`Loopback`], everything it has framed).
    fn bytes_sent(&self) -> u64;
    /// Framed bytes received from peers so far.
    fn bytes_received(&self) -> u64;
}

fn wire_err(e: WireError) -> anyhow::Error {
    anyhow!("{e}")
}

// ---------------------------------------------------------------------------
// Loopback
// ---------------------------------------------------------------------------

/// The in-address-space transport: every rank lives in this process, and
/// `exchange` is an encode/decode round trip per frame.
///
/// ```
/// use microadam::dist::transport::{Loopback, Transport};
/// use microadam::dist::wire::{Frame, PayloadTag, FRAME_OVERHEAD};
///
/// let mut t = Loopback::new(2);
/// let frames: Vec<Frame> = (0..2u16)
///     .map(|rank| Frame {
///         rank,
///         step: 1,
///         tag: PayloadTag::Dense,
///         flags: 0,
///         loss: 0.5,
///         payload: vec![1, 2, 3, 4],
///         stats: vec![],
///     })
///     .collect();
/// let out = t.exchange(frames).unwrap();
/// assert_eq!(out.len(), 2);
/// assert_eq!(out[1].payload, vec![1, 2, 3, 4]);
/// // 4 payload bytes framed: header + payload + crc, per rank
/// assert_eq!(t.bytes_sent(), 2 * (FRAME_OVERHEAD as u64 + 4));
/// ```
pub struct Loopback {
    ranks: usize,
    sent: u64,
    received: u64,
}

impl Loopback {
    /// Loopback transport hosting all `ranks` ranks.
    pub fn new(ranks: usize) -> Self {
        assert!(ranks > 0);
        Self { ranks, sent: 0, received: 0 }
    }
}

impl Transport for Loopback {
    fn name(&self) -> &'static str {
        "loopback"
    }

    fn ranks(&self) -> usize {
        self.ranks
    }

    fn exchange(&mut self, local: Vec<Frame>) -> Result<Vec<Frame>> {
        if local.len() != self.ranks {
            bail!("loopback hosts all {} ranks, got {} frames", self.ranks, local.len());
        }
        let mut out = Vec::with_capacity(local.len());
        for f in &local {
            // The round trip is the point: loopback runs the same
            // serialization the socket transports ship, so framed-byte
            // accounting and codec coverage don't depend on the topology.
            let bytes = f.encode();
            self.sent += bytes.len() as u64;
            let (back, used) = Frame::decode(&bytes).map_err(wire_err)?;
            debug_assert_eq!(used, bytes.len());
            self.received += used as u64;
            out.push(back);
        }
        Ok(out)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// Unix-domain sockets
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-connected UDS rendezvous: rank 0 binds *before*
/// spawning workers (no connect race), accepts after.
pub struct UdsPending {
    listener: UnixListener,
    path: PathBuf,
    ranks: usize,
}

impl UdsPending {
    /// Bind the rendezvous socket at `path` for a world of `ranks`.
    /// A stale socket file from a previous run is removed first.
    pub fn bind<P: AsRef<Path>>(path: P, ranks: usize) -> Result<UdsPending> {
        assert!(ranks > 0);
        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("uds: bind {}", path.display()))?;
        Ok(UdsPending { listener, path, ranks })
    }

    /// Accept the `ranks - 1` workers. Each must introduce itself with a
    /// [`FLAG_HELLO`] frame carrying its rank; duplicates and
    /// out-of-range ranks abort the run. Gives up after [`PEER_TIMEOUT`]
    /// if a worker never shows (e.g. it crashed at startup), so the
    /// launcher can reap instead of hanging.
    pub fn accept(self) -> Result<UdsTransport> {
        // UnixListener has no accept timeout; poll a non-blocking accept
        // against a deadline instead.
        self.listener.set_nonblocking(true)?;
        let deadline = Instant::now() + PEER_TIMEOUT;
        let mut slots: Vec<Option<UnixStream>> = (1..self.ranks).map(|_| None).collect();
        for _ in 1..self.ranks {
            let (mut stream, _) = loop {
                match self.listener.accept() {
                    Ok(conn) => break conn,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if Instant::now() >= deadline {
                            bail!(
                                "uds: timed out waiting for workers at {}",
                                self.path.display()
                            );
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e).context("uds: accept"),
                }
            };
            // the accepted stream must block normally (it may inherit the
            // listener's non-blocking mode on some platforms)
            stream.set_nonblocking(false)?;
            stream.set_read_timeout(Some(PEER_TIMEOUT))?;
            let hello = Frame::read_from(&mut stream).map_err(wire_err)?;
            if hello.flags & FLAG_HELLO == 0 {
                bail!("uds: worker spoke before the handshake");
            }
            let r = hello.rank as usize;
            if r == 0 || r >= self.ranks {
                bail!("uds: hello from rank {r}, world is 0..{}", self.ranks);
            }
            if slots[r - 1].replace(stream).is_some() {
                bail!("uds: two workers claimed rank {r}");
            }
        }
        let workers = slots
            .into_iter()
            .map(|s| s.expect("every slot filled by the accept loop"))
            .collect();
        Ok(UdsTransport {
            ranks: self.ranks,
            role: UdsRole::Coordinator { workers, path: self.path },
            sent: 0,
            received: 0,
        })
    }
}

enum UdsRole {
    /// Rank 0: one stream per worker, index `rank - 1`.
    Coordinator { workers: Vec<UnixStream>, path: PathBuf },
    /// A worker rank: the single stream to rank 0.
    Worker { stream: UnixStream },
}

/// Unix-domain-socket transport (see [`UdsPending`] for the rank-0 side).
pub struct UdsTransport {
    ranks: usize,
    role: UdsRole,
    sent: u64,
    received: u64,
}

impl UdsTransport {
    /// Connect worker `rank` to the rendezvous socket, retrying until the
    /// coordinator has bound it (or [`CONNECT_TIMEOUT`] passes), then send
    /// the hello frame.
    pub fn connect<P: AsRef<Path>>(path: P, rank: usize, ranks: usize) -> Result<UdsTransport> {
        assert!(rank > 0 && rank < ranks, "workers are ranks 1..{ranks}, got {rank}");
        let path = path.as_ref();
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        let mut stream = loop {
            match UnixStream::connect(path) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(e))
                            .with_context(|| format!("uds: connect {}", path.display()));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_read_timeout(Some(PEER_TIMEOUT))?;
        let hello = Frame::hello(rank).encode();
        stream.write_all(&hello).context("uds: send hello")?;
        Ok(UdsTransport {
            ranks,
            role: UdsRole::Worker { stream },
            sent: hello.len() as u64,
            received: 0,
        })
    }
}

impl Drop for UdsTransport {
    fn drop(&mut self) {
        if let UdsRole::Coordinator { path, .. } = &self.role {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Transport for UdsTransport {
    fn name(&self) -> &'static str {
        "uds"
    }

    fn ranks(&self) -> usize {
        self.ranks
    }

    fn exchange(&mut self, mut local: Vec<Frame>) -> Result<Vec<Frame>> {
        if local.len() != 1 {
            bail!("uds endpoints host exactly one rank, got {} frames", local.len());
        }
        let mine = local.pop().expect("one frame");
        match &mut self.role {
            UdsRole::Coordinator { workers, .. } => {
                if mine.rank != 0 {
                    bail!("uds coordinator must host rank 0, got {}", mine.rank);
                }
                let step = mine.step;
                let mut frames = Vec::with_capacity(self.ranks);
                frames.push(mine);
                // Gather: one frame per worker, read in rank order (the
                // sockets buffer early senders).
                for (i, w) in workers.iter_mut().enumerate() {
                    let f = Frame::read_from(w)
                        .map_err(wire_err)
                        .with_context(|| format!("uds: gather from rank {}", i + 1))?;
                    if f.rank as usize != i + 1 || f.step != step {
                        bail!(
                            "uds: expected rank {}/step {step}, got rank {}/step {}",
                            i + 1,
                            f.rank,
                            f.step
                        );
                    }
                    self.received += f.encoded_len() as u64;
                    frames.push(f);
                }
                // Relay the full bundle back to every worker.
                let mut bundle = Vec::new();
                for f in &frames {
                    f.encode_into(&mut bundle);
                }
                for w in workers.iter_mut() {
                    w.write_all(&bundle).context("uds: relay bundle")?;
                    self.sent += bundle.len() as u64;
                }
                Ok(frames)
            }
            UdsRole::Worker { stream } => {
                let step = mine.step;
                let bytes = mine.encode();
                stream.write_all(&bytes).context("uds: send frame")?;
                self.sent += bytes.len() as u64;
                let mut frames = Vec::with_capacity(self.ranks);
                for r in 0..self.ranks {
                    let f = Frame::read_from(stream)
                        .map_err(wire_err)
                        .with_context(|| format!("uds: bundle frame {r}"))?;
                    if f.rank as usize != r || f.step != step {
                        bail!(
                            "uds: bundle out of order (expected rank {r}/step {step}, \
                             got rank {}/step {})",
                            f.rank,
                            f.step
                        );
                    }
                    self.received += f.encoded_len() as u64;
                    frames.push(f);
                }
                Ok(frames)
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// File-backed shared memory
// ---------------------------------------------------------------------------

/// A single-writer / single-reader mailbox file:
///
/// ```text
/// off len field
///   0   1 full flag: 0 = empty (writer may fill), 1 = full (reader may drain)
///   1   7 reserved (zero)
///   8   8 message length, u64 LE
///  16   . message bytes (one encoded frame, or a relay bundle)
/// ```
///
/// The writer stores the message and its length *before* flipping the
/// flag to 1; the reader drains and flips it back to 0. Each `pwrite`
/// completes into the (shared) page cache before the next begins, so a
/// reader that observes the flag set also observes the bytes it guards.
/// Synchronous training needs only one message in flight per direction,
/// so a mailbox (rather than a deeper ring) loses no parallelism.
struct Mailbox {
    file: File,
    path: PathBuf,
    /// Corruption guard for the length field: the largest message this
    /// direction can legitimately carry (one frame uplink, a full bundle
    /// downlink), so a garbage length fails before a huge allocation
    /// without rejecting valid large configurations.
    max_msg: u64,
}

/// Upper bound on one encoded frame: payload + stats sections at their
/// wire-level caps, plus framing.
fn max_frame_bytes() -> u64 {
    (2 * MAX_SECTION_BYTES + 4096) as u64
}

impl Mailbox {
    /// Create the mailbox at `path` — the coordinator does this for every
    /// direction before workers start. The 16-byte header is written to a
    /// temp file and renamed into place, so a concurrently-polling worker
    /// either sees no file or a fully-initialized one, never a
    /// half-written header. A stale mailbox from a previous run is
    /// replaced by the rename.
    fn create<P: AsRef<Path>>(path: P, max_msg: u64) -> Result<Mailbox> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = OpenOptions::new()
                .write(true)
                .create(true)
                .truncate(true)
                .open(&tmp)
                .with_context(|| format!("shm: create {}", tmp.display()))?;
            f.write_all(&[0u8; 16])?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("shm: publish {}", path.display()))?;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("shm: reopen {}", path.display()))?;
        Ok(Mailbox { file, path, max_msg })
    }

    /// Open an existing mailbox, waiting for the coordinator to create it.
    /// (Reusing a rendezvous directory from a *crashed* run with workers
    /// started before the coordinator can hand a worker the stale inode —
    /// use a fresh directory for hand-started shm runs.)
    fn open_wait<P: AsRef<Path>>(path: P, max_msg: u64) -> Result<Mailbox> {
        let path = path.as_ref().to_path_buf();
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        loop {
            match OpenOptions::new().read(true).write(true).open(&path) {
                // the rename in create() guarantees an existing file is
                // fully initialized (>= 16 header bytes)
                Ok(file) => return Ok(Mailbox { file, path, max_msg }),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(e))
                            .with_context(|| format!("shm: open {}", path.display()));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    fn flag(&self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.file.read_exact_at(&mut b, 0)?;
        Ok(b[0])
    }

    /// Busy-wait (with sleeps) until the flag equals `want`.
    fn wait_flag(&self, want: u8) -> Result<()> {
        let deadline = Instant::now() + PEER_TIMEOUT;
        let mut spins = 0u32;
        while self.flag()? != want {
            if Instant::now() >= deadline {
                bail!("shm: peer on {} went silent", self.path.display());
            }
            // Short spin first (a step is milliseconds), then back off.
            spins += 1;
            if spins > 1000 {
                std::thread::sleep(Duration::from_millis(1));
            } else {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        Ok(())
    }

    /// Publish one message (blocks until the reader drained the previous).
    fn send(&mut self, msg: &[u8]) -> Result<()> {
        self.wait_flag(0)?;
        let need = 16 + msg.len() as u64;
        if self.file.metadata()?.len() < need {
            self.file.set_len(need)?;
        }
        self.file.write_all_at(msg, 16)?;
        self.file.write_all_at(&(msg.len() as u64).to_le_bytes(), 8)?;
        // The flag flip is last: a reader that sees it also sees the bytes.
        self.file.write_all_at(&[1u8], 0)?;
        Ok(())
    }

    /// Drain one message (blocks until the writer published one).
    fn recv(&mut self) -> Result<Vec<u8>> {
        self.wait_flag(1)?;
        let mut len8 = [0u8; 8];
        self.file.read_exact_at(&mut len8, 8)?;
        let len = u64::from_le_bytes(len8);
        if len > self.max_msg {
            bail!(
                "shm: implausible {len} B message on {} (cap {})",
                self.path.display(),
                self.max_msg
            );
        }
        let len = len as usize;
        let mut msg = vec![0u8; len];
        self.file.read_exact_at(&mut msg, 16)?;
        self.file.write_all_at(&[0u8], 0)?;
        Ok(msg)
    }
}

enum ShmRole {
    /// Rank 0: an (uplink, downlink) mailbox pair per worker, index
    /// `rank - 1`.
    Coordinator { pairs: Vec<(Mailbox, Mailbox)>, dir: PathBuf },
    /// A worker: its own uplink + downlink.
    Worker { up: Mailbox, down: Mailbox },
}

/// Shared-memory transport over per-worker mailbox files. Put the
/// rendezvous directory on tmpfs (e.g. under `/dev/shm`) and the exchange
/// never leaves the page cache.
pub struct ShmTransport {
    ranks: usize,
    role: ShmRole,
    sent: u64,
    received: u64,
}

fn up_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("up_{rank}.mbox"))
}

fn down_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("down_{rank}.mbox"))
}

impl ShmTransport {
    /// Rank-0 side: create the rendezvous directory and every mailbox
    /// (call *before* spawning workers so they never see a half-made dir).
    pub fn coordinator<P: AsRef<Path>>(dir: P, ranks: usize) -> Result<ShmTransport> {
        assert!(ranks > 0);
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        // uplink carries one frame; downlink carries the full bundle
        let bundle_cap = max_frame_bytes() * ranks as u64;
        let pairs = (1..ranks)
            .map(|r| {
                Ok((
                    Mailbox::create(up_path(&dir, r), max_frame_bytes())?,
                    Mailbox::create(down_path(&dir, r), bundle_cap)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ShmTransport { ranks, role: ShmRole::Coordinator { pairs, dir }, sent: 0, received: 0 })
    }

    /// Worker side: open this rank's mailbox pair (waiting for the
    /// coordinator to create them).
    pub fn worker<P: AsRef<Path>>(dir: P, rank: usize, ranks: usize) -> Result<ShmTransport> {
        assert!(rank > 0 && rank < ranks, "workers are ranks 1..{ranks}, got {rank}");
        let dir = dir.as_ref();
        let up = Mailbox::open_wait(up_path(dir, rank), max_frame_bytes())?;
        let down = Mailbox::open_wait(down_path(dir, rank), max_frame_bytes() * ranks as u64)?;
        Ok(ShmTransport { ranks, role: ShmRole::Worker { up, down }, sent: 0, received: 0 })
    }
}

impl Drop for ShmTransport {
    fn drop(&mut self) {
        // Remove only what this transport created: its mailbox files, and
        // the directory iff that leaves it empty (non-recursive). The
        // rendezvous may be a user-supplied directory (/dev/shm itself,
        // say) — never delete anything we didn't make.
        if let ShmRole::Coordinator { pairs, dir } = &self.role {
            for (up, down) in pairs {
                let _ = std::fs::remove_file(&up.path);
                let _ = std::fs::remove_file(&down.path);
            }
            let _ = std::fs::remove_dir(dir);
        }
    }
}

impl Transport for ShmTransport {
    fn name(&self) -> &'static str {
        "shm"
    }

    fn ranks(&self) -> usize {
        self.ranks
    }

    fn exchange(&mut self, mut local: Vec<Frame>) -> Result<Vec<Frame>> {
        if local.len() != 1 {
            bail!("shm endpoints host exactly one rank, got {} frames", local.len());
        }
        let mine = local.pop().expect("one frame");
        match &mut self.role {
            ShmRole::Coordinator { pairs, .. } => {
                if mine.rank != 0 {
                    bail!("shm coordinator must host rank 0, got {}", mine.rank);
                }
                let step = mine.step;
                let mut frames = Vec::with_capacity(self.ranks);
                frames.push(mine);
                for (i, (up, _)) in pairs.iter_mut().enumerate() {
                    let msg = up.recv().with_context(|| format!("shm: gather rank {}", i + 1))?;
                    let (f, used) = Frame::decode(&msg).map_err(wire_err)?;
                    if used != msg.len() || f.rank as usize != i + 1 || f.step != step {
                        bail!(
                            "shm: expected one rank-{}/step-{step} frame, got rank {}/step {}",
                            i + 1,
                            f.rank,
                            f.step
                        );
                    }
                    self.received += used as u64;
                    frames.push(f);
                }
                let mut bundle = Vec::new();
                for f in &frames {
                    f.encode_into(&mut bundle);
                }
                for (_, down) in pairs.iter_mut() {
                    down.send(&bundle).context("shm: relay bundle")?;
                    self.sent += bundle.len() as u64;
                }
                Ok(frames)
            }
            ShmRole::Worker { up, down } => {
                let step = mine.step;
                let bytes = mine.encode();
                up.send(&bytes).context("shm: send frame")?;
                self.sent += bytes.len() as u64;
                let bundle = down.recv().context("shm: receive bundle")?;
                self.received += bundle.len() as u64;
                let frames = Frame::decode_bundle(&bundle, self.ranks).map_err(wire_err)?;
                for (r, f) in frames.iter().enumerate() {
                    if f.rank as usize != r || f.step != step {
                        bail!(
                            "shm: bundle out of order (expected rank {r}/step {step}, \
                             got rank {}/step {})",
                            f.rank,
                            f.step
                        );
                    }
                }
                Ok(frames)
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::wire::{PayloadTag, FRAME_OVERHEAD};

    fn frame(rank: usize, step: u64, payload: Vec<u8>) -> Frame {
        Frame {
            rank: rank as u16,
            step,
            tag: PayloadTag::TopK,
            flags: 0,
            loss: rank as f32 + step as f32,
            payload,
            stats: Vec::new(),
        }
    }

    fn unique_dir(tag: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static N: AtomicU64 = AtomicU64::new(0);
        std::env::temp_dir().join(format!(
            "microadam-test-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::SeqCst)
        ))
    }

    #[test]
    fn loopback_roundtrips_and_counts() {
        let mut t = Loopback::new(3);
        let frames: Vec<Frame> = (0..3).map(|r| frame(r, 5, vec![r as u8; 8])).collect();
        let out = t.exchange(frames.clone()).unwrap();
        assert_eq!(out, frames);
        assert_eq!(t.bytes_sent(), 3 * (FRAME_OVERHEAD as u64 + 8));
        assert_eq!(t.bytes_received(), t.bytes_sent());
        // wrong cardinality is an error, not a hang
        assert!(t.exchange(vec![frame(0, 6, vec![])]).is_err());
    }

    #[test]
    fn uds_gathers_across_threads() {
        let path = unique_dir("uds").with_extension("sock");
        let ranks = 3;
        let pending = UdsPending::bind(&path, ranks).unwrap();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let path = path.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = UdsTransport::connect(&path, r, ranks).unwrap();
                let mut got = Vec::new();
                for step in 1..=4u64 {
                    let out = t.exchange(vec![frame(r, step, vec![r as u8, step as u8])]).unwrap();
                    got.push(out);
                }
                (t.bytes_sent(), got)
            }));
        }
        let mut coord = pending.accept().unwrap();
        let mut coord_views = Vec::new();
        for step in 1..=4u64 {
            coord_views.push(coord.exchange(vec![frame(0, step, vec![0, step as u8])]).unwrap());
        }
        for h in handles {
            let (sent, got) = h.join().unwrap();
            // hello + 4 gradient frames of 2 payload bytes each
            assert_eq!(sent, 5 * FRAME_OVERHEAD as u64 + 4 * 2);
            assert_eq!(got, coord_views, "every rank sees the same bundles");
        }
        for (s, view) in coord_views.iter().enumerate() {
            assert_eq!(view.len(), ranks);
            for (r, f) in view.iter().enumerate() {
                assert_eq!(f.rank as usize, r);
                assert_eq!(f.step, s as u64 + 1);
            }
        }
    }

    #[test]
    fn shm_gathers_across_threads() {
        let dir = unique_dir("shm");
        let ranks = 3;
        let mut coord = ShmTransport::coordinator(&dir, ranks).unwrap();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let dir = dir.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = ShmTransport::worker(&dir, r, ranks).unwrap();
                let mut got = Vec::new();
                for step in 1..=4u64 {
                    let out = t.exchange(vec![frame(r, step, vec![r as u8; 6])]).unwrap();
                    got.push(out);
                }
                (t.bytes_sent(), got)
            }));
        }
        let mut coord_views = Vec::new();
        for step in 1..=4u64 {
            coord_views.push(coord.exchange(vec![frame(0, step, vec![0u8; 6])]).unwrap());
        }
        for h in handles {
            let (sent, got) = h.join().unwrap();
            assert_eq!(sent, 4 * (FRAME_OVERHEAD as u64 + 6));
            assert_eq!(got, coord_views);
        }
    }

    #[test]
    fn transport_names_parse_back() {
        for k in [TransportKind::Loopback, TransportKind::Uds, TransportKind::Shm] {
            assert_eq!(parse_transport(transport_name(k)).unwrap(), k);
        }
        assert!(parse_transport("pigeon").is_err());
    }
}
