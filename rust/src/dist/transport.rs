//! Inter-process transports for the data-parallel engine: how the
//! per-rank wire frames of [`crate::dist::wire`] physically move.
//!
//! Every transport implements the same collective, a **gather-to-all
//! through rank 0**: each process submits the frames of the ranks it
//! hosts, and receives the full rank-ordered set of every rank's frame.
//! All ranks then aggregate identically (the reducers are deterministic),
//! so parameters and optimizer state stay in lockstep without any
//! parameter broadcast — the only per-step traffic is one gradient frame
//! up per worker and one relay bundle down.
//!
//! The collective is split into two phases on the [`Transport`] trait —
//! [`Transport::post_send`] (submit this endpoint's frames; starts the
//! uplink) and [`Transport::collect`] (complete the gather) — so the
//! rank-0 coordinator can **pipeline**: its own frame is already the head
//! of the relay bundle while worker frames are still arriving, and each
//! worker frame is relayed the moment the rank-ascending prefix it
//! completes allows, instead of after the whole gather. The relayed byte
//! stream is identical either way (bundles are self-delimiting,
//! rank-ascending concatenations), so pipelining changes *when* bytes
//! move, never *which* bytes — all four transports stay bit-identical to
//! in-core loopback by construction.
//!
//! Four implementations:
//!
//! * [`Loopback`] — the single-process path ([`crate::dist::DistTrainer`]
//!   hosts every rank). Frames still round-trip through
//!   [`Frame::encode`]/[`Frame::decode`], so the serialization layer is
//!   exercised — and the framed byte counts measured — even when nothing
//!   leaves the address space.
//! * [`UdsTransport`] — Unix-domain stream sockets. Rank 0 binds the
//!   rendezvous socket ([`UdsPending::bind`]), workers connect and
//!   identify themselves with a [`FLAG_HELLO`] frame, and
//!   [`UdsPending::accept`] resolves them into rank-indexed streams.
//! * [`TcpTransport`] — the multi-host twin of uds: the same
//!   rendezvous/hello/bundle protocol over `TcpListener`/`TcpStream`
//!   (`TCP_NODELAY` on every stream, `--rendezvous host:port`, ephemeral
//!   `:0` ports resolved via [`TcpPending::local_addr`]). The wire spec
//!   (`rust/src/dist/README.md`) needs no changes: frames are
//!   byte-identical on every transport.
//! * [`ShmTransport`] — file-backed shared memory: one single-writer /
//!   single-reader mailbox file per direction per worker under the
//!   rendezvous directory (tmpfs paths like `/dev/shm/...` make this a
//!   page-cache-only exchange). The mailbox protocol is documented in
//!   `rust/src/dist/README.md` §8. Its downlink is one bundle message, so
//!   the coordinator cannot stream the relay — but its gather still polls
//!   all uplinks concurrently and observes out-of-order arrival.
//!
//! The rank-0 star is only one of three **aggregation topologies** over
//! the stream transports: `--topology ring|tree` re-wires the uds/tcp
//! star rendezvous into point-to-point neighbor links driven by
//! [`RingDriver`] (successor hop chain, in-network reduction via
//! [`Transport::collect_reduced`]) or [`TreeDriver`] (binary gather/relay
//! tree) — see `rust/src/dist/README.md` §10 for the normative hop-frame
//! layout and fan-in rules. Loopback and shm stay star-only.
//!
//! A worker's uplink per step is exactly one frame, so its
//! [`Transport::bytes_sent`] grows by `FRAME_OVERHEAD +
//! wire_bytes_per_rank()` per step — the equality the transport parity
//! tests measure over the real socket/mailbox.
//!
//! [`FLAG_HELLO`]: crate::dist::wire::FLAG_HELLO
//! [`FRAME_OVERHEAD`]: crate::dist::wire::FRAME_OVERHEAD

use std::fs::{File, OpenOptions};
use std::io::{ErrorKind, Read, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::os::unix::fs::FileExt;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::wire::{
    self, Frame, FrameReader, PayloadTag, WireError, FLAG_HELLO, FLAG_HOP, MAX_SECTION_BYTES,
};

/// How long a transport waits for a peer mid-run before giving up.
/// Generous: a step on the native workloads takes milliseconds; a
/// two-minute silence means a peer died.
pub const PEER_TIMEOUT: Duration = Duration::from_secs(120);
/// How long a worker retries the rendezvous (rank 0 may still be setting
/// up, or the operator starts workers by hand before the coordinator).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(60);
/// How long the rendezvous accept loop waits for a connected peer's hello
/// frame before rejecting it. Deliberately much shorter than
/// [`PEER_TIMEOUT`]: a legitimate worker sends its hello immediately after
/// connecting, and a silent connection must not hold the accept loop
/// hostage while other ranks queue behind it.
pub const HELLO_WAIT: Duration = Duration::from_secs(10);
/// Per-stream read timeout of the pipelined gather's round-robin poll.
const GATHER_POLL: Duration = Duration::from_millis(1);

/// Which transport a config/CLI names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process exchange (the default; `ranks` replicas in one address
    /// space).
    Loopback,
    /// Unix-domain stream sockets via a rendezvous socket path.
    Uds,
    /// TCP sockets via a rendezvous `host:port` — the multi-host twin of
    /// uds.
    Tcp,
    /// File-backed shared-memory mailboxes under a rendezvous directory.
    Shm,
}

/// Parse a transport name (kebab-case, as in the CLI and config files).
pub fn parse_transport(s: &str) -> Result<TransportKind> {
    Ok(match s {
        "loopback" | "local" => TransportKind::Loopback,
        "uds" | "unix" => TransportKind::Uds,
        "tcp" => TransportKind::Tcp,
        "shm" => TransportKind::Shm,
        other => bail!("unknown transport {other} (expected loopback|uds|tcp|shm)"),
    })
}

/// Canonical name of a transport kind.
pub fn transport_name(k: TransportKind) -> &'static str {
    match k {
        TransportKind::Loopback => "loopback",
        TransportKind::Uds => "uds",
        TransportKind::Tcp => "tcp",
        TransportKind::Shm => "shm",
    }
}

/// Which aggregation topology a run's per-step collective uses (see
/// `rust/src/dist/README.md` §10). Star is the PR-5 rank-0 gather/relay;
/// ring and tree are the scale-out alternatives layered over the same
/// stream machinery by [`RingDriver`] / [`TreeDriver`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Topology {
    /// Every worker uplinks to rank 0, which relays the rank-ascending
    /// bundle — O(ranks) bandwidth and decode on one endpoint.
    #[default]
    Star,
    /// Successor-directed hop chain: each endpoint folds its payload into
    /// a circulating partial-aggregate ([`FLAG_HOP`] frames) — O(1)
    /// per-endpoint bandwidth, O(ranks) latency.
    Ring,
    /// Binary reduction tree: endpoints gather from children, forward up,
    /// and relay the complement back down — O(log ranks) depth with at
    /// most 3 links per endpoint.
    Tree,
}

/// Parse a topology name (kebab-case, as in the CLI and config files).
pub fn parse_topology(s: &str) -> Result<Topology> {
    Ok(match s {
        "star" => Topology::Star,
        "ring" => Topology::Ring,
        "tree" => Topology::Tree,
        other => bail!("unknown topology {other} (expected star|ring|tree)"),
    })
}

/// Canonical name of a topology.
pub fn topology_name(t: Topology) -> &'static str {
    match t {
        Topology::Star => "star",
        Topology::Ring => "ring",
        Topology::Tree => "tree",
    }
}

/// Default rendezvous for a launcher-started run: a socket path (uds) or
/// directory (shm) under the system temp dir, unique per process — or,
/// for tcp, a loopback address with an ephemeral port (the launcher
/// resolves the actually-bound port via [`TcpPending::local_addr`] before
/// handing it to workers).
pub fn default_rendezvous(kind: TransportKind) -> PathBuf {
    let tag = match kind {
        TransportKind::Loopback => "loop",
        TransportKind::Uds => "uds",
        TransportKind::Tcp => return PathBuf::from("127.0.0.1:0"),
        TransportKind::Shm => "shm",
    };
    std::env::temp_dir().join(format!("microadam-rdv-{tag}-{}", std::process::id()))
}

/// The per-step frame collective every rank runs, split into the two
/// phases of a pipelined gather: submit the frames of the locally-hosted
/// ranks ([`Transport::post_send`]), then receive every rank's frame in
/// rank order ([`Transport::collect`]).
///
/// Implementations must be deterministic relays — they move bytes, never
/// reorder ranks, and never touch payloads (the CRC in every frame pins
/// that down). Pipelining latitude is *timing only*: `collect` may relay
/// and receive in any internal order, but the frames it returns (and the
/// bundle bytes a worker sees) are always the rank-ascending set.
pub trait Transport: Send {
    /// Transport display name (`loopback` / `uds` / `tcp` / `shm`).
    fn name(&self) -> &'static str;
    /// World size (total rank count across all processes).
    fn ranks(&self) -> usize;
    /// Phase 1 of the gather: submit this process's frames (one per
    /// hosted rank, rank-ascending) and start the uplink. On the rank-0
    /// coordinator this seeds the relay bundle with rank 0's frame, so
    /// relaying can begin while worker frames are still arriving.
    ///
    /// ```
    /// use microadam::dist::transport::{Loopback, Transport};
    /// use microadam::dist::wire::{Frame, PayloadTag};
    ///
    /// let mut t = Loopback::new(1);
    /// let f = Frame { rank: 0, step: 1, tag: PayloadTag::Dense, flags: 0,
    ///                 loss: 0.25, payload: vec![7], stats: vec![] };
    /// t.post_send(vec![f.clone()]).unwrap();
    /// assert_eq!(t.collect().unwrap(), vec![f]);
    /// // collect consumed the round: a second collect is an error
    /// assert!(t.collect().is_err());
    /// ```
    fn post_send(&mut self, local: Vec<Frame>) -> Result<()>;
    /// Phase 2 of the gather: block until every rank's frame of the round
    /// opened by [`Transport::post_send`] has arrived (or [`PEER_TIMEOUT`]
    /// expires) and return all `ranks()` frames, rank-ascending.
    fn collect(&mut self) -> Result<Vec<Frame>>;
    /// One whole gather-to-all: [`Transport::post_send`] then
    /// [`Transport::collect`].
    fn exchange(&mut self, local: Vec<Frame>) -> Result<Vec<Frame>> {
        self.post_send(local)?;
        self.collect()
    }
    /// Aggregation topology of this endpoint's collective ([`Topology::Star`]
    /// unless a topology driver wraps the streams).
    fn topology(&self) -> Topology {
        Topology::Star
    }
    /// Streaming variant of [`Transport::collect`]: invoke `on_frame` once
    /// per gathered frame **in arrival order** (locally-hosted frames
    /// first), possibly while later frames are still in flight, then
    /// return the same rank-ascending set `collect` would. The trainer
    /// uses the callback to decode each rank's payload slab under the
    /// gather tail instead of after it. The default runs the callbacks
    /// after a plain collect — correct everywhere, overlapping nothing;
    /// the stream transports override it with true under-the-gather
    /// delivery. An `on_frame` error aborts the round as a collect error.
    fn collect_streaming(
        &mut self,
        on_frame: &mut dyn FnMut(&Frame) -> Result<()>,
    ) -> Result<Vec<Frame>> {
        let frames = self.collect()?;
        for f in &frames {
            on_frame(f)?;
        }
        Ok(frames)
    }
    /// In-network-reduced variant of [`Transport::collect`] for topologies
    /// that aggregate *inside* the collective (ring): `fold(payload, acc)`
    /// must add one rank's wire payload into the running per-coordinate
    /// partial `acc` (growing it on first use). Topologies that support it
    /// return a **single** [`FLAG_HOP`] result frame whose payload is the
    /// finished partial over all ranks ([`wire::hop_payload`] layout) —
    /// identical bytes on every endpoint. The default ignores `fold` and
    /// returns the plain gathered set, so callers must branch on
    /// [`Transport::topology`], not on the result shape alone.
    fn collect_reduced(
        &mut self,
        fold: &mut dyn FnMut(&[u8], &mut Vec<f32>) -> Result<()>,
    ) -> Result<Vec<Frame>> {
        let _ = fold;
        self.collect()
    }
    /// Framed bytes this endpoint has serialized and sent so far (for
    /// [`Loopback`], everything it has framed).
    fn bytes_sent(&self) -> u64;
    /// Framed bytes received from peers so far.
    fn bytes_received(&self) -> u64;
    /// Cumulative milliseconds this endpoint spent relaying bundle bytes
    /// *while* gather frames were still in flight — the wire latency the
    /// pipelined coordinator hides. 0 on workers, loopback and shm (whose
    /// downlink is a single bundle message).
    fn overlap_ms(&self) -> f64 {
        0.0
    }
    /// Ranks of the most recent completed gather in uplink-arrival order
    /// (coordinator endpoints only; empty elsewhere). Pipelining means
    /// this is *not* necessarily sorted — the regression tests assert the
    /// aggregate is arrival-order-invariant.
    fn last_arrival(&self) -> &[u16] {
        &[]
    }
    /// Milliseconds after the round opened ([`Transport::post_send`]) at
    /// which each uplink frame of the most recent completed gather
    /// arrived — index-aligned with [`Transport::last_arrival`]
    /// (coordinator endpoints only; empty elsewhere).
    fn last_arrival_ms(&self) -> &[f64] {
        &[]
    }
}

fn wire_err(e: WireError) -> anyhow::Error {
    anyhow!("{e}")
}

// ---------------------------------------------------------------------------
// Loopback
// ---------------------------------------------------------------------------

/// The in-address-space transport: every rank lives in this process, and
/// a gather is an encode/decode round trip per frame.
///
/// ```
/// use microadam::dist::transport::{Loopback, Transport};
/// use microadam::dist::wire::{Frame, PayloadTag, FRAME_OVERHEAD};
///
/// let mut t = Loopback::new(2);
/// let frames: Vec<Frame> = (0..2u16)
///     .map(|rank| Frame {
///         rank,
///         step: 1,
///         tag: PayloadTag::Dense,
///         flags: 0,
///         loss: 0.5,
///         payload: vec![1, 2, 3, 4],
///         stats: vec![],
///     })
///     .collect();
/// let out = t.exchange(frames).unwrap();
/// assert_eq!(out.len(), 2);
/// assert_eq!(out[1].payload, vec![1, 2, 3, 4]);
/// // 4 payload bytes framed: header + payload + crc, per rank
/// assert_eq!(t.bytes_sent(), 2 * (FRAME_OVERHEAD as u64 + 4));
/// ```
pub struct Loopback {
    ranks: usize,
    sent: u64,
    received: u64,
    /// Encoded frames between `post_send` and `collect`.
    pending: Option<Vec<Vec<u8>>>,
}

impl Loopback {
    /// Loopback transport hosting all `ranks` ranks.
    pub fn new(ranks: usize) -> Self {
        assert!(ranks > 0);
        Self { ranks, sent: 0, received: 0, pending: None }
    }
}

impl Transport for Loopback {
    fn name(&self) -> &'static str {
        "loopback"
    }

    fn ranks(&self) -> usize {
        self.ranks
    }

    fn post_send(&mut self, local: Vec<Frame>) -> Result<()> {
        if self.pending.is_some() {
            bail!("loopback: gather already in flight (post_send without collect)");
        }
        if local.len() != self.ranks {
            bail!("loopback hosts all {} ranks, got {} frames", self.ranks, local.len());
        }
        // The round trip is the point: loopback runs the same
        // serialization the socket transports ship, so framed-byte
        // accounting and codec coverage don't depend on the topology.
        let sp = crate::trace::begin();
        let mut encoded = Vec::with_capacity(local.len());
        for f in &local {
            let bytes = f.encode();
            self.sent += bytes.len() as u64;
            encoded.push(bytes);
        }
        self.pending = Some(encoded);
        sp.end("dist", "post_send", 0);
        Ok(())
    }

    fn collect(&mut self) -> Result<Vec<Frame>> {
        let encoded =
            self.pending.take().ok_or_else(|| anyhow!("loopback: collect without post_send"))?;
        let sp = crate::trace::begin();
        let mut out = Vec::with_capacity(encoded.len());
        for bytes in &encoded {
            let (back, used) = Frame::decode(bytes).map_err(wire_err)?;
            debug_assert_eq!(used, bytes.len());
            self.received += used as u64;
            out.push(back);
        }
        sp.end("dist", "gather", 0);
        Ok(out)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// Shared stream-endpoint machinery (uds + tcp)
// ---------------------------------------------------------------------------

/// What the stream hub and the topology drivers need from a socket beyond
/// `Read + Write`: a settable receive timeout (reads only — `SO_RCVTIMEO`
/// never blocks the relay writes). Public so the topology fault-injection
/// tests can drive [`RingDriver::from_streams`] /
/// [`TreeDriver::from_streams`] over raw sockets.
pub trait GatherStream: Read + Write + Send {
    fn set_recv_timeout(&self, t: Option<Duration>) -> std::io::Result<()>;
}

impl GatherStream for UnixStream {
    fn set_recv_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        self.set_read_timeout(t)
    }
}

impl GatherStream for TcpStream {
    fn set_recv_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        self.set_read_timeout(t)
    }
}

/// Coordinator gather state between `post_send` and the end of `collect`.
struct PendingGather {
    step: u64,
    /// Slot `r` holds rank `r`'s frame; slot 0 is filled by `post_send`.
    frames: Vec<Option<Frame>>,
    /// Encoded bytes of every gathered frame — the relay source.
    encoded: Vec<Option<Vec<u8>>>,
    /// `frames[0..prefix]` are all present. Bundles are rank-ascending,
    /// so only this prefix may be relayed: frame `r` never overtakes a
    /// missing frame `< r` on any worker's downlink.
    prefix: usize,
    /// Worker `i` (rank `i+1`) has delivered its uplink frame this round.
    /// Only then is it guaranteed to be draining its downlink — relaying
    /// earlier could deadlock two blocking writes against each other on
    /// large frames.
    ready: Vec<bool>,
    /// Frames relayed to worker `i` so far this round.
    sent_upto: Vec<usize>,
    /// Ranks in uplink-arrival order.
    arrival: Vec<u16>,
    /// When `post_send` opened this round — the zero point of the
    /// per-frame arrival latencies.
    opened: Instant,
    /// Milliseconds after `opened` at which each frame arrived,
    /// index-aligned with `arrival`.
    arrival_ms: Vec<f64>,
}

/// The rank-0 side of a stream transport: one stream per worker and the
/// pipelined gather/relay loop over them.
struct StreamHub<S: GatherStream> {
    ranks: usize,
    /// Index `i` = rank `i + 1`.
    workers: Vec<S>,
    /// Per-worker incremental frame assemblers (partial TCP segments,
    /// bytes from a next-step frame that ran ahead — all handled here).
    readers: Vec<FrameReader>,
    pending: Option<PendingGather>,
    last_arrival: Vec<u16>,
    last_arrival_ms: Vec<f64>,
    overlap_micros: u64,
    sent: u64,
    received: u64,
}

impl<S: GatherStream> StreamHub<S> {
    fn new(workers: Vec<S>, ranks: usize) -> Self {
        let readers = workers.iter().map(|_| FrameReader::new()).collect();
        Self {
            ranks,
            workers,
            readers,
            pending: None,
            last_arrival: Vec::new(),
            last_arrival_ms: Vec::new(),
            overlap_micros: 0,
            sent: 0,
            received: 0,
        }
    }

    fn post_send(&mut self, mine: Frame, kind: &str) -> Result<()> {
        if self.pending.is_some() {
            bail!("{kind}: gather already in flight (post_send without collect)");
        }
        if mine.rank != 0 {
            bail!("{kind} coordinator must host rank 0, got {}", mine.rank);
        }
        let mut frames: Vec<Option<Frame>> = (0..self.ranks).map(|_| None).collect();
        let mut encoded: Vec<Option<Vec<u8>>> = (0..self.ranks).map(|_| None).collect();
        let step = mine.step;
        encoded[0] = Some(mine.encode());
        frames[0] = Some(mine);
        self.pending = Some(PendingGather {
            step,
            frames,
            encoded,
            prefix: 1,
            ready: vec![false; self.workers.len()],
            sent_upto: vec![0; self.workers.len()],
            arrival: Vec::new(),
            opened: Instant::now(),
            arrival_ms: Vec::new(),
        });
        Ok(())
    }

    fn collect(&mut self, kind: &str) -> Result<Vec<Frame>> {
        self.collect_cb(kind, None)
    }

    fn collect_cb(
        &mut self,
        kind: &str,
        mut on_frame: Option<&mut dyn FnMut(&Frame) -> Result<()>>,
    ) -> Result<Vec<Frame>> {
        let mut p =
            self.pending.take().ok_or_else(|| anyhow!("{kind}: collect without post_send"))?;
        // Brief read timeouts during the gather: the round-robin poll must
        // not freeze on one silent worker while another has bytes ready.
        for w in &self.workers {
            w.set_recv_timeout(Some(GATHER_POLL)).context("gather poll timeout")?;
        }
        let sp = crate::trace::begin();
        let overlap_before = self.overlap_micros;
        let res = self.collect_inner(&mut p, kind, &mut on_frame);
        for w in &self.workers {
            let _ = w.set_recv_timeout(Some(PEER_TIMEOUT));
        }
        self.last_arrival = std::mem::take(&mut p.arrival);
        self.last_arrival_ms = std::mem::take(&mut p.arrival_ms);
        // The relay time hidden under this round's gather, as a real span
        // nested at the gather's start (complements the cumulative
        // `overlap_ms()` float).
        crate::trace::span_at(
            "dist",
            "relay_overlap",
            0,
            sp.start_ns(),
            (self.overlap_micros - overlap_before) * 1000,
        );
        res
    }

    fn collect_inner(
        &mut self,
        p: &mut PendingGather,
        kind: &str,
        on_frame: &mut Option<&mut dyn FnMut(&Frame) -> Result<()>>,
    ) -> Result<Vec<Frame>> {
        let n = self.workers.len();
        // Streaming contract: locally-hosted frames first — rank 0's own
        // frame is decodable before any worker byte arrives.
        if let Some(cb) = on_frame.as_deref_mut() {
            if let Some(f0) = &p.frames[0] {
                cb(f0)?;
            }
        }
        let deadline = Instant::now() + PEER_TIMEOUT;
        loop {
            let done = p.prefix == self.ranks && p.sent_upto.iter().all(|&s| s == self.ranks);
            if done {
                break;
            }
            if Instant::now() >= deadline {
                let have: Vec<usize> =
                    (0..self.ranks).filter(|&r| p.frames[r].is_some()).collect();
                bail!(
                    "{kind}: gather timed out at step {} (have frames from ranks {have:?} \
                     of 0..{})",
                    p.step,
                    self.ranks
                );
            }
            // 1. poll every worker whose frame is still outstanding
            for i in 0..n {
                if p.frames[i + 1].is_some() {
                    continue;
                }
                match self.readers[i].poll_read_raw(&mut self.workers[i]) {
                    Ok(Some((f, raw))) => {
                        if f.rank as usize != i + 1 || f.step != p.step {
                            bail!(
                                "{kind}: expected rank {}/step {}, got rank {}/step {}",
                                i + 1,
                                p.step,
                                f.rank,
                                f.step
                            );
                        }
                        self.received += raw.len() as u64;
                        p.arrival.push(f.rank);
                        p.arrival_ms.push(p.opened.elapsed().as_secs_f64() * 1e3);
                        // streaming decode: hand the frame over in arrival
                        // order, while other uplinks are still in flight
                        if let Some(cb) = on_frame.as_deref_mut() {
                            cb(&f)?;
                        }
                        // relay the worker's exact (CRC-verified) wire
                        // bytes — no re-encode pass on the hot path
                        p.encoded[i + 1] = Some(raw);
                        p.frames[i + 1] = Some(f);
                        p.ready[i] = true;
                    }
                    Ok(None) => {}
                    Err(e) => {
                        return Err(wire_err(e))
                            .with_context(|| format!("{kind}: gather from rank {}", i + 1))
                    }
                }
            }
            while p.prefix < self.ranks && p.frames[p.prefix].is_some() {
                p.prefix += 1;
            }
            // 2. relay the completed rank-ascending prefix to every ready
            //    worker — this is the pipelining: bundle bytes go out while
            //    later gather frames are still in flight
            let missing = p.frames.iter().filter(|f| f.is_none()).count();
            let t0 = Instant::now();
            let mut relayed = false;
            for i in 0..n {
                if !p.ready[i] {
                    continue;
                }
                while p.sent_upto[i] < p.prefix {
                    let bytes = p.encoded[p.sent_upto[i]].as_ref().ok_or_else(|| {
                        anyhow!(
                            "{kind}: relay invariant broken — rank {} is inside the gathered \
                             prefix but has no encoded bytes",
                            p.sent_upto[i]
                        )
                    })?;
                    self.workers[i]
                        .write_all(bytes)
                        .with_context(|| format!("{kind}: relay to rank {}", i + 1))?;
                    self.sent += bytes.len() as u64;
                    p.sent_upto[i] += 1;
                    relayed = true;
                }
            }
            if relayed && missing > 0 {
                self.overlap_micros += t0.elapsed().as_micros() as u64;
            }
        }
        p.frames
            .iter_mut()
            .enumerate()
            .map(|(r, f)| {
                f.take().ok_or_else(|| {
                    anyhow!("{kind}: gather loop finished with rank {r}'s frame missing")
                })
            })
            .collect()
    }
}

/// One endpoint of a stream transport: the rank-0 hub, or a worker's
/// single stream to rank 0.
enum StreamRole<S: GatherStream> {
    Coordinator { hub: StreamHub<S> },
    Worker { stream: S, pending_step: Option<u64>, sent: u64, received: u64 },
}

struct StreamEndpoint<S: GatherStream> {
    name: &'static str,
    ranks: usize,
    role: StreamRole<S>,
}

impl<S: GatherStream> StreamEndpoint<S> {
    fn coordinator(name: &'static str, workers: Vec<S>, ranks: usize) -> Self {
        Self { name, ranks, role: StreamRole::Coordinator { hub: StreamHub::new(workers, ranks) } }
    }

    fn worker(name: &'static str, stream: S, ranks: usize, hello_bytes: u64) -> Self {
        Self {
            name,
            ranks,
            role: StreamRole::Worker {
                stream,
                pending_step: None,
                sent: hello_bytes,
                received: 0,
            },
        }
    }

    fn post_send(&mut self, mut local: Vec<Frame>) -> Result<()> {
        if local.len() != 1 {
            bail!("{} endpoints host exactly one rank, got {} frames", self.name, local.len());
        }
        let Some(mine) = local.pop() else {
            bail!("{}: post_send needs this endpoint's frame", self.name);
        };
        let name = self.name;
        let sp = crate::trace::begin();
        let res = match &mut self.role {
            StreamRole::Coordinator { hub } => hub.post_send(mine, name),
            StreamRole::Worker { stream, pending_step, sent, .. } => {
                if pending_step.is_some() {
                    bail!("{name}: gather already in flight (post_send without collect)");
                }
                let step = mine.step;
                let bytes = mine.encode();
                stream.write_all(&bytes).with_context(|| format!("{name}: send frame"))?;
                *sent += bytes.len() as u64;
                *pending_step = Some(step);
                Ok(())
            }
        };
        sp.end("dist", "post_send", 0);
        res
    }

    fn collect(&mut self) -> Result<Vec<Frame>> {
        self.collect_cb(None)
    }

    fn collect_cb(
        &mut self,
        mut on_frame: Option<&mut dyn FnMut(&Frame) -> Result<()>>,
    ) -> Result<Vec<Frame>> {
        let name = self.name;
        let ranks = self.ranks;
        let sp = crate::trace::begin();
        let res = match &mut self.role {
            StreamRole::Coordinator { hub } => hub.collect_cb(name, on_frame),
            StreamRole::Worker { stream, pending_step, received, .. } => {
                let step = pending_step
                    .take()
                    .ok_or_else(|| anyhow!("{name}: collect without post_send"))?;
                let mut frames = Vec::with_capacity(ranks);
                for r in 0..ranks {
                    let f = Frame::read_from(stream)
                        .map_err(wire_err)
                        .with_context(|| format!("{name}: bundle frame {r}"))?;
                    if f.rank as usize != r || f.step != step {
                        bail!(
                            "{name}: bundle out of order (expected rank {r}/step {step}, \
                             got rank {}/step {})",
                            f.rank,
                            f.step
                        );
                    }
                    *received += f.encoded_len() as u64;
                    // streaming decode: the pipelined relay delivers the
                    // bundle prefix while the coordinator is still
                    // gathering the tail, so per-frame decode overlaps it
                    if let Some(cb) = on_frame.as_deref_mut() {
                        cb(&f)?;
                    }
                    frames.push(f);
                }
                Ok(frames)
            }
        };
        sp.end("dist", "gather", 0);
        res
    }

    fn bytes_sent(&self) -> u64 {
        match &self.role {
            StreamRole::Coordinator { hub } => hub.sent,
            StreamRole::Worker { sent, .. } => *sent,
        }
    }

    fn bytes_received(&self) -> u64 {
        match &self.role {
            StreamRole::Coordinator { hub } => hub.received,
            StreamRole::Worker { received, .. } => *received,
        }
    }

    fn overlap_ms(&self) -> f64 {
        match &self.role {
            StreamRole::Coordinator { hub } => hub.overlap_micros as f64 / 1000.0,
            StreamRole::Worker { .. } => 0.0,
        }
    }

    fn last_arrival(&self) -> &[u16] {
        match &self.role {
            StreamRole::Coordinator { hub } => &hub.last_arrival,
            StreamRole::Worker { .. } => &[],
        }
    }

    fn last_arrival_ms(&self) -> &[f64] {
        match &self.role {
            StreamRole::Coordinator { hub } => &hub.last_arrival_ms,
            StreamRole::Worker { .. } => &[],
        }
    }
}

/// Shared accept loop of the rendezvous listeners: poll non-blocking
/// accepts against the deadline, then demand a hello frame within
/// `hello_wait` from each connection.
fn read_hello<S: GatherStream>(stream: &mut S, name: &str, hello_wait: Duration) -> Result<Frame> {
    stream.set_recv_timeout(Some(hello_wait))?;
    let hello = match Frame::read_from(stream) {
        Ok(f) => f,
        Err(WireError::Io(e))
            if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
        {
            bail!(
                "{name}: peer connected but sent no hello within {:.1}s — rejecting it \
                 so the other ranks' rendezvous is not held up",
                hello_wait.as_secs_f64()
            );
        }
        Err(e) => return Err(wire_err(e)).with_context(|| format!("{name}: read hello")),
    };
    stream.set_recv_timeout(Some(PEER_TIMEOUT))?;
    if hello.flags & FLAG_HELLO == 0 {
        bail!("{name}: worker spoke before the handshake");
    }
    Ok(hello)
}

/// Place an accepted, hello-validated stream into its rank slot.
fn place_worker<S>(slots: &mut [Option<S>], stream: S, rank: usize, name: &str) -> Result<()> {
    let ranks = slots.len() + 1;
    if rank == 0 || rank >= ranks {
        bail!("{name}: hello from rank {rank}, world is 0..{ranks}");
    }
    if slots[rank - 1].replace(stream).is_some() {
        bail!("{name}: two workers claimed rank {rank}");
    }
    Ok(())
}

/// The rendezvous accept loop shared by the stream listeners: poll
/// `accept_one` (a non-blocking accept returning `WouldBlock` while no
/// connection is pending, with any per-stream socket setup applied)
/// against the peer deadline, demand each connection's hello within
/// `hello_wait`, and return the workers rank-slotted.
fn accept_workers<S, F>(
    mut accept_one: F,
    ranks: usize,
    hello_wait: Duration,
    name: &'static str,
    rendezvous: &str,
) -> Result<Vec<S>>
where
    S: GatherStream,
    F: FnMut() -> std::io::Result<S>,
{
    let deadline = Instant::now() + PEER_TIMEOUT;
    let mut slots: Vec<Option<S>> = (1..ranks).map(|_| None).collect();
    for _ in 1..ranks {
        let mut stream = loop {
            match accept_one() {
                Ok(s) => break s,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!("{name}: timed out waiting for workers at {rendezvous}");
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).with_context(|| format!("{name}: accept")),
            }
        };
        let hello = read_hello(&mut stream, name, hello_wait)?;
        place_worker(&mut slots, stream, hello.rank as usize, name)?;
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            s.ok_or_else(|| {
                anyhow!("{name}: accept loop ended with rank {}'s stream unfilled", i + 1)
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Unix-domain sockets
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-connected UDS rendezvous: rank 0 binds *before*
/// spawning workers (no connect race), accepts after.
pub struct UdsPending {
    listener: UnixListener,
    path: PathBuf,
    ranks: usize,
    hello_wait: Duration,
}

impl UdsPending {
    /// Bind the rendezvous socket at `path` for a world of `ranks`.
    /// A stale socket file from a previous run is removed first.
    pub fn bind<P: AsRef<Path>>(path: P, ranks: usize) -> Result<UdsPending> {
        assert!(ranks > 0);
        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("uds: bind {}", path.display()))?;
        Ok(UdsPending { listener, path, ranks, hello_wait: HELLO_WAIT })
    }

    /// Shrink (or grow) the per-connection hello wait — tests use this to
    /// keep the never-sent-hello failure path fast.
    pub fn set_hello_wait(&mut self, d: Duration) {
        self.hello_wait = d;
    }

    /// Accept the `ranks - 1` workers. Each must introduce itself with a
    /// [`FLAG_HELLO`] frame carrying its rank within [`HELLO_WAIT`] of
    /// connecting; duplicates, out-of-range ranks and silent connections
    /// abort the run (a peer that never says hello is bounded by the
    /// hello wait, not [`PEER_TIMEOUT`], so it cannot hold the accept
    /// loop past the other ranks). Gives up after [`PEER_TIMEOUT`] if a
    /// worker never shows (e.g. it crashed at startup), so the launcher
    /// can reap instead of hanging.
    pub fn accept(self) -> Result<UdsTransport> {
        let ranks = self.ranks;
        let (workers, path) = self.accept_streams()?;
        Ok(UdsTransport {
            inner: StreamEndpoint::coordinator("uds", workers, ranks),
            path: Some(path),
        })
    }

    /// The raw rendezvous: accept and rank-slot the worker streams without
    /// committing them to the star endpoint — the topology constructors
    /// ([`ring_uds_coordinator`] / [`tree_uds_coordinator`]) reuse the
    /// star hello machinery through this and then re-wire the links.
    fn accept_streams(self) -> Result<(Vec<UnixStream>, PathBuf)> {
        // UnixListener has no accept timeout; poll a non-blocking accept
        // against a deadline instead.
        self.listener.set_nonblocking(true)?;
        let rendezvous = self.path.display().to_string();
        let workers = accept_workers(
            || {
                let (stream, _) = self.listener.accept()?;
                // the accepted stream must block normally (it may inherit
                // the listener's non-blocking mode on some platforms)
                stream.set_nonblocking(false)?;
                // Writes are bounded too: a worker that delivers its
                // uplink but stops draining its downlink must fail the
                // relay typed within the peer timeout, not hang the
                // coordinator forever.
                stream.set_write_timeout(Some(PEER_TIMEOUT))?;
                Ok(stream)
            },
            self.ranks,
            self.hello_wait,
            "uds",
            &rendezvous,
        )?;
        Ok((workers, self.path))
    }
}

/// Unix-domain-socket transport (see [`UdsPending`] for the rank-0 side).
pub struct UdsTransport {
    inner: StreamEndpoint<UnixStream>,
    /// The rendezvous socket file (coordinator only; removed on drop).
    path: Option<PathBuf>,
}

impl UdsTransport {
    /// Connect worker `rank` to the rendezvous socket, retrying until the
    /// coordinator has bound it (or [`CONNECT_TIMEOUT`] passes), then send
    /// the hello frame.
    pub fn connect<P: AsRef<Path>>(path: P, rank: usize, ranks: usize) -> Result<UdsTransport> {
        let (stream, hello_bytes) = Self::connect_stream(path.as_ref(), rank, ranks)?;
        Ok(UdsTransport {
            inner: StreamEndpoint::worker("uds", stream, ranks, hello_bytes),
            path: None,
        })
    }

    /// The raw worker rendezvous (connect + hello), shared with the
    /// topology worker constructors.
    fn connect_stream(path: &Path, rank: usize, ranks: usize) -> Result<(UnixStream, u64)> {
        assert!(rank > 0 && rank < ranks, "workers are ranks 1..{ranks}, got {rank}");
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        let mut stream = loop {
            match UnixStream::connect(path) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(e))
                            .with_context(|| format!("uds: connect {}", path.display()));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_read_timeout(Some(PEER_TIMEOUT))?;
        stream.set_write_timeout(Some(PEER_TIMEOUT))?;
        let hello = Frame::hello(rank).encode();
        stream.write_all(&hello).context("uds: send hello")?;
        Ok((stream, hello.len() as u64))
    }

    /// Ranks of the last completed gather in uplink-arrival order
    /// (coordinator only; empty on workers).
    pub fn last_arrival_order(&self) -> &[u16] {
        self.inner.last_arrival()
    }
}

impl Drop for UdsTransport {
    fn drop(&mut self) {
        if let Some(path) = &self.path {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Transport for UdsTransport {
    fn name(&self) -> &'static str {
        "uds"
    }

    fn ranks(&self) -> usize {
        self.inner.ranks
    }

    fn post_send(&mut self, local: Vec<Frame>) -> Result<()> {
        self.inner.post_send(local)
    }

    fn collect(&mut self) -> Result<Vec<Frame>> {
        self.inner.collect()
    }

    fn collect_streaming(
        &mut self,
        on_frame: &mut dyn FnMut(&Frame) -> Result<()>,
    ) -> Result<Vec<Frame>> {
        self.inner.collect_cb(Some(on_frame))
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn bytes_received(&self) -> u64 {
        self.inner.bytes_received()
    }

    fn overlap_ms(&self) -> f64 {
        self.inner.overlap_ms()
    }

    fn last_arrival(&self) -> &[u16] {
        self.inner.last_arrival()
    }

    fn last_arrival_ms(&self) -> &[f64] {
        self.inner.last_arrival_ms()
    }
}

// ---------------------------------------------------------------------------
// TCP sockets (multi-host)
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-connected TCP rendezvous — the multi-host twin of
/// [`UdsPending`]. Rank 0 binds `host:port` *before* spawning (or telling
/// the operator to start) workers; an ephemeral `:0` port is resolved via
/// [`TcpPending::local_addr`].
pub struct TcpPending {
    listener: TcpListener,
    addr: String,
    ranks: usize,
    hello_wait: Duration,
}

impl TcpPending {
    /// Bind the rendezvous listener at `addr` (`host:port`) for a world
    /// of `ranks`.
    pub fn bind(addr: &str, ranks: usize) -> Result<TcpPending> {
        assert!(ranks > 0);
        let listener =
            TcpListener::bind(addr).with_context(|| format!("tcp: bind {addr}"))?;
        Ok(TcpPending { listener, addr: addr.to_string(), ranks, hello_wait: HELLO_WAIT })
    }

    /// Shrink (or grow) the per-connection hello wait — tests use this to
    /// keep the never-sent-hello failure path fast.
    pub fn set_hello_wait(&mut self, d: Duration) {
        self.hello_wait = d;
    }

    /// The actually-bound address: with an ephemeral `:0` bind this is
    /// the concrete port workers must be pointed at.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("tcp: local_addr")
    }

    /// Accept the `ranks - 1` workers — the same hello protocol as
    /// [`UdsPending::accept`], with `TCP_NODELAY` set on every accepted
    /// stream (frames are small; Nagle would serialize the pipelined
    /// relay behind ACKs).
    pub fn accept(self) -> Result<TcpTransport> {
        let ranks = self.ranks;
        let workers = self.accept_streams()?;
        Ok(TcpTransport { inner: StreamEndpoint::coordinator("tcp", workers, ranks) })
    }

    /// The raw rendezvous (accept + rank-slot), shared with the topology
    /// coordinator constructors ([`ring_tcp_coordinator`] /
    /// [`tree_tcp_coordinator`]).
    fn accept_streams(self) -> Result<Vec<TcpStream>> {
        self.listener.set_nonblocking(true)?;
        accept_workers(
            || {
                let (stream, _) = self.listener.accept()?;
                stream.set_nonblocking(false)?;
                stream.set_nodelay(true)?;
                // bounded writes: a non-draining worker fails the relay
                // typed instead of hanging the coordinator (see the uds
                // twin)
                stream.set_write_timeout(Some(PEER_TIMEOUT))?;
                Ok(stream)
            },
            self.ranks,
            self.hello_wait,
            "tcp",
            &self.addr,
        )
    }
}

/// TCP transport (see [`TcpPending`] for the rank-0 side): the same
/// rendezvous/hello/config-digest/bundle session as uds, over
/// `host:port` — runs between real hosts.
pub struct TcpTransport {
    inner: StreamEndpoint<TcpStream>,
}

impl TcpTransport {
    /// Connect worker `rank` to the rendezvous address (`host:port`),
    /// retrying until the coordinator has bound it (or
    /// [`CONNECT_TIMEOUT`] passes), then send the hello frame.
    /// `TCP_NODELAY` is set before any byte moves.
    pub fn connect(addr: &str, rank: usize, ranks: usize) -> Result<TcpTransport> {
        let (stream, hello_bytes) = Self::connect_stream(addr, rank, ranks)?;
        Ok(TcpTransport {
            inner: StreamEndpoint::worker("tcp", stream, ranks, hello_bytes),
        })
    }

    /// The raw worker rendezvous (connect + nodelay + hello), shared with
    /// the topology worker constructors.
    fn connect_stream(addr: &str, rank: usize, ranks: usize) -> Result<(TcpStream, u64)> {
        assert!(rank > 0 && rank < ranks, "workers are ranks 1..{ranks}, got {rank}");
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(e)).with_context(|| format!("tcp: connect {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(PEER_TIMEOUT))?;
        stream.set_write_timeout(Some(PEER_TIMEOUT))?;
        let hello = Frame::hello(rank).encode();
        stream.write_all(&hello).context("tcp: send hello")?;
        Ok((stream, hello.len() as u64))
    }

    /// Ranks of the last completed gather in uplink-arrival order
    /// (coordinator only; empty on workers).
    pub fn last_arrival_order(&self) -> &[u16] {
        self.inner.last_arrival()
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn ranks(&self) -> usize {
        self.inner.ranks
    }

    fn post_send(&mut self, local: Vec<Frame>) -> Result<()> {
        self.inner.post_send(local)
    }

    fn collect(&mut self) -> Result<Vec<Frame>> {
        self.inner.collect()
    }

    fn collect_streaming(
        &mut self,
        on_frame: &mut dyn FnMut(&Frame) -> Result<()>,
    ) -> Result<Vec<Frame>> {
        self.inner.collect_cb(Some(on_frame))
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn bytes_received(&self) -> u64 {
        self.inner.bytes_received()
    }

    fn overlap_ms(&self) -> f64 {
        self.inner.overlap_ms()
    }

    fn last_arrival(&self) -> &[u16] {
        self.inner.last_arrival()
    }

    fn last_arrival_ms(&self) -> &[f64] {
        self.inner.last_arrival_ms()
    }
}

// ---------------------------------------------------------------------------
// Ring / tree topology drivers (uds + tcp)
// ---------------------------------------------------------------------------
//
// Both drivers reuse the star rendezvous (bind → hello → rank slots) purely
// as a control plane: once every rank is identified, the endpoints exchange
// a link table (rank → per-rank listener address) over the star streams,
// dial their topology neighbors directly, and drop the star links. The
// per-step data plane then never funnels through rank 0's hub. Listeners
// are bound *before* the table is broadcast, so every dial lands in an
// already-open backlog — the connect-then-accept sequence cannot deadlock
// across the world. Hop-frame layout and fan-in rules are normative in
// `rust/src/dist/README.md` §10.

/// How a topology driver opens its neighbor links: one listener per rank
/// plus point-to-point dials. Implemented for tcp (ephemeral ports on the
/// rendezvous interface) and uds (per-rank socket paths derived from the
/// rendezvous path).
trait LinkFabric {
    type Stream: GatherStream + Send + 'static;
    type Listener: Send;
    /// Transport display name for error contexts (`tcp` / `uds`).
    fn kind(&self) -> &'static str;
    /// Bind this rank's link listener; returns it plus the address string
    /// peers dial (published through the link table).
    fn bind(&self) -> Result<(Self::Listener, String)>;
    /// Dial a peer's published link address (retrying until
    /// [`CONNECT_TIMEOUT`]), with the peer timeouts applied to the stream.
    fn connect(&self, addr: &str) -> Result<Self::Stream>;
    /// Accept one inbound link (polling against [`PEER_TIMEOUT`]), with
    /// the peer timeouts applied to the stream.
    fn accept(&self, listener: &Self::Listener) -> Result<Self::Stream>;
    /// Remove any filesystem residue of the listener once wiring is done.
    fn cleanup(&self);
}

/// TCP link fabric: each rank binds an ephemeral port on the interface the
/// star rendezvous already proved reachable.
struct TcpFabric {
    ip: IpAddr,
}

impl LinkFabric for TcpFabric {
    type Stream = TcpStream;
    type Listener = TcpListener;

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn bind(&self) -> Result<(TcpListener, String)> {
        let listener = TcpListener::bind((self.ip, 0))
            .with_context(|| format!("tcp: bind link listener on {}", self.ip))?;
        let addr = listener.local_addr().context("tcp: link local_addr")?.to_string();
        Ok((listener, addr))
    }

    fn connect(&self, addr: &str) -> Result<TcpStream> {
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(e))
                            .with_context(|| format!("tcp: link connect {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(PEER_TIMEOUT))?;
        stream.set_write_timeout(Some(PEER_TIMEOUT))?;
        Ok(stream)
    }

    fn accept(&self, listener: &TcpListener) -> Result<TcpStream> {
        listener.set_nonblocking(true)?;
        let deadline = Instant::now() + PEER_TIMEOUT;
        let stream = loop {
            match listener.accept() {
                Ok((s, _)) => break s,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!("tcp: timed out waiting for a link peer");
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("tcp: link accept"),
            }
        };
        stream.set_nonblocking(false)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(PEER_TIMEOUT))?;
        stream.set_write_timeout(Some(PEER_TIMEOUT))?;
        Ok(stream)
    }

    fn cleanup(&self) {}
}

/// UDS link fabric: rank `r` listens at `<rendezvous>.r<r>`.
struct UdsFabric {
    path: PathBuf,
}

impl LinkFabric for UdsFabric {
    type Stream = UnixStream;
    type Listener = UnixListener;

    fn kind(&self) -> &'static str {
        "uds"
    }

    fn bind(&self) -> Result<(UnixListener, String)> {
        // a crashed previous run may have left the per-rank socket file
        let _ = std::fs::remove_file(&self.path);
        let listener = UnixListener::bind(&self.path)
            .with_context(|| format!("uds: bind link listener {}", self.path.display()))?;
        Ok((listener, self.path.display().to_string()))
    }

    fn connect(&self, addr: &str) -> Result<UnixStream> {
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        let stream = loop {
            match UnixStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(e))
                            .with_context(|| format!("uds: link connect {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_read_timeout(Some(PEER_TIMEOUT))?;
        stream.set_write_timeout(Some(PEER_TIMEOUT))?;
        Ok(stream)
    }

    fn accept(&self, listener: &UnixListener) -> Result<UnixStream> {
        listener.set_nonblocking(true)?;
        let deadline = Instant::now() + PEER_TIMEOUT;
        let stream = loop {
            match listener.accept() {
                Ok((s, _)) => break s,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "uds: timed out waiting for a link peer at {}",
                            self.path.display()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("uds: link accept"),
            }
        };
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(PEER_TIMEOUT))?;
        stream.set_write_timeout(Some(PEER_TIMEOUT))?;
        Ok(stream)
    }

    fn cleanup(&self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// A link-table frame: rendezvous control traffic, so it rides the
/// handshake flag (step 0, payload = UTF-8 address bytes).
fn link_frame(rank: usize, payload: Vec<u8>) -> Frame {
    Frame {
        rank: rank as u16,
        step: 0,
        tag: PayloadTag::Dense,
        flags: FLAG_HELLO,
        loss: 0.0,
        payload,
        stats: Vec::new(),
    }
}

/// Coordinator side of the link-table exchange: read every worker's LINK
/// frame (its bound listener address) off the star streams, then broadcast
/// the full rank → address table back (newline-joined).
fn gather_link_table<S: GatherStream>(
    star: &mut [S],
    my_addr: String,
    ranks: usize,
    name: &str,
) -> Result<Vec<String>> {
    let mut table = vec![String::new(); ranks];
    table[0] = my_addr;
    for (i, stream) in star.iter_mut().enumerate() {
        let f = Frame::read_from(stream)
            .map_err(wire_err)
            .with_context(|| format!("{name}: link address from rank {}", i + 1))?;
        if f.flags & FLAG_HELLO == 0 || f.rank as usize != i + 1 {
            bail!(
                "{name}: expected rank {}'s link frame, got rank {} flags {:#04x}",
                i + 1,
                f.rank,
                f.flags
            );
        }
        let addr = String::from_utf8(f.payload)
            .map_err(|_| anyhow!("{name}: rank {}'s link address is not UTF-8", i + 1))?;
        table[i + 1] = addr;
    }
    let frame = link_frame(0, table.join("\n").into_bytes()).encode();
    for (i, stream) in star.iter_mut().enumerate() {
        stream
            .write_all(&frame)
            .with_context(|| format!("{name}: link table to rank {}", i + 1))?;
    }
    Ok(table)
}

/// Worker side of the link-table exchange: publish this rank's listener
/// address, receive the full table.
fn worker_link_table<S: GatherStream>(
    star: &mut S,
    my_addr: &str,
    rank: usize,
    ranks: usize,
    name: &str,
) -> Result<Vec<String>> {
    let frame = link_frame(rank, my_addr.as_bytes().to_vec()).encode();
    star.write_all(&frame).with_context(|| format!("{name}: send link address"))?;
    let f = Frame::read_from(star)
        .map_err(wire_err)
        .with_context(|| format!("{name}: link table"))?;
    if f.flags & FLAG_HELLO == 0 || f.rank != 0 {
        bail!(
            "{name}: expected the link table from rank 0, got rank {} flags {:#04x}",
            f.rank,
            f.flags
        );
    }
    let text =
        String::from_utf8(f.payload).map_err(|_| anyhow!("{name}: link table is not UTF-8"))?;
    let table: Vec<String> = text.split('\n').map(str::to_string).collect();
    if table.len() != ranks {
        bail!("{name}: link table has {} entries, world is {ranks}", table.len());
    }
    Ok(table)
}

/// Dial the successor, accept the predecessor. Every listener was bound
/// before the table broadcast, so the dial lands in an open backlog.
fn wire_ring<F: LinkFabric>(
    fabric: &F,
    listener: &F::Listener,
    table: &[String],
    rank: usize,
    ranks: usize,
    name: &str,
) -> Result<(F::Stream, F::Stream)> {
    let next_rank = (rank + 1) % ranks;
    let prev_rank = (rank + ranks - 1) % ranks;
    let mut next = fabric.connect(&table[next_rank])?;
    next.write_all(&Frame::hello(rank).encode())
        .with_context(|| format!("{name}: hello to successor rank {next_rank}"))?;
    let mut prev = fabric.accept(listener)?;
    let hello = read_hello(&mut prev, name, HELLO_WAIT)?;
    if hello.rank as usize != prev_rank {
        bail!("{name}: predecessor identified as rank {}, expected {prev_rank}", hello.rank);
    }
    Ok((next, prev))
}

/// Dial the parent (non-root ranks), accept this rank's children in
/// whatever order they arrive, identified by their hello frames.
fn wire_tree<F: LinkFabric>(
    fabric: &F,
    listener: &F::Listener,
    table: &[String],
    rank: usize,
    ranks: usize,
    name: &str,
) -> Result<(Option<F::Stream>, Vec<(usize, F::Stream)>)> {
    let parent = if rank == 0 {
        None
    } else {
        let p = wire::tree_parent(rank);
        let mut s = fabric.connect(&table[p])?;
        s.write_all(&Frame::hello(rank).encode())
            .with_context(|| format!("{name}: hello to parent rank {p}"))?;
        Some(s)
    };
    let expected = wire::tree_children(rank, ranks);
    let mut slots: Vec<Option<F::Stream>> = expected.iter().map(|_| None).collect();
    for _ in 0..expected.len() {
        let mut s = fabric.accept(listener)?;
        let hello = read_hello(&mut s, name, HELLO_WAIT)?;
        let r = hello.rank as usize;
        let Some(i) = expected.iter().position(|&c| c == r) else {
            bail!("{name}: hello from rank {r}, which is not a child of rank {rank}");
        };
        if slots[i].replace(s).is_some() {
            bail!("{name}: two link peers claimed child rank {r}");
        }
    }
    let children = expected
        .into_iter()
        .zip(slots)
        .map(|(r, s)| {
            s.map(|s| (r, s))
                .ok_or_else(|| anyhow!("{name}: child rank {r}'s link was never filled"))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((parent, children))
}

/// Ring collective over point-to-point successor/predecessor links.
///
/// Two collectives share the links:
///
/// * [`Transport::collect`] — a plain `(ranks − 1)`-round all-gather: each
///   round every endpoint forwards the frame it holds to its successor and
///   receives its predecessor's, so every frame travels the whole ring.
///   The config handshake rides this path.
/// * [`Transport::collect_reduced`] — the in-network reduction: rank 0
///   seeds a [`FLAG_HOP`] frame with its own folded payload; each
///   successor validates the hop's fan-in count, folds its payload into
///   the circulating partial, and forwards; the last rank finishes the
///   partial and circulates the single result frame once around. Folding
///   is rank-ascending from a zeroed accumulator — the same op order as
///   the star aggregate, so the result is bit-identical to star
///   (`rust/src/dist/README.md` §10).
pub struct RingDriver<S: GatherStream> {
    name: &'static str,
    rank: usize,
    ranks: usize,
    next: S,
    prev: S,
    reader: FrameReader,
    pending: Option<Frame>,
    sent: u64,
    received: u64,
}

impl<S: GatherStream> RingDriver<S> {
    /// Assemble a ring endpoint from already-wired neighbor streams
    /// (`next` = dialed successor, `prev` = accepted predecessor). Public
    /// for the fault-injection tests; runs use the
    /// `ring_{tcp,uds}_{coordinator,worker}` constructors.
    pub fn from_streams(
        name: &'static str,
        rank: usize,
        ranks: usize,
        next: S,
        prev: S,
    ) -> Result<Self> {
        if ranks < 2 {
            bail!("{name}: a ring needs at least 2 ranks, got {ranks}");
        }
        if rank >= ranks {
            bail!("{name}: rank {rank} out of world 0..{ranks}");
        }
        Ok(Self {
            name,
            rank,
            ranks,
            next,
            prev,
            reader: FrameReader::new(),
            pending: None,
            sent: 0,
            received: 0,
        })
    }

    fn prev_rank(&self) -> usize {
        (self.rank + self.ranks - 1) % self.ranks
    }

    fn take_pending(&mut self) -> Result<Frame> {
        self.pending.take().ok_or_else(|| anyhow!("{}: collect without post_send", self.name))
    }

    fn send_next(&mut self, bytes: &[u8], what: &str) -> Result<()> {
        self.next.write_all(bytes).with_context(|| {
            format!("{}: {what} to successor rank {}", self.name, (self.rank + 1) % self.ranks)
        })?;
        self.sent += bytes.len() as u64;
        Ok(())
    }

    /// Poll the predecessor link for one complete frame, bounded by
    /// [`PEER_TIMEOUT`].
    fn ring_read(&mut self, what: &str) -> Result<(Frame, Vec<u8>)> {
        let deadline = Instant::now() + PEER_TIMEOUT;
        loop {
            match self.reader.poll_read_raw(&mut self.prev) {
                Ok(Some((f, raw))) => {
                    self.received += raw.len() as u64;
                    return Ok((f, raw));
                }
                Ok(None) => {
                    if Instant::now() >= deadline {
                        bail!(
                            "{}: predecessor rank {} went silent mid-{what}",
                            self.name,
                            self.prev_rank()
                        );
                    }
                }
                Err(e) => {
                    return Err(wire_err(e)).with_context(|| {
                        format!(
                            "{}: {what} from predecessor rank {}",
                            self.name,
                            self.prev_rank()
                        )
                    })
                }
            }
        }
    }

    /// The `(ranks − 1)`-round all-gather: in round `k` this endpoint
    /// holds the frame that originated `k` hops back, forwards its raw
    /// bytes, and receives the one originating `k + 1` hops back.
    fn collect_allgather(&mut self, mine: Frame) -> Result<Vec<Frame>> {
        let n = self.ranks;
        let step = mine.step;
        let mut slots: Vec<Option<Frame>> = (0..n).map(|_| None).collect();
        let mut cur = mine.encode();
        slots[self.rank] = Some(mine);
        for round in 1..n {
            let cur_out = std::mem::take(&mut cur);
            self.send_next(&cur_out, "all-gather frame")?;
            let (f, raw) = self.ring_read("all-gather")?;
            let from = (self.rank + n - round) % n;
            if f.step != step || f.rank as usize != from {
                bail!(
                    "{}: all-gather round {round} expected rank {from}/step {step}, \
                     got rank {}/step {}",
                    self.name,
                    f.rank,
                    f.step
                );
            }
            if slots[from].replace(f).is_some() {
                bail!("{}: duplicate all-gather frame from rank {from}", self.name);
            }
            cur = raw;
        }
        slots
            .iter_mut()
            .enumerate()
            .map(|(r, f)| {
                f.take().ok_or_else(|| {
                    anyhow!("{}: all-gather finished with rank {r}'s frame missing", self.name)
                })
            })
            .collect()
    }

    /// The in-ring reduction: reduction leg up the rank order, then the
    /// finished frame circulates once around.
    fn collect_hop(
        &mut self,
        mine: Frame,
        fold: &mut dyn FnMut(&[u8], &mut Vec<f32>) -> Result<()>,
    ) -> Result<Vec<Frame>> {
        let n = self.ranks;
        let step = mine.step;
        let tag = mine.tag;
        let last = n - 1;
        let outgoing = if self.rank == 0 {
            let mut acc = Vec::new();
            fold(&mine.payload, &mut acc)?;
            Frame {
                rank: 0,
                step,
                tag,
                flags: FLAG_HOP,
                // seeded exactly like the star loss fold: 0.0, then rank
                // 0's term
                loss: 0.0 + mine.loss,
                payload: wire::hop_payload(1, &acc),
                stats: Vec::new(),
            }
        } else {
            let (hop, _) = self.ring_read("reduction hop")?;
            let from = self.rank - 1;
            if hop.flags & FLAG_HOP == 0
                || hop.step != step
                || hop.tag != tag
                || hop.rank as usize != from
            {
                bail!(
                    "{}: expected a hop frame from rank {from} at step {step}, got rank {} \
                     step {} flags {:#04x}",
                    self.name,
                    hop.rank,
                    hop.step,
                    hop.flags
                );
            }
            let (fan_in, partial) = wire::hop_from_payload(&hop.payload)
                .map_err(wire_err)
                .with_context(|| format!("{}: hop payload from rank {from}", self.name))?;
            if fan_in as usize != self.rank {
                bail!(
                    "{}: hop fan-in is {fan_in}, but ranks 0..{} should have folded by now",
                    self.name,
                    self.rank
                );
            }
            let mut acc = partial;
            fold(&mine.payload, &mut acc)?;
            Frame {
                rank: self.rank as u16,
                step,
                tag,
                flags: FLAG_HOP,
                loss: hop.loss + mine.loss,
                payload: wire::hop_payload((self.rank + 1) as u16, &acc),
                stats: Vec::new(),
            }
        };
        let what = if self.rank == last { "reduction result" } else { "reduction hop" };
        self.send_next(&outgoing.encode(), what)?;
        let result = if self.rank == last {
            outgoing
        } else {
            let (f, raw) = self.ring_read("reduction result")?;
            if f.flags & FLAG_HOP == 0 || f.step != step || f.tag != tag || f.rank as usize != last
            {
                bail!(
                    "{}: expected the finished reduction frame from rank {last}, got rank {} \
                     step {} flags {:#04x}",
                    self.name,
                    f.rank,
                    f.step,
                    f.flags
                );
            }
            // forward the result onward unless the successor originated it
            if (self.rank + 1) % n != last {
                self.send_next(&raw, "reduction result")?;
            }
            f
        };
        Ok(vec![result])
    }
}

impl<S: GatherStream> Transport for RingDriver<S> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn ranks(&self) -> usize {
        self.ranks
    }

    fn post_send(&mut self, mut local: Vec<Frame>) -> Result<()> {
        if self.pending.is_some() {
            bail!("{}: gather already in flight (post_send without collect)", self.name);
        }
        if local.len() != 1 {
            bail!("{} endpoints host exactly one rank, got {} frames", self.name, local.len());
        }
        let Some(mine) = local.pop() else {
            bail!("{}: post_send needs this endpoint's frame", self.name);
        };
        if mine.rank as usize != self.rank {
            bail!(
                "{}: this endpoint hosts rank {}, got a frame from rank {}",
                self.name,
                self.rank,
                mine.rank
            );
        }
        self.pending = Some(mine);
        Ok(())
    }

    fn collect(&mut self) -> Result<Vec<Frame>> {
        let mine = self.take_pending()?;
        self.prev.set_recv_timeout(Some(GATHER_POLL)).context("gather poll timeout")?;
        let res = self.collect_allgather(mine);
        let _ = self.prev.set_recv_timeout(Some(PEER_TIMEOUT));
        res
    }

    fn collect_reduced(
        &mut self,
        fold: &mut dyn FnMut(&[u8], &mut Vec<f32>) -> Result<()>,
    ) -> Result<Vec<Frame>> {
        let mine = self.take_pending()?;
        self.prev.set_recv_timeout(Some(GATHER_POLL)).context("gather poll timeout")?;
        let res = self.collect_hop(mine, fold);
        let _ = self.prev.set_recv_timeout(Some(PEER_TIMEOUT));
        res
    }

    fn topology(&self) -> Topology {
        Topology::Ring
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

/// Binary-tree gather/relay over point-to-point links: every endpoint
/// gathers its children's subtrees (forwarding each frame toward the root
/// the moment it arrives) and relays the complement of each child's
/// subtree back down once that child has delivered — the star hub's
/// ready-gating rule applied hop by hop, so a blocking downlink write can
/// never face a peer still blocked on its own uplink. `collect` returns
/// the full rank-ascending frame set, exactly like star.
pub struct TreeDriver<S: GatherStream> {
    name: &'static str,
    rank: usize,
    ranks: usize,
    parent: Option<S>,
    parent_reader: FrameReader,
    /// `(child rank, link)` pairs, as produced by the tree wiring.
    children: Vec<(usize, S)>,
    child_readers: Vec<FrameReader>,
    pending: Option<Frame>,
    sent: u64,
    received: u64,
    overlap_micros: u64,
    last_arrival: Vec<u16>,
    last_arrival_ms: Vec<f64>,
}

impl<S: GatherStream> TreeDriver<S> {
    /// Assemble a tree endpoint from already-wired links. Public for the
    /// fault-injection tests; runs use the
    /// `tree_{tcp,uds}_{coordinator,worker}` constructors.
    pub fn from_streams(
        name: &'static str,
        rank: usize,
        ranks: usize,
        parent: Option<S>,
        children: Vec<(usize, S)>,
    ) -> Result<Self> {
        if ranks < 2 {
            bail!("{name}: a tree needs at least 2 ranks, got {ranks}");
        }
        if rank >= ranks {
            bail!("{name}: rank {rank} out of world 0..{ranks}");
        }
        if (rank == 0) != parent.is_none() {
            bail!(
                "{name}: rank {rank} must {} a parent link",
                if rank == 0 { "not have" } else { "have" }
            );
        }
        let mut got: Vec<usize> = children.iter().map(|(r, _)| *r).collect();
        got.sort_unstable();
        let expected = wire::tree_children(rank, ranks);
        if got != expected {
            bail!("{name}: rank {rank}'s children are {expected:?}, got {got:?}");
        }
        let child_readers = children.iter().map(|_| FrameReader::new()).collect();
        Ok(Self {
            name,
            rank,
            ranks,
            parent,
            parent_reader: FrameReader::new(),
            children,
            child_readers,
            pending: None,
            sent: 0,
            received: 0,
            overlap_micros: 0,
            last_arrival: Vec::new(),
            last_arrival_ms: Vec::new(),
        })
    }

    fn take_pending(&mut self) -> Result<Frame> {
        self.pending.take().ok_or_else(|| anyhow!("{}: collect without post_send", self.name))
    }

    fn collect_cb(
        &mut self,
        mut on_frame: Option<&mut dyn FnMut(&Frame) -> Result<()>>,
    ) -> Result<Vec<Frame>> {
        let mine = self.take_pending()?;
        // Brief read timeouts during the gather — the poll must not freeze
        // on one silent link while another has bytes ready.
        for (_, c) in &self.children {
            c.set_recv_timeout(Some(GATHER_POLL)).context("gather poll timeout")?;
        }
        if let Some(p) = &self.parent {
            p.set_recv_timeout(Some(GATHER_POLL)).context("gather poll timeout")?;
        }
        let res = self.collect_inner(mine, &mut on_frame);
        for (_, c) in &self.children {
            let _ = c.set_recv_timeout(Some(PEER_TIMEOUT));
        }
        if let Some(p) = &self.parent {
            let _ = p.set_recv_timeout(Some(PEER_TIMEOUT));
        }
        res
    }

    fn collect_inner(
        &mut self,
        mine: Frame,
        on_frame: &mut Option<&mut dyn FnMut(&Frame) -> Result<()>>,
    ) -> Result<Vec<Frame>> {
        let name = self.name;
        let n = self.ranks;
        let step = mine.step;
        let kids: Vec<usize> = self.children.iter().map(|(r, _)| *r).collect();
        let kid_sub: Vec<usize> = kids.iter().map(|&r| wire::tree_subtree_size(r, n)).collect();
        let my_sub = wire::tree_subtree_size(self.rank, n);
        // What this endpoint is owed each way: the complement of its own
        // subtree comes down from the parent; each child is owed the
        // complement of *its* subtree.
        let need_from_parent = if self.rank == 0 { 0 } else { n - my_sub };
        let mut slots: Vec<Option<Frame>> = (0..n).map(|_| None).collect();
        let mut raws: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
        let mut kid_got = vec![0usize; kids.len()];
        let mut kid_sent = vec![vec![false; n]; kids.len()];
        let mut kid_sent_cnt = vec![0usize; kids.len()];
        let mut from_parent = 0usize;
        let opened = Instant::now();
        let mut arrival: Vec<u16> = Vec::new();
        let mut arrival_ms: Vec<f64> = Vec::new();

        // Own frame: up to the parent immediately, and first through the
        // streaming callback (locally-hosted frames first).
        let raw0 = mine.encode();
        if let Some(p) = &mut self.parent {
            p.write_all(&raw0).with_context(|| format!("{name}: own frame to parent"))?;
            self.sent += raw0.len() as u64;
        }
        if let Some(cb) = on_frame.as_deref_mut() {
            cb(&mine)?;
        }
        raws[self.rank] = Some(raw0);
        slots[self.rank] = Some(mine);

        let deadline = Instant::now() + PEER_TIMEOUT;
        loop {
            let up_done = kid_got.iter().zip(&kid_sub).all(|(&g, &s)| g == s);
            let down_done = from_parent == need_from_parent;
            let served = kid_sent_cnt.iter().zip(&kid_sub).all(|(&c, &s)| c == n - s);
            if up_done && down_done && served {
                break;
            }
            if Instant::now() >= deadline {
                let have: Vec<usize> = (0..n).filter(|&r| slots[r].is_some()).collect();
                bail!(
                    "{name}: tree gather timed out at step {step} (have frames from ranks \
                     {have:?} of 0..{n})"
                );
            }
            // 1. drain the children: each frame is validated against its
            //    child's subtree, forwarded toward the root, and stored.
            for i in 0..kids.len() {
                if kid_got[i] == kid_sub[i] {
                    continue;
                }
                match self.child_readers[i].poll_read_raw(&mut self.children[i].1) {
                    Ok(Some((f, raw))) => {
                        let r = f.rank as usize;
                        if f.step != step || r >= n || !wire::tree_in_subtree(r, kids[i], n) {
                            bail!(
                                "{name}: child rank {} delivered rank {}/step {} (expected \
                                 its subtree at step {step})",
                                kids[i],
                                f.rank,
                                f.step
                            );
                        }
                        if slots[r].is_some() {
                            bail!(
                                "{name}: duplicate frame for rank {r} from child rank {}",
                                kids[i]
                            );
                        }
                        self.received += raw.len() as u64;
                        arrival.push(f.rank);
                        arrival_ms.push(opened.elapsed().as_secs_f64() * 1e3);
                        if let Some(p) = &mut self.parent {
                            p.write_all(&raw)
                                .with_context(|| format!("{name}: forward rank {r} to parent"))?;
                            self.sent += raw.len() as u64;
                        }
                        if let Some(cb) = on_frame.as_deref_mut() {
                            cb(&f)?;
                        }
                        raws[r] = Some(raw);
                        slots[r] = Some(f);
                        kid_got[i] += 1;
                    }
                    Ok(None) => {}
                    Err(e) => {
                        return Err(wire_err(e)).with_context(|| {
                            format!("{name}: gather from child rank {}", kids[i])
                        })
                    }
                }
            }
            // 2. drain the parent: everything outside this endpoint's own
            //    subtree arrives here.
            if from_parent < need_from_parent {
                if let Some(p) = &mut self.parent {
                    match self.parent_reader.poll_read_raw(p) {
                        Ok(Some((f, raw))) => {
                            let r = f.rank as usize;
                            if f.step != step || r >= n || wire::tree_in_subtree(r, self.rank, n)
                            {
                                bail!(
                                    "{name}: parent delivered rank {}/step {} (expected the \
                                     complement of rank {}'s subtree at step {step})",
                                    f.rank,
                                    f.step,
                                    self.rank
                                );
                            }
                            if slots[r].is_some() {
                                bail!("{name}: duplicate frame for rank {r} from the parent");
                            }
                            self.received += raw.len() as u64;
                            arrival.push(f.rank);
                            arrival_ms.push(opened.elapsed().as_secs_f64() * 1e3);
                            if let Some(cb) = on_frame.as_deref_mut() {
                                cb(&f)?;
                            }
                            raws[r] = Some(raw);
                            slots[r] = Some(f);
                            from_parent += 1;
                        }
                        Ok(None) => {}
                        Err(e) => {
                            return Err(wire_err(e))
                                .with_context(|| format!("{name}: gather from parent"))
                        }
                    }
                }
            }
            // 3. relay down, ready-gated: only a child whose whole subtree
            //    has been delivered is guaranteed to be draining its link
            //    (the star hub's deadlock rule, applied per hop).
            let missing = slots.iter().filter(|s| s.is_none()).count();
            let t0 = Instant::now();
            let mut relayed = false;
            for i in 0..kids.len() {
                if kid_got[i] != kid_sub[i] {
                    continue;
                }
                for r in 0..n {
                    if kid_sent[i][r] || wire::tree_in_subtree(r, kids[i], n) {
                        continue;
                    }
                    let Some(bytes) = raws[r].as_ref() else { continue };
                    self.children[i].1.write_all(bytes).with_context(|| {
                        format!("{name}: relay rank {r} to child rank {}", kids[i])
                    })?;
                    self.sent += bytes.len() as u64;
                    kid_sent[i][r] = true;
                    kid_sent_cnt[i] += 1;
                    relayed = true;
                }
            }
            if relayed && missing > 0 {
                self.overlap_micros += t0.elapsed().as_micros() as u64;
            }
        }
        self.last_arrival = arrival;
        self.last_arrival_ms = arrival_ms;
        slots
            .iter_mut()
            .enumerate()
            .map(|(r, f)| {
                f.take().ok_or_else(|| {
                    anyhow!("{name}: tree gather finished with rank {r}'s frame missing")
                })
            })
            .collect()
    }
}

impl<S: GatherStream> Transport for TreeDriver<S> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn ranks(&self) -> usize {
        self.ranks
    }

    fn post_send(&mut self, mut local: Vec<Frame>) -> Result<()> {
        if self.pending.is_some() {
            bail!("{}: gather already in flight (post_send without collect)", self.name);
        }
        if local.len() != 1 {
            bail!("{} endpoints host exactly one rank, got {} frames", self.name, local.len());
        }
        let Some(mine) = local.pop() else {
            bail!("{}: post_send needs this endpoint's frame", self.name);
        };
        if mine.rank as usize != self.rank {
            bail!(
                "{}: this endpoint hosts rank {}, got a frame from rank {}",
                self.name,
                self.rank,
                mine.rank
            );
        }
        self.pending = Some(mine);
        Ok(())
    }

    fn collect(&mut self) -> Result<Vec<Frame>> {
        self.collect_cb(None)
    }

    fn collect_streaming(
        &mut self,
        on_frame: &mut dyn FnMut(&Frame) -> Result<()>,
    ) -> Result<Vec<Frame>> {
        self.collect_cb(Some(on_frame))
    }

    fn topology(&self) -> Topology {
        Topology::Tree
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }

    fn overlap_ms(&self) -> f64 {
        self.overlap_micros as f64 / 1000.0
    }

    fn last_arrival(&self) -> &[u16] {
        &self.last_arrival
    }

    fn last_arrival_ms(&self) -> &[f64] {
        &self.last_arrival_ms
    }
}

// --- topology constructors (star rendezvous → link table → wired driver) ---

fn ring_coordinator<F: LinkFabric>(
    fabric: &F,
    mut star: Vec<F::Stream>,
    ranks: usize,
    name: &'static str,
) -> Result<RingDriver<F::Stream>> {
    let (listener, my_addr) = fabric.bind()?;
    let table = gather_link_table(&mut star, my_addr, ranks, fabric.kind())?;
    let (next, prev) = wire_ring(fabric, &listener, &table, 0, ranks, name)?;
    drop(listener);
    fabric.cleanup();
    RingDriver::from_streams(name, 0, ranks, next, prev)
}

fn ring_worker<F: LinkFabric>(
    fabric: &F,
    star: &mut F::Stream,
    rank: usize,
    ranks: usize,
    name: &'static str,
) -> Result<RingDriver<F::Stream>> {
    let (listener, my_addr) = fabric.bind()?;
    let table = worker_link_table(star, &my_addr, rank, ranks, fabric.kind())?;
    let (next, prev) = wire_ring(fabric, &listener, &table, rank, ranks, name)?;
    drop(listener);
    fabric.cleanup();
    RingDriver::from_streams(name, rank, ranks, next, prev)
}

fn tree_coordinator<F: LinkFabric>(
    fabric: &F,
    mut star: Vec<F::Stream>,
    ranks: usize,
    name: &'static str,
) -> Result<TreeDriver<F::Stream>> {
    let (listener, my_addr) = fabric.bind()?;
    let table = gather_link_table(&mut star, my_addr, ranks, fabric.kind())?;
    let (parent, children) = wire_tree(fabric, &listener, &table, 0, ranks, name)?;
    drop(listener);
    fabric.cleanup();
    TreeDriver::from_streams(name, 0, ranks, parent, children)
}

fn tree_worker<F: LinkFabric>(
    fabric: &F,
    star: &mut F::Stream,
    rank: usize,
    ranks: usize,
    name: &'static str,
) -> Result<TreeDriver<F::Stream>> {
    let (listener, my_addr) = fabric.bind()?;
    let table = worker_link_table(star, &my_addr, rank, ranks, fabric.kind())?;
    let (parent, children) = wire_tree(fabric, &listener, &table, rank, ranks, name)?;
    drop(listener);
    fabric.cleanup();
    TreeDriver::from_streams(name, rank, ranks, parent, children)
}

/// UDS link listener path of `rank`, derived from the star rendezvous
/// path (`<rendezvous>.r<rank>`).
fn uds_link_path(rendezvous: &Path, rank: usize) -> PathBuf {
    PathBuf::from(format!("{}.r{rank}", rendezvous.display()))
}

/// Ring coordinator over tcp: run the star rendezvous of `pending`, then
/// re-wire the world into successor/predecessor links. The star streams
/// are dropped once the ring is up.
pub fn ring_tcp_coordinator(pending: TcpPending) -> Result<RingDriver<TcpStream>> {
    let ranks = pending.ranks;
    if ranks < 2 {
        bail!("tcp-ring: a ring needs at least 2 ranks, got {ranks}");
    }
    let ip = pending.local_addr()?.ip();
    let star = pending.accept_streams()?;
    ring_coordinator(&TcpFabric { ip }, star, ranks, "tcp-ring")
}

/// Ring worker over tcp: star rendezvous at `addr`, then ring links.
pub fn ring_tcp_worker(addr: &str, rank: usize, ranks: usize) -> Result<RingDriver<TcpStream>> {
    let (mut star, _) = TcpTransport::connect_stream(addr, rank, ranks)?;
    let ip = star.local_addr().context("tcp: link local_addr")?.ip();
    ring_worker(&TcpFabric { ip }, &mut star, rank, ranks, "tcp-ring")
}

/// Tree coordinator (root) over tcp.
pub fn tree_tcp_coordinator(pending: TcpPending) -> Result<TreeDriver<TcpStream>> {
    let ranks = pending.ranks;
    if ranks < 2 {
        bail!("tcp-tree: a tree needs at least 2 ranks, got {ranks}");
    }
    let ip = pending.local_addr()?.ip();
    let star = pending.accept_streams()?;
    tree_coordinator(&TcpFabric { ip }, star, ranks, "tcp-tree")
}

/// Tree worker over tcp: star rendezvous at `addr`, then parent/child
/// links.
pub fn tree_tcp_worker(addr: &str, rank: usize, ranks: usize) -> Result<TreeDriver<TcpStream>> {
    let (mut star, _) = TcpTransport::connect_stream(addr, rank, ranks)?;
    let ip = star.local_addr().context("tcp: link local_addr")?.ip();
    tree_worker(&TcpFabric { ip }, &mut star, rank, ranks, "tcp-tree")
}

/// Ring coordinator over uds (see [`ring_tcp_coordinator`]).
pub fn ring_uds_coordinator(pending: UdsPending) -> Result<RingDriver<UnixStream>> {
    let ranks = pending.ranks;
    if ranks < 2 {
        bail!("uds-ring: a ring needs at least 2 ranks, got {ranks}");
    }
    let (star, path) = pending.accept_streams()?;
    let fabric = UdsFabric { path: uds_link_path(&path, 0) };
    let driver = ring_coordinator(&fabric, star, ranks, "uds-ring");
    // the star rendezvous socket is not needed once the ring is wired
    let _ = std::fs::remove_file(&path);
    driver
}

/// Ring worker over uds: star rendezvous at `path`, then ring links.
pub fn ring_uds_worker<P: AsRef<Path>>(
    path: P,
    rank: usize,
    ranks: usize,
) -> Result<RingDriver<UnixStream>> {
    let path = path.as_ref();
    let (mut star, _) = UdsTransport::connect_stream(path, rank, ranks)?;
    let fabric = UdsFabric { path: uds_link_path(path, rank) };
    ring_worker(&fabric, &mut star, rank, ranks, "uds-ring")
}

/// Tree coordinator (root) over uds.
pub fn tree_uds_coordinator(pending: UdsPending) -> Result<TreeDriver<UnixStream>> {
    let ranks = pending.ranks;
    if ranks < 2 {
        bail!("uds-tree: a tree needs at least 2 ranks, got {ranks}");
    }
    let (star, path) = pending.accept_streams()?;
    let fabric = UdsFabric { path: uds_link_path(&path, 0) };
    let driver = tree_coordinator(&fabric, star, ranks, "uds-tree");
    let _ = std::fs::remove_file(&path);
    driver
}

/// Tree worker over uds: star rendezvous at `path`, then parent/child
/// links.
pub fn tree_uds_worker<P: AsRef<Path>>(
    path: P,
    rank: usize,
    ranks: usize,
) -> Result<TreeDriver<UnixStream>> {
    let path = path.as_ref();
    let (mut star, _) = UdsTransport::connect_stream(path, rank, ranks)?;
    let fabric = UdsFabric { path: uds_link_path(path, rank) };
    tree_worker(&fabric, &mut star, rank, ranks, "uds-tree")
}

// ---------------------------------------------------------------------------
// File-backed shared memory
// ---------------------------------------------------------------------------

/// A single-writer / single-reader mailbox file:
///
/// ```text
/// off len field
///   0   1 full flag: 0 = empty (writer may fill), 1 = full (reader may drain)
///   1   7 reserved (zero)
///   8   8 message length, u64 LE
///  16   . message bytes (one encoded frame, or a relay bundle)
/// ```
///
/// The writer stores the message and its length *before* flipping the
/// flag to 1; the reader drains and flips it back to 0. Each `pwrite`
/// completes into the (shared) page cache before the next begins, so a
/// reader that observes the flag set also observes the bytes it guards.
/// Synchronous training needs only one message in flight per direction,
/// so a mailbox (rather than a deeper ring) loses no parallelism.
struct Mailbox {
    file: File,
    path: PathBuf,
    /// Corruption guard for the length field: the largest message this
    /// direction can legitimately carry (one frame uplink, a full bundle
    /// downlink), so a garbage length fails before a huge allocation
    /// without rejecting valid large configurations.
    max_msg: u64,
}

/// Upper bound on one encoded frame: payload + stats sections at their
/// wire-level caps, plus framing.
fn max_frame_bytes() -> u64 {
    (2 * MAX_SECTION_BYTES + 4096) as u64
}

impl Mailbox {
    /// Create the mailbox at `path` — the coordinator does this for every
    /// direction before workers start. The 16-byte header is written to a
    /// temp file and renamed into place, so a concurrently-polling worker
    /// either sees no file or a fully-initialized one, never a
    /// half-written header. A stale mailbox from a previous run is
    /// replaced by the rename.
    fn create<P: AsRef<Path>>(path: P, max_msg: u64) -> Result<Mailbox> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = OpenOptions::new()
                .write(true)
                .create(true)
                .truncate(true)
                .open(&tmp)
                .with_context(|| format!("shm: create {}", tmp.display()))?;
            f.write_all(&[0u8; 16])?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("shm: publish {}", path.display()))?;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("shm: reopen {}", path.display()))?;
        Ok(Mailbox { file, path, max_msg })
    }

    /// Open an existing mailbox, waiting for the coordinator to create it.
    /// (Reusing a rendezvous directory from a *crashed* run with workers
    /// started before the coordinator can hand a worker the stale inode —
    /// use a fresh directory for hand-started shm runs.)
    fn open_wait<P: AsRef<Path>>(path: P, max_msg: u64) -> Result<Mailbox> {
        let path = path.as_ref().to_path_buf();
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        loop {
            match OpenOptions::new().read(true).write(true).open(&path) {
                // the rename in create() guarantees an existing file is
                // fully initialized (>= 16 header bytes)
                Ok(file) => return Ok(Mailbox { file, path, max_msg }),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(e))
                            .with_context(|| format!("shm: open {}", path.display()));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    fn flag(&self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.file.read_exact_at(&mut b, 0)?;
        Ok(b[0])
    }

    /// Busy-wait (with sleeps) until the flag equals `want`.
    fn wait_flag(&self, want: u8) -> Result<()> {
        let deadline = Instant::now() + PEER_TIMEOUT;
        let mut spins = 0u32;
        while self.flag()? != want {
            if Instant::now() >= deadline {
                bail!("shm: peer on {} went silent", self.path.display());
            }
            // Short spin first (a step is milliseconds), then back off.
            spins += 1;
            if spins > 1000 {
                std::thread::sleep(Duration::from_millis(1));
            } else {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
        Ok(())
    }

    /// Publish one message (blocks until the reader drained the previous).
    fn send(&mut self, msg: &[u8]) -> Result<()> {
        self.wait_flag(0)?;
        let need = 16 + msg.len() as u64;
        if self.file.metadata()?.len() < need {
            self.file.set_len(need)?;
        }
        self.file.write_all_at(msg, 16)?;
        self.file.write_all_at(&(msg.len() as u64).to_le_bytes(), 8)?;
        // The flag flip is last: a reader that sees it also sees the bytes.
        self.file.write_all_at(&[1u8], 0)?;
        Ok(())
    }

    /// Drain the published message, which the caller knows is there (the
    /// flag read 1).
    fn drain(&mut self) -> Result<Vec<u8>> {
        let mut len8 = [0u8; 8];
        self.file.read_exact_at(&mut len8, 8)?;
        let len = u64::from_le_bytes(len8);
        if len > self.max_msg {
            bail!(
                "shm: implausible {len} B message on {} (cap {})",
                self.path.display(),
                self.max_msg
            );
        }
        let len = len as usize;
        let mut msg = vec![0u8; len];
        self.file.read_exact_at(&mut msg, 16)?;
        self.file.write_all_at(&[0u8], 0)?;
        Ok(msg)
    }

    /// Drain one message if the writer has published one — the
    /// non-blocking poll of the coordinator's gather loop.
    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        if self.flag()? != 1 {
            return Ok(None);
        }
        self.drain().map(Some)
    }

    /// Drain one message (blocks until the writer published one).
    fn recv(&mut self) -> Result<Vec<u8>> {
        self.wait_flag(1)?;
        self.drain()
    }
}

/// Coordinator gather state between shm `post_send` and `collect`.
struct PendingShm {
    step: u64,
    frames: Vec<Option<Frame>>,
    arrival: Vec<u16>,
    /// When `post_send` opened this round (arrival-latency zero point).
    opened: Instant,
    /// Milliseconds after `opened` per arrived frame, aligned with `arrival`.
    arrival_ms: Vec<f64>,
}

enum ShmRole {
    /// Rank 0: an (uplink, downlink) mailbox pair per worker, index
    /// `rank - 1`.
    Coordinator { pairs: Vec<(Mailbox, Mailbox)>, dir: PathBuf, pending: Option<PendingShm> },
    /// A worker: its own uplink + downlink.
    Worker { up: Mailbox, down: Mailbox, pending_step: Option<u64> },
}

/// Shared-memory transport over per-worker mailbox files. Put the
/// rendezvous directory on tmpfs (e.g. under `/dev/shm`) and the exchange
/// never leaves the page cache. The downlink is a single bundle message,
/// so the relay cannot stream (no overlap is reported), but the gather
/// polls every uplink concurrently and records arrival order.
pub struct ShmTransport {
    ranks: usize,
    role: ShmRole,
    sent: u64,
    received: u64,
    last_arrival: Vec<u16>,
    last_arrival_ms: Vec<f64>,
}

fn up_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("up_{rank}.mbox"))
}

fn down_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("down_{rank}.mbox"))
}

impl ShmTransport {
    /// Rank-0 side: create the rendezvous directory and every mailbox
    /// (call *before* spawning workers so they never see a half-made dir).
    pub fn coordinator<P: AsRef<Path>>(dir: P, ranks: usize) -> Result<ShmTransport> {
        assert!(ranks > 0);
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        // uplink carries one frame; downlink carries the full bundle
        let bundle_cap = max_frame_bytes() * ranks as u64;
        let pairs = (1..ranks)
            .map(|r| {
                Ok((
                    Mailbox::create(up_path(&dir, r), max_frame_bytes())?,
                    Mailbox::create(down_path(&dir, r), bundle_cap)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ShmTransport {
            ranks,
            role: ShmRole::Coordinator { pairs, dir, pending: None },
            sent: 0,
            received: 0,
            last_arrival: Vec::new(),
            last_arrival_ms: Vec::new(),
        })
    }

    /// Worker side: open this rank's mailbox pair (waiting for the
    /// coordinator to create them).
    pub fn worker<P: AsRef<Path>>(dir: P, rank: usize, ranks: usize) -> Result<ShmTransport> {
        assert!(rank > 0 && rank < ranks, "workers are ranks 1..{ranks}, got {rank}");
        let dir = dir.as_ref();
        let up = Mailbox::open_wait(up_path(dir, rank), max_frame_bytes())?;
        let down = Mailbox::open_wait(down_path(dir, rank), max_frame_bytes() * ranks as u64)?;
        Ok(ShmTransport {
            ranks,
            role: ShmRole::Worker { up, down, pending_step: None },
            sent: 0,
            received: 0,
            last_arrival: Vec::new(),
            last_arrival_ms: Vec::new(),
        })
    }

    /// Ranks of the last completed gather in uplink-arrival order
    /// (coordinator only; empty on workers).
    pub fn last_arrival_order(&self) -> &[u16] {
        &self.last_arrival
    }
}

impl Drop for ShmTransport {
    fn drop(&mut self) {
        // Remove only what this transport created: its mailbox files, and
        // the directory iff that leaves it empty (non-recursive). The
        // rendezvous may be a user-supplied directory (/dev/shm itself,
        // say) — never delete anything we didn't make.
        if let ShmRole::Coordinator { pairs, dir, .. } = &self.role {
            for (up, down) in pairs {
                let _ = std::fs::remove_file(&up.path);
                let _ = std::fs::remove_file(&down.path);
            }
            let _ = std::fs::remove_dir(dir);
        }
    }
}

impl Transport for ShmTransport {
    fn name(&self) -> &'static str {
        "shm"
    }

    fn ranks(&self) -> usize {
        self.ranks
    }

    fn post_send(&mut self, mut local: Vec<Frame>) -> Result<()> {
        if local.len() != 1 {
            bail!("shm endpoints host exactly one rank, got {} frames", local.len());
        }
        let Some(mine) = local.pop() else {
            bail!("shm: post_send needs this endpoint's frame");
        };
        let sp = crate::trace::begin();
        let res = match &mut self.role {
            ShmRole::Coordinator { pending, .. } => {
                if pending.is_some() {
                    bail!("shm: gather already in flight (post_send without collect)");
                }
                if mine.rank != 0 {
                    bail!("shm coordinator must host rank 0, got {}", mine.rank);
                }
                let mut frames: Vec<Option<Frame>> = (0..self.ranks).map(|_| None).collect();
                let step = mine.step;
                frames[0] = Some(mine);
                *pending = Some(PendingShm {
                    step,
                    frames,
                    arrival: Vec::new(),
                    opened: Instant::now(),
                    arrival_ms: Vec::new(),
                });
                Ok(())
            }
            ShmRole::Worker { up, pending_step, .. } => {
                if pending_step.is_some() {
                    bail!("shm: gather already in flight (post_send without collect)");
                }
                let step = mine.step;
                let bytes = mine.encode();
                up.send(&bytes).context("shm: send frame")?;
                self.sent += bytes.len() as u64;
                *pending_step = Some(step);
                Ok(())
            }
        };
        sp.end("dist", "post_send", 0);
        res
    }

    fn collect(&mut self) -> Result<Vec<Frame>> {
        let sp = crate::trace::begin();
        let res = match &mut self.role {
            ShmRole::Coordinator { pairs, pending, .. } => {
                let mut p = pending
                    .take()
                    .ok_or_else(|| anyhow!("shm: collect without post_send"))?;
                // Poll every uplink concurrently: frames land in their
                // rank slot in whatever order workers publish them.
                let deadline = Instant::now() + PEER_TIMEOUT;
                let mut spins = 0u32;
                while p.frames.iter().any(|f| f.is_none()) {
                    let mut progress = false;
                    for (i, (up, _)) in pairs.iter_mut().enumerate() {
                        if p.frames[i + 1].is_some() {
                            continue;
                        }
                        let Some(msg) = up
                            .try_recv()
                            .with_context(|| format!("shm: gather rank {}", i + 1))?
                        else {
                            continue;
                        };
                        let (f, used) = Frame::decode(&msg).map_err(wire_err)?;
                        if used != msg.len() || f.rank as usize != i + 1 || f.step != p.step {
                            bail!(
                                "shm: expected one rank-{}/step-{} frame, got rank {}/step {}",
                                i + 1,
                                p.step,
                                f.rank,
                                f.step
                            );
                        }
                        self.received += used as u64;
                        p.arrival.push(f.rank);
                        p.arrival_ms.push(p.opened.elapsed().as_secs_f64() * 1e3);
                        p.frames[i + 1] = Some(f);
                        progress = true;
                    }
                    if progress {
                        continue;
                    }
                    if Instant::now() >= deadline {
                        let have: Vec<usize> =
                            (0..self.ranks).filter(|&r| p.frames[r].is_some()).collect();
                        bail!(
                            "shm: gather timed out at step {} (have frames from ranks \
                             {have:?} of 0..{})",
                            p.step,
                            self.ranks
                        );
                    }
                    spins += 1;
                    if spins > 1000 {
                        std::thread::sleep(Duration::from_millis(1));
                    } else {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                }
                let frames: Vec<Frame> = p
                    .frames
                    .into_iter()
                    .enumerate()
                    .map(|(r, f)| {
                        f.ok_or_else(|| {
                            anyhow!("shm: gather loop finished with rank {r}'s frame missing")
                        })
                    })
                    .collect::<Result<_>>()?;
                let mut bundle = Vec::new();
                for f in &frames {
                    f.encode_into(&mut bundle);
                }
                for (_, down) in pairs.iter_mut() {
                    down.send(&bundle).context("shm: relay bundle")?;
                    self.sent += bundle.len() as u64;
                }
                self.last_arrival = p.arrival;
                self.last_arrival_ms = p.arrival_ms;
                Ok(frames)
            }
            ShmRole::Worker { down, pending_step, .. } => {
                let step = pending_step
                    .take()
                    .ok_or_else(|| anyhow!("shm: collect without post_send"))?;
                let bundle = down.recv().context("shm: receive bundle")?;
                self.received += bundle.len() as u64;
                let frames = Frame::decode_bundle(&bundle, self.ranks).map_err(wire_err)?;
                for (r, f) in frames.iter().enumerate() {
                    if f.rank as usize != r || f.step != step {
                        bail!(
                            "shm: bundle out of order (expected rank {r}/step {step}, \
                             got rank {}/step {})",
                            f.rank,
                            f.step
                        );
                    }
                }
                Ok(frames)
            }
        };
        sp.end("dist", "gather", 0);
        res
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }

    fn last_arrival(&self) -> &[u16] {
        &self.last_arrival
    }

    fn last_arrival_ms(&self) -> &[f64] {
        &self.last_arrival_ms
    }
}

/// In-memory stream harness for the loom model-checking lane
/// (`rust/tests/loom/`): drives the *real* [`StreamHub`] gather/relay
/// loop over scheduler-instrumented pipes and machine-checks the relay
/// ordering invariant — the hub never writes relay bytes to a worker
/// before that worker's own uplink frame has fully landed (the
/// `PendingGather::ready` gating; relaying earlier can deadlock two
/// blocking writes against each other on real sockets).
#[cfg(loom)]
pub mod loom_model {
    use std::io::{Read, Write};

    use loom::sync::{Arc, Mutex};
    use loom::thread;

    use super::{GatherStream, StreamHub};
    use crate::dist::wire::{Frame, PayloadTag};

    /// One direction of a model pipe: appended by the writer, consumed
    /// front-to-back by the reader.
    #[derive(Default)]
    struct Dir {
        data: Vec<u8>,
        read: usize,
    }

    /// One hub<->worker connection.
    struct Conn {
        up: Mutex<Dir>,
        down: Mutex<Dir>,
        /// Exact byte length of the worker's uplink frame this round —
        /// the hub may only relay once all of it has been consumed.
        expected_uplink: usize,
    }

    /// The hub's side: non-blocking reads (WouldBlock + a scheduler
    /// yield when the uplink is drained), relay writes checked against
    /// the ordering invariant.
    struct HubSide {
        conn: Arc<Conn>,
    }

    impl Read for HubSide {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            {
                let mut up = self.conn.up.lock().unwrap_or_else(|e| e.into_inner());
                if up.read < up.data.len() {
                    let n = out.len().min(up.data.len() - up.read);
                    out[..n].copy_from_slice(&up.data[up.read..up.read + n]);
                    up.read += n;
                    return Ok(n);
                }
            }
            // Park until a worker makes progress, then report "no bytes
            // yet" exactly like a timed-out socket read.
            thread::yield_now();
            Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "model uplink empty"))
        }
    }

    impl Write for HubSide {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            {
                let up = self.conn.up.lock().unwrap_or_else(|e| e.into_inner());
                assert!(
                    up.data.len() == self.conn.expected_uplink && up.read == up.data.len(),
                    "relay-ordering violation: hub relayed to a worker whose uplink \
                     frame has not fully landed ({} of {} bytes consumed)",
                    up.read,
                    self.conn.expected_uplink
                );
            }
            let mut down = self.conn.down.lock().unwrap_or_else(|e| e.into_inner());
            down.data.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    impl GatherStream for HubSide {
        fn set_recv_timeout(&self, _t: Option<std::time::Duration>) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// A worker's side: blocking reads (cooperatively spinning on the
    /// scheduler), appending writes.
    struct WorkerSide {
        conn: Arc<Conn>,
    }

    impl Read for WorkerSide {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            loop {
                {
                    let mut down = self.conn.down.lock().unwrap_or_else(|e| e.into_inner());
                    if down.read < down.data.len() {
                        let n = out.len().min(down.data.len() - down.read);
                        out[..n].copy_from_slice(&down.data[down.read..down.read + n]);
                        down.read += n;
                        return Ok(n);
                    }
                }
                thread::yield_now();
            }
        }
    }

    impl Write for WorkerSide {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let mut up = self.conn.up.lock().unwrap_or_else(|e| e.into_inner());
            up.data.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// One model round of the pipelined gather, `ranks = 3`: two worker
    /// threads upload their frames (split mid-header, so the hub's
    /// incremental assembly is exercised), the hub gathers and relays,
    /// and each worker reads back the full rank-ascending bundle.
    /// Checked on every explored schedule: the relay-ordering invariant
    /// (in [`HubSide::write`]), rank-ascending bundles at the workers,
    /// and a complete rank-ordered gather at the hub.
    pub fn relay_ordering_model() {
        const RANKS: usize = 3;
        const STEP: u64 = 7;
        let mk = |rank: usize| Frame {
            rank: rank as u16,
            step: STEP,
            tag: PayloadTag::Dense,
            flags: 0,
            loss: 0.25,
            payload: vec![rank as u8; 3],
            stats: Vec::new(),
        };

        let conns: Vec<Arc<Conn>> = (1..RANKS)
            .map(|r| {
                Arc::new(Conn {
                    up: Mutex::new(Dir::default()),
                    down: Mutex::new(Dir::default()),
                    expected_uplink: mk(r).encoded_len(),
                })
            })
            .collect();

        let workers: Vec<_> = (1..RANKS)
            .map(|r| {
                let conn = Arc::clone(&conns[r - 1]);
                let frame = mk(r);
                thread::spawn(move || {
                    let mut s = WorkerSide { conn };
                    let bytes = frame.encode();
                    // Split mid-header: the hub must assemble partial
                    // segments without ever relaying early.
                    let cut = 10.min(bytes.len());
                    s.write_all(&bytes[..cut]).expect("model pipe write");
                    thread::yield_now();
                    s.write_all(&bytes[cut..]).expect("model pipe write");
                    for want in 0..RANKS {
                        let f = Frame::read_from(&mut s).expect("bundle frame");
                        assert_eq!(f.rank as usize, want, "bundle must be rank-ascending");
                        assert_eq!(f.step, STEP, "bundle frame from the wrong step");
                    }
                })
            })
            .collect();

        let hub_sides: Vec<HubSide> =
            conns.iter().map(|c| HubSide { conn: Arc::clone(c) }).collect();
        let mut hub = StreamHub::new(hub_sides, RANKS);
        hub.post_send(mk(0), "loom").expect("hub post_send");
        let frames = hub.collect("loom").expect("hub collect");
        assert_eq!(frames.len(), RANKS, "gather must return every rank's frame");
        for (r, f) in frames.iter().enumerate() {
            assert_eq!(f.rank as usize, r, "gather must be rank-ordered");
        }
        for w in workers {
            w.join().expect("model worker");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::wire::{PayloadTag, FRAME_OVERHEAD};

    fn frame(rank: usize, step: u64, payload: Vec<u8>) -> Frame {
        Frame {
            rank: rank as u16,
            step,
            tag: PayloadTag::TopK,
            flags: 0,
            loss: rank as f32 + step as f32,
            payload,
            stats: Vec::new(),
        }
    }

    fn unique_dir(tag: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static N: AtomicU64 = AtomicU64::new(0);
        std::env::temp_dir().join(format!(
            "microadam-test-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::SeqCst)
        ))
    }

    #[test]
    fn loopback_roundtrips_and_counts() {
        let mut t = Loopback::new(3);
        let frames: Vec<Frame> = (0..3).map(|r| frame(r, 5, vec![r as u8; 8])).collect();
        let out = t.exchange(frames.clone()).unwrap();
        assert_eq!(out, frames);
        assert_eq!(t.bytes_sent(), 3 * (FRAME_OVERHEAD as u64 + 8));
        assert_eq!(t.bytes_received(), t.bytes_sent());
        // wrong cardinality is an error, not a hang
        assert!(t.exchange(vec![frame(0, 6, vec![])]).is_err());
    }

    #[test]
    fn loopback_phases_enforce_their_order() {
        let mut t = Loopback::new(1);
        assert!(t.collect().is_err(), "collect before post_send");
        t.post_send(vec![frame(0, 1, vec![1])]).unwrap();
        assert!(t.post_send(vec![frame(0, 1, vec![1])]).is_err(), "double post_send");
        assert_eq!(t.collect().unwrap().len(), 1);
        assert!(t.collect().is_err(), "collect consumed the round");
    }

    #[test]
    fn uds_gathers_across_threads() {
        let path = unique_dir("uds").with_extension("sock");
        let ranks = 3;
        let pending = UdsPending::bind(&path, ranks).unwrap();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let path = path.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = UdsTransport::connect(&path, r, ranks).unwrap();
                let mut got = Vec::new();
                for step in 1..=4u64 {
                    let out = t.exchange(vec![frame(r, step, vec![r as u8, step as u8])]).unwrap();
                    got.push(out);
                }
                (t.bytes_sent(), got)
            }));
        }
        let mut coord = pending.accept().unwrap();
        let mut coord_views = Vec::new();
        for step in 1..=4u64 {
            coord_views.push(coord.exchange(vec![frame(0, step, vec![0, step as u8])]).unwrap());
            // every gather saw both workers arrive, in some order
            let mut order: Vec<u16> = coord.last_arrival_order().to_vec();
            order.sort_unstable();
            assert_eq!(order, vec![1, 2]);
        }
        for h in handles {
            let (sent, got) = h.join().unwrap();
            // hello + 4 gradient frames of 2 payload bytes each
            assert_eq!(sent, 5 * FRAME_OVERHEAD as u64 + 4 * 2);
            assert_eq!(got, coord_views, "every rank sees the same bundles");
        }
        for (s, view) in coord_views.iter().enumerate() {
            assert_eq!(view.len(), ranks);
            for (r, f) in view.iter().enumerate() {
                assert_eq!(f.rank as usize, r);
                assert_eq!(f.step, s as u64 + 1);
            }
        }
    }

    #[test]
    fn tcp_gathers_across_threads() {
        let ranks = 3;
        let pending = TcpPending::bind("127.0.0.1:0", ranks).unwrap();
        let addr = pending.local_addr().unwrap().to_string();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, r, ranks).unwrap();
                let mut got = Vec::new();
                for step in 1..=4u64 {
                    let out = t.exchange(vec![frame(r, step, vec![r as u8, step as u8])]).unwrap();
                    got.push(out);
                }
                (t.bytes_sent(), got)
            }));
        }
        let mut coord = pending.accept().unwrap();
        let mut coord_views = Vec::new();
        for step in 1..=4u64 {
            coord_views.push(coord.exchange(vec![frame(0, step, vec![0, step as u8])]).unwrap());
        }
        for h in handles {
            let (sent, got) = h.join().unwrap();
            assert_eq!(sent, 5 * FRAME_OVERHEAD as u64 + 4 * 2);
            assert_eq!(got, coord_views, "every rank sees the same bundles");
        }
    }

    #[test]
    fn shm_gathers_across_threads() {
        let dir = unique_dir("shm");
        let ranks = 3;
        let mut coord = ShmTransport::coordinator(&dir, ranks).unwrap();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let dir = dir.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = ShmTransport::worker(&dir, r, ranks).unwrap();
                let mut got = Vec::new();
                for step in 1..=4u64 {
                    let out = t.exchange(vec![frame(r, step, vec![r as u8; 6])]).unwrap();
                    got.push(out);
                }
                (t.bytes_sent(), got)
            }));
        }
        let mut coord_views = Vec::new();
        for step in 1..=4u64 {
            coord_views.push(coord.exchange(vec![frame(0, step, vec![0u8; 6])]).unwrap());
        }
        for h in handles {
            let (sent, got) = h.join().unwrap();
            assert_eq!(sent, 4 * (FRAME_OVERHEAD as u64 + 6));
            assert_eq!(got, coord_views);
        }
    }

    #[test]
    fn transport_names_parse_back() {
        for k in [
            TransportKind::Loopback,
            TransportKind::Uds,
            TransportKind::Tcp,
            TransportKind::Shm,
        ] {
            assert_eq!(parse_transport(transport_name(k)).unwrap(), k);
        }
        assert!(parse_transport("pigeon").is_err());
    }

    #[test]
    fn topology_names_parse_back() {
        for t in [Topology::Star, Topology::Ring, Topology::Tree] {
            assert_eq!(parse_topology(topology_name(t)).unwrap(), t);
        }
        assert!(parse_topology("mesh").is_err());
        assert_eq!(Topology::default(), Topology::Star);
    }

    #[test]
    fn topology_from_streams_validates_shape() {
        let (a, b) = UnixStream::pair().unwrap();
        assert!(RingDriver::from_streams("uds-ring", 0, 1, a, b).is_err(), "1-rank ring");
        let (a, b) = UnixStream::pair().unwrap();
        assert!(RingDriver::from_streams("uds-ring", 5, 4, a, b).is_err(), "rank out of world");
        let (a, _peer) = UnixStream::pair().unwrap();
        assert!(
            TreeDriver::from_streams("uds-tree", 0, 2, Some(a), vec![]).is_err(),
            "root with a parent link"
        );
        assert!(
            TreeDriver::<UnixStream>::from_streams("uds-tree", 0, 2, None, vec![]).is_err(),
            "root missing its child"
        );
        let (a, _peer) = UnixStream::pair().unwrap();
        assert!(TreeDriver::from_streams("uds-tree", 0, 2, None, vec![(1, a)]).is_ok());
    }

    #[test]
    fn tcp_ring_allgathers_across_threads() {
        let ranks = 3;
        let pending = TcpPending::bind("127.0.0.1:0", ranks).unwrap();
        let addr = pending.local_addr().unwrap().to_string();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = ring_tcp_worker(&addr, r, ranks).unwrap();
                let mut got = Vec::new();
                for step in 1..=3u64 {
                    got.push(
                        t.exchange(vec![frame(r, step, vec![r as u8, step as u8])]).unwrap(),
                    );
                }
                got
            }));
        }
        let mut coord = ring_tcp_coordinator(pending).unwrap();
        assert_eq!(coord.topology(), Topology::Ring);
        let mut views = Vec::new();
        for step in 1..=3u64 {
            views.push(coord.exchange(vec![frame(0, step, vec![0, step as u8])]).unwrap());
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), views, "every rank sees the same gathered set");
        }
        for (s, view) in views.iter().enumerate() {
            assert_eq!(view.len(), ranks);
            for (r, f) in view.iter().enumerate() {
                assert_eq!((f.rank as usize, f.step), (r, s as u64 + 1));
            }
        }
    }

    #[test]
    fn tcp_ring_reduces_in_network() {
        use crate::dist::wire::{dense_payload, hop_from_payload, FLAG_HOP};

        let ranks = 3;
        fn fold(payload: &[u8], acc: &mut Vec<f32>) -> Result<()> {
            if acc.is_empty() {
                acc.resize(payload.len() / 4, 0.0);
            }
            for (a, b) in acc.iter_mut().zip(payload.chunks_exact(4)) {
                *a += f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            Ok(())
        }
        let grad = |r: usize| vec![(r + 1) as f32, 10.0 * (r + 1) as f32];
        let pending = TcpPending::bind("127.0.0.1:0", ranks).unwrap();
        let addr = pending.local_addr().unwrap().to_string();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = ring_tcp_worker(&addr, r, ranks).unwrap();
                t.post_send(vec![frame(r, 1, dense_payload(&grad(r)))]).unwrap();
                t.collect_reduced(&mut fold).unwrap()
            }));
        }
        let mut coord = ring_tcp_coordinator(pending).unwrap();
        coord.post_send(vec![frame(0, 1, dense_payload(&grad(0)))]).unwrap();
        let out = coord.collect_reduced(&mut fold).unwrap();
        for h in handles {
            assert_eq!(h.join().unwrap(), out, "every rank holds the identical result frame");
        }
        assert_eq!(out.len(), 1, "in-network reduction yields a single frame");
        assert_ne!(out[0].flags & FLAG_HOP, 0);
        let (fan_in, sum) = hop_from_payload(&out[0].payload).unwrap();
        assert_eq!(fan_in as usize, ranks);
        assert_eq!(sum, vec![1.0 + 2.0 + 3.0, 10.0 + 20.0 + 30.0]);
        // losses fold rank-ascending too (frame() sets loss = rank + step)
        assert_eq!(out[0].loss, (0.0 + 1.0) + (1.0 + 1.0) + (2.0 + 1.0));
    }

    #[test]
    fn uds_tree_gathers_across_threads() {
        let path = unique_dir("tree").with_extension("sock");
        let ranks = 4;
        let pending = UdsPending::bind(&path, ranks).unwrap();
        let mut handles = Vec::new();
        for r in 1..ranks {
            let path = path.clone();
            handles.push(std::thread::spawn(move || {
                let mut t = tree_uds_worker(&path, r, ranks).unwrap();
                let mut got = Vec::new();
                for step in 1..=3u64 {
                    got.push(
                        t.exchange(vec![frame(r, step, vec![r as u8, step as u8])]).unwrap(),
                    );
                }
                got
            }));
        }
        let mut coord = tree_uds_coordinator(pending).unwrap();
        assert_eq!(coord.topology(), Topology::Tree);
        let mut views = Vec::new();
        for step in 1..=3u64 {
            views.push(coord.exchange(vec![frame(0, step, vec![0, step as u8])]).unwrap());
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), views, "every rank sees the same gathered set");
        }
        for (s, view) in views.iter().enumerate() {
            assert_eq!(view.len(), ranks);
            for (r, f) in view.iter().enumerate() {
                assert_eq!((f.rank as usize, f.step), (r, s as u64 + 1));
            }
        }
    }
}
