//! The `dist::` wire format: one versioned, CRC-guarded, little-endian
//! frame per rank per step.
//!
//! This module implements the normative spec in `rust/src/dist/README.md`
//! — the document is the contract, this file is the implementation, and
//! `rust/tests/test_wire.rs` pins the two together (worked byte counts,
//! corrupt-frame rejection, encode/decode round trips). Every byte that a
//! [`crate::dist::transport::Transport`] moves between ranks goes through
//! [`Frame::encode`] / [`Frame::decode`]; nothing else is ever on the wire.
//!
//! Frame layout (all integers little-endian, offsets in bytes):
//!
//! ```text
//! off len field          contents
//!   0   4 magic          "uADM" (0x75 0x41 0x44 0x4D)
//!   4   2 version        u16, currently 1; receivers reject any other
//!   6   2 rank           u16 sender rank
//!   8   8 step           u64 training step the payload belongs to
//!  16   1 tag            payload kind: 0 dense / 1 topk / 2 eftopk
//!  17   1 flags          bit 0 = handshake, bit 1 = topology hop; rest 0
//!  18   4 loss           f32 bits, sender's local batch loss
//!  22   4 payload_len    u32 byte length of the payload section
//!  26   4 stats_count    u32 count of Quant4 bucket-stats records
//!  30   . payload        reducer payload (see below)
//!   .   . stats          stats_count x (lo f32, hi f32) = 8 B each
//!   .   4 crc32          IEEE CRC-32 over every preceding byte
//! ```
//!
//! The payload is exactly the slab the sending reducer holds resident
//! (see [`crate::dist::reducer`]): a dense frame carries `d` f32 values
//! (`4 d` bytes); a sparse frame carries `NB * k_b` u16 block-relative
//! indices followed by `NB * k_b` bf16 value bit patterns (`4 NB k_b`
//! bytes). `payload_len` therefore always equals the reducer's
//! `wire_bytes_per_rank()`, and a full frame is that plus the fixed
//! [`FRAME_OVERHEAD`] — an equality the transports assert every step.
//!
//! The stats section carries [`BucketStats`] records for payloads that are
//! themselves Quant4-compressed. The v1 reducers keep their Quant4 error
//! residuals rank-local (only the Top-K slab travels), so they emit
//! `stats_count = 0`; the section is specified, encoded, decoded and
//! round-trip-tested so a quantized-payload reducer needs no format bump.

use std::fmt;
use std::io::Read;

use crate::quant::BucketStats;

/// Frame magic: `"uADM"`.
pub const MAGIC: [u8; 4] = *b"uADM";
/// Current (and only) wire-format version. Receivers reject frames whose
/// version field differs — there is no cross-version negotiation in v1.
pub const VERSION: u16 = 1;
/// Fixed header bytes before the payload section.
pub const HEADER_BYTES: usize = 30;
/// Trailing CRC-32 bytes.
pub const CRC_BYTES: usize = 4;
/// Total framing overhead of a stats-free frame: header + CRC. A gradient
/// frame occupies exactly `FRAME_OVERHEAD + wire_bytes_per_rank()` bytes.
pub const FRAME_OVERHEAD: usize = HEADER_BYTES + CRC_BYTES;
/// Hard ceiling on `payload_len` (and on the stats section): a corrupt
/// length field must not turn into a multi-gigabyte allocation.
pub const MAX_SECTION_BYTES: usize = 1 << 28;

/// `flags` bit 0: handshake frame (`step = 0`). Two payloads exist: the
/// transport-level rendezvous hello (empty payload, rank identification)
/// and the session's config-digest round ([`HELLO_DIGEST_BYTES`] payload,
/// see `rust/src/dist/README.md` §6).
pub const FLAG_HELLO: u8 = 1;

/// Payload length of a config-digest handshake frame: one little-endian
/// [`fnv1a64`] of the canonical run-config JSON.
pub const HELLO_DIGEST_BYTES: usize = 8;

/// `flags` bit 1: in-network partial-aggregate (hop) frame — the ring
/// topology's circulating partial sums and its final result frame. The
/// payload starts with a [`HOP_PREFIX_BYTES`] fan-in prefix followed by
/// the raw f32 bit patterns of the running per-coordinate sum (see
/// `rust/src/dist/README.md` §10). Frames without this bit carry plain
/// reducer payloads; receivers that see it on a non-topology link reject
/// the frame.
pub const FLAG_HOP: u8 = 2;

/// Byte length of the hop-payload prefix: `fan-in u16 | reserved u16`.
/// The fan-in counts how many ranks' contributions the partial already
/// folds in (1 after the originating rank, `ranks` on the result frame),
/// so a receiver can detect a skipped or replayed hop before touching the
/// partial itself.
pub const HOP_PREFIX_BYTES: usize = 4;

/// Encode a hop payload: the fan-in prefix (`fan_in` little-endian plus
/// two reserved zero bytes) followed by the partial sum's raw f32 bit
/// patterns — bit-preserving, exactly like [`dense_payload`].
pub fn hop_payload(fan_in: u16, partial: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HOP_PREFIX_BYTES + 4 * partial.len());
    out.extend_from_slice(&fan_in.to_le_bytes());
    out.extend_from_slice(&[0u8, 0u8]);
    for &v in partial {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

/// Decode a hop payload produced by [`hop_payload`]: the fan-in count and
/// the bit-preserved f32 partial. A payload shorter than the prefix, or
/// whose value section is not a whole number of f32s, is a typed
/// [`WireError::Truncated`] — never a panic (this is a `dist::` decode
/// path under the no-panic rule).
pub fn hop_from_payload(payload: &[u8]) -> Result<(u16, Vec<f32>), WireError> {
    if payload.len() < HOP_PREFIX_BYTES {
        return Err(WireError::Truncated { need: HOP_PREFIX_BYTES, have: payload.len() });
    }
    let fan_in = le_u16(payload, 0);
    let body = &payload[HOP_PREFIX_BYTES..];
    if body.len() % 4 != 0 {
        return Err(WireError::Truncated {
            need: HOP_PREFIX_BYTES + (body.len() / 4 + 1) * 4,
            have: payload.len(),
        });
    }
    let mut out = vec![0f32; body.len() / 4];
    dense_from_payload(body, &mut out)?;
    Ok((fan_in, out))
}

// ---------------------------------------------------------------------------
// Tree fan-in accounting (binary reduction tree, heap-indexed)
// ---------------------------------------------------------------------------

/// Parent of `rank` in the binary reduction tree. Rank 0 is the root and
/// is returned as its own parent.
pub fn tree_parent(rank: usize) -> usize {
    if rank == 0 {
        0
    } else {
        (rank - 1) / 2
    }
}

/// Children of `rank` in a `ranks`-wide binary reduction tree (0, 1 or 2
/// entries, ascending).
pub fn tree_children(rank: usize, ranks: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(2);
    for c in [2 * rank + 1, 2 * rank + 2] {
        if c < ranks {
            out.push(c);
        }
    }
    out
}

/// Number of ranks in the subtree rooted at `rank`, itself included —
/// the fan-in a tree gather expects over the link from that subtree.
pub fn tree_subtree_size(rank: usize, ranks: usize) -> usize {
    if rank >= ranks {
        return 0;
    }
    let mut n = 1;
    for c in tree_children(rank, ranks) {
        n += tree_subtree_size(c, ranks);
    }
    n
}

/// Whether `rank` lies in the subtree rooted at `root` (a rank is in its
/// own subtree). Drives the tree relay rule: a parent forwards a frame
/// down a child link only when the frame's rank is *outside* that child's
/// subtree.
pub fn tree_in_subtree(rank: usize, root: usize, ranks: usize) -> bool {
    if rank >= ranks || root >= ranks {
        return false;
    }
    let mut r = rank;
    while r > root {
        r = (r - 1) / 2;
    }
    r == root
}

/// FNV-1a 64-bit hash (offset basis 0xcbf29ce484222325, prime
/// 0x100000001b3) — the config-digest function of the handshake round.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// What a frame's payload section holds — mirrors
/// [`crate::dist::reducer::ReducerKind`] so a receiver can type-check the
/// exchange before touching the payload bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadTag {
    /// `d` f32 values (the uncompressed gradient).
    Dense = 0,
    /// `(u16 idx, bf16 val)` slab, no error feedback at the sender.
    TopK = 1,
    /// `(u16 idx, bf16 val)` slab with rank-local Quant4 error feedback.
    EfTopK = 2,
}

impl PayloadTag {
    /// Decode a tag byte.
    pub fn from_byte(b: u8) -> Result<Self, WireError> {
        Ok(match b {
            0 => PayloadTag::Dense,
            1 => PayloadTag::TopK,
            2 => PayloadTag::EfTopK,
            other => return Err(WireError::BadTag(other)),
        })
    }
}

/// One decoded wire frame (see the module docs for the byte layout).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Sender rank.
    pub rank: u16,
    /// Training step the payload belongs to (0 for handshakes).
    pub step: u64,
    /// Payload kind.
    pub tag: PayloadTag,
    /// Frame flags ([`FLAG_HELLO`]).
    pub flags: u8,
    /// Sender's local batch loss for this step.
    pub loss: f32,
    /// Reducer payload bytes (exactly `wire_bytes_per_rank()` long for
    /// gradient frames).
    pub payload: Vec<u8>,
    /// Quant4 bucket stats (empty for the v1 reducers).
    pub stats: Vec<BucketStats>,
}

/// Typed decode/transport errors — each corrupt-frame class is its own
/// variant so tests (and operators) can tell *how* a frame was bad.
#[derive(Debug)]
pub enum WireError {
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version field differed from [`VERSION`].
    BadVersion(u16),
    /// Unknown payload tag byte.
    BadTag(u8),
    /// Fewer bytes available than the header (or its length fields) claim.
    Truncated { need: usize, have: usize },
    /// A length field exceeded [`MAX_SECTION_BYTES`].
    TooLarge(usize),
    /// CRC-32 mismatch: the frame was damaged in flight.
    BadCrc { expect: u32, got: u32 },
    /// Underlying I/O failure while reading from a stream.
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?} (want {MAGIC:02x?})"),
            WireError::BadVersion(v) => write!(f, "unsupported wire version {v} (speak {VERSION})"),
            WireError::BadTag(t) => write!(f, "unknown payload tag {t}"),
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::TooLarge(n) => {
                write!(f, "frame section of {n} bytes exceeds the {MAX_SECTION_BYTES} B cap")
            }
            WireError::BadCrc { expect, got } => {
                write!(f, "crc mismatch: frame says {expect:#010x}, bytes hash to {got:#010x}")
            }
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// IEEE CRC-32 (reflected, polynomial 0xEDB88320), table built at compile
// time — no dependency, identical to zlib's crc32.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

// Little-endian field readers for the decode paths. Callers verify the
// buffer length before slicing (decode and frame_len both gate on
// HEADER_BYTES / the computed total first), and building the byte arrays
// by index keeps the hot decode path free of `try_into().expect(..)` —
// the no-panic rule for this module is machine-enforced by repolint.
fn le_u16(b: &[u8], o: usize) -> u16 {
    u16::from_le_bytes([b[o], b[o + 1]])
}

fn le_u32(b: &[u8], o: usize) -> u32 {
    u32::from_le_bytes([b[o], b[o + 1], b[o + 2], b[o + 3]])
}

fn le_u64(b: &[u8], o: usize) -> u64 {
    u64::from_le_bytes([
        b[o],
        b[o + 1],
        b[o + 2],
        b[o + 3],
        b[o + 4],
        b[o + 5],
        b[o + 6],
        b[o + 7],
    ])
}

/// IEEE CRC-32 of `bytes` (zlib-compatible).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

impl Frame {
    /// A handshake frame for `rank` (empty payload, step 0).
    pub fn hello(rank: usize) -> Frame {
        Frame {
            rank: rank as u16,
            step: 0,
            tag: PayloadTag::Dense,
            flags: FLAG_HELLO,
            loss: 0.0,
            payload: Vec::new(),
            stats: Vec::new(),
        }
    }

    /// Encoded byte length of this frame.
    pub fn encoded_len(&self) -> usize {
        FRAME_OVERHEAD + self.payload.len() + 8 * self.stats.len()
    }

    /// Append the encoded frame to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.reserve(self.encoded_len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.rank.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.push(self.tag as u8);
        out.push(self.flags);
        out.extend_from_slice(&self.loss.to_bits().to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.stats.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        for s in &self.stats {
            out.extend_from_slice(&s.lo.to_bits().to_le_bytes());
            out.extend_from_slice(&s.hi.to_bits().to_le_bytes());
        }
        let crc = crc32(&out[start..]);
        out.extend_from_slice(&crc.to_le_bytes());
    }

    /// Encode into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    /// Decode one frame from the front of `buf`; returns the frame and the
    /// number of bytes it occupied (so bundles of concatenated frames
    /// decode by advancing the slice).
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), WireError> {
        if buf.len() < HEADER_BYTES {
            return Err(WireError::Truncated { need: HEADER_BYTES, have: buf.len() });
        }
        if buf[0..4] != MAGIC {
            return Err(WireError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
        }
        let version = le_u16(buf, 4);
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let rank = le_u16(buf, 6);
        let step = le_u64(buf, 8);
        let tag = PayloadTag::from_byte(buf[16])?;
        let flags = buf[17];
        let loss = f32::from_bits(le_u32(buf, 18));
        let payload_len = le_u32(buf, 22) as usize;
        let stats_count = le_u32(buf, 26) as usize;
        if payload_len > MAX_SECTION_BYTES {
            return Err(WireError::TooLarge(payload_len));
        }
        if stats_count * 8 > MAX_SECTION_BYTES {
            return Err(WireError::TooLarge(stats_count * 8));
        }
        let total = HEADER_BYTES + payload_len + 8 * stats_count + CRC_BYTES;
        if buf.len() < total {
            return Err(WireError::Truncated { need: total, have: buf.len() });
        }
        let expect = le_u32(buf, total - 4);
        let got = crc32(&buf[..total - 4]);
        if expect != got {
            return Err(WireError::BadCrc { expect, got });
        }
        let payload = buf[HEADER_BYTES..HEADER_BYTES + payload_len].to_vec();
        let mut stats = Vec::with_capacity(stats_count);
        let mut o = HEADER_BYTES + payload_len;
        for _ in 0..stats_count {
            let lo = f32::from_bits(le_u32(buf, o));
            let hi = f32::from_bits(le_u32(buf, o + 4));
            stats.push(BucketStats { lo, hi });
            o += 8;
        }
        Ok((Frame { rank, step, tag, flags, loss, payload, stats }, total))
    }

    /// Decode `n` concatenated frames (a coordinator relay bundle).
    pub fn decode_bundle(mut buf: &[u8], n: usize) -> Result<Vec<Frame>, WireError> {
        let mut frames = Vec::with_capacity(n);
        for _ in 0..n {
            let (f, used) = Frame::decode(buf)?;
            buf = &buf[used..];
            frames.push(f);
        }
        if !buf.is_empty() {
            return Err(WireError::Truncated { need: 0, have: buf.len() });
        }
        Ok(frames)
    }

    /// Read one frame from a byte stream (blocking until it is complete),
    /// validating magic/version/lengths/CRC exactly like [`Frame::decode`].
    pub fn read_from<R: Read>(r: &mut R) -> Result<Frame, WireError> {
        let mut buf = vec![0u8; HEADER_BYTES];
        r.read_exact(&mut buf)?;
        let total = frame_len(&buf)?;
        buf.resize(total, 0);
        r.read_exact(&mut buf[HEADER_BYTES..])?;
        let (frame, used) = Frame::decode(&buf)?;
        debug_assert_eq!(used, total);
        Ok(frame)
    }
}

/// Total encoded length of the frame whose first [`HEADER_BYTES`] bytes are
/// `header`, after validating everything a header alone can prove: magic,
/// exact version match, and the section-length caps. This is the fail-fast
/// gate of the streaming readers — a stale-version or garbage peer is
/// rejected as soon as its header is in, before any payload byte arrives.
pub fn frame_len(header: &[u8]) -> Result<usize, WireError> {
    assert!(header.len() >= HEADER_BYTES, "frame_len needs a full header");
    if header[0..4] != MAGIC {
        return Err(WireError::BadMagic([header[0], header[1], header[2], header[3]]));
    }
    let version = le_u16(header, 4);
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let payload_len = le_u32(header, 22) as usize;
    let stats_count = le_u32(header, 26) as usize;
    if payload_len > MAX_SECTION_BYTES {
        return Err(WireError::TooLarge(payload_len));
    }
    if stats_count * 8 > MAX_SECTION_BYTES {
        return Err(WireError::TooLarge(stats_count * 8));
    }
    Ok(HEADER_BYTES + payload_len + 8 * stats_count + CRC_BYTES)
}

/// Incremental frame assembler for non-blocking / timeout-polled streams.
///
/// A TCP (or Unix) socket delivers a frame in arbitrary segments; a
/// pipelined gather cannot afford to block on any one peer while others
/// have bytes ready. `FrameReader` buffers whatever a stream has available
/// and yields a frame the moment its last byte is in:
///
/// * the header is validated ([`frame_len`]) as soon as its 30 bytes have
///   arrived — a bad-magic or stale-version peer fails *before* its
///   payload is read;
/// * `WouldBlock` / read-timeout just means "no frame yet" (`Ok(None)`);
/// * EOF mid-frame (a peer that disconnected) is a typed
///   [`WireError::Truncated`], never a hang or a partial frame;
/// * bytes past a frame boundary are kept for the next frame, so a peer
///   that runs ahead loses nothing.
///
/// ```
/// use microadam::dist::wire::{Frame, FrameReader, PayloadTag, WireError};
/// use std::io::Cursor;
///
/// let f = Frame { rank: 1, step: 3, tag: PayloadTag::Dense, flags: 0,
///                 loss: 0.5, payload: vec![9, 9], stats: vec![] };
/// let bytes = f.encode();
/// // a peer that runs ahead: two frames land in one read
/// let mut both = bytes.clone();
/// both.extend_from_slice(&bytes);
/// let mut reader = FrameReader::new();
/// let mut src = Cursor::new(both);
/// assert_eq!(reader.poll_read(&mut src).unwrap().unwrap(), f);
/// // the second frame is served from the buffered remainder
/// assert_eq!(reader.poll_read(&mut src).unwrap().unwrap(), f);
/// // a peer that disconnects mid-frame is a typed error, never a hang
/// let mut reader = FrameReader::new();
/// let mut cut = Cursor::new(bytes[..bytes.len() - 3].to_vec());
/// assert!(matches!(reader.poll_read(&mut cut), Err(WireError::Truncated { .. })));
/// ```
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Total frame length once the header has been parsed and validated.
    need: Option<usize>,
}

impl FrameReader {
    /// Fresh reader with no buffered bytes.
    pub fn new() -> Self {
        Self { buf: Vec::new(), need: None }
    }

    /// Bytes buffered toward the next frame (0 = sitting between frames).
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Pull whatever `r` has available and return a frame if one is now
    /// complete. `Ok(None)` means "not yet" (the stream would block);
    /// every corruption, cap violation and mid-frame disconnect is a typed
    /// [`WireError`].
    pub fn poll_read<R: Read>(&mut self, r: &mut R) -> Result<Option<Frame>, WireError> {
        Ok(self.poll_read_raw(r)?.map(|(frame, _)| frame))
    }

    /// Like [`FrameReader::poll_read`], but also hands back the frame's
    /// exact wire bytes (already CRC-verified). A relay that forwards the
    /// frame can reuse them verbatim instead of re-encoding — no second
    /// O(payload) pass, and byte preservation holds by construction.
    pub fn poll_read_raw<R: Read>(
        &mut self,
        r: &mut R,
    ) -> Result<Option<(Frame, Vec<u8>)>, WireError> {
        let mut chunk = [0u8; 16384];
        loop {
            if self.need.is_none() && self.buf.len() >= HEADER_BYTES {
                self.need = Some(frame_len(&self.buf)?);
            }
            if let Some(need) = self.need {
                if self.buf.len() >= need {
                    let raw: Vec<u8> = self.buf.drain(..need).collect();
                    let (frame, used) = Frame::decode(&raw)?;
                    debug_assert_eq!(used, need);
                    self.need = None;
                    return Ok(Some((frame, raw)));
                }
            }
            match r.read(&mut chunk) {
                // EOF with a frame outstanding: the peer disconnected
                // mid-frame (or before sending one we are waiting for)
                Ok(0) => {
                    return Err(WireError::Truncated {
                        need: self.need.unwrap_or(HEADER_BYTES),
                        have: self.buf.len(),
                    })
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(None)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(WireError::Io(e)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Payload codecs: exactly the resident reducer slabs, little-endian
// ---------------------------------------------------------------------------

/// Serialize a sparse `(u16 idx, bf16 val)` slab: all indices, then all
/// value bit patterns, little-endian (`4 B` per entry — the same cost the
/// slab has resident in RAM).
pub fn slab_payload(idx: &[u16], val: &[u16]) -> Vec<u8> {
    assert_eq!(idx.len(), val.len(), "slab idx/val must pair up");
    let mut out = Vec::with_capacity(4 * idx.len());
    for &i in idx {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for &v in val {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a sparse slab payload produced by [`slab_payload`] into `idx`
/// and `val` (both of the expected entry count).
pub fn slab_from_payload(
    payload: &[u8],
    idx: &mut [u16],
    val: &mut [u16],
) -> Result<(), WireError> {
    assert_eq!(idx.len(), val.len(), "slab idx/val must pair up");
    let want = 4 * idx.len();
    if payload.len() != want {
        return Err(WireError::Truncated { need: want, have: payload.len() });
    }
    let half = 2 * idx.len();
    for (o, d) in idx.iter_mut().enumerate() {
        *d = u16::from_le_bytes([payload[2 * o], payload[2 * o + 1]]);
    }
    for (o, d) in val.iter_mut().enumerate() {
        *d = u16::from_le_bytes([payload[half + 2 * o], payload[half + 2 * o + 1]]);
    }
    Ok(())
}

/// Serialize a dense f32 gradient (`4 B`/value, bit-preserving).
pub fn dense_payload(g: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * g.len());
    for &v in g {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

/// Decode a dense payload into `out` (bit-preserving inverse of
/// [`dense_payload`]; `out.len()` must match the encoded count).
pub fn dense_from_payload(payload: &[u8], out: &mut [f32]) -> Result<(), WireError> {
    let want = 4 * out.len();
    if payload.len() != want {
        return Err(WireError::Truncated { need: want, have: payload.len() });
    }
    for (o, d) in out.iter_mut().enumerate() {
        let b = [payload[4 * o], payload[4 * o + 1], payload[4 * o + 2], payload[4 * o + 3]];
        *d = f32::from_bits(u32::from_le_bytes(b));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Frame {
        Frame {
            rank: 3,
            step: 41,
            tag: PayloadTag::EfTopK,
            flags: 0,
            loss: 1.25,
            payload: vec![7, 8, 9, 10],
            stats: vec![BucketStats { lo: -0.5, hi: 2.0 }],
        }
    }

    #[test]
    fn overhead_constant_matches_empty_frame() {
        let f = Frame { payload: Vec::new(), stats: Vec::new(), ..sample() };
        assert_eq!(f.encode().len(), FRAME_OVERHEAD);
        assert_eq!(f.encoded_len(), FRAME_OVERHEAD);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let f = sample();
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        let (back, used) = Frame::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, f);
    }

    #[test]
    fn crc32_matches_known_vector() {
        // zlib's canonical check value: crc32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn read_from_consumes_exactly_one_frame() {
        let a = sample();
        let b = Frame { rank: 4, step: 42, ..sample() };
        let mut bytes = a.encode();
        bytes.extend_from_slice(&b.encode());
        let mut cur = std::io::Cursor::new(bytes);
        assert_eq!(Frame::read_from(&mut cur).unwrap(), a);
        assert_eq!(Frame::read_from(&mut cur).unwrap(), b);
    }

    #[test]
    fn bundle_decodes_in_order() {
        let frames: Vec<Frame> =
            (0..4).map(|r| Frame { rank: r, step: 9, ..sample() }).collect();
        let mut bytes = Vec::new();
        for f in &frames {
            f.encode_into(&mut bytes);
        }
        let back = Frame::decode_bundle(&bytes, 4).unwrap();
        assert_eq!(back, frames);
        // trailing garbage is rejected, not ignored
        bytes.push(0);
        assert!(Frame::decode_bundle(&bytes, 4).is_err());
    }

    #[test]
    fn slab_and_dense_payloads_roundtrip() {
        let idx: Vec<u16> = (0..13).map(|i| i * 7).collect();
        let val: Vec<u16> = (0..13).map(|i| 0x3f80 ^ i).collect();
        let p = slab_payload(&idx, &val);
        assert_eq!(p.len(), 4 * 13);
        let mut i2 = vec![0u16; 13];
        let mut v2 = vec![0u16; 13];
        slab_from_payload(&p, &mut i2, &mut v2).unwrap();
        assert_eq!(i2, idx);
        assert_eq!(v2, val);

        let g: Vec<f32> = (0..9).map(|i| (i as f32 - 4.0) * 0.37).collect();
        let p = dense_payload(&g);
        let mut g2 = vec![0f32; 9];
        dense_from_payload(&p, &mut g2).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn hello_frames_are_flagged_and_empty() {
        let h = Frame::hello(5);
        assert_eq!(h.flags & FLAG_HELLO, FLAG_HELLO);
        assert_eq!(h.step, 0);
        assert!(h.payload.is_empty());
        let (back, _) = Frame::decode(&h.encode()).unwrap();
        assert_eq!(back, h);
    }

    #[test]
    fn hop_payload_roundtrips_bit_exactly() {
        // NaN payloads and -0.0 must survive: the hop partial is a raw
        // bit-pattern transfer, not a numeric re-encode.
        let partial = [1.5f32, -0.0, f32::from_bits(0x7fc0_dead), f32::MIN_POSITIVE, -3.25e7];
        let p = hop_payload(3, &partial);
        assert_eq!(p.len(), HOP_PREFIX_BYTES + 4 * partial.len());
        assert_eq!(&p[2..4], &[0u8, 0u8], "reserved prefix bytes must be zero");
        let (fan_in, back) = hop_from_payload(&p).unwrap();
        assert_eq!(fan_in, 3);
        assert_eq!(back.len(), partial.len());
        for (a, b) in partial.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // empty partial is legal (a zero-d model is degenerate but decodable)
        let (fan_in, back) = hop_from_payload(&hop_payload(1, &[])).unwrap();
        assert_eq!((fan_in, back.len()), (1, 0));
    }

    #[test]
    fn malformed_hop_payloads_are_typed_errors() {
        // shorter than the fan-in prefix
        for cut in 0..HOP_PREFIX_BYTES {
            assert!(matches!(
                hop_from_payload(&vec![0u8; cut]),
                Err(WireError::Truncated { .. })
            ));
        }
        // value section not a whole number of f32s
        let mut p = hop_payload(2, &[1.0, 2.0]);
        p.pop();
        assert!(matches!(hop_from_payload(&p), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn tree_helpers_are_consistent() {
        for ranks in 1..=9usize {
            // parent/child inverse, and every rank is in exactly one
            // child subtree of its parent
            for r in 0..ranks {
                for c in tree_children(r, ranks) {
                    assert_eq!(tree_parent(c), r);
                    assert!(tree_in_subtree(c, r, ranks));
                }
                assert!(tree_in_subtree(r, r, ranks));
                assert!(tree_in_subtree(r, 0, ranks), "root subtree spans all ranks");
            }
            // subtree sizes partition: root's subtree is everything, and
            // each node is 1 + sum of child subtrees
            assert_eq!(tree_subtree_size(0, ranks), ranks);
            for r in 0..ranks {
                let kids: usize =
                    tree_children(r, ranks).iter().map(|&c| tree_subtree_size(c, ranks)).sum();
                assert_eq!(tree_subtree_size(r, ranks), 1 + kids);
            }
        }
        assert_eq!(tree_parent(0), 0);
        assert!(!tree_in_subtree(5, 1, 4), "out-of-range rank is in no subtree");
        assert_eq!(tree_subtree_size(7, 4), 0);
        // the 4-rank tree used throughout the tests: 0 -> {1, 2}, 1 -> {3}
        assert_eq!(tree_children(0, 4), vec![1, 2]);
        assert_eq!(tree_children(1, 4), vec![3]);
        assert!(tree_children(2, 4).is_empty());
        assert!(tree_in_subtree(3, 1, 4));
        assert!(!tree_in_subtree(3, 2, 4));
    }
}
