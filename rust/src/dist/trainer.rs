//! The data-parallel training driver: N replicas -> gradient exchange ->
//! one shared optimizer step.
//!
//! Per step:
//! 1. every replica draws a batch from **its own** seeded shard and
//!    computes a local gradient on the shared parameters (native MLP
//!    replicas fan out across the [`ExecPool`]; artifact replicas run
//!    sequentially through the one PJRT client);
//! 2. the [`GradReducer`] aggregates the per-rank gradients into the mean
//!    (exactly for [`ReducerKind::Dense`], compressed for
//!    `TopK`/`EfTopK`), accumulating bytes-on-the-wire accounting;
//! 3. the aggregated gradient feeds the ordinary
//!    [`Optimizer::step_multi`] hot path with the layout's real
//!    per-tensor chunk boundaries — the same code path as the
//!    single-process [`crate::coordinator::trainer::Trainer`].
//!
//! Guarantee (pinned in `rust/tests/test_dist_parity.rs`): `ranks = 1`
//! with `DenseAllReduce` is **bit-identical** to single-process training
//! for every optimizer kind — the reducer is an exact identity and the
//! chunked step is bit-equal to the flat step.
//!
//! The trainer wraps the coordinator stack: [`TrainConfig`] (with its
//! `ranks`/`reduce` fields) configures it, [`MetricsLogger`] records it,
//! and [`Checkpoint`] persists it.

use anyhow::{bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::layout::TensorSpec;
use crate::coordinator::metrics::MetricsLogger;
use crate::exec::ExecPool;
use crate::models::mlp::Mlp;
use crate::optim::{self, Optimizer};
use crate::runtime::{self, lit_f32, Runtime};
use crate::util::json;

use super::reducer::{build_reducer, reducer_name, GradReducer, SparseReduceConfig};
use super::replica::{native_model_spec, ArtifactReplica, NativeModelSpec, NativeReplica};

/// Which gradient backend drives the replicas.
enum Engine {
    /// Pure-rust MLP: runs everywhere, replicas step in parallel.
    Native { mlp: Mlp, spec: NativeModelSpec, replicas: Vec<NativeReplica> },
    /// Shared AOT artifact via the PJRT runtime (sequential across ranks).
    Artifact { rt: Runtime, model: String, replicas: Vec<ArtifactReplica> },
}

/// Multi-replica data-parallel trainer.
pub struct DistTrainer {
    pub cfg: TrainConfig,
    pub ranks: usize,
    engine: Engine,
    reducer: Box<dyn GradReducer>,
    opt: Box<dyn Optimizer>,
    /// Canonical shared parameters (host-resident flat vector).
    params: Vec<f32>,
    /// Flat dimension (padded for artifact models, exact for native).
    d: usize,
    /// Real per-tensor boundaries for `step_multi`.
    tensors: Vec<TensorSpec>,
    /// Aggregated-gradient buffer.
    agg: Vec<f32>,
    pool: ExecPool,
    pub t: u64,
    /// Total paper-dtype bytes all ranks have put on the wire so far.
    wire_bytes: u64,
}

impl DistTrainer {
    /// Build from a [`TrainConfig`] (`cfg.ranks` / `cfg.reduce` select the
    /// topology). Artifact models need the PJRT runtime; without it — or
    /// without `artifacts/` — the trainer falls back to the native MLP
    /// workload so `microadam train --ranks N` works on the stub runtime.
    /// The optimizer update always runs natively (`cfg.backend` only
    /// selects how single-process training applies it).
    pub fn new(mut cfg: TrainConfig) -> Result<Self> {
        let ranks = cfg.ranks.max(1);
        if cfg.grad_accum > 1 {
            bail!(
                "dist: grad_accum > 1 is not supported — each rank already \
                 contributes one shard per step (use more ranks instead)"
            );
        }

        let engine = Self::resolve_engine(&cfg, ranks)?;
        // After an artifact->native fallback the run trains mlp_tiny, not
        // the requested artifact model; record what actually ran so the
        // metrics header / provenance JSON can't mislabel the data.
        if matches!(engine, Engine::Native { .. }) && !cfg.model.starts_with("mlp") {
            cfg.model = "mlp_tiny".into();
        }
        let (d, tensors, params) = match &engine {
            Engine::Native { mlp, .. } => {
                (mlp.dim(), mlp.specs().to_vec(), mlp.init(cfg.seed))
            }
            Engine::Artifact { rt, model, .. } => {
                let layout = rt.meta(model)?.layout()?;
                let flat = layout.init_flat(cfg.seed);
                (layout.d_padded, layout.tensors, flat)
            }
        };

        let opt = optim::build(cfg.optimizer, d, &tensors, cfg.weight_decay);
        let reducer = build_reducer(cfg.reduce, d, ranks, SparseReduceConfig::default());
        let pool = if cfg.workers == 0 { ExecPool::auto() } else { ExecPool::new(cfg.workers) };
        Ok(Self {
            cfg,
            ranks,
            engine,
            reducer,
            opt,
            params,
            d,
            tensors,
            agg: vec![0.0; d],
            pool,
            t: 0,
            wire_bytes: 0,
        })
    }

    fn resolve_engine(cfg: &TrainConfig, ranks: usize) -> Result<Engine> {
        // Explicit native model names skip the artifact runtime entirely —
        // but a typo'd mlp name must not silently train a different preset.
        if cfg.model.starts_with("mlp") && !super::replica::is_native_model(&cfg.model) {
            bail!(
                "dist: unknown native model {} (available: mlp_tiny, mlp_small)",
                cfg.model
            );
        }
        if !cfg.model.starts_with("mlp") {
            match Runtime::load(&cfg.artifacts_dir) {
                Ok(rt) if runtime::engine_available() && rt.has(&cfg.model) => {
                    let meta = rt.meta(&cfg.model)?.clone();
                    let d_padded = meta.layout()?.d_padded;
                    let replicas = (0..ranks)
                        .map(|r| ArtifactReplica::new(r, &meta, cfg.seed, d_padded))
                        .collect::<Result<Vec<_>>>()?;
                    return Ok(Engine::Artifact { rt, model: cfg.model.clone(), replicas });
                }
                Ok(_) if runtime::engine_available() => {
                    bail!("dist: model artifact {} not found in {}", cfg.model, cfg.artifacts_dir)
                }
                _ => {
                    eprintln!(
                        "[dist] artifact runtime unavailable for model {} — \
                         falling back to the native mlp_tiny workload",
                        cfg.model
                    );
                }
            }
        }
        let spec = native_model_spec(&cfg.model);
        let mlp = Mlp::new(spec.sizes.clone());
        let d = mlp.dim();
        let replicas =
            (0..ranks).map(|r| NativeReplica::new(r, &spec, cfg.seed, d)).collect();
        Ok(Engine::Native { mlp, spec, replicas })
    }

    /// Whether the native (artifact-free) engine is driving the replicas.
    pub fn is_native(&self) -> bool {
        matches!(self.engine, Engine::Native { .. })
    }

    /// Flat parameter dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Current parameters (host copy).
    pub fn params_vec(&self) -> Vec<f32> {
        self.params.clone()
    }

    /// Replace parameters (checkpoint resume); the length must match.
    pub fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.d {
            bail!(
                "dist set_params: {} values, but the model has d = {} — \
                 checkpoint does not match this model",
                flat.len(),
                self.d
            );
        }
        self.params.copy_from_slice(flat);
        Ok(())
    }

    /// Paper-dtype optimizer state bytes.
    pub fn opt_state_bytes(&self) -> usize {
        self.opt.paper_state_bytes()
    }

    /// Paper-dtype bytes of per-rank reducer residual state (all ranks).
    pub fn reducer_state_bytes(&self) -> usize {
        self.reducer.residual_state_bytes()
    }

    /// Total paper-dtype bytes put on the wire so far (all ranks).
    pub fn wire_bytes_total(&self) -> u64 {
        self.wire_bytes
    }

    /// Reducer display name.
    pub fn reducer_name(&self) -> String {
        self.reducer.name()
    }

    /// One synchronous data-parallel step; returns the mean replica loss.
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        self.t += 1;

        // 1. local gradients on every rank
        let loss = match &mut self.engine {
            Engine::Native { mlp, spec, replicas } => {
                let params = &self.params[..];
                let mlp = &*mlp;
                let spec = &*spec;
                // Group replicas so at most `workers` threads run, per the
                // ExecPool convention (callers build <= workers shards).
                let per = replicas.len().div_ceil(self.pool.workers().min(replicas.len()));
                let shards: Vec<&mut [NativeReplica]> = replicas.chunks_mut(per).collect();
                self.pool.run_shards(shards, |_, group| {
                    for r in group {
                        r.local_step(mlp, spec, params);
                    }
                });
                replicas.iter().map(|r| r.last_loss).sum::<f32>() / replicas.len() as f32
            }
            Engine::Artifact { rt, model, replicas } => {
                let plit = lit_f32(&self.params, &[self.d])?;
                for r in replicas.iter_mut() {
                    r.local_step(rt, model, &plit)?;
                }
                replicas.iter().map(|r| r.last_loss).sum::<f32>() / replicas.len() as f32
            }
        };

        // 2. gradient exchange
        let grads: Vec<&[f32]> = match &self.engine {
            Engine::Native { replicas, .. } => {
                replicas.iter().map(|r| r.grads.as_slice()).collect()
            }
            Engine::Artifact { replicas, .. } => {
                replicas.iter().map(|r| r.grads.as_slice()).collect()
            }
        };
        self.reducer.reduce(&grads, &mut self.agg, &self.pool);
        self.wire_bytes += (self.ranks * self.reducer.wire_bytes_per_rank()) as u64;

        // 3. shared optimizer step over the real tensor boundaries
        optim::step_with_layout(
            self.opt.as_mut(),
            &self.tensors,
            self.d,
            &mut self.params,
            &self.agg,
            lr,
            &self.pool,
        );
        Ok(loss)
    }

    /// Run the configured number of steps, logging to `logger`.
    pub fn train(&mut self, logger: &mut MetricsLogger) -> Result<()> {
        logger.log_header(self.cfg.to_json())?;
        let steps = self.cfg.steps;
        for step in 1..=steps {
            let lr = self.cfg.schedule.lr(step);
            let loss = self.step(lr)?;
            if !loss.is_finite() {
                bail!("non-finite loss at step {step}");
            }
            logger.log_step(step, loss, lr)?;
            if step % self.cfg.log_every == 0 || step == steps {
                eprintln!(
                    "[dist x{} {} {}] step {step}/{steps} loss {loss:.4} lr {lr:.2e} wire {} MB",
                    self.ranks,
                    reducer_name(self.cfg.reduce),
                    crate::coordinator::config::optimizer_name(self.cfg.optimizer),
                    self.wire_bytes / (1 << 20),
                );
            }
        }
        logger.log_record(json::obj(vec![
            ("final_loss", json::num(logger.tail_loss(10) as f64)),
            ("opt_state_bytes", json::num(self.opt_state_bytes() as f64)),
            ("ranks", json::num(self.ranks as f64)),
            ("reducer", json::s(&self.reducer.name())),
            ("wire_bytes_total", json::num(self.wire_bytes as f64)),
            ("reducer_state_bytes", json::num(self.reducer_state_bytes() as f64)),
        ]))?;
        logger.flush()?;
        Ok(())
    }

    /// Persist a params-only checkpoint through the coordinator format.
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        Checkpoint { step: self.t, params: self.params.clone(), opt: None }.save(path)
    }

    /// Resume parameters + step counter from a checkpoint. Params-only
    /// initialization: optimizer/reducer state, the LR schedule position,
    /// and the replicas' data streams are NOT fast-forwarded (the same
    /// limitation as the single-process resume path) — `t` resumes for
    /// provenance, while `train()` runs its configured steps from fresh
    /// streams.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        self.set_params(&ck.params)?;
        self.t = ck.step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::TrainConfig;
    use crate::coordinator::schedule::LrSchedule;
    use crate::dist::reducer::ReducerKind;
    use crate::optim::OptimizerKind;

    fn cfg(ranks: usize, reduce: ReducerKind, steps: u64) -> TrainConfig {
        TrainConfig {
            model: "mlp_tiny".into(),
            optimizer: OptimizerKind::MicroAdam,
            schedule: LrSchedule::Const { lr: 3e-3 },
            steps,
            seed: 7,
            log_every: 10_000,
            workers: 2,
            ranks,
            reduce,
            ..Default::default()
        }
    }

    #[test]
    fn dist_trainer_trains_native_eftopk() {
        let mut t = DistTrainer::new(cfg(4, ReducerKind::EfTopK, 40)).unwrap();
        assert!(t.is_native());
        let mut logger = MetricsLogger::new("").unwrap();
        t.train(&mut logger).unwrap();
        assert_eq!(logger.history.len(), 40);
        assert!(logger.tail_loss(5).is_finite());
        assert!(t.wire_bytes_total() > 0);
        assert!(t.reducer_state_bytes() > 0);
    }

    #[test]
    fn set_params_rejects_wrong_length() {
        let mut t = DistTrainer::new(cfg(2, ReducerKind::Dense, 1)).unwrap();
        let d = t.dim();
        assert!(t.set_params(&vec![0.0; d + 1]).is_err());
        assert!(t.set_params(&vec![0.0; d]).is_ok());
    }

    #[test]
    fn grad_accum_is_rejected() {
        let mut c = cfg(2, ReducerKind::Dense, 1);
        c.grad_accum = 2;
        assert!(DistTrainer::new(c).is_err());
    }

    #[test]
    fn checkpoint_roundtrip_resumes_params() {
        let path = "/tmp/microadam_dist_ck_test.bin";
        let mut a = DistTrainer::new(cfg(2, ReducerKind::EfTopK, 5)).unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        a.train(&mut logger).unwrap();
        a.save_checkpoint(path).unwrap();
        let mut b = DistTrainer::new(cfg(2, ReducerKind::EfTopK, 5)).unwrap();
        b.load_checkpoint(path).unwrap();
        assert_eq!(b.t, 5);
        assert_eq!(a.params_vec(), b.params_vec());
        let _ = std::fs::remove_file(path);
    }
}
