//! The data-parallel training driver: N replicas -> framed gradient
//! exchange -> one (replicated) optimizer step.
//!
//! One [`DistTrainer`] instance is one *process's* view of the run. In
//! loopback mode it hosts every rank; under a multi-process transport
//! (`--transport uds|shm`) each process hosts one rank and the full set
//! of frames is gathered through rank 0. Per step, every process:
//!
//! 1. draws a batch on each hosted replica (its **own** seeded shard) and
//!    computes local gradients against the process's parameters (native
//!    MLP replicas fan out across the [`ExecPool`]; artifact replicas run
//!    sequentially through the one PJRT client, loopback only);
//! 2. runs the [`GradReducer`]'s per-rank compress phase and wraps each
//!    hosted rank's payload in a wire frame
//!    ([`crate::dist::wire::Frame`]);
//! 3. exchanges frames through the [`Transport`]'s split gather phases —
//!    `post_send` as soon as its own payloads are framed (on rank 0 this
//!    seeds the relay bundle so the coordinator streams it while worker
//!    frames are still arriving), then `collect` for the full
//!    rank-ordered set — and aggregates the gathered payloads into the
//!    mean gradient, the same deterministic kernel on every process;
//! 4. feeds that gradient into the ordinary [`Optimizer::step_multi`] hot
//!    path with the layout's real per-tensor chunk boundaries — the same
//!    code path as the single-process
//!    [`crate::coordinator::trainer::Trainer`].
//!
//! Because step 3 hands every process identical bytes and steps 3-4 are
//! deterministic, the replicated parameters/optimizer state never drift:
//! there is **no parameter broadcast**, and a `uds`/`tcp`/`shm` run is
//! bit-identical to the loopback run with the same seeds (pinned in
//! `rust/tests/test_transport_parity.rs` and
//! `rust/tests/test_tcp_parity.rs`).
//!
//! Guarantee (pinned in `rust/tests/test_dist_parity.rs`): `ranks = 1`
//! with `DenseAllReduce` is **bit-identical** to single-process training
//! for every optimizer kind — the reducer is an exact identity, the f32
//! payload codec is bit-preserving, and the chunked step is bit-equal to
//! the flat step.
//!
//! The trainer wraps the coordinator stack: [`TrainConfig`] (with its
//! `ranks`/`reduce`/`transport` fields) configures it, [`MetricsLogger`]
//! records it (rank 0 / loopback only), and [`Checkpoint`] persists it.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::layout::TensorSpec;
use crate::coordinator::metrics::MetricsLogger;
use crate::exec::ExecPool;
use crate::models::mlp::Mlp;
use crate::optim::{self, Optimizer};
use crate::runtime::{self, lit_f32, Runtime};
use crate::trace;
use crate::util::json;

use super::reducer::{build_reducer, reducer_name, GradReducer, SparseReduceConfig};
use super::replica::{native_model_spec, ArtifactReplica, NativeModelSpec, NativeReplica};
use super::transport::{
    topology_name, transport_name, Loopback, Topology, Transport, TransportKind,
};
use super::wire::{self, Frame};

/// Which gradient backend drives the replicas.
enum Engine {
    /// Pure-rust MLP: runs everywhere, replicas step in parallel.
    Native { mlp: Mlp, spec: NativeModelSpec, replicas: Vec<NativeReplica> },
    /// Shared AOT artifact via the PJRT runtime (sequential across ranks;
    /// loopback topology only — there is one PJRT client per process).
    Artifact { rt: Runtime, model: String, replicas: Vec<ArtifactReplica> },
}

/// One process's endpoint of a (possibly multi-process) data-parallel run.
pub struct DistTrainer {
    pub cfg: TrainConfig,
    /// World size (total replica count across all processes).
    pub ranks: usize,
    engine: Engine,
    /// The ranks this process hosts (ascending): all of `0..ranks` in
    /// loopback, exactly one rank per process otherwise.
    local_ranks: Vec<usize>,
    transport: Box<dyn Transport>,
    reducer: Box<dyn GradReducer>,
    opt: Box<dyn Optimizer>,
    /// This process's parameters (replicated: every process holds the
    /// same bits, kept in lockstep by the deterministic exchange).
    params: Vec<f32>,
    /// Flat dimension (padded for artifact models, exact for native).
    d: usize,
    /// Real per-tensor boundaries for `step_multi`.
    tensors: Vec<TensorSpec>,
    /// Aggregated-gradient buffer.
    agg: Vec<f32>,
    pool: ExecPool,
    pub t: u64,
    /// Total framed bytes all ranks have put on the wire so far
    /// (`ranks * (wire_bytes_per_rank + FRAME_OVERHEAD)` per step).
    wire_bytes: u64,
    /// Cumulative microseconds of decoded-slab lead time under the
    /// gather: for every streamed frame, the gap between its slab decode
    /// finishing and the gather completing (see
    /// [`DistTrainer::decode_overlap_ms`]).
    decode_overlap_micros: u64,
}

impl DistTrainer {
    /// Build the in-process (loopback) trainer from a [`TrainConfig`]
    /// (`cfg.ranks` / `cfg.reduce` select the topology). Multi-process
    /// transports go through [`DistTrainer::with_transport`] — the CLI
    /// launcher (`microadam train --transport uds|shm`) wires that up.
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        if cfg.transport != TransportKind::Loopback {
            bail!(
                "DistTrainer::new is the in-process constructor; `--transport {}` runs \
                 through the multi-process launcher (or DistTrainer::with_transport)",
                transport_name(cfg.transport)
            );
        }
        if cfg.topology != Topology::Star {
            bail!(
                "dist: loopback hosts every rank in-process, so `--topology {}` has no \
                 links to re-wire — ring/tree need a stream transport (uds|tcp)",
                topology_name(cfg.topology)
            );
        }
        let ranks = cfg.ranks.max(1);
        let local: Vec<usize> = (0..ranks).collect();
        Self::with_transport(cfg, Box::new(Loopback::new(ranks)), local)
    }

    /// Build one endpoint of the run: `transport` carries the exchange and
    /// `local_ranks` names the replicas this process hosts (all ranks for
    /// [`Loopback`], exactly one per worker/coordinator process for the
    /// socket/shared-memory transports). Artifact models need the PJRT
    /// runtime *and* the loopback topology; otherwise — or without
    /// `artifacts/` — the trainer falls back to the native MLP workload so
    /// `microadam train --ranks N` works on the stub runtime. The
    /// optimizer update always runs natively (`cfg.backend` only selects
    /// how single-process training applies it).
    pub fn with_transport(
        mut cfg: TrainConfig,
        transport: Box<dyn Transport>,
        local_ranks: Vec<usize>,
    ) -> Result<Self> {
        let ranks = cfg.ranks.max(1);
        if transport.ranks() != ranks {
            bail!(
                "dist: transport built for {} ranks, config says {ranks}",
                transport.ranks()
            );
        }
        if transport.topology() != cfg.topology {
            bail!(
                "dist: transport aggregates over a {} topology, config says {} — \
                 every endpoint must run the collective the config records",
                topology_name(transport.topology()),
                topology_name(cfg.topology)
            );
        }
        if local_ranks.is_empty()
            || local_ranks.windows(2).any(|w| w[0] >= w[1])
            || local_ranks.iter().any(|&r| r >= ranks)
        {
            bail!("dist: local_ranks must be ascending, unique and < {ranks}");
        }
        if cfg.grad_accum > 1 {
            bail!(
                "dist: grad_accum > 1 is not supported — each rank already \
                 contributes one shard per step (use more ranks instead)"
            );
        }
        if !super::reducer::reducer_supported(cfg.optimizer, cfg.reduce) {
            bail!(
                "dist: optimizer {} does not support the {} reducer (plain \
                 Top-K drops gradient mass with no error feedback, which \
                 would bias this optimizer's compressed state) — use dense \
                 or eftopk",
                crate::coordinator::config::optimizer_name(cfg.optimizer),
                reducer_name(cfg.reduce),
            );
        }

        // Multi-process endpoints host a strict subset of the ranks; the
        // artifact engine is loopback-only (one PJRT client per process,
        // and every process must resolve the *same* engine for the
        // replicated step to stay in lockstep).
        let allow_artifact = local_ranks.len() == ranks;
        let engine = Self::resolve_engine(&cfg, &local_ranks, allow_artifact)?;
        // After an artifact->native fallback the run trains mlp_tiny, not
        // the requested artifact model; record what actually ran so the
        // metrics header / provenance JSON can't mislabel the data.
        if matches!(engine, Engine::Native { .. }) && !cfg.model.starts_with("mlp") {
            cfg.model = "mlp_tiny".into();
        }
        let (d, tensors, params) = match &engine {
            Engine::Native { mlp, .. } => {
                (mlp.dim(), mlp.specs().to_vec(), mlp.init(cfg.seed))
            }
            Engine::Artifact { rt, model, .. } => {
                let layout = rt.meta(model)?.layout()?;
                let flat = layout.init_flat(cfg.seed);
                (layout.d_padded, layout.tensors, flat)
            }
        };

        let opt = optim::build(cfg.optimizer, d, &tensors, cfg.weight_decay);
        let reducer = build_reducer(cfg.reduce, d, ranks, SparseReduceConfig::default());
        let pool = if cfg.workers == 0 {
            ExecPool::auto_with(cfg.pin_workers)
        } else {
            ExecPool::new_with(cfg.workers, cfg.pin_workers)
        };
        let mut me = Self {
            cfg,
            ranks,
            engine,
            local_ranks,
            transport,
            reducer,
            opt,
            params,
            d,
            tensors,
            agg: vec![0.0; d],
            pool,
            t: 0,
            wire_bytes: 0,
            decode_overlap_micros: 0,
        };
        me.config_handshake()?;
        Ok(me)
    }

    /// Digest of everything trajectory-relevant in the config. `out` and
    /// `trace` are endpoint-local sinks (workers clear them) and
    /// deliberately excluded.
    fn config_digest(cfg: &TrainConfig) -> u64 {
        let mut c = cfg.clone();
        c.out = String::new();
        c.trace = String::new();
        wire::fnv1a64(c.to_json().to_string().as_bytes())
    }

    /// Session round 0: every rank exchanges a handshake frame carrying
    /// the FNV-1a digest of its canonical config. The replicated-state
    /// guarantee rests on every process stepping identically, so a
    /// hand-started worker running a different seed/lr/optimizer must
    /// fail fast here instead of silently diverging for the whole run.
    fn config_handshake(&mut self) -> Result<()> {
        let digest = Self::config_digest(&self.cfg).to_le_bytes();
        let tag = self.reducer.payload_tag();
        let local: Vec<Frame> = self
            .local_ranks
            .iter()
            .map(|&r| Frame {
                rank: r as u16,
                step: 0,
                tag,
                flags: wire::FLAG_HELLO,
                loss: 0.0,
                payload: digest.to_vec(),
                stats: Vec::new(),
            })
            .collect();
        let frames = self.transport.exchange(local)?;
        if frames.len() != self.ranks {
            bail!("dist: handshake returned {} frames for {} ranks", frames.len(), self.ranks);
        }
        for (r, f) in frames.iter().enumerate() {
            if f.rank as usize != r || f.step != 0 || f.flags & wire::FLAG_HELLO == 0 {
                bail!("dist: malformed handshake frame in slot {r}");
            }
            if f.payload != digest {
                bail!(
                    "dist: rank {r} is running a different config (digest mismatch) — \
                     every endpoint must share the coordinator's provenance config \
                     (seed, lr schedule, optimizer, reducer, ranks)"
                );
            }
        }
        Ok(())
    }

    fn resolve_engine(
        cfg: &TrainConfig,
        local_ranks: &[usize],
        allow_artifact: bool,
    ) -> Result<Engine> {
        // Explicit native model names skip the artifact runtime entirely —
        // but a typo'd mlp name must not silently train a different preset.
        if cfg.model.starts_with("mlp") && !super::replica::is_native_model(&cfg.model) {
            bail!(
                "dist: unknown native model {} (available: mlp_tiny, mlp_small)",
                cfg.model
            );
        }
        if !cfg.model.starts_with("mlp") && allow_artifact {
            match Runtime::load(&cfg.artifacts_dir) {
                Ok(rt) if runtime::engine_available() && rt.has(&cfg.model) => {
                    let meta = rt.meta(&cfg.model)?.clone();
                    let d_padded = meta.layout()?.d_padded;
                    let replicas = local_ranks
                        .iter()
                        .map(|&r| ArtifactReplica::new(r, &meta, cfg.seed, d_padded))
                        .collect::<Result<Vec<_>>>()?;
                    return Ok(Engine::Artifact { rt, model: cfg.model.clone(), replicas });
                }
                Ok(_) if runtime::engine_available() => {
                    bail!("dist: model artifact {} not found in {}", cfg.model, cfg.artifacts_dir)
                }
                _ => {
                    eprintln!(
                        "[dist] artifact runtime unavailable for model {} — \
                         falling back to the native mlp_tiny workload",
                        cfg.model
                    );
                }
            }
        } else if !cfg.model.starts_with("mlp") {
            eprintln!(
                "[dist] multi-process transports drive the native workloads only — \
                 falling back from {} to mlp_tiny",
                cfg.model
            );
        }
        let spec = native_model_spec(&cfg.model);
        let mlp = Mlp::new(spec.sizes.clone());
        let d = mlp.dim();
        let replicas = local_ranks
            .iter()
            .map(|&r| NativeReplica::new(r, &spec, cfg.seed, d))
            .collect();
        Ok(Engine::Native { mlp, spec, replicas })
    }

    /// Whether the native (artifact-free) engine is driving the replicas.
    pub fn is_native(&self) -> bool {
        matches!(self.engine, Engine::Native { .. })
    }

    /// Whether this endpoint hosts rank 0 (loopback, or the coordinator
    /// process) — the endpoint that logs metrics and writes checkpoints.
    pub fn is_primary(&self) -> bool {
        self.local_ranks.contains(&0)
    }

    /// Flat parameter dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Current parameters (host copy).
    pub fn params_vec(&self) -> Vec<f32> {
        self.params.clone()
    }

    /// Replace parameters (checkpoint resume); the length must match.
    pub fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.d {
            bail!(
                "dist set_params: {} values, but the model has d = {} — \
                 checkpoint does not match this model",
                flat.len(),
                self.d
            );
        }
        self.params.copy_from_slice(flat);
        Ok(())
    }

    /// Paper-dtype optimizer state bytes.
    pub fn opt_state_bytes(&self) -> usize {
        self.opt.paper_state_bytes()
    }

    /// Measured resident optimizer-state bytes (allocated buffers — the
    /// dist optimizer always runs natively, so this is always available).
    pub fn opt_resident_bytes(&self) -> usize {
        self.opt.state_bytes()
    }

    /// Paper-dtype bytes of per-rank reducer residual state (all ranks).
    pub fn reducer_state_bytes(&self) -> usize {
        self.reducer.residual_state_bytes()
    }

    /// Total framed bytes put on the wire so far (all ranks):
    /// payloads plus the fixed per-frame overhead.
    pub fn wire_bytes_total(&self) -> u64 {
        self.wire_bytes
    }

    /// Framed bytes one rank puts on the wire per step: the reducer's
    /// payload plus [`wire::FRAME_OVERHEAD`].
    pub fn frame_bytes_per_rank(&self) -> usize {
        self.reducer.wire_bytes_per_rank() + wire::FRAME_OVERHEAD
    }

    /// Framed bytes this endpoint's transport has actually serialized and
    /// sent (loopback: everything it framed).
    pub fn transport_bytes_sent(&self) -> u64 {
        self.transport.bytes_sent()
    }

    /// Framed bytes this endpoint's transport has received from peers.
    pub fn transport_bytes_received(&self) -> u64 {
        self.transport.bytes_received()
    }

    /// Cumulative milliseconds the transport spent relaying bundle bytes
    /// while gather frames were still arriving — the wire latency the
    /// pipelined coordinator hides (0 on workers, loopback and shm).
    pub fn gather_overlap_ms(&self) -> f64 {
        self.transport.overlap_ms()
    }

    /// Cumulative milliseconds of decoded-slab lead time under the
    /// gather: for every frame handed over by the streaming collect, the
    /// gap between its payload slab being decoded and the whole gather
    /// completing. > 0 means slab decode genuinely ran while later
    /// frames were still in flight (star/tree streaming decode; 0 on the
    /// ring path, which folds in-network instead of decoding per rank).
    pub fn decode_overlap_ms(&self) -> f64 {
        self.decode_overlap_micros as f64 / 1000.0
    }

    /// Aggregation topology of this endpoint's collective.
    pub fn topology(&self) -> Topology {
        self.transport.topology()
    }

    /// Ranks in the order their frames completed in the most recent
    /// gather (coordinator only; empty on workers/loopback).
    pub fn last_arrival_order(&self) -> &[u16] {
        self.transport.last_arrival()
    }

    /// Per-frame arrival latency (ms since the gather opened), parallel
    /// to [`DistTrainer::last_arrival_order`].
    pub fn last_arrival_ms(&self) -> &[f64] {
        self.transport.last_arrival_ms()
    }

    /// Reducer display name.
    pub fn reducer_name(&self) -> String {
        self.reducer.name()
    }

    /// Transport display name.
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// One synchronous data-parallel step; returns the mean replica loss
    /// across all ranks (identical on every endpoint).
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        self.t += 1;

        // 1. local gradients on every hosted rank
        let sp = trace::begin();
        match &mut self.engine {
            Engine::Native { mlp, spec, replicas } => {
                let params = &self.params[..];
                let mlp = &*mlp;
                let spec = &*spec;
                // Group replicas so at most `workers` threads run, per the
                // ExecPool convention (callers build <= workers shards).
                let per = replicas.len().div_ceil(self.pool.workers().min(replicas.len()));
                let shards: Vec<&mut [NativeReplica]> = replicas.chunks_mut(per).collect();
                self.pool.run_shards(shards, |_, group| {
                    for r in group {
                        r.local_step(mlp, spec, params);
                    }
                });
            }
            Engine::Artifact { rt, model, replicas } => {
                let plit = lit_f32(&self.params, &[self.d])?;
                for r in replicas.iter_mut() {
                    r.local_step(rt, model, &plit)?;
                }
            }
        }
        sp.end("dist", "local_grad", 0);

        // 2. compress each hosted rank and frame its payload
        let sp = trace::begin();
        let tag = self.reducer.payload_tag();
        let wire_per_rank = self.reducer.wire_bytes_per_rank();
        let mut local = Vec::with_capacity(self.local_ranks.len());
        {
            let reducer = &mut self.reducer;
            let mut frame_one = |rank: usize, grads: &[f32], loss: f32| {
                let payload = reducer.compress_payload(rank, grads);
                // The spec's accounting identity: a frame is exactly the
                // accounted wire bytes plus the fixed overhead.
                assert_eq!(
                    payload.len(),
                    wire_per_rank,
                    "rank {rank} payload drifted from wire_bytes_per_rank"
                );
                Frame {
                    rank: rank as u16,
                    step: self.t,
                    tag,
                    flags: 0,
                    loss,
                    payload,
                    stats: Vec::new(),
                }
            };
            match &self.engine {
                Engine::Native { replicas, .. } => {
                    for (&r, rep) in self.local_ranks.iter().zip(replicas) {
                        local.push(frame_one(r, &rep.grads, rep.last_loss));
                    }
                }
                Engine::Artifact { replicas, .. } => {
                    for (&r, rep) in self.local_ranks.iter().zip(replicas) {
                        local.push(frame_one(r, &rep.grads, rep.last_loss));
                    }
                }
            }
        }
        sp.end("dist", "compress", 0);

        // 3. gather-to-all and aggregate (identical on every endpoint).
        //    The phases are explicit: post_send fires the moment this
        //    endpoint's payloads are framed, so the rank-0 coordinator
        //    relays its frame (and each completed rank-ascending prefix)
        //    while the remaining worker frames are still in flight.
        self.transport.post_send(local)?;
        let d = self.d;
        let step_now = self.t;
        let loss = if self.transport.topology() == Topology::Ring {
            // In-ring reduction: every endpoint folds the wire payloads
            // into the circulating partial with the same rank-ascending op
            // order the star aggregate uses, so the single result frame —
            // and everything downstream of it — is bit-identical to star.
            let reducer = &mut self.reducer;
            let mut fold = |payload: &[u8], acc: &mut Vec<f32>| -> Result<()> {
                if acc.is_empty() {
                    acc.resize(d, 0.0);
                } else if acc.len() != d {
                    bail!("dist: ring partial carries {} coordinates, model d = {d}", acc.len());
                }
                reducer.accumulate_payload(payload, acc)
            };
            let frames = self.transport.collect_reduced(&mut fold)?;
            let [result] = frames.as_slice() else {
                bail!(
                    "dist: ring reduction returned {} frames (expected the single result \
                     frame)",
                    frames.len()
                );
            };
            if result.flags & wire::FLAG_HOP == 0 || result.step != step_now || result.tag != tag
            {
                bail!(
                    "dist: malformed ring result frame (rank {} step {} tag {:?} flags \
                     {:#04x}) at step {step_now}",
                    result.rank,
                    result.step,
                    result.tag,
                    result.flags
                );
            }
            let (fan_in, sum) = wire::hop_from_payload(&result.payload)
                .map_err(|e| anyhow!("dist: ring result payload: {e}"))?;
            if fan_in as usize != self.ranks {
                bail!("dist: ring result folded {fan_in} ranks, world is {}", self.ranks);
            }
            if sum.len() != d {
                bail!("dist: ring result carries {} coordinates, model d = {d}", sum.len());
            }
            self.agg.copy_from_slice(&sum);
            self.reducer.finalize_partial(&mut self.agg);
            // the hop chain folded losses rank-ascending from 0.0 — the
            // same fold the streaming path below runs over full frames
            result.loss / self.ranks as f32
        } else {
            // Star / tree: the full frame set, decoded *streaming* — each
            // rank's payload slab is loaded the moment its frame arrives,
            // while later frames are still in flight, overlapping decode
            // with the gather tail.
            let reducer = &mut self.reducer;
            let mut decoded_at: Vec<Instant> = Vec::with_capacity(self.ranks);
            let mut on_frame = |f: &Frame| -> Result<()> {
                if f.step != step_now || f.tag != tag {
                    bail!(
                        "dist: mismatched frame (rank {} step {} tag {:?}) at step {step_now}",
                        f.rank,
                        f.step,
                        f.tag
                    );
                }
                reducer.load_payload(f.rank as usize, &f.payload)?;
                decoded_at.push(Instant::now());
                Ok(())
            };
            let frames = self.transport.collect_streaming(&mut on_frame)?;
            let gather_done = Instant::now();
            for t0 in &decoded_at {
                self.decode_overlap_micros +=
                    gather_done.duration_since(*t0).as_micros() as u64;
            }
            if frames.len() != self.ranks {
                bail!(
                    "dist: transport returned {} frames for {} ranks",
                    frames.len(),
                    self.ranks
                );
            }
            let mut loss_sum = 0f32;
            for (r, f) in frames.iter().enumerate() {
                if f.rank as usize != r || f.step != step_now || f.tag != tag {
                    bail!(
                        "dist: mismatched frame in slot {r} (rank {} step {} tag {:?}) at \
                         step {step_now}",
                        f.rank,
                        f.step,
                        f.tag
                    );
                }
                loss_sum += f.loss;
            }
            self.reducer.aggregate_loaded(&mut self.agg, &self.pool)?;
            loss_sum / self.ranks as f32
        };
        self.wire_bytes += (self.ranks * (wire_per_rank + wire::FRAME_OVERHEAD)) as u64;

        // 4. replicated optimizer step over the real tensor boundaries
        let sp = trace::begin();
        optim::step_with_layout(
            self.opt.as_mut(),
            &self.tensors,
            self.d,
            &mut self.params,
            &self.agg,
            lr,
            &self.pool,
        );
        sp.end("dist", "optim_step", 0);
        Ok(loss)
    }

    /// Per-step EF-health gauges into the trace sink, sampled from the
    /// ranks this endpoint hosts (the compress phase refreshes them only
    /// while tracing is enabled). Also re-emits the last gather's
    /// per-rank arrival latencies as gauges so they land in the JSONL
    /// next to the health numbers.
    fn emit_ef_gauges(&self) {
        let n = self.local_ranks.len() as f32;
        let (mut rn, mut tm, mut qe) = (0f32, 0f32, 0f32);
        for &r in &self.local_ranks {
            rn += self.reducer.residual_norm(r);
            tm += self.reducer.topk_mass(r);
            qe += self.reducer.quant_abs_err(r);
        }
        trace::gauge("ef.residual_norm", (rn / n) as f64);
        trace::gauge("ef.topk_mass", (tm / n) as f64);
        trace::gauge("ef.quant_abs_err", (qe / n) as f64);
        trace::gauge("ef.slab_density", self.reducer.slab_density());
        let arrival = self.transport.last_arrival();
        for (&rk, &ms) in arrival.iter().zip(self.transport.last_arrival_ms()) {
            trace::gauge(&format!("dist.arrival_ms.r{rk}"), ms);
        }
    }

    /// Run the configured number of steps. Only the primary endpoint
    /// (loopback / rank 0) logs to `logger` and prints progress; worker
    /// processes run silently in lockstep.
    pub fn train(&mut self, logger: &mut MetricsLogger) -> Result<()> {
        let primary = self.is_primary();
        if primary {
            logger.log_header(self.cfg.to_json())?;
        }
        let steps = self.cfg.steps;
        for step in 1..=steps {
            let lr = self.cfg.schedule.lr(step);
            let loss = self.step(lr)?;
            if !loss.is_finite() {
                bail!("non-finite loss at step {step}");
            }
            if primary {
                logger.log_step(step, loss, lr)?;
                if trace::enabled() {
                    self.emit_ef_gauges();
                    for rec in trace::drain_step_records(step) {
                        logger.log_record(rec)?;
                    }
                }
                if step % self.cfg.log_every == 0 || step == steps {
                    eprintln!(
                        "[dist x{} {} {} via {}] step {step}/{steps} loss {loss:.4} lr {lr:.2e} wire {} MB",
                        self.ranks,
                        reducer_name(self.cfg.reduce),
                        crate::coordinator::config::optimizer_name(self.cfg.optimizer),
                        self.transport.name(),
                        self.wire_bytes / (1 << 20),
                    );
                }
            }
        }
        if primary {
            logger.log_record(json::obj(vec![
                ("final_loss", json::num(logger.tail_loss(10) as f64)),
                ("opt_state_bytes", json::num(self.opt_state_bytes() as f64)),
                ("ranks", json::num(self.ranks as f64)),
                ("reducer", json::s(&self.reducer.name())),
                ("transport", json::s(self.transport.name())),
                ("wire_bytes_total", json::num(self.wire_bytes as f64)),
                ("frame_bytes_per_rank", json::num(self.frame_bytes_per_rank() as f64)),
                ("reducer_state_bytes", json::num(self.reducer_state_bytes() as f64)),
                ("gather_overlap_ms", json::num(self.gather_overlap_ms())),
                ("topology", json::s(topology_name(self.transport.topology()))),
                ("decode_overlap_ms", json::num(self.decode_overlap_ms())),
            ]))?;
            logger.flush()?;
        }
        Ok(())
    }

    /// Persist a checkpoint through the coordinator format: parameters,
    /// step counter, and the optimizer's state snapshot when the configured
    /// optimizer supports one (micro-adam, ldadam, adammini). The state is
    /// replicated bit-identically on every process, so any endpoint's
    /// snapshot is *the* run state.
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        Checkpoint {
            step: self.t,
            params: self.params.clone(),
            opt: self.opt.snapshot_state(),
        }
        .save(path)
    }

    /// Resume parameters, step counter, and (when the checkpoint carries
    /// one) the optimizer-state snapshot. A snapshot whose kind does not
    /// match the configured optimizer is a typed error. Reducer EF state,
    /// the LR schedule position, and the replicas' data streams are NOT
    /// fast-forwarded — `t` resumes for provenance, while `train()` runs
    /// its configured steps from fresh streams.
    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        self.set_params(&ck.params)?;
        if let Some(snap) = &ck.opt {
            self.opt.restore_state(snap)?;
        }
        self.t = ck.step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::TrainConfig;
    use crate::coordinator::schedule::LrSchedule;
    use crate::dist::reducer::ReducerKind;
    use crate::optim::OptimizerKind;

    fn cfg(ranks: usize, reduce: ReducerKind, steps: u64) -> TrainConfig {
        TrainConfig {
            model: "mlp_tiny".into(),
            optimizer: OptimizerKind::MicroAdam,
            schedule: LrSchedule::Const { lr: 3e-3 },
            steps,
            seed: 7,
            log_every: 10_000,
            workers: 2,
            ranks,
            reduce,
            ..Default::default()
        }
    }

    #[test]
    fn dist_trainer_trains_native_eftopk() {
        let mut t = DistTrainer::new(cfg(4, ReducerKind::EfTopK, 40)).unwrap();
        assert!(t.is_native());
        assert!(t.is_primary());
        let mut logger = MetricsLogger::new("").unwrap();
        t.train(&mut logger).unwrap();
        assert_eq!(logger.history.len(), 40);
        assert!(logger.tail_loss(5).is_finite());
        assert!(t.wire_bytes_total() > 0);
        assert!(t.reducer_state_bytes() > 0);
        // framed accounting: every rank, every step, payload + overhead
        assert_eq!(
            t.wire_bytes_total(),
            40 * 4 * t.frame_bytes_per_rank() as u64
        );
        // loopback physically framed exactly what the accounting claims,
        // plus the one-time config-digest handshake round
        let handshake = 4 * (wire::FRAME_OVERHEAD + wire::HELLO_DIGEST_BYTES) as u64;
        assert_eq!(t.transport_bytes_sent(), t.wire_bytes_total() + handshake);
    }

    #[test]
    fn set_params_rejects_wrong_length() {
        let mut t = DistTrainer::new(cfg(2, ReducerKind::Dense, 1)).unwrap();
        let d = t.dim();
        assert!(t.set_params(&vec![0.0; d + 1]).is_err());
        assert!(t.set_params(&vec![0.0; d]).is_ok());
    }

    #[test]
    fn grad_accum_is_rejected() {
        let mut c = cfg(2, ReducerKind::Dense, 1);
        c.grad_accum = 2;
        assert!(DistTrainer::new(c).is_err());
    }

    #[test]
    fn non_loopback_transport_requires_launcher() {
        let mut c = cfg(2, ReducerKind::Dense, 1);
        c.transport = TransportKind::Uds;
        assert!(DistTrainer::new(c).is_err());
    }

    #[test]
    fn loopback_rejects_ring_and_tree_topologies() {
        for t in [Topology::Ring, Topology::Tree] {
            let mut c = cfg(2, ReducerKind::Dense, 1);
            c.topology = t;
            let err = DistTrainer::new(c).map(|_| ()).unwrap_err().to_string();
            assert!(err.contains("topology"), "{err}");
        }
    }

    #[test]
    fn checkpoint_roundtrip_resumes_params() {
        let path = "/tmp/microadam_dist_ck_test.bin";
        let mut a = DistTrainer::new(cfg(2, ReducerKind::EfTopK, 5)).unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        a.train(&mut logger).unwrap();
        a.save_checkpoint(path).unwrap();
        let mut b = DistTrainer::new(cfg(2, ReducerKind::EfTopK, 5)).unwrap();
        b.load_checkpoint(path).unwrap();
        assert_eq!(b.t, 5);
        assert_eq!(a.params_vec(), b.params_vec());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn checkpoint_carries_optimizer_state() {
        // For every snapshot-capable optimizer: the dist checkpoint holds
        // the state, and a fresh trainer restores it bit-exactly.
        for (kind, path) in [
            (OptimizerKind::MicroAdam, "/tmp/microadam_dist_ck_opt_ma.bin"),
            (OptimizerKind::LdAdam, "/tmp/microadam_dist_ck_opt_ld.bin"),
            (OptimizerKind::AdamMini, "/tmp/microadam_dist_ck_opt_mini.bin"),
        ] {
            let mut c = cfg(2, ReducerKind::Dense, 5);
            c.optimizer = kind;
            let mut a = DistTrainer::new(c.clone()).unwrap();
            let mut logger = MetricsLogger::new("").unwrap();
            a.train(&mut logger).unwrap();
            a.save_checkpoint(path).unwrap();
            let snap = a.opt.snapshot_state();
            assert!(snap.is_some(), "{kind:?} should snapshot");
            let mut b = DistTrainer::new(c).unwrap();
            b.load_checkpoint(path).unwrap();
            assert_eq!(b.opt.snapshot_state(), snap, "{kind:?} restore");
            assert_eq!(b.t, 5);
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    fn checkpoint_with_mismatched_optimizer_is_typed_error() {
        let path = "/tmp/microadam_dist_ck_mismatch.bin";
        let mut c = cfg(1, ReducerKind::Dense, 3);
        c.optimizer = OptimizerKind::AdamMini;
        let mut a = DistTrainer::new(c).unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        a.train(&mut logger).unwrap();
        a.save_checkpoint(path).unwrap();
        let mut c2 = cfg(1, ReducerKind::Dense, 3);
        c2.optimizer = OptimizerKind::LdAdam;
        let mut b = DistTrainer::new(c2).unwrap();
        let err = b.load_checkpoint(path).unwrap_err().to_string();
        assert!(err.contains("adammini"), "{err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn unsupported_optimizer_reducer_combo_is_typed_error() {
        for kind in [OptimizerKind::LdAdam, OptimizerKind::AdamMini] {
            let mut c = cfg(2, ReducerKind::TopK, 1);
            c.optimizer = kind;
            let err = DistTrainer::new(c).map(|_| ()).unwrap_err().to_string();
            assert!(err.contains("topk"), "{kind:?}: {err}");
            // dense and eftopk stay supported for the same optimizer
            for ok in [ReducerKind::Dense, ReducerKind::EfTopK] {
                let mut c = cfg(2, ok, 1);
                c.optimizer = kind;
                assert!(DistTrainer::new(c).is_ok(), "{kind:?} x {ok:?}");
            }
        }
    }
}
