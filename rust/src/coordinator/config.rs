//! Training configuration: JSON files + named presets.
//!
//! The config system is the launcher's contract: everything a run needs is
//! one JSON object (model artifact, optimizer, schedule, steps, seed, output
//! dir), so experiments are reproducible from the file alone.

use anyhow::{anyhow, bail, Result};

use super::schedule::LrSchedule;
use crate::dist::reducer::{parse_reducer, reducer_name, ReducerKind};
use crate::dist::transport::{
    parse_topology, parse_transport, topology_name, transport_name, Topology, TransportKind,
};
use crate::optim::OptimizerKind;
use crate::util::json::{self, Json};

/// Which implementation performs the optimizer update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptBackend {
    /// AOT artifact (`microadam_step_d*` etc.) executed via PJRT.
    Aot,
    /// Native rust implementation from [`crate::optim`].
    Native,
}

/// Full training run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model artifact name, e.g. "lm_small" or "cls_tiny".
    pub model: String,
    pub optimizer: OptimizerKind,
    pub backend: OptBackend,
    pub schedule: LrSchedule,
    pub steps: u64,
    pub seed: u64,
    pub weight_decay: f32,
    /// Gradient accumulation (micro-steps per optimizer step).
    pub grad_accum: usize,
    /// Metrics JSONL path (empty = no file logging).
    pub out: String,
    /// Chrome trace-event JSON path (empty = tracing disabled). When set,
    /// the run records [`crate::trace`] spans/gauges: phase spans and
    /// EF-health records drain into the metrics JSONL, and the Chrome
    /// trace file is written at the end of the run.
    pub trace: String,
    /// Log every n steps.
    pub log_every: u64,
    pub artifacts_dir: String,
    /// Worker count for the native block-sharded optimizer step
    /// (0 = auto-detect from the machine / `MICROADAM_WORKERS`).
    pub workers: usize,
    /// Pin exec workers to cpus (NUMA-aware placement + static shard
    /// striping + first-touch warm pass; see [`crate::exec`]). Best
    /// effort: off by default, a no-op where the platform refuses.
    pub pin_workers: bool,
    /// Data-parallel replica count (1 = single-process training; > 1
    /// routes through [`crate::dist::DistTrainer`]).
    pub ranks: usize,
    /// Gradient exchange for the data-parallel engine.
    pub reduce: ReducerKind,
    /// How replicas exchange frames: in-process (`loopback`, default) or
    /// the multi-process `uds`/`tcp`/`shm` transports, which make
    /// `microadam train` launch one worker process per extra rank (`tcp`
    /// additionally spans real hosts via `--rendezvous host:port` +
    /// `--external yes`).
    pub transport: TransportKind,
    /// Aggregation topology for the multi-process transports: rank-0 `star`
    /// (default), successor-chained `ring` (partial hop aggregation), or
    /// binary `tree` (gather from children, relay the bundle down). Loopback
    /// and shm are star-only.
    pub topology: Topology,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "lm_tiny".into(),
            optimizer: OptimizerKind::MicroAdam,
            backend: OptBackend::Aot,
            schedule: LrSchedule::Const { lr: 1e-3 },
            steps: 100,
            seed: 7,
            weight_decay: 0.0,
            grad_accum: 1,
            out: String::new(),
            trace: String::new(),
            log_every: 10,
            artifacts_dir: "artifacts".into(),
            workers: 0,
            pin_workers: false,
            ranks: 1,
            reduce: ReducerKind::Dense,
            transport: TransportKind::Loopback,
            topology: Topology::Star,
        }
    }
}

impl TrainConfig {
    /// Parse from a JSON string.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("config parse: {e}"))?;
        let mut cfg = TrainConfig::default();
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            cfg.model = v.to_string();
        }
        if let Some(v) = j.get("optimizer").and_then(Json::as_str) {
            cfg.optimizer = parse_optimizer(v)?;
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            cfg.backend = match v {
                "aot" => OptBackend::Aot,
                "native" => OptBackend::Native,
                other => bail!("unknown backend {other}"),
            };
        }
        if let Some(v) = j.get("steps").and_then(Json::as_f64) {
            cfg.steps = v as u64;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            cfg.seed = v as u64;
        }
        if let Some(v) = j.get("weight_decay").and_then(Json::as_f64) {
            cfg.weight_decay = v as f32;
        }
        if let Some(v) = j.get("grad_accum").and_then(Json::as_f64) {
            cfg.grad_accum = (v as usize).max(1);
        }
        if let Some(v) = j.get("out").and_then(Json::as_str) {
            cfg.out = v.to_string();
        }
        if let Some(v) = j.get("trace").and_then(Json::as_str) {
            cfg.trace = v.to_string();
        }
        if let Some(v) = j.get("log_every").and_then(Json::as_f64) {
            cfg.log_every = (v as u64).max(1);
        }
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("workers").and_then(Json::as_f64) {
            cfg.workers = v as usize;
        }
        if let Some(v) = j.get("pin_workers").and_then(Json::as_bool) {
            cfg.pin_workers = v;
        }
        if let Some(v) = j.get("ranks").and_then(Json::as_f64) {
            cfg.ranks = (v as usize).max(1);
        }
        if let Some(v) = j.get("reduce").and_then(Json::as_str) {
            cfg.reduce = parse_reducer(v)?;
        }
        if let Some(v) = j.get("transport").and_then(Json::as_str) {
            cfg.transport = parse_transport(v)?;
        }
        if let Some(v) = j.get("topology").and_then(Json::as_str) {
            cfg.topology = parse_topology(v)?;
        }
        let lr = j.get("lr").and_then(Json::as_f64).unwrap_or(1e-3) as f32;
        cfg.schedule = match j.get("schedule").and_then(Json::as_str).unwrap_or("const") {
            "const" => LrSchedule::Const { lr },
            "warmup-cosine" => LrSchedule::WarmupCosine {
                lr,
                warmup: j.get("warmup").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                total: j.get("total").and_then(Json::as_f64).unwrap_or(cfg.steps as f64) as u64,
                floor_frac: j.get("floor_frac").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            },
            "linear-decay" => LrSchedule::LinearDecay {
                lr,
                total: j.get("total").and_then(Json::as_f64).unwrap_or(cfg.steps as f64) as u64,
            },
            other => bail!("unknown schedule {other}"),
        };
        Ok(cfg)
    }

    /// Load from a file.
    pub fn from_file(path: &str) -> Result<Self> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }

    /// Serialize back to JSON (for run provenance logging).
    pub fn to_json(&self) -> Json {
        let (sched, lr, warmup, total, floor) = match self.schedule {
            LrSchedule::Const { lr } => ("const", lr, 0, 0, 0.0),
            LrSchedule::WarmupCosine { lr, warmup, total, floor_frac } => {
                ("warmup-cosine", lr, warmup, total, floor_frac)
            }
            LrSchedule::LinearDecay { lr, total } => ("linear-decay", lr, 0, total, 0.0),
        };
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("optimizer", json::s(optimizer_name(self.optimizer))),
            ("backend", json::s(match self.backend {
                OptBackend::Aot => "aot",
                OptBackend::Native => "native",
            })),
            ("schedule", json::s(sched)),
            ("lr", json::num(lr as f64)),
            ("warmup", json::num(warmup as f64)),
            ("total", json::num(total as f64)),
            ("floor_frac", json::num(floor as f64)),
            ("steps", json::num(self.steps as f64)),
            ("seed", json::num(self.seed as f64)),
            ("weight_decay", json::num(self.weight_decay as f64)),
            ("grad_accum", json::num(self.grad_accum as f64)),
            ("out", json::s(&self.out)),
            ("trace", json::s(&self.trace)),
            ("log_every", json::num(self.log_every as f64)),
            ("artifacts_dir", json::s(&self.artifacts_dir)),
            ("workers", json::num(self.workers as f64)),
            ("pin_workers", Json::Bool(self.pin_workers)),
            ("ranks", json::num(self.ranks as f64)),
            ("reduce", json::s(reducer_name(self.reduce))),
            ("transport", json::s(transport_name(self.transport))),
            ("topology", json::s(topology_name(self.topology))),
        ])
    }
}

/// Parse an optimizer name (kebab-case, as in the CLI and config files).
pub fn parse_optimizer(s: &str) -> Result<OptimizerKind> {
    Ok(match s {
        "micro-adam" | "microadam" => OptimizerKind::MicroAdam,
        "adam" => OptimizerKind::Adam,
        "adamw" => OptimizerKind::AdamW,
        "adamw-8bit" | "adam-8bit" | "adamw8bit" => OptimizerKind::AdamW8bit,
        "sgd" => OptimizerKind::Sgd,
        "adafactor" => OptimizerKind::AdaFactor,
        "came" => OptimizerKind::Came,
        "galore" => OptimizerKind::GaLore,
        "galore-ef" => OptimizerKind::GaLoreEf,
        "ldadam" | "ld-adam" => OptimizerKind::LdAdam,
        "adammini" | "adam-mini" => OptimizerKind::AdamMini,
        other => bail!("unknown optimizer {other}"),
    })
}

/// Canonical kebab-case name of an optimizer kind.
pub fn optimizer_name(k: OptimizerKind) -> &'static str {
    match k {
        OptimizerKind::MicroAdam => "micro-adam",
        OptimizerKind::Adam => "adam",
        OptimizerKind::AdamW => "adamw",
        OptimizerKind::AdamW8bit => "adamw-8bit",
        OptimizerKind::Sgd => "sgd",
        OptimizerKind::AdaFactor => "adafactor",
        OptimizerKind::Came => "came",
        OptimizerKind::GaLore => "galore",
        OptimizerKind::GaLoreEf => "galore-ef",
        OptimizerKind::LdAdam => "ldadam",
        OptimizerKind::AdamMini => "adammini",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_json() {
        let cfg = TrainConfig {
            model: "lm_small".into(),
            optimizer: OptimizerKind::AdamW8bit,
            backend: OptBackend::Native,
            schedule: LrSchedule::WarmupCosine { lr: 3e-4, warmup: 10, total: 200, floor_frac: 0.1 },
            steps: 200,
            seed: 42,
            weight_decay: 0.1,
            grad_accum: 4,
            out: "runs/x.jsonl".into(),
            trace: "runs/x.trace.json".into(),
            log_every: 5,
            artifacts_dir: "artifacts".into(),
            workers: 3,
            pin_workers: true,
            ranks: 4,
            reduce: ReducerKind::EfTopK,
            transport: TransportKind::Uds,
            topology: Topology::Ring,
        };
        let j = cfg.to_json().to_string();
        let back = TrainConfig::from_json(&j).unwrap();
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.workers, 3);
        assert!(back.pin_workers);
        assert_eq!(back.optimizer, cfg.optimizer);
        assert_eq!(back.backend, cfg.backend);
        assert_eq!(back.schedule, cfg.schedule);
        assert_eq!(back.steps, cfg.steps);
        assert_eq!(back.grad_accum, 4);
        assert_eq!(back.trace, "runs/x.trace.json");
        assert_eq!(back.ranks, 4);
        assert_eq!(back.reduce, ReducerKind::EfTopK);
        assert_eq!(back.transport, TransportKind::Uds);
        assert_eq!(back.topology, Topology::Ring);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let cfg = TrainConfig::from_json(r#"{"model": "cls_tiny"}"#).unwrap();
        assert_eq!(cfg.model, "cls_tiny");
        assert_eq!(cfg.optimizer, OptimizerKind::MicroAdam);
        assert_eq!(cfg.steps, 100);
        assert_eq!(cfg.ranks, 1);
        assert_eq!(cfg.reduce, ReducerKind::Dense);
        assert!(!cfg.pin_workers);
        // configs written before the topology field existed keep meaning star
        assert_eq!(cfg.topology, Topology::Star);
    }

    #[test]
    fn ranks_and_reduce_parse_and_clamp() {
        let cfg =
            TrainConfig::from_json(r#"{"ranks": 8, "reduce": "eftopk", "transport": "shm"}"#)
                .unwrap();
        assert_eq!(cfg.ranks, 8);
        assert_eq!(cfg.reduce, ReducerKind::EfTopK);
        assert_eq!(cfg.transport, TransportKind::Shm);
        // ranks clamps to >= 1, transport defaults to loopback
        let cfg = TrainConfig::from_json(r#"{"ranks": 0}"#).unwrap();
        assert_eq!(cfg.ranks, 1);
        assert_eq!(cfg.transport, TransportKind::Loopback);
        // tcp round-trips like the other transports (the worker spawned by
        // the launcher reconstructs its transport from this field)
        let cfg = TrainConfig::from_json(r#"{"transport": "tcp", "ranks": 4}"#).unwrap();
        assert_eq!(cfg.transport, TransportKind::Tcp);
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.transport, TransportKind::Tcp);
        assert!(TrainConfig::from_json(r#"{"reduce": "gossip"}"#).is_err());
        assert!(TrainConfig::from_json(r#"{"transport": "pigeon"}"#).is_err());
        let cfg = TrainConfig::from_json(r#"{"topology": "tree", "transport": "tcp"}"#).unwrap();
        assert_eq!(cfg.topology, Topology::Tree);
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.topology, Topology::Tree);
        assert!(TrainConfig::from_json(r#"{"topology": "mesh"}"#).is_err());
    }

    #[test]
    fn all_optimizer_names_parse_back() {
        for &k in OptimizerKind::all() {
            assert_eq!(parse_optimizer(optimizer_name(k)).unwrap(), k);
        }
    }

    #[test]
    fn bad_values_are_rejected() {
        assert!(TrainConfig::from_json(r#"{"optimizer": "frobnicator"}"#).is_err());
        assert!(TrainConfig::from_json(r#"{"schedule": "spiral"}"#).is_err());
        assert!(TrainConfig::from_json("{nope").is_err());
    }
}
