//! Training coordinator (L3).
//!
//! Owns everything around the compiled compute: configuration, parameter
//! layout, optimizer state (native or AOT-artifact-backed), LR schedules,
//! the train loop itself, checkpoints and metrics. This is the component a
//! downstream user drives via the `microadam` CLI or the library API.

pub mod checkpoint;
pub mod config;
pub mod layout;
pub mod metrics;
pub mod schedule;
pub mod state;
pub mod trainer;
