//! Run metrics: JSONL step logs + summaries (the training-curve figures are
//! regenerated from these files).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::{self, Json};

/// One training-step record.
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    pub elapsed_s: f64,
}

/// JSONL writer (one object per line), plus an in-memory history for
/// summaries and tests.
pub struct MetricsLogger {
    file: Option<BufWriter<File>>,
    start: Instant,
    pub history: Vec<StepMetrics>,
}

impl MetricsLogger {
    /// `path` empty -> memory-only logging.
    pub fn new(path: &str) -> Result<Self> {
        let file = if path.is_empty() {
            None
        } else {
            if let Some(dir) = Path::new(path).parent() {
                std::fs::create_dir_all(dir)?;
            }
            Some(BufWriter::new(File::create(path)?))
        };
        Ok(Self { file, start: Instant::now(), history: Vec::new() })
    }

    /// Write a free-form header record (run provenance: config, etc.).
    pub fn log_header(&mut self, meta: Json) -> Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", meta.to_string())?;
        }
        Ok(())
    }

    pub fn log_step(&mut self, step: u64, loss: f32, lr: f32) -> Result<()> {
        let m = StepMetrics { step, loss, lr, elapsed_s: self.start.elapsed().as_secs_f64() };
        if let Some(f) = &mut self.file {
            let j = json::obj(vec![
                ("step", json::num(step as f64)),
                ("loss", json::num(loss as f64)),
                ("lr", json::num(lr as f64)),
                ("elapsed_s", json::num(m.elapsed_s)),
            ]);
            writeln!(f, "{}", j.to_string())?;
        }
        self.history.push(m);
        Ok(())
    }

    /// Write an arbitrary record (eval accuracy, memory snapshots, ...).
    pub fn log_record(&mut self, j: Json) -> Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", j.to_string())?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    /// Mean loss over the last `n` steps (curve-tail summary).
    pub fn tail_loss(&self, n: usize) -> f32 {
        let h = &self.history;
        if h.is_empty() {
            return f32::NAN;
        }
        let k = n.min(h.len());
        h[h.len() - k..].iter().map(|m| m.loss).sum::<f32>() / k as f32
    }

    /// First-step loss (for improvement assertions).
    pub fn first_loss(&self) -> f32 {
        self.history.first().map(|m| m.loss).unwrap_or(f32::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_only_logger_accumulates() {
        let mut l = MetricsLogger::new("").unwrap();
        for t in 1..=10 {
            l.log_step(t, 1.0 / t as f32, 0.1).unwrap();
        }
        assert_eq!(l.history.len(), 10);
        assert!(l.tail_loss(3) < l.first_loss());
    }

    #[test]
    fn jsonl_file_has_one_object_per_line() {
        let path = "/tmp/microadam_test_metrics.jsonl";
        let _ = std::fs::remove_file(path);
        let mut l = MetricsLogger::new(path).unwrap();
        l.log_header(json::obj(vec![("run", json::s("test"))])).unwrap();
        l.log_step(1, 2.5, 0.1).unwrap();
        l.log_step(2, 2.0, 0.1).unwrap();
        l.flush().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            Json::parse(line).unwrap();
        }
        let rec = Json::parse(lines[2]).unwrap();
        assert_eq!(rec.get("step").unwrap().as_f64(), Some(2.0));
        let _ = std::fs::remove_file(path);
    }
}
