//! Run metrics: JSONL step logs + summaries (the training-curve figures are
//! regenerated from these files).
//!
//! Each step line carries both the cumulative `elapsed_s` and the
//! per-step wall time `step_ms` (the delta since the previous
//! `log_step`), so per-step regressions are visible without
//! differentiating the cumulative clock. The in-memory `history` is a
//! bounded ring ([`MetricsLogger::with_capacity`], default
//! [`DEFAULT_HISTORY_CAP`]): long runs evict the oldest records instead
//! of growing without limit, while the JSONL file always keeps every
//! line.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::{self, Json};

/// Default bound of the in-memory `history` ring. Generous for every
/// in-repo run (tests and benches log a few hundred steps) while keeping
/// a pathological multi-million-step run at a few hundred KB.
pub const DEFAULT_HISTORY_CAP: usize = 4096;

/// One training-step record.
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    /// Seconds since the logger was created (cumulative clock).
    pub elapsed_s: f64,
    /// Wall milliseconds since the previous `log_step` (the first step
    /// measures from logger creation).
    pub step_ms: f64,
}

/// JSONL writer (one object per line), plus a bounded in-memory history
/// for summaries and tests.
pub struct MetricsLogger {
    file: Option<BufWriter<File>>,
    start: Instant,
    /// When the previous `log_step` fired (`step_ms` zero point).
    last: Instant,
    /// Ring bound: `history` never exceeds this many records.
    cap: usize,
    pub history: VecDeque<StepMetrics>,
}

impl MetricsLogger {
    /// `path` empty -> memory-only logging. History bounded at
    /// [`DEFAULT_HISTORY_CAP`].
    pub fn new(path: &str) -> Result<Self> {
        Self::with_capacity(path, DEFAULT_HISTORY_CAP)
    }

    /// `path` empty -> memory-only logging; `cap` bounds the in-memory
    /// `history` ring (oldest records evicted; the JSONL file keeps
    /// everything).
    pub fn with_capacity(path: &str, cap: usize) -> Result<Self> {
        let file = if path.is_empty() {
            None
        } else {
            if let Some(dir) = Path::new(path).parent() {
                std::fs::create_dir_all(dir)?;
            }
            Some(BufWriter::new(File::create(path)?))
        };
        let now = Instant::now();
        Ok(Self {
            file,
            start: now,
            last: now,
            cap: cap.max(1),
            history: VecDeque::new(),
        })
    }

    /// Write a free-form header record (run provenance: config, etc.).
    pub fn log_header(&mut self, meta: Json) -> Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", meta.to_string())?;
        }
        Ok(())
    }

    pub fn log_step(&mut self, step: u64, loss: f32, lr: f32) -> Result<()> {
        let now = Instant::now();
        let m = StepMetrics {
            step,
            loss,
            lr,
            elapsed_s: now.duration_since(self.start).as_secs_f64(),
            step_ms: now.duration_since(self.last).as_secs_f64() * 1e3,
        };
        self.last = now;
        if let Some(f) = &mut self.file {
            let j = json::obj(vec![
                ("step", json::num(step as f64)),
                ("loss", json::num(loss as f64)),
                ("lr", json::num(lr as f64)),
                ("elapsed_s", json::num(m.elapsed_s)),
                ("step_ms", json::num(m.step_ms)),
            ]);
            writeln!(f, "{}", j.to_string())?;
        }
        if self.history.len() == self.cap {
            self.history.pop_front();
        }
        self.history.push_back(m);
        Ok(())
    }

    /// Write an arbitrary record (eval accuracy, memory snapshots, trace
    /// drains, ...).
    pub fn log_record(&mut self, j: Json) -> Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", j.to_string())?;
        }
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    /// Mean loss over the last `n` retained steps (curve-tail summary).
    pub fn tail_loss(&self, n: usize) -> f32 {
        if self.history.is_empty() {
            return f32::NAN;
        }
        let k = n.min(self.history.len());
        self.history.iter().rev().take(k).map(|m| m.loss).sum::<f32>() / k as f32
    }

    /// Loss of the oldest *retained* step (the true first step unless the
    /// ring has evicted it) — for improvement assertions.
    pub fn first_loss(&self) -> f32 {
        self.history.front().map(|m| m.loss).unwrap_or(f32::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_only_logger_accumulates() {
        let mut l = MetricsLogger::new("").unwrap();
        for t in 1..=10 {
            l.log_step(t, 1.0 / t as f32, 0.1).unwrap();
        }
        assert_eq!(l.history.len(), 10);
        assert!(l.tail_loss(3) < l.first_loss());
        // per-step wall time is a positive delta, bounded by the total
        for m in &l.history {
            assert!(m.step_ms >= 0.0);
            assert!(m.step_ms <= m.elapsed_s * 1e3 + 1e-6);
        }
    }

    #[test]
    fn history_ring_is_bounded() {
        let mut l = MetricsLogger::with_capacity("", 4).unwrap();
        for t in 1..=10u64 {
            l.log_step(t, t as f32, 0.1).unwrap();
        }
        assert_eq!(l.history.len(), 4);
        // oldest evicted: steps 7..=10 remain
        assert_eq!(l.history.front().map(|m| m.step), Some(7));
        assert_eq!(l.first_loss(), 7.0);
        assert_eq!(l.tail_loss(2), 9.5);
    }

    #[test]
    fn jsonl_file_has_one_object_per_line() {
        let path = "/tmp/microadam_test_metrics.jsonl";
        let _ = std::fs::remove_file(path);
        let mut l = MetricsLogger::new(path).unwrap();
        l.log_header(json::obj(vec![("run", json::s("test"))])).unwrap();
        l.log_step(1, 2.5, 0.1).unwrap();
        l.log_step(2, 2.0, 0.1).unwrap();
        l.flush().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            Json::parse(line).unwrap();
        }
        let rec = Json::parse(lines[2]).unwrap();
        assert_eq!(rec.get("step").unwrap().as_f64(), Some(2.0));
        assert!(rec.get("step_ms").and_then(Json::as_f64).is_some());
        let _ = std::fs::remove_file(path);
    }
}
