//! The train loop: fwd/bwd artifact -> optimizer (AOT artifact or native) —
//! the end-to-end driver behind `microadam train` and the table harnesses.
//!
//! Data flow per step (AOT backend):
//! ```text
//!   MarkovCorpus/NliDataset/ImageDataset  -> token/image literals
//!   lm_*/cls_*/cnn_* artifact             -> (loss, grads) literals
//!   *_step_d* artifact                    -> new params literal (+ state)
//! ```
//! Parameters stay in a PJRT literal between steps; only the scalar loss is
//! read back on the hot path. With the native backend, gradients round-trip
//! to host Vec<f32>s and any [`crate::optim`] optimizer applies the update.
//!
//! This is the single-process driver; `--ranks N` (and the
//! `--transport uds|shm` multi-process launcher) route through
//! [`crate::dist::DistTrainer`] instead, which wraps the same
//! config/metrics/checkpoint stack around the framed gradient exchange
//! and is pinned bit-identical to this loop at `ranks = 1` + dense.

use anyhow::{bail, Result};

use super::config::{OptBackend, TrainConfig};
use super::layout::ParamLayout;
use super::metrics::MetricsLogger;
use super::state::{AotAdamW8bitState, AotAdamWState, AotMicroAdamState};
use crate::data::{ImageDataset, MarkovCorpus, NliDataset};
use crate::exec::ExecPool;
use crate::optim::{self, Optimizer, OptimizerKind};
use crate::runtime::{self, lit_f32, lit_i32, ArtifactMeta, Literal, Runtime};
use crate::trace;
use crate::util::json;

/// Data source driving the model artifact's batch inputs. Shared with the
/// data-parallel engine ([`crate::dist`]), where each replica owns one
/// stream seeded per rank.
pub(crate) enum Data {
    Lm { corpus: MarkovCorpus, batch: usize, seq: usize },
    Cls { ds: NliDataset, batch: usize, seq: usize },
    Cnn { ds: ImageDataset, batch: usize, image: usize, channels: usize },
}

impl Data {
    /// Build the stream shaped by `meta`'s input signature, seeded with the
    /// already-mixed data seed (see [`Trainer::new`] / `dist::rank_data_seed`).
    pub(crate) fn from_meta(meta: &ArtifactMeta, data_seed: u64) -> Result<Data> {
        match meta.raw.get("model").and_then(crate::util::json::Json::as_str) {
            Some("transformer_lm") => {
                let (b, s) = (meta.inputs[1].2[0], meta.inputs[1].2[1]);
                let vocab = meta.config("vocab").unwrap_or(256.0) as usize;
                Ok(Data::Lm { corpus: MarkovCorpus::new(vocab, data_seed), batch: b, seq: s })
            }
            Some("transformer_cls") => {
                let (b, s) = (meta.inputs[1].2[0], meta.inputs[1].2[1]);
                let vocab = meta.config("vocab").unwrap_or(256.0) as usize;
                let classes = meta.config("n_classes").unwrap_or(3.0) as usize;
                Ok(Data::Cls { ds: NliDataset::new(vocab, classes, data_seed), batch: b, seq: s })
            }
            Some("cnn") => {
                let shape = &meta.inputs[1].2;
                let classes = meta.config("n_classes").unwrap_or(10.0) as usize;
                Ok(Data::Cnn {
                    ds: ImageDataset::new(shape[1], shape[3], classes, data_seed),
                    batch: shape[0],
                    image: shape[1],
                    channels: shape[3],
                })
            }
            other => bail!("{}: unsupported model kind {other:?}", meta.name),
        }
    }

    /// Draw the next batch as artifact input literals.
    pub(crate) fn next_batch_literals(&mut self) -> Result<Vec<Literal>> {
        match self {
            Data::Lm { corpus, batch, seq } => {
                let (mut toks, mut tgts) = (Vec::new(), Vec::new());
                corpus.next_batch(*batch, *seq, &mut toks, &mut tgts);
                Ok(vec![lit_i32(&toks, &[*batch, *seq])?, lit_i32(&tgts, &[*batch, *seq])?])
            }
            Data::Cls { ds, batch, seq } => {
                let (mut toks, mut labs) = (Vec::new(), Vec::new());
                ds.next_batch(*batch, *seq, &mut toks, &mut labs);
                Ok(vec![lit_i32(&toks, &[*batch, *seq])?, lit_i32(&labs, &[*batch])?])
            }
            Data::Cnn { ds, batch, image, channels } => {
                let (mut imgs, mut labs) = (Vec::new(), Vec::new());
                ds.next_batch(*batch, &mut imgs, &mut labs);
                Ok(vec![
                    lit_f32(&imgs, &[*batch, *image, *image, *channels])?,
                    lit_i32(&labs, &[*batch])?,
                ])
            }
        }
    }
}

enum Opt {
    AotMicroAdam(AotMicroAdamState),
    AotAdamW(AotAdamWState),
    AotAdamW8bit(AotAdamW8bitState),
    Native(Box<dyn Optimizer>),
}

impl Opt {
    fn paper_state_bytes(&self) -> usize {
        match self {
            Opt::AotMicroAdam(s) => s.paper_state_bytes(),
            Opt::AotAdamW(s) => s.paper_state_bytes(),
            Opt::AotAdamW8bit(s) => s.paper_state_bytes(),
            Opt::Native(o) => o.paper_state_bytes(),
        }
    }

    /// Measured resident state bytes — native backends only (AOT state
    /// lives in PJRT literals whose footprint the client owns).
    fn resident_state_bytes(&self) -> Option<usize> {
        match self {
            Opt::Native(o) => Some(o.state_bytes()),
            _ => None,
        }
    }
}

/// End-to-end trainer over one model artifact.
pub struct Trainer {
    pub cfg: TrainConfig,
    rt: Runtime,
    pub layout: ParamLayout,
    /// Canonical parameters: a PJRT literal between steps.
    params: Literal,
    opt: Opt,
    data: Data,
    /// Worker pool for the native block-sharded optimizer hot path.
    pool: ExecPool,
    pub t: u64,
    accum_scratch: Vec<f32>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let meta = rt.meta(&cfg.model)?.clone();
        let layout = meta.layout()?;
        let d = layout.d_padded;

        // Data source shaped from the artifact's input signature.
        let data = Data::from_meta(&meta, cfg.seed ^ 0xda7a)?;

        // Optimizer backend.
        let opt = match cfg.backend {
            OptBackend::Aot => {
                let art = match cfg.optimizer {
                    OptimizerKind::MicroAdam => format!("microadam_step_d{d}"),
                    OptimizerKind::Adam | OptimizerKind::AdamW => format!("adamw_step_d{d}"),
                    OptimizerKind::AdamW8bit => format!("adamw8bit_step_d{d}"),
                    other => bail!("optimizer {other:?} has no AOT artifact; use backend=native"),
                };
                if !rt.has(&art) {
                    bail!("artifact {art} not found — re-run `make artifacts`");
                }
                let ometa = rt.meta(&art)?.clone();
                match cfg.optimizer {
                    OptimizerKind::MicroAdam => Opt::AotMicroAdam(AotMicroAdamState::new(&ometa)?),
                    OptimizerKind::Adam | OptimizerKind::AdamW => {
                        Opt::AotAdamW(AotAdamWState::new(&ometa)?)
                    }
                    _ => Opt::AotAdamW8bit(AotAdamW8bitState::new(&ometa)?),
                }
            }
            OptBackend::Native => Opt::Native(optim::build(
                cfg.optimizer,
                d,
                &layout.tensors,
                cfg.weight_decay,
            )),
        };

        let flat = layout.init_flat(cfg.seed);
        let params = lit_f32(&flat, &[d])?;
        let pool = if cfg.workers == 0 {
            ExecPool::auto_with(cfg.pin_workers)
        } else {
            ExecPool::new_with(cfg.workers, cfg.pin_workers)
        };
        Ok(Self {
            cfg,
            rt,
            layout,
            params,
            opt,
            data,
            pool,
            t: 0,
            accum_scratch: vec![0.0; d],
        })
    }

    /// Current parameters read back to host.
    pub fn params_vec(&self) -> Result<Vec<f32>> {
        runtime::to_f32(&self.params)
    }

    /// Replace parameters (checkpoint resume). The length must match the
    /// layout exactly — a truncated or foreign checkpoint would otherwise
    /// silently corrupt the run.
    pub fn set_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.layout.d_padded {
            bail!(
                "set_params: {} values, but model {} has d_padded = {} — \
                 checkpoint does not match this model/layout",
                flat.len(),
                self.cfg.model,
                self.layout.d_padded
            );
        }
        self.params = lit_f32(flat, &[self.layout.d_padded])?;
        Ok(())
    }

    /// Paper-dtype optimizer state footprint in bytes.
    pub fn opt_state_bytes(&self) -> usize {
        self.opt.paper_state_bytes()
    }

    /// Measured resident optimizer-state bytes (allocated buffers), when
    /// the backend is native; `None` for AOT state held in PJRT literals.
    pub fn opt_resident_bytes(&self) -> Option<usize> {
        self.opt.resident_state_bytes()
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.rt
    }

    /// Typed optimizer-state snapshot for the checkpoint format: AOT
    /// MicroAdam reads its literals back to host; native optimizers
    /// delegate to [`Optimizer::snapshot_state`]. `None` when the backend
    /// keeps no checkpointable state (AOT AdamW/AdamW8bit).
    pub fn opt_snapshot(&self) -> Result<Option<optim::OptSnapshot>> {
        match &self.opt {
            Opt::AotMicroAdam(s) => Ok(Some(optim::OptSnapshot::MicroAdam(s.snapshot()?))),
            Opt::Native(o) => Ok(o.snapshot_state()),
            _ => Ok(None),
        }
    }

    /// Restore an optimizer-state snapshot (checkpoint resume). A snapshot
    /// kind that does not match the configured optimizer is a typed error —
    /// resuming with mismatched state would silently fork the trajectory.
    pub fn restore_opt_snapshot(&mut self, snap: &optim::OptSnapshot) -> Result<()> {
        match &mut self.opt {
            Opt::AotMicroAdam(s) => match snap {
                optim::OptSnapshot::MicroAdam(ms) => s.restore(ms),
                other => bail!(
                    "AOT micro-adam cannot restore a {} snapshot",
                    other.kind_name()
                ),
            },
            Opt::Native(o) => o.restore_state(snap),
            _ => bail!(
                "optimizer backend for {:?} keeps no checkpointable state",
                self.cfg.optimizer
            ),
        }
    }

    pub fn microadam_state(&self) -> Option<&AotMicroAdamState> {
        match &self.opt {
            Opt::AotMicroAdam(s) => Some(s),
            _ => None,
        }
    }

    pub fn microadam_state_mut(&mut self) -> Option<&mut AotMicroAdamState> {
        match &mut self.opt {
            Opt::AotMicroAdam(s) => Some(s),
            _ => None,
        }
    }

    fn next_batch_literals(&mut self) -> Result<Vec<Literal>> {
        self.data.next_batch_literals()
    }

    /// One optimizer step (with `grad_accum` fwd/bwd micro-steps): returns
    /// the mean micro-loss.
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        self.t += 1;
        let accum = self.cfg.grad_accum.max(1);
        let mut loss_sum = 0f32;
        let mut grads_lit: Option<Literal> = None;
        if accum > 1 {
            self.accum_scratch.fill(0.0);
        }
        for _ in 0..accum {
            let mut inputs = vec![self.params.clone()];
            inputs.extend(self.next_batch_literals()?);
            let mut outs = self.rt.execute_named(&self.cfg.model, &inputs)?;
            let g = outs.pop().unwrap();
            let loss = outs.pop().unwrap();
            loss_sum += runtime::scalar_f32(&loss)?;
            if accum == 1 {
                grads_lit = Some(g);
            } else {
                // host-side accumulation (the grad-accum path trades one
                // readback per micro-step for a batch-size-free artifact)
                let gv = runtime::to_f32(&g)?;
                for (a, b) in self.accum_scratch.iter_mut().zip(&gv) {
                    *a += *b / accum as f32;
                }
            }
        }
        let grads = match grads_lit {
            Some(g) => g,
            None => lit_f32(&self.accum_scratch, &[self.layout.d_padded])?,
        };

        let params = std::mem::replace(&mut self.params, runtime::empty_f32());
        let wd = self.cfg.weight_decay;
        self.params = match &mut self.opt {
            Opt::AotMicroAdam(s) => s.step(&mut self.rt, params, grads, lr, wd)?,
            Opt::AotAdamW(s) => s.step(&mut self.rt, params, grads, lr, wd)?,
            Opt::AotAdamW8bit(s) => s.step(&mut self.rt, params, grads, lr, wd)?,
            Opt::Native(o) => {
                let mut pv = runtime::to_f32(&params)?;
                let gv = runtime::to_f32(&grads)?;
                // Real per-tensor boundaries from the layout, so
                // tensor-aware optimizers see the model's structure
                // (single-tensor layouts keep the zero-copy flat path).
                optim::step_with_layout(
                    o.as_mut(),
                    &self.layout.tensors,
                    self.layout.d_padded,
                    &mut pv,
                    &gv,
                    lr,
                    &self.pool,
                );
                lit_f32(&pv, &[self.layout.d_padded])?
            }
        };
        Ok(loss_sum / accum as f32)
    }

    /// Per-step coordinator gauges into the trace sink: optimizer state
    /// footprint normalized per parameter (paper accounting, and the
    /// measured resident bytes when the backend is native).
    fn emit_gauges(&self) {
        let d = self.layout.d_padded.max(1) as f64;
        trace::gauge("coord.paper_bytes_per_param", self.opt.paper_state_bytes() as f64 / d);
        if let Some(resident) = self.opt.resident_state_bytes() {
            trace::gauge("coord.resident_bytes_per_param", resident as f64 / d);
        }
    }

    /// Run the configured number of steps, logging to `logger`.
    pub fn train(&mut self, logger: &mut MetricsLogger) -> Result<()> {
        logger.log_header(self.cfg.to_json())?;
        let steps = self.cfg.steps;
        for step in 1..=steps {
            let lr = self.cfg.schedule.lr(step);
            let loss = self.step(lr)?;
            if !loss.is_finite() {
                bail!("non-finite loss at step {step}");
            }
            logger.log_step(step, loss, lr)?;
            if trace::enabled() {
                self.emit_gauges();
                for rec in trace::drain_step_records(step) {
                    logger.log_record(rec)?;
                }
            }
            if step % self.cfg.log_every == 0 || step == steps {
                eprintln!(
                    "[train {} {}] step {step}/{steps} loss {loss:.4} lr {lr:.2e}",
                    self.cfg.model,
                    super::config::optimizer_name(self.cfg.optimizer),
                );
            }
        }
        logger.log_record(json::obj(vec![
            ("final_loss", json::num(logger.tail_loss(10) as f64)),
            ("opt_state_bytes", json::num(self.opt_state_bytes() as f64)),
        ]))?;
        logger.flush()?;
        Ok(())
    }

    /// Classifier eval accuracy using the `<model>_logits` artifact over
    /// `batches` fresh batches. `batches` must be positive; NaN logits
    /// count as misses instead of panicking.
    pub fn eval_accuracy(&mut self, batches: usize) -> Result<f32> {
        let logits_name = format!("{}_logits", self.cfg.model);
        if !self.rt.has(&logits_name) {
            bail!("{logits_name} artifact not available");
        }
        if batches == 0 {
            bail!("eval_accuracy: empty eval (batches == 0)");
        }
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..batches {
            let batch_lits = self.next_batch_literals()?;
            let labels: Vec<i32> = match &self.data {
                Data::Lm { .. } => bail!("eval_accuracy is for classifier models"),
                _ => runtime::to_i32(batch_lits.last().unwrap())?,
            };
            let inputs = vec![self.params.clone(), batch_lits[0].clone()];
            let outs = self.rt.execute_named(&logits_name, &inputs)?;
            let logits = runtime::to_f32(&outs[0])?;
            let classes = logits.len() / labels.len();
            for (n, &lab) in labels.iter().enumerate() {
                let row = &logits[n * classes..(n + 1) * classes];
                let pred = argmax_nan_tolerant(row);
                correct += (pred == lab as usize) as usize;
                total += 1;
            }
        }
        if total == 0 {
            bail!("eval_accuracy: eval batches held no examples");
        }
        Ok(correct as f32 / total as f32)
    }
}

/// Index of the largest finite entry; NaNs never win the comparison, so a
/// diverged model no longer panics in `partial_cmp`. An all-NaN row falls
/// back to class 0 (and so still scores a hit on label-0 examples — the
/// caller's non-finite-loss bail is the real divergence guard).
pub(crate) fn argmax_nan_tolerant(row: &[f32]) -> usize {
    let mut pred = 0usize;
    let mut best = f32::NEG_INFINITY;
    for (c, &v) in row.iter().enumerate() {
        if v > best {
            best = v;
            pred = c;
        }
    }
    pred
}

#[cfg(test)]
mod tests {
    use super::argmax_nan_tolerant;

    #[test]
    fn argmax_ignores_nans() {
        assert_eq!(argmax_nan_tolerant(&[0.1, 0.7, 0.3]), 1);
        assert_eq!(argmax_nan_tolerant(&[f32::NAN, 0.2, 0.1]), 1);
        assert_eq!(argmax_nan_tolerant(&[0.2, f32::NAN, 0.5]), 2);
        // all-NaN falls back to class 0 rather than panicking
        assert_eq!(argmax_nan_tolerant(&[f32::NAN, f32::NAN]), 0);
        // -inf rows still resolve
        assert_eq!(argmax_nan_tolerant(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax_nan_tolerant(&[]), 0);
    }
}
