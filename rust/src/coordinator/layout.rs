//! Parameter layout manager: the flat-vector view of a model.
//!
//! The L2 graphs operate on one flat, block-padded f32 vector; the manifest
//! records every tensor's name/shape/offset/init so the rust side can
//! initialize, inspect and (for shaped optimizers like GaLore/AdaFactor)
//! re-slice parameters without python.

use crate::util::rng::Rng;

/// One named tensor inside the flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl TensorSpec {
    pub fn new(name: &str, shape: &[usize], offset: usize) -> Self {
        Self { name: name.to_string(), shape: shape.to_vec(), offset }
    }

    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }

    /// (rows, cols) view for 2-D tensors, None otherwise.
    pub fn as_matrix(&self) -> Option<(usize, usize)> {
        if self.shape.len() == 2 {
            Some((self.shape[0], self.shape[1]))
        } else {
            None
        }
    }
}

/// Init scheme for one tensor (mirrors the manifest's `init` field).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Init {
    Normal,
    Zeros,
    Ones,
}

/// Full parameter layout: specs plus init metadata and padding.
#[derive(Debug, Clone)]
pub struct ParamLayout {
    pub tensors: Vec<TensorSpec>,
    pub inits: Vec<(Init, f32)>,
    /// Total model parameters (sum of tensor sizes).
    pub d_model: usize,
    /// Padded flat-vector length (multiple of the optimizer tile).
    pub d_padded: usize,
}

impl ParamLayout {
    pub fn new(tensors: Vec<TensorSpec>, inits: Vec<(Init, f32)>, d_padded: usize) -> Self {
        let d_model = tensors.iter().map(|t| t.size()).sum();
        assert!(d_padded >= d_model, "padding smaller than model");
        assert_eq!(tensors.len(), inits.len());
        Self { tensors, inits, d_model, d_padded }
    }

    /// Initialize a fresh padded flat parameter vector (seeded, reproducible).
    pub fn init_flat(&self, seed: u64) -> Vec<f32> {
        let mut flat = vec![0f32; self.d_padded];
        let mut rng = Rng::seed_from_u64(seed);
        for (spec, &(init, std)) in self.tensors.iter().zip(&self.inits) {
            let s = &mut flat[spec.offset..spec.offset + spec.size()];
            match init {
                Init::Zeros => s.fill(0.0),
                Init::Ones => s.fill(1.0),
                Init::Normal => {
                    for v in s.iter_mut() {
                        *v = gauss(&mut rng) * std;
                    }
                }
            }
        }
        flat
    }

    /// View one tensor inside a flat vector.
    pub fn tensor<'a>(&self, flat: &'a [f32], name: &str) -> Option<&'a [f32]> {
        let spec = self.tensors.iter().find(|t| t.name == name)?;
        Some(&flat[spec.offset..spec.offset + spec.size()])
    }

    /// Validate internal consistency: contiguous offsets, unique names.
    pub fn validate(&self) -> Result<(), String> {
        let mut off = 0;
        let mut names = std::collections::HashSet::new();
        for t in &self.tensors {
            if t.offset != off {
                return Err(format!("tensor {} offset {} != expected {off}", t.name, t.offset));
            }
            if !names.insert(&t.name) {
                return Err(format!("duplicate tensor name {}", t.name));
            }
            off += t.size();
        }
        if off != self.d_model {
            return Err(format!("sizes sum {off} != d_model {}", self.d_model));
        }
        Ok(())
    }
}

fn gauss(rng: &mut Rng) -> f32 {
    rng.gauss()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> ParamLayout {
        ParamLayout::new(
            vec![
                TensorSpec::new("w1", &[4, 8], 0),
                TensorSpec::new("b1", &[8], 32),
                TensorSpec::new("w2", &[8, 2], 40),
            ],
            vec![(Init::Normal, 0.02), (Init::Zeros, 0.0), (Init::Normal, 0.1)],
            64,
        )
    }

    #[test]
    fn layout_accounting() {
        let l = layout();
        assert_eq!(l.d_model, 56);
        assert_eq!(l.d_padded, 64);
        l.validate().unwrap();
    }

    #[test]
    fn init_respects_schemes_and_padding() {
        let l = layout();
        let flat = l.init_flat(0);
        assert_eq!(flat.len(), 64);
        // b1 zeros
        assert!(flat[32..40].iter().all(|&v| v == 0.0));
        // w1 nonzero with ~0.02 scale
        let w1 = l.tensor(&flat, "w1").unwrap();
        assert!(w1.iter().any(|&v| v != 0.0));
        assert!(w1.iter().all(|&v| v.abs() < 0.2));
        // padding zeros
        assert!(flat[56..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let l = layout();
        assert_eq!(l.init_flat(7), l.init_flat(7));
        assert_ne!(l.init_flat(7), l.init_flat(8));
    }

    #[test]
    fn validate_catches_gap() {
        let l = ParamLayout {
            tensors: vec![TensorSpec::new("a", &[4], 0), TensorSpec::new("b", &[4], 8)],
            inits: vec![(Init::Zeros, 0.0), (Init::Zeros, 0.0)],
            d_model: 8,
            d_padded: 16,
        };
        assert!(l.validate().is_err());
    }
}
