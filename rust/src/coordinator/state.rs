//! AOT optimizer-state managers: the rust-owned buffers behind the
//! `*_step_d*` artifacts.
//!
//! State lives in PJRT [`Literal`]s between steps (no per-step host
//! round-trips); the coordinator swaps in the step artifact's outputs and
//! only reads buffers back for checkpoints or inspection. Shapes come from
//! the manifest's `hyper` block and are validated by the runtime on every
//! execute.
//!
//! [`MicroAdamSnapshot`] is the backend-neutral host copy both engines
//! (AOT and native) serialize through the checkpoint format — the
//! data-parallel [`crate::dist::DistTrainer`] persists params-only
//! checkpoints through the same format, so a dist run can seed a
//! single-process fine-tune and vice versa.

use anyhow::{anyhow, Result};

use crate::runtime::{
    self, empty_f32, empty_i32, empty_u8, lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32,
    lit_u8, ArtifactMeta, Literal, Runtime,
};

/// MicroAdam artifact state: 4-bit EF + quant stats + sliding window.
pub struct AotMicroAdamState {
    pub d: usize,
    pub m: usize,
    pub nb: usize,
    pub kb: usize,
    pub nq: usize,
    artifact: String,
    ef: Literal,
    qlo: Literal,
    qhi: Literal,
    w_idx: Literal,
    w_val: Literal,
    pub t: u64,
}

impl AotMicroAdamState {
    pub fn new(meta: &ArtifactMeta) -> Result<Self> {
        let get = |k: &str| {
            meta.hyper(k)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("{}: missing hyper.{k}", meta.name))
        };
        let d = get("d")?;
        let m = get("m")?;
        let nb = get("nb")?;
        let kb = get("kb")?;
        let qbucket = get("qbucket")?;
        let nq = d / qbucket;
        Ok(Self {
            d,
            m,
            nb,
            kb,
            nq,
            artifact: meta.name.clone(),
            ef: lit_u8(&vec![0u8; d / 2], &[d / 2])?,
            qlo: lit_f32(&vec![0f32; nq], &[nq])?,
            qhi: lit_f32(&vec![0f32; nq], &[nq])?,
            w_idx: lit_i32(&vec![0i32; m * nb * kb], &[m, nb, kb])?,
            w_val: lit_f32(&vec![0f32; m * nb * kb], &[m, nb, kb])?,
            t: 0,
        })
    }

    /// One optimizer step: consumes the params and grads literals (grads
    /// straight from the fwd/bwd artifact — no host round-trip) and returns
    /// the updated params literal. Internal state literals are replaced.
    pub fn step(
        &mut self,
        rt: &mut Runtime,
        params: Literal,
        grads: Literal,
        lr: f32,
        wd: f32,
    ) -> Result<Literal> {
        self.t += 1;
        let inputs = [
            params,
            grads,
            std::mem::replace(&mut self.ef, empty_u8()),
            std::mem::replace(&mut self.qlo, empty_f32()),
            std::mem::replace(&mut self.qhi, empty_f32()),
            std::mem::replace(&mut self.w_idx, empty_i32()),
            std::mem::replace(&mut self.w_val, empty_f32()),
            lit_scalar_i32(self.t as i32)?,
            lit_scalar_f32(lr)?,
            lit_scalar_f32(wd)?,
        ];
        let mut outs = rt.execute_named(&self.artifact, &inputs)?;
        // outputs: params, ef, qlo, qhi, w_idx, w_val
        self.w_val = outs.pop().unwrap();
        self.w_idx = outs.pop().unwrap();
        self.qhi = outs.pop().unwrap();
        self.qlo = outs.pop().unwrap();
        self.ef = outs.pop().unwrap();
        Ok(outs.pop().unwrap())
    }

    /// Persistent state bytes with the paper's storage dtypes
    /// (`0.5 d + 4 m k`, §3.2).
    pub fn paper_state_bytes(&self) -> usize {
        self.d / 2 + 4 * self.m * self.nb * self.kb
    }

    /// Read the EF + window buffers back to host (for checkpoints/tests).
    pub fn snapshot(&self) -> Result<MicroAdamSnapshot> {
        Ok(MicroAdamSnapshot {
            ef: runtime::to_u8(&self.ef)?,
            qlo: runtime::to_f32(&self.qlo)?,
            qhi: runtime::to_f32(&self.qhi)?,
            w_idx: runtime::to_i32(&self.w_idx)?,
            w_val: runtime::to_f32(&self.w_val)?,
            // the L2 graph keeps f32 window values
            w_bf16: false,
            t: self.t,
        })
    }

    /// Restore a snapshot (checkpoint resume).
    pub fn restore(&mut self, s: &MicroAdamSnapshot) -> Result<()> {
        self.ef = lit_u8(&s.ef, &[self.d / 2])?;
        self.qlo = lit_f32(&s.qlo, &[self.nq])?;
        self.qhi = lit_f32(&s.qhi, &[self.nq])?;
        self.w_idx = lit_i32(&s.w_idx, &[self.m, self.nb, self.kb])?;
        self.w_val = lit_f32(&s.w_val, &[self.m, self.nb, self.kb])?;
        self.t = s.t;
        Ok(())
    }
}

/// Host-side copy of the MicroAdam state (checkpoint payload).
#[derive(Debug, Clone, PartialEq)]
pub struct MicroAdamSnapshot {
    pub ef: Vec<u8>,
    pub qlo: Vec<f32>,
    pub qhi: Vec<f32>,
    pub w_idx: Vec<i32>,
    /// Window values widened to f32 (exact for bf16-origin windows).
    pub w_val: Vec<f32>,
    /// Whether the originating window stored bf16 (native default) or f32
    /// (AOT state, native baseline mode). Restore refuses a dtype switch —
    /// it would silently break the bit-exact-resume contract.
    pub w_bf16: bool,
    pub t: u64,
}

/// AdamW artifact state: dense fp32 m/v literals.
pub struct AotAdamWState {
    pub d: usize,
    artifact: String,
    m: Literal,
    v: Literal,
    pub t: u64,
}

impl AotAdamWState {
    pub fn new(meta: &ArtifactMeta) -> Result<Self> {
        let d = meta.hyper("d").map(|v| v as usize).ok_or_else(|| anyhow!("missing hyper.d"))?;
        Ok(Self {
            d,
            artifact: meta.name.clone(),
            m: lit_f32(&vec![0f32; d], &[d])?,
            v: lit_f32(&vec![0f32; d], &[d])?,
            t: 0,
        })
    }

    pub fn step(
        &mut self,
        rt: &mut Runtime,
        params: Literal,
        grads: Literal,
        lr: f32,
        wd: f32,
    ) -> Result<Literal> {
        self.t += 1;
        let inputs = [
            params,
            grads,
            std::mem::replace(&mut self.m, empty_f32()),
            std::mem::replace(&mut self.v, empty_f32()),
            lit_scalar_i32(self.t as i32)?,
            lit_scalar_f32(lr)?,
            lit_scalar_f32(wd)?,
        ];
        let mut outs = rt.execute_named(&self.artifact, &inputs)?;
        self.v = outs.pop().unwrap();
        self.m = outs.pop().unwrap();
        Ok(outs.pop().unwrap())
    }

    pub fn paper_state_bytes(&self) -> usize {
        8 * self.d
    }
}

/// AdamW-8bit artifact state: u8 m/v codes + per-bucket scales.
pub struct AotAdamW8bitState {
    pub d: usize,
    nq8: usize,
    artifact: String,
    m8: Literal,
    mscale: Literal,
    v8: Literal,
    vscale: Literal,
    pub t: u64,
}

impl AotAdamW8bitState {
    pub fn new(meta: &ArtifactMeta) -> Result<Self> {
        let d = meta.hyper("d").map(|v| v as usize).ok_or_else(|| anyhow!("missing hyper.d"))?;
        let nq8 = d / 256;
        Ok(Self {
            d,
            nq8,
            artifact: meta.name.clone(),
            // code 128 == 0.0 in the signed dynamic table
            m8: lit_u8(&vec![128u8; d], &[d])?,
            mscale: lit_f32(&vec![0f32; nq8], &[nq8])?,
            v8: lit_u8(&vec![0u8; d], &[d])?,
            vscale: lit_f32(&vec![0f32; nq8], &[nq8])?,
            t: 0,
        })
    }

    pub fn step(
        &mut self,
        rt: &mut Runtime,
        params: Literal,
        grads: Literal,
        lr: f32,
        wd: f32,
    ) -> Result<Literal> {
        self.t += 1;
        let inputs = [
            params,
            grads,
            std::mem::replace(&mut self.m8, empty_u8()),
            std::mem::replace(&mut self.mscale, empty_f32()),
            std::mem::replace(&mut self.v8, empty_u8()),
            std::mem::replace(&mut self.vscale, empty_f32()),
            lit_scalar_i32(self.t as i32)?,
            lit_scalar_f32(lr)?,
            lit_scalar_f32(wd)?,
        ];
        let mut outs = rt.execute_named(&self.artifact, &inputs)?;
        self.vscale = outs.pop().unwrap();
        self.v8 = outs.pop().unwrap();
        self.mscale = outs.pop().unwrap();
        self.m8 = outs.pop().unwrap();
        Ok(outs.pop().unwrap())
    }

    pub fn paper_state_bytes(&self) -> usize {
        2 * self.d + 8 * self.nq8
    }
}
