//! Learning-rate schedules (constant, linear warmup + cosine decay — the
//! recipe the paper uses for the ImageNet runs, §B.3).

/// LR schedule over 1-based steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Constant `lr`.
    Const { lr: f32 },
    /// Linear warmup for `warmup` steps to `lr`, then cosine decay to
    /// `lr * floor_frac` at `total`.
    WarmupCosine { lr: f32, warmup: u64, total: u64, floor_frac: f32 },
    /// Linear decay from `lr` to zero over `total`.
    LinearDecay { lr: f32, total: u64 },
}

impl LrSchedule {
    pub fn lr(&self, t: u64) -> f32 {
        match *self {
            LrSchedule::Const { lr } => lr,
            LrSchedule::WarmupCosine { lr, warmup, total, floor_frac } => {
                if warmup > 0 && t <= warmup {
                    return lr * t as f32 / warmup as f32;
                }
                let total = total.max(warmup + 1);
                let p = ((t - warmup) as f32 / (total - warmup) as f32).clamp(0.0, 1.0);
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * p).cos());
                lr * (floor_frac + (1.0 - floor_frac) * cos)
            }
            LrSchedule::LinearDecay { lr, total } => {
                lr * (1.0 - (t.min(total) - 1) as f32 / total as f32)
            }
        }
    }

    /// Peak learning rate.
    pub fn peak(&self) -> f32 {
        match *self {
            LrSchedule::Const { lr }
            | LrSchedule::WarmupCosine { lr, .. }
            | LrSchedule::LinearDecay { lr, .. } => lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Const { lr: 0.1 };
        assert_eq!(s.lr(1), 0.1);
        assert_eq!(s.lr(1000), 0.1);
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let s = LrSchedule::WarmupCosine { lr: 1.0, warmup: 10, total: 110, floor_frac: 0.0 };
        assert!((s.lr(5) - 0.5).abs() < 1e-6);
        assert!((s.lr(10) - 1.0).abs() < 1e-6);
        assert!(s.lr(60) < 1.0);
        assert!(s.lr(110) < 0.01);
        // monotone decay after warmup
        let mut prev = s.lr(10);
        for t in 11..=110 {
            let cur = s.lr(t);
            assert!(cur <= prev + 1e-6);
            prev = cur;
        }
    }

    #[test]
    fn cosine_floor_is_respected() {
        let s = LrSchedule::WarmupCosine { lr: 1.0, warmup: 0, total: 100, floor_frac: 0.1 };
        assert!(s.lr(100) >= 0.1 - 1e-6);
    }

    #[test]
    fn linear_decay_hits_near_zero() {
        let s = LrSchedule::LinearDecay { lr: 1.0, total: 100 };
        assert!((s.lr(1) - 1.0).abs() < 1e-6);
        assert!(s.lr(100) < 0.02);
    }
}
