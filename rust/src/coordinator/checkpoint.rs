//! Binary checkpoints: parameters + step counter + a typed optimizer-state
//! payload, so a resumed run continues bit-exactly.
//!
//! Format (little-endian):
//! ```text
//!   magic "MADM" | version u32 | step u64 | d u64 | params f32[d]
//!   | opt tag u8 | [tagged optimizer state]
//!       tag 0: none (params-only)
//!       tag 1: MicroAdam  — ef len u64, ef bytes, qlo/qhi f32,
//!                           w_idx i32, w_val f32 lens + payloads,
//!                           w_bf16 u8, t u64
//!       tag 2: LDAdam     — proj/m/v f32 lens + payloads, ef len u64 +
//!                           bytes, qlo len u64 + qlo/qhi f32, t u64
//!       tag 3: Adam-mini  — m/v f32 lens + payloads, t u64
//! ```
//! Version 2 added the `w_bf16` window-dtype marker (native windows store
//! bf16 by default since PR 3; restore refuses a silent dtype switch).
//! Version 3 turned the `has_opt` byte into the optimizer-state tag above
//! (values 0/1 keep their v2 meaning, so v2 files still load).

use std::io::{Read, Write};

use anyhow::{bail, Result};

use super::state::MicroAdamSnapshot;
use crate::optim::adammini::AdamMiniSnapshot;
use crate::optim::ldadam::LdAdamSnapshot;
use crate::optim::OptSnapshot;

const MAGIC: &[u8; 4] = b"MADM";
const VERSION: u32 = 3;
/// Oldest version `load` still accepts (tag values 0/1 are unchanged).
const MIN_VERSION: u32 = 2;

/// A checkpoint payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
    pub opt: Option<OptSnapshot>,
}

impl Checkpoint {
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        write_f32s(&mut f, &self.params)?;
        match &self.opt {
            None => f.write_all(&[0u8])?,
            Some(OptSnapshot::MicroAdam(s)) => {
                f.write_all(&[1u8])?;
                f.write_all(&(s.ef.len() as u64).to_le_bytes())?;
                f.write_all(&s.ef)?;
                f.write_all(&(s.qlo.len() as u64).to_le_bytes())?;
                write_f32s(&mut f, &s.qlo)?;
                write_f32s(&mut f, &s.qhi)?;
                f.write_all(&(s.w_idx.len() as u64).to_le_bytes())?;
                write_i32s(&mut f, &s.w_idx)?;
                write_f32s(&mut f, &s.w_val)?;
                f.write_all(&[u8::from(s.w_bf16)])?;
                f.write_all(&s.t.to_le_bytes())?;
            }
            Some(OptSnapshot::LdAdam(s)) => {
                f.write_all(&[2u8])?;
                for xs in [&s.proj, &s.m, &s.v] {
                    f.write_all(&(xs.len() as u64).to_le_bytes())?;
                    write_f32s(&mut f, xs)?;
                }
                f.write_all(&(s.ef.len() as u64).to_le_bytes())?;
                f.write_all(&s.ef)?;
                f.write_all(&(s.qlo.len() as u64).to_le_bytes())?;
                write_f32s(&mut f, &s.qlo)?;
                write_f32s(&mut f, &s.qhi)?;
                f.write_all(&s.t.to_le_bytes())?;
            }
            Some(OptSnapshot::AdamMini(s)) => {
                f.write_all(&[3u8])?;
                for xs in [&s.m, &s.v] {
                    f.write_all(&(xs.len() as u64).to_le_bytes())?;
                    write_f32s(&mut f, xs)?;
                }
                f.write_all(&s.t.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &str) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path}: not a microadam checkpoint");
        }
        let version = read_u32(&mut f)?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            bail!("{path}: checkpoint version {version}, expected {MIN_VERSION}..={VERSION}");
        }
        let step = read_u64(&mut f)?;
        let d = read_u64(&mut f)? as usize;
        let params = read_f32s(&mut f, d)?;
        let mut tag = [0u8];
        f.read_exact(&mut tag)?;
        let opt = match tag[0] {
            0 => None,
            1 => {
                let ef_len = read_u64(&mut f)? as usize;
                let mut ef = vec![0u8; ef_len];
                f.read_exact(&mut ef)?;
                let nq = read_u64(&mut f)? as usize;
                let qlo = read_f32s(&mut f, nq)?;
                let qhi = read_f32s(&mut f, nq)?;
                let wlen = read_u64(&mut f)? as usize;
                let w_idx = read_i32s(&mut f, wlen)?;
                let w_val = read_f32s(&mut f, wlen)?;
                let mut w_bf16 = [0u8];
                f.read_exact(&mut w_bf16)?;
                let t = read_u64(&mut f)?;
                Some(OptSnapshot::MicroAdam(MicroAdamSnapshot {
                    ef,
                    qlo,
                    qhi,
                    w_idx,
                    w_val,
                    w_bf16: w_bf16[0] != 0,
                    t,
                }))
            }
            2 => {
                let plen = read_u64(&mut f)? as usize;
                let proj = read_f32s(&mut f, plen)?;
                let mlen = read_u64(&mut f)? as usize;
                let m = read_f32s(&mut f, mlen)?;
                let vlen = read_u64(&mut f)? as usize;
                let v = read_f32s(&mut f, vlen)?;
                let ef_len = read_u64(&mut f)? as usize;
                let mut ef = vec![0u8; ef_len];
                f.read_exact(&mut ef)?;
                let nq = read_u64(&mut f)? as usize;
                let qlo = read_f32s(&mut f, nq)?;
                let qhi = read_f32s(&mut f, nq)?;
                let t = read_u64(&mut f)?;
                Some(OptSnapshot::LdAdam(LdAdamSnapshot { proj, m, v, ef, qlo, qhi, t }))
            }
            3 => {
                let mlen = read_u64(&mut f)? as usize;
                let m = read_f32s(&mut f, mlen)?;
                let vlen = read_u64(&mut f)? as usize;
                let v = read_f32s(&mut f, vlen)?;
                let t = read_u64(&mut f)?;
                Some(OptSnapshot::AdamMini(AdamMiniSnapshot { m, v, t }))
            }
            other => bail!("{path}: unknown optimizer-state tag {other}"),
        };
        Ok(Checkpoint { step, params, opt })
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // SAFETY: `f32` is plain-old-data with no padding, so viewing the
    // slice as `xs.len() * 4` initialized bytes is valid; the borrow is
    // consumed by `write_all` before `xs` can move or drop.
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn write_i32s<W: Write>(w: &mut W, xs: &[i32]) -> Result<()> {
    // SAFETY: `i32` is plain-old-data with no padding, so viewing the
    // slice as `xs.len() * 4` initialized bytes is valid; the borrow is
    // consumed by `write_all` before `xs` can move or drop.
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn read_i32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<i32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_without_opt_state() {
        let ck = Checkpoint { step: 42, params: vec![1.0, -2.5, 3.25], opt: None };
        let path = "/tmp/microadam_ck_test1.bin";
        ck.save(path).unwrap();
        let back = Checkpoint::load(path).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn roundtrip_with_microadam_state() {
        let ck = Checkpoint {
            step: 7,
            params: vec![0.5; 16],
            opt: Some(OptSnapshot::MicroAdam(MicroAdamSnapshot {
                ef: vec![1, 2, 3, 255, 0, 7, 8, 9],
                qlo: vec![-1.0],
                qhi: vec![1.0],
                w_idx: vec![0, 3, 1, 2],
                w_val: vec![0.1, -0.2, 0.3, -0.4],
                w_bf16: true,
                t: 7,
            })),
        };
        let path = "/tmp/microadam_ck_test2.bin";
        ck.save(path).unwrap();
        assert_eq!(Checkpoint::load(path).unwrap(), ck);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn roundtrip_with_ldadam_state() {
        let ck = Checkpoint {
            step: 9,
            params: vec![0.25; 8],
            opt: Some(OptSnapshot::LdAdam(LdAdamSnapshot {
                proj: vec![0.1, 0.2, 0.3, 0.4],
                m: vec![1.0, -1.0],
                v: vec![0.5, 0.5],
                ef: vec![7, 8, 9, 10],
                qlo: vec![-0.5],
                qhi: vec![0.5],
                t: 9,
            })),
        };
        let path = "/tmp/microadam_ck_test_ld.bin";
        ck.save(path).unwrap();
        assert_eq!(Checkpoint::load(path).unwrap(), ck);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn roundtrip_with_adammini_state() {
        let ck = Checkpoint {
            step: 5,
            params: vec![-1.0; 6],
            opt: Some(OptSnapshot::AdamMini(AdamMiniSnapshot {
                m: vec![0.1, 0.2, 0.3],
                v: vec![0.9],
                t: 5,
            })),
        };
        let path = "/tmp/microadam_ck_test_mini.bin";
        ck.save(path).unwrap();
        assert_eq!(Checkpoint::load(path).unwrap(), ck);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = "/tmp/microadam_ck_test3.bin";
        std::fs::write(path, b"NOPE....").unwrap();
        assert!(Checkpoint::load(path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_unknown_tag() {
        // A well-formed v3 header with a bogus optimizer tag must be a
        // typed error, not a panic or a silent params-only load.
        let path = "/tmp/microadam_ck_test4.bin";
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes()); // step
        bytes.extend_from_slice(&0u64.to_le_bytes()); // d = 0
        bytes.push(9); // unknown tag
        std::fs::write(path, &bytes).unwrap();
        let err = Checkpoint::load(path).unwrap_err().to_string();
        assert!(err.contains("tag"), "{err}");
        let _ = std::fs::remove_file(path);
    }
}
