//! Binary checkpoints: parameters + step counter + (for MicroAdam) the
//! quantized EF / window state, so a resumed run continues bit-exactly.
//!
//! Format (little-endian):
//! ```text
//!   magic "MADM" | version u32 | step u64 | d u64 | params f32[d]
//!   | has_opt u8 | [MicroAdam state: ef len u64, ef bytes, qlo/qhi f32,
//!                   w_idx i32, w_val f32 lens + payloads, w_bf16 u8,
//!                   t u64]
//! ```
//! Version 2 added the `w_bf16` window-dtype marker (native windows store
//! bf16 by default since PR 3; restore refuses a silent dtype switch).

use std::io::{Read, Write};

use anyhow::{bail, Result};

use super::state::MicroAdamSnapshot;

const MAGIC: &[u8; 4] = b"MADM";
const VERSION: u32 = 2;

/// A checkpoint payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
    pub opt: Option<MicroAdamSnapshot>,
}

impl Checkpoint {
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        write_f32s(&mut f, &self.params)?;
        match &self.opt {
            None => f.write_all(&[0u8])?,
            Some(s) => {
                f.write_all(&[1u8])?;
                f.write_all(&(s.ef.len() as u64).to_le_bytes())?;
                f.write_all(&s.ef)?;
                f.write_all(&(s.qlo.len() as u64).to_le_bytes())?;
                write_f32s(&mut f, &s.qlo)?;
                write_f32s(&mut f, &s.qhi)?;
                f.write_all(&(s.w_idx.len() as u64).to_le_bytes())?;
                write_i32s(&mut f, &s.w_idx)?;
                write_f32s(&mut f, &s.w_val)?;
                f.write_all(&[u8::from(s.w_bf16)])?;
                f.write_all(&s.t.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &str) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path}: not a microadam checkpoint");
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            bail!("{path}: checkpoint version {version}, expected {VERSION}");
        }
        let step = read_u64(&mut f)?;
        let d = read_u64(&mut f)? as usize;
        let params = read_f32s(&mut f, d)?;
        let mut has_opt = [0u8];
        f.read_exact(&mut has_opt)?;
        let opt = if has_opt[0] == 1 {
            let ef_len = read_u64(&mut f)? as usize;
            let mut ef = vec![0u8; ef_len];
            f.read_exact(&mut ef)?;
            let nq = read_u64(&mut f)? as usize;
            let qlo = read_f32s(&mut f, nq)?;
            let qhi = read_f32s(&mut f, nq)?;
            let wlen = read_u64(&mut f)? as usize;
            let w_idx = read_i32s(&mut f, wlen)?;
            let w_val = read_f32s(&mut f, wlen)?;
            let mut w_bf16 = [0u8];
            f.read_exact(&mut w_bf16)?;
            let t = read_u64(&mut f)?;
            Some(MicroAdamSnapshot { ef, qlo, qhi, w_idx, w_val, w_bf16: w_bf16[0] != 0, t })
        } else {
            None
        };
        Ok(Checkpoint { step, params, opt })
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // SAFETY: `f32` is plain-old-data with no padding, so viewing the
    // slice as `xs.len() * 4` initialized bytes is valid; the borrow is
    // consumed by `write_all` before `xs` can move or drop.
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn write_i32s<W: Write>(w: &mut W, xs: &[i32]) -> Result<()> {
    // SAFETY: `i32` is plain-old-data with no padding, so viewing the
    // slice as `xs.len() * 4` initialized bytes is valid; the borrow is
    // consumed by `write_all` before `xs` can move or drop.
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn read_i32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<i32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_without_opt_state() {
        let ck = Checkpoint { step: 42, params: vec![1.0, -2.5, 3.25], opt: None };
        let path = "/tmp/microadam_ck_test1.bin";
        ck.save(path).unwrap();
        let back = Checkpoint::load(path).unwrap();
        assert_eq!(back, ck);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn roundtrip_with_microadam_state() {
        let ck = Checkpoint {
            step: 7,
            params: vec![0.5; 16],
            opt: Some(MicroAdamSnapshot {
                ef: vec![1, 2, 3, 255, 0, 7, 8, 9],
                qlo: vec![-1.0],
                qhi: vec![1.0],
                w_idx: vec![0, 3, 1, 2],
                w_val: vec![0.1, -0.2, 0.3, -0.4],
                w_bf16: true,
                t: 7,
            }),
        };
        let path = "/tmp/microadam_ck_test2.bin";
        ck.save(path).unwrap();
        assert_eq!(Checkpoint::load(path).unwrap(), ck);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = "/tmp/microadam_ck_test3.bin";
        std::fs::write(path, b"NOPE....").unwrap();
        assert!(Checkpoint::load(path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
