//! perf_probe — time one artifact in isolation (the §Perf workhorse).
//!
//! Usage: perf_probe <manifest-dir> <artifact-name> [iters]
//!
//! Builds zero-filled inputs of the manifest shapes, compiles the artifact,
//! and reports median wall time per execute. Used to attribute e2e step
//! time to fwd/bwd vs optimizer kernels and to sweep the L1 tile size.

use anyhow::{bail, Result};
use microadam::runtime::{lit_f32, lit_i32, lit_u8, Runtime};
use microadam::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 {
        bail!("usage: perf_probe <manifest-dir> <artifact> [iters]");
    }
    let iters: usize = args.get(2).map(|v| v.parse()).transpose()?.unwrap_or(5);
    let mut rt = Runtime::load(&args[0])?;
    let meta = rt.meta(&args[1])?.clone();
    let mut rng = Rng::seed_from_u64(0);
    let mut inputs = Vec::new();
    for (name, dtype, shape) in &meta.inputs {
        let n: usize = shape.iter().product();
        let lit = match dtype.as_str() {
            "float32" => {
                let v: Vec<f32> = (0..n).map(|_| rng.gen_f32() - 0.5).collect();
                lit_f32(&v, shape)?
            }
            "int32" => {
                // step counter t=1; token-ish inputs stay small
                let v: Vec<i32> = (0..n).map(|_| (rng.gen_range(16)) as i32 + 1).collect();
                lit_i32(&v, shape)?
            }
            "uint8" => lit_u8(&vec![0u8; n], shape)?,
            other => bail!("{name}: dtype {other}"),
        };
        inputs.push(lit);
    }
    let t0 = std::time::Instant::now();
    rt.compile(&meta.name)?;
    eprintln!("compile: {:.2}s", t0.elapsed().as_secs_f32());
    // warmup
    rt.execute_named(&meta.name, &inputs)?;
    let mut samples = Vec::new();
    for _ in 0..iters {
        let t = std::time::Instant::now();
        rt.execute_named(&meta.name, &inputs)?;
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "{}: median {:.3}s min {:.3}s over {iters} iters",
        meta.name,
        samples[samples.len() / 2],
        samples[0]
    );
    Ok(())
}
