//! perf_probe — time one artifact in isolation (the §Perf workhorse).
//!
//! Usage:
//!   perf_probe <manifest-dir> <artifact-name> [iters]
//!   perf_probe --native [d] [iters]
//!
//! Artifact mode builds zero-filled inputs of the manifest shapes, compiles
//! the artifact, and reports median wall time per execute. Used to
//! attribute e2e step time to fwd/bwd vs optimizer kernels and to sweep the
//! L1 tile size.
//!
//! `--native` needs no artifacts (it runs on the stub runtime too): it
//! times the fused MicroAdam step at several worker counts on the
//! persistent pool — the smoke-lane probe behind `make bench-smoke`.

use anyhow::{bail, Result};
use microadam::exec::ExecPool;
use microadam::optim::microadam::{MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;
use microadam::runtime::{lit_f32, lit_i32, lit_u8, Runtime};
use microadam::util::rng::Rng;

/// Median fused-step wall time at 1/2/4/8 workers plus the 4-pass
/// reference, on synthetic data. Prints steps/s so the smoke lane records
/// a throughput trajectory.
fn native_probe(d: usize, iters: usize) {
    println!("native fused-step probe, d = {d}, {iters} iters/row");
    let grads: Vec<f32> = (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
    let warm = microadam::WINDOW + 2;

    let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
    let mut params = vec![0.1f32; d];
    let t_ref = microadam::bench::time_it("step_reference (4-pass)", warm, iters, || {
        opt.step_reference(&mut params, &grads, 1e-3)
    });
    println!("    -> {:.1} steps/s", 1.0 / t_ref);

    for workers in [1usize, 2, 4, 8] {
        let pool = ExecPool::new(workers);
        let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
        let mut params = vec![0.1f32; d];
        let t = microadam::bench::time_it(&format!("fused step ({workers} workers)"), warm, iters, || {
            opt.step_sharded(&mut params, &grads, 1e-3, &pool)
        });
        println!("    -> {:.1} steps/s ({:.2}x vs reference)", 1.0 / t, t_ref / t);
    }
    let probe = MicroAdam::new(d, MicroAdamConfig::default());
    println!(
        "state: {} B resident ({:.3} B/param), window {} B/value",
        probe.state_bytes(),
        probe.state_bytes() as f64 / d as f64,
        probe.window_value_bytes()
    );
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(|a| a == "--native").unwrap_or(false) {
        let d: usize = args.get(1).map(|v| v.parse()).transpose()?.unwrap_or(1 << 20);
        let iters: usize = args.get(2).map(|v| v.parse()).transpose()?.unwrap_or(5);
        // MICROADAM_TRACE=path records the probe (per-phase fused-step
        // spans + time_it medians) and writes a Chrome trace file.
        let trace_path = std::env::var("MICROADAM_TRACE").ok().filter(|p| !p.is_empty());
        let session = trace_path.as_deref().map(microadam::trace::session_to);
        native_probe(d, iters);
        if let Some(s) = session {
            s.finish()?;
            println!("chrome trace written to {}", trace_path.unwrap_or_default());
        }
        return Ok(());
    }
    if args.len() < 2 {
        bail!("usage: perf_probe <manifest-dir> <artifact> [iters] | perf_probe --native [d] [iters]");
    }
    let iters: usize = args.get(2).map(|v| v.parse()).transpose()?.unwrap_or(5);
    let mut rt = Runtime::load(&args[0])?;
    let meta = rt.meta(&args[1])?.clone();
    let mut rng = Rng::seed_from_u64(0);
    let mut inputs = Vec::new();
    for (name, dtype, shape) in &meta.inputs {
        let n: usize = shape.iter().product();
        let lit = match dtype.as_str() {
            "float32" => {
                let v: Vec<f32> = (0..n).map(|_| rng.gen_f32() - 0.5).collect();
                lit_f32(&v, shape)?
            }
            "int32" => {
                // step counter t=1; token-ish inputs stay small
                let v: Vec<i32> = (0..n).map(|_| (rng.gen_range(16)) as i32 + 1).collect();
                lit_i32(&v, shape)?
            }
            "uint8" => lit_u8(&vec![0u8; n], shape)?,
            other => bail!("{name}: dtype {other}"),
        };
        inputs.push(lit);
    }
    let t0 = std::time::Instant::now();
    rt.compile(&meta.name)?;
    eprintln!("compile: {:.2}s", t0.elapsed().as_secs_f32());
    // warmup
    rt.execute_named(&meta.name, &inputs)?;
    let mut samples = Vec::new();
    for _ in 0..iters {
        let t = std::time::Instant::now();
        rt.execute_named(&meta.name, &inputs)?;
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "{}: median {:.3}s min {:.3}s over {iters} iters",
        meta.name,
        samples[samples.len() / 2],
        samples[0]
    );
    Ok(())
}
