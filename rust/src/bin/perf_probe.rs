//! perf_probe — time one artifact in isolation (the §Perf workhorse).
//!
//! Usage:
//!   perf_probe <manifest-dir> <artifact-name> [iters]
//!   perf_probe --native [d] [iters] [--sizes 64k,256k,1m]
//!
//! Artifact mode builds zero-filled inputs of the manifest shapes, compiles
//! the artifact, and reports median wall time per execute. Used to
//! attribute e2e step time to fwd/bwd vs optimizer kernels and to sweep the
//! L1 tile size.
//!
//! `--native` needs no artifacts (it runs on the stub runtime too): it
//! times the fused MicroAdam step at several worker counts on the
//! persistent pool, plus a scalar-vs-simd fused row — the smoke-lane probe
//! behind `make bench-smoke`. `--sizes` runs the probe once per listed
//! dimension (`k` = x1024, `m` = x1048576) instead of the single
//! positional `d`, so one invocation sweeps the cache-residency regimes.

use anyhow::{bail, Result};
use microadam::exec::ExecPool;
use microadam::optim::microadam::{MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;
use microadam::runtime::{lit_f32, lit_i32, lit_u8, Runtime};
use microadam::simd::{self, Policy};
use microadam::util::rng::Rng;

/// Median fused-step wall time at 1/2/4/8 workers plus the 4-pass
/// reference, on synthetic data. Prints steps/s so the smoke lane records
/// a throughput trajectory.
fn native_probe(d: usize, iters: usize) {
    println!("native fused-step probe, d = {d}, {iters} iters/row");
    let grads: Vec<f32> = (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
    let warm = microadam::WINDOW + 2;

    let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
    let mut params = vec![0.1f32; d];
    let t_ref = microadam::bench::time_it("step_reference (4-pass)", warm, iters, || {
        opt.step_reference(&mut params, &grads, 1e-3)
    });
    println!("    -> {:.1} steps/s", 1.0 / t_ref);

    for workers in [1usize, 2, 4, 8] {
        let pool = ExecPool::new(workers);
        let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
        let mut params = vec![0.1f32; d];
        let t = microadam::bench::time_it(&format!("fused step ({workers} workers)"), warm, iters, || {
            opt.step_sharded(&mut params, &grads, 1e-3, &pool)
        });
        println!("    -> {:.1} steps/s ({:.2}x vs reference)", 1.0 / t, t_ref / t);
    }

    // Scalar-vs-simd fused row: same math under both policies (simd is a
    // codegen knob, never a numerics knob), so the ratio is vectorization.
    let mut fused = |policy: Policy, label: &str| -> f64 {
        let mut opt = MicroAdam::new(d, MicroAdamConfig { simd: policy, ..Default::default() });
        let mut params = vec![0.1f32; d];
        microadam::bench::time_it(&format!("fused step (1 worker, {label})"), warm, iters, || {
            opt.step(&mut params, &grads, 1e-3)
        })
    };
    let level = simd::level_name(simd::detected());
    let ts = fused(Policy::Scalar, "scalar");
    let tv = fused(Policy::Auto, level);
    println!("    simd fused speedup: {:.2}x (detected: {level})", ts / tv.max(1e-12));
    let probe = MicroAdam::new(d, MicroAdamConfig::default());
    println!(
        "state: {} B resident ({:.3} B/param), window {} B/value",
        probe.state_bytes(),
        probe.state_bytes() as f64 / d as f64,
        probe.window_value_bytes()
    );
}

/// Parse one `--sizes` element: an integer with an optional `k` (x1024)
/// or `m` (x1048576) suffix, e.g. `64k`, `256k`, `1m`.
fn parse_size(s: &str) -> Result<usize> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(n) = t.strip_suffix('k') {
        (n, 1usize << 10)
    } else if let Some(n) = t.strip_suffix('m') {
        (n, 1usize << 20)
    } else {
        (t.as_str(), 1)
    };
    match digits.parse::<usize>() {
        Ok(v) if v > 0 => Ok(v * mult),
        _ => bail!("bad --sizes element {s:?} (want e.g. 64k, 256k, 1m)"),
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(|a| a == "--native").unwrap_or(false) {
        // Positional [d] [iters] stop at the first `--` flag.
        let pos: Vec<&String> = args.iter().skip(1).take_while(|a| !a.starts_with("--")).collect();
        let d: usize = pos.first().map(|v| v.parse()).transpose()?.unwrap_or(1 << 20);
        let iters: usize = pos.get(1).map(|v| v.parse()).transpose()?.unwrap_or(5);
        let sizes: Vec<usize> = match args.iter().position(|a| a == "--sizes") {
            Some(i) => match args.get(i + 1) {
                Some(list) => list.split(',').map(parse_size).collect::<Result<_>>()?,
                None => bail!("--sizes needs a comma-separated list (e.g. 64k,256k,1m)"),
            },
            None => vec![d],
        };
        // MICROADAM_TRACE=path records the probe (per-phase fused-step
        // spans + time_it medians) and writes a Chrome trace file.
        let trace_path = std::env::var("MICROADAM_TRACE").ok().filter(|p| !p.is_empty());
        let session = trace_path.as_deref().map(microadam::trace::session_to);
        for (i, &d) in sizes.iter().enumerate() {
            if i > 0 {
                println!();
            }
            native_probe(d, iters);
        }
        if let Some(s) = session {
            s.finish()?;
            println!("chrome trace written to {}", trace_path.unwrap_or_default());
        }
        return Ok(());
    }
    if args.len() < 2 {
        bail!("usage: perf_probe <manifest-dir> <artifact> [iters] | perf_probe --native [d] [iters] [--sizes 64k,256k,1m]");
    }
    let iters: usize = args.get(2).map(|v| v.parse()).transpose()?.unwrap_or(5);
    let mut rt = Runtime::load(&args[0])?;
    let meta = rt.meta(&args[1])?.clone();
    let mut rng = Rng::seed_from_u64(0);
    let mut inputs = Vec::new();
    for (name, dtype, shape) in &meta.inputs {
        let n: usize = shape.iter().product();
        let lit = match dtype.as_str() {
            "float32" => {
                let v: Vec<f32> = (0..n).map(|_| rng.gen_f32() - 0.5).collect();
                lit_f32(&v, shape)?
            }
            "int32" => {
                // step counter t=1; token-ish inputs stay small
                let v: Vec<i32> = (0..n).map(|_| (rng.gen_range(16)) as i32 + 1).collect();
                lit_i32(&v, shape)?
            }
            "uint8" => lit_u8(&vec![0u8; n], shape)?,
            other => bail!("{name}: dtype {other}"),
        };
        inputs.push(lit);
    }
    let t0 = std::time::Instant::now();
    rt.compile(&meta.name)?;
    eprintln!("compile: {:.2}s", t0.elapsed().as_secs_f32());
    // warmup
    rt.execute_named(&meta.name, &inputs)?;
    let mut samples = Vec::new();
    for _ in 0..iters {
        let t = std::time::Instant::now();
        rt.execute_named(&meta.name, &inputs)?;
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "{}: median {:.3}s min {:.3}s over {iters} iters",
        meta.name,
        samples[samples.len() / 2],
        samples[0]
    );
    Ok(())
}
