//! # MicroAdam — memory-efficient adaptive optimization (NeurIPS 2024 reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *"MicroAdam: Accurate
//! Adaptive Optimization with Low Space Overhead and Provable Convergence"*
//! (Modoranu et al., NeurIPS 2024).
//!
//! Layer map:
//! * **L3 (this crate)** — training coordinator: config system, parameter
//!   layout manager, optimizer state ownership (quantized error feedback +
//!   sliding gradient window), data pipeline, LR schedules, checkpoints,
//!   metrics, and the full set of *native* optimizers used as baselines
//!   (AdamW, AdamW-8bit, SGD, AdaFactor, CAME, GaLore, GaLore+EF) plus a
//!   native MicroAdam cross-validated against the AOT artifact.
//! * **L2/L1 (python/, build-time only)** — JAX model graphs and Pallas
//!   kernels, AOT-lowered to HLO text; loaded and executed from
//!   [`runtime`] via the PJRT CPU client (behind the off-by-default `pjrt`
//!   cargo feature — without it the runtime is a host-only stub and every
//!   native path still builds and runs). Python never runs at train time.
//! * **[`exec`]** — the block-sharded parallel step engine: a persistent
//!   parked-worker pool (zero thread spawns per step) + per-worker scratch
//!   arenas behind the fused dequantize/Top-K/re-quantize/AdamStats/update
//!   pass.
//! * **[`dist`]** — the multi-replica data-parallel engine: per-rank data
//!   shards, pluggable compressed gradient exchange (dense / Top-K /
//!   Top-K + quantized error feedback), a versioned CRC-guarded wire
//!   format ([`dist::wire`], spec in `rust/src/dist/README.md`), and
//!   three transports behind one trait ([`dist::transport`]): in-process
//!   loopback, Unix-domain sockets and shared-memory mailboxes. The
//!   [`dist::DistTrainer`] loop runs behind `microadam train --ranks N
//!   --reduce eftopk [--transport uds|shm]`; the multi-process runs are
//!   bit-identical to loopback with the same seeds.
//! * **[`trace`]** — zero-dependency tracing/metrics: per-shard/per-phase
//!   spans over the fused engine, transport gather/relay spans, EF-health
//!   gauges (residual norm, Top-K captured mass, Quant4 error), drained
//!   into the metrics JSONL and exportable as Chrome trace-event JSON
//!   (`--trace <path>`). True no-op when disabled.
//!
//! See the repo-level `README.md` for the CLI quickstart and the
//! paper→module map. Library quickstart:
//! ```
//! use microadam::optim::{microadam::MicroAdam, Optimizer};
//! let mut opt = MicroAdam::new(4096, Default::default());
//! let mut params = vec![0.1f32; 4096];
//! let grads = vec![0.01f32; 4096];
//! opt.step(&mut params, &grads, 1e-3);
//! assert_eq!(opt.t(), 1);
//! ```

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod exec;
pub mod linalg;
pub mod memory;
pub mod models;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod simd;
pub mod topk;
pub mod trace;
pub mod util;

/// Paper-default Top-K block size `B_d` (must stay below 2^15 so
/// block-relative indices fit `i16`/`u16`, §3.1).
pub const BLOCK: usize = 4096;
/// Paper-default EF quantization bucket `B_q` (§B: bucket size 64).
pub const QBUCKET: usize = 64;
/// Paper-default sliding window length `m`.
pub const WINDOW: usize = 10;
/// Paper-default gradient density `k/d` (1% == 99% sparsity).
pub const DENSITY: f64 = 0.01;

/// `k_b`: Top-K entries kept per block at the given density.
pub fn kb_for_block(block: usize, density: f64) -> usize {
    ((block as f64 * density).ceil() as usize).max(1)
}

/// Round `n` up to a multiple of `to`.
pub fn pad_up(n: usize, to: usize) -> usize {
    n.div_ceil(to) * to
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kb_matches_paper_regime() {
        // 1% of 4096 -> 41 entries per block.
        assert_eq!(kb_for_block(4096, 0.01), 41);
        assert_eq!(kb_for_block(64, 0.05), 4);
        assert_eq!(kb_for_block(8, 1e-9), 1); // never zero
    }

    #[test]
    fn pad_up_is_idempotent_on_multiples() {
        assert_eq!(pad_up(4096, 4096), 4096);
        assert_eq!(pad_up(4097, 4096), 8192);
        assert_eq!(pad_up(0, 4096), 0);
    }
}
