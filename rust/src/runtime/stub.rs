//! Host-only runtime stand-in (default build, no `pjrt` feature).
//!
//! [`Literal`] here is a plain host buffer with the same construction /
//! readback API the PJRT backend exposes, so the coordinator, trainer and
//! AOT state managers compile and run unchanged. The manifest still loads
//! (`microadam list` works offline); only [`Runtime::compile`] /
//! [`Runtime::execute_named`] fail, with an error pointing at the `pjrt`
//! feature. Nothing in the native hot path (optimizers, fused step engine,
//! repro harnesses on the native backend substrates) ever reaches them.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use super::ArtifactMeta;

/// Element dtype of a host literal (mirrors the manifest dtypes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
}

impl ElementType {
    fn size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// A host-memory tensor literal: dtype + shape + native-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
}

/// Manifest-backed registry without an execution engine.
pub struct Runtime {
    #[allow(dead_code)]
    dir: PathBuf,
    artifacts: HashMap<String, ArtifactMeta>,
}

impl Runtime {
    /// Load `dir/manifest.json`; metadata queries work, execution doesn't.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let artifacts = super::load_manifest(&dir)?;
        Ok(Self { dir, artifacts })
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; have: {:?}", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn compile(&mut self, name: &str) -> Result<()> {
        Err(no_pjrt(name))
    }

    pub fn execute_named(&mut self, name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        Err(no_pjrt(name))
    }
}

/// Whether this build can actually execute artifacts (`false`: stub).
/// Callers that have a native fallback (e.g. [`crate::dist`]) check this
/// up front instead of failing at the first `execute_named`.
pub fn engine_available() -> bool {
    false
}

fn no_pjrt(name: &str) -> anyhow::Error {
    anyhow!(
        "artifact {name}: executing AOT artifacts needs the PJRT runtime — \
         rebuild with `--features pjrt` (and the vendored `xla` crate, see \
         rust/Cargo.toml), or use the native backend (`--backend native`)"
    )
}

// ---------------------------------------------------------------------------
// Literal construction / readback helpers
// ---------------------------------------------------------------------------

fn make(ty: ElementType, shape: &[usize], bytes: Vec<u8>) -> Result<Literal> {
    let want: usize = shape.iter().product();
    if bytes.len() != want * ty.size() {
        bail!("literal: {} bytes for {want} x {ty:?}", bytes.len());
    }
    Ok(Literal { ty, shape: shape.to_vec(), bytes })
}

/// f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    make(ElementType::F32, shape, data.iter().flat_map(|v| v.to_ne_bytes()).collect())
}

/// i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    make(ElementType::S32, shape, data.iter().flat_map(|v| v.to_ne_bytes()).collect())
}

/// u8 literal of the given shape.
pub fn lit_u8(data: &[u8], shape: &[usize]) -> Result<Literal> {
    make(ElementType::U8, shape, data.to_vec())
}

/// f32 scalar literal (shape []).
pub fn lit_scalar_f32(v: f32) -> Result<Literal> {
    lit_f32(&[v], &[])
}

/// i32 scalar literal (shape []).
pub fn lit_scalar_i32(v: i32) -> Result<Literal> {
    lit_i32(&[v], &[])
}

/// Zero-element f32 literal (state-swap placeholder).
pub fn empty_f32() -> Literal {
    Literal { ty: ElementType::F32, shape: vec![0], bytes: Vec::new() }
}

/// Zero-element i32 literal (state-swap placeholder).
pub fn empty_i32() -> Literal {
    Literal { ty: ElementType::S32, shape: vec![0], bytes: Vec::new() }
}

/// Zero-element u8 literal (state-swap placeholder).
pub fn empty_u8() -> Literal {
    Literal { ty: ElementType::U8, shape: vec![0], bytes: Vec::new() }
}

fn expect_ty(lit: &Literal, ty: ElementType, what: &str) -> Result<()> {
    if lit.ty != ty {
        bail!("{what}: literal is {:?}, not {ty:?}", lit.ty);
    }
    Ok(())
}

/// Read a literal back as `Vec<f32>`.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    expect_ty(lit, ElementType::F32, "to_f32")?;
    Ok(lit.bytes.chunks_exact(4).map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Read a literal back as `Vec<i32>`.
pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
    expect_ty(lit, ElementType::S32, "to_i32")?;
    Ok(lit.bytes.chunks_exact(4).map(|c| i32::from_ne_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Read a literal back as `Vec<u8>`.
pub fn to_u8(lit: &Literal) -> Result<Vec<u8>> {
    expect_ty(lit, ElementType::U8, "to_u8")?;
    Ok(lit.bytes.clone())
}

/// Read a scalar f32 literal.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    let v = to_f32(lit)?;
    v.first().copied().ok_or_else(|| anyhow!("scalar_f32: empty literal"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_mismatch_is_rejected() {
        let l = lit_u8(&[1, 2], &[2]).unwrap();
        assert!(to_f32(&l).is_err());
        assert!(to_i32(&l).is_err());
        assert!(to_u8(&l).is_ok());
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_f32(&[1.0, 2.0], &[2, 1]).is_ok());
    }

    #[test]
    fn execute_errors_mention_the_pjrt_feature() {
        // A manifest-less dir errors at load; build a Runtime by hand to
        // exercise the execute path.
        let mut rt = Runtime { dir: PathBuf::new(), artifacts: HashMap::new() };
        let err = rt.compile("whatever").unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
        let err = rt.execute_named("whatever", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
    }
}
