//! Artifact runtime: manifest metadata plus (feature-gated) PJRT execution.
//!
//! The manifest schema, [`ArtifactMeta`], and the literal helper API are
//! backend-independent and always compiled. The actual execution engine is
//! selected at build time:
//!
//! * `--features pjrt` — `pjrt`: wraps the vendored `xla` crate per the
//!   /opt/xla-example/load_hlo pattern (`PjRtClient::cpu()` ->
//!   `HloModuleProto::from_text_file` -> `compile` -> `execute`). Artifacts
//!   are compiled lazily and cached; every `execute_named` call validates
//!   literal dtypes/shapes against the manifest signature so a stale
//!   artifact directory fails fast with a readable error instead of
//!   mis-executing. Python never runs here: the manifest + HLO text
//!   produced once by `make artifacts` fully describe the compute.
//! * default — `stub`: a host-only stand-in. Literals are plain host
//!   buffers (construction/readback work normally), the manifest still
//!   loads and lists, and only `compile`/`execute_named` return an error
//!   directing the user to the `pjrt` feature. Everything native —
//!   optimizers, the fused step engine, benches, repro harnesses that
//!   don't touch artifacts — builds and runs without any XLA system libs.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::layout::{Init, ParamLayout, TensorSpec};
use crate::util::json::Json;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;

/// One artifact's manifest entry (signature + metadata).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<(String, String, Vec<usize>)>, // (name, dtype, shape)
    pub outputs: Vec<String>,
    pub raw: Json,
}

impl ArtifactMeta {
    fn from_json(name: &str, j: &Json) -> Result<Self> {
        let file = j
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact {name}: missing file"))?
            .to_string();
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let mut inputs = Vec::new();
        for inp in j.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
            inputs.push((
                inp.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
                inp.get("dtype").and_then(Json::as_str).unwrap_or("?").to_string(),
                inp.get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
            ));
        }
        let outputs = j
            .get("outputs")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        Ok(Self { name: name.to_string(), file, kind, inputs, outputs, raw: j.clone() })
    }

    /// Parse the `layout` block into a [`ParamLayout`] (model artifacts).
    pub fn layout(&self) -> Result<ParamLayout> {
        let l = self.raw.get("layout").ok_or_else(|| anyhow!("{}: no layout", self.name))?;
        let d_padded = l.get("d_padded").and_then(Json::as_usize).context("d_padded")?;
        let mut tensors = Vec::new();
        let mut inits = Vec::new();
        for p in l.get("params").and_then(Json::as_arr).context("params")? {
            let name = p.get("name").and_then(Json::as_str).context("name")?;
            let shape: Vec<usize> = p
                .get("shape")
                .and_then(Json::as_arr)
                .context("shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = p.get("offset").and_then(Json::as_usize).context("offset")?;
            let init = match p.get("init").and_then(Json::as_str) {
                Some("normal") => Init::Normal,
                Some("ones") => Init::Ones,
                _ => Init::Zeros,
            };
            let std = p.get("init_std").and_then(Json::as_f64).unwrap_or(0.0) as f32;
            tensors.push(TensorSpec::new(name, &shape, offset));
            inits.push((init, std));
        }
        Ok(ParamLayout::new(tensors, inits, d_padded))
    }

    /// Optimizer hyper-parameter block value (opt_step artifacts).
    pub fn hyper(&self, key: &str) -> Option<f64> {
        self.raw.get("hyper")?.get(key)?.as_f64()
    }

    /// Model config block value (fwdbwd/infer artifacts).
    pub fn config(&self, key: &str) -> Option<f64> {
        self.raw.get("config")?.get(key)?.as_f64()
    }
}

/// Read and parse `dir/manifest.json` into the artifact table (shared by
/// both runtime backends).
pub(crate) fn load_manifest(dir: &Path) -> Result<HashMap<String, ArtifactMeta>> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
    let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let mut artifacts = HashMap::new();
    for (name, entry) in manifest
        .get("artifacts")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("manifest missing artifacts"))?
    {
        artifacts.insert(name.clone(), ArtifactMeta::from_json(name, entry)?);
    }
    Ok(artifacts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let l = lit_i32(&[5, -6], &[2]).unwrap();
        assert_eq!(to_i32(&l).unwrap(), vec![5, -6]);
        let l = lit_u8(&[7, 255], &[2]).unwrap();
        assert_eq!(to_u8(&l).unwrap(), vec![7, 255]);
        let l = lit_scalar_f32(2.5).unwrap();
        assert_eq!(scalar_f32(&l).unwrap(), 2.5);
    }

    #[test]
    fn empty_literals_have_zero_elements() {
        assert_eq!(to_f32(&empty_f32()).unwrap(), Vec::<f32>::new());
        assert_eq!(to_i32(&empty_i32()).unwrap(), Vec::<i32>::new());
        assert_eq!(to_u8(&empty_u8()).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn missing_manifest_is_a_readable_error() {
        let err = match Runtime::load("/nonexistent-dir") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
