//! PJRT runtime: load and execute AOT artifacts from the L3 hot path.
//!
//! Wraps the `xla` crate per the /opt/xla-example/load_hlo pattern:
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `client.compile` -> `execute`. Artifacts are compiled lazily and cached;
//! every `execute_named` call validates literal dtypes/shapes against the
//! manifest signature so a stale artifact directory fails fast with a
//! readable error instead of mis-executing.
//!
//! Python never runs here: the manifest + HLO text produced once by
//! `make artifacts` fully describe the compute.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::layout::{Init, ParamLayout, TensorSpec};
use crate::util::json::Json;

/// One artifact's manifest entry (signature + metadata).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<(String, String, Vec<usize>)>, // (name, dtype, shape)
    pub outputs: Vec<String>,
    pub raw: Json,
}

impl ArtifactMeta {
    fn from_json(name: &str, j: &Json) -> Result<Self> {
        let file = j
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact {name}: missing file"))?
            .to_string();
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let mut inputs = Vec::new();
        for inp in j.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
            inputs.push((
                inp.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
                inp.get("dtype").and_then(Json::as_str).unwrap_or("?").to_string(),
                inp.get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
            ));
        }
        let outputs = j
            .get("outputs")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        Ok(Self { name: name.to_string(), file, kind, inputs, outputs, raw: j.clone() })
    }

    /// Parse the `layout` block into a [`ParamLayout`] (model artifacts).
    pub fn layout(&self) -> Result<ParamLayout> {
        let l = self.raw.get("layout").ok_or_else(|| anyhow!("{}: no layout", self.name))?;
        let d_padded = l.get("d_padded").and_then(Json::as_usize).context("d_padded")?;
        let mut tensors = Vec::new();
        let mut inits = Vec::new();
        for p in l.get("params").and_then(Json::as_arr).context("params")? {
            let name = p.get("name").and_then(Json::as_str).context("name")?;
            let shape: Vec<usize> = p
                .get("shape")
                .and_then(Json::as_arr)
                .context("shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = p.get("offset").and_then(Json::as_usize).context("offset")?;
            let init = match p.get("init").and_then(Json::as_str) {
                Some("normal") => Init::Normal,
                Some("ones") => Init::Ones,
                _ => Init::Zeros,
            };
            let std = p.get("init_std").and_then(Json::as_f64).unwrap_or(0.0) as f32;
            tensors.push(TensorSpec::new(name, &shape, offset));
            inits.push((init, std));
        }
        Ok(ParamLayout::new(tensors, inits, d_padded))
    }

    /// Optimizer hyper-parameter block value (opt_step artifacts).
    pub fn hyper(&self, key: &str) -> Option<f64> {
        self.raw.get("hyper")?.get(key)?.as_f64()
    }

    /// Model config block value (fwdbwd/infer artifacts).
    pub fn config(&self, key: &str) -> Option<f64> {
        self.raw.get("config")?.get(key)?.as_f64()
    }
}

/// Lazily-compiled artifact registry over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: HashMap<String, ArtifactMeta>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load `dir/manifest.json` and connect the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut artifacts = HashMap::new();
        for (name, entry) in manifest
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            artifacts.insert(name.clone(), ArtifactMeta::from_json(name, entry)?);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client, dir, artifacts, executables: HashMap::new() })
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; have: {:?}", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let meta = self.meta(name)?.clone();
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        log_compile(name, t0.elapsed());
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact; inputs are validated against the manifest and
    /// the tuple output is decomposed into one literal per manifest output.
    pub fn execute_named(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.validate_inputs(name, inputs)?;
        self.compile(name)?;
        let exe = self.executables.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback {name}: {e:?}"))?;
        let outs = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let meta = self.meta(name)?;
        if outs.len() != meta.outputs.len() {
            bail!("{name}: {} outputs, manifest says {}", outs.len(), meta.outputs.len());
        }
        Ok(outs)
    }

    fn validate_inputs(&self, name: &str, inputs: &[xla::Literal]) -> Result<()> {
        let meta = self.meta(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!("{name}: {} inputs, manifest wants {}", inputs.len(), meta.inputs.len());
        }
        for (lit, (iname, dtype, shape)) in inputs.iter().zip(&meta.inputs) {
            let count = lit.element_count();
            let want: usize = shape.iter().product();
            if count != want {
                bail!("{name}.{iname}: literal has {count} elements, manifest wants {want} {shape:?}");
            }
            let ty = lit.ty().map_err(|e| anyhow!("{e:?}"))?;
            let want_ty = match dtype.as_str() {
                "float32" => xla::ElementType::F32,
                "int32" => xla::ElementType::S32,
                "uint8" => xla::ElementType::U8,
                other => bail!("{name}.{iname}: unsupported manifest dtype {other}"),
            };
            if ty != want_ty {
                bail!("{name}.{iname}: literal type {ty:?}, manifest wants {want_ty:?}");
            }
        }
        Ok(())
    }
}

fn log_compile(name: &str, dt: std::time::Duration) {
    if std::env::var_os("MICROADAM_QUIET").is_none() {
        eprintln!("[runtime] compiled {name} in {:.2}s", dt.as_secs_f32());
    }
}

// ---------------------------------------------------------------------------
// Literal construction / readback helpers
// ---------------------------------------------------------------------------

/// f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let bytes = as_bytes(data);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("lit_f32: {e:?}"))
}

/// i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let bytes = as_bytes(data);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("lit_i32: {e:?}"))
}

/// u8 literal of the given shape.
pub fn lit_u8(data: &[u8], shape: &[usize]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, shape, data)
        .map_err(|e| anyhow!("lit_u8: {e:?}"))
}

/// f32 scalar literal (shape []).
pub fn lit_scalar_f32(v: f32) -> Result<xla::Literal> {
    lit_f32(&[v], &[])
}

/// i32 scalar literal (shape []).
pub fn lit_scalar_i32(v: i32) -> Result<xla::Literal> {
    lit_i32(&[v], &[])
}

fn as_bytes<T: Copy>(data: &[T]) -> &[u8] {
    // Safety: plain-old-data reinterpretation for literal upload only.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// Read a literal back as `Vec<f32>`.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_f32: {e:?}"))
}

/// Read a literal back as `Vec<i32>`.
pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_i32: {e:?}"))
}

/// Read a literal back as `Vec<u8>`.
pub fn to_u8(lit: &xla::Literal) -> Result<Vec<u8>> {
    lit.to_vec::<u8>().map_err(|e| anyhow!("to_u8: {e:?}"))
}

/// Read a scalar f32 literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(to_f32(lit)?[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let l = lit_i32(&[5, -6], &[2]).unwrap();
        assert_eq!(to_i32(&l).unwrap(), vec![5, -6]);
        let l = lit_u8(&[7, 255], &[2]).unwrap();
        assert_eq!(to_u8(&l).unwrap(), vec![7, 255]);
        let l = lit_scalar_f32(2.5).unwrap();
        assert_eq!(scalar_f32(&l).unwrap(), 2.5);
    }

    #[test]
    fn missing_manifest_is_a_readable_error() {
        let err = match Runtime::load("/nonexistent-dir") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
