//! PJRT-backed execution engine (`--features pjrt`): load and execute AOT
//! artifacts from the L3 hot path via the vendored `xla` crate.
//!
//! Requires the `xla` dependency to be enabled in `rust/Cargo.toml` (see
//! the note there); without the feature the crate uses [`super::stub`]
//! instead and none of this file is compiled.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use super::ArtifactMeta;

/// PJRT literal type (device buffer handle + host conversion).
pub type Literal = xla::Literal;

/// Lazily-compiled artifact registry over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: HashMap<String, ArtifactMeta>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load `dir/manifest.json` and connect the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let artifacts = super::load_manifest(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self { client, dir, artifacts, executables: HashMap::new() })
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}; have: {:?}", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let meta = self.meta(name)?.clone();
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        log_compile(name, t0.elapsed());
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact; inputs are validated against the manifest and
    /// the tuple output is decomposed into one literal per manifest output.
    pub fn execute_named(&mut self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        self.validate_inputs(name, inputs)?;
        self.compile(name)?;
        let exe = self.executables.get(name).unwrap();
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback {name}: {e:?}"))?;
        let outs = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let meta = self.meta(name)?;
        if outs.len() != meta.outputs.len() {
            bail!("{name}: {} outputs, manifest says {}", outs.len(), meta.outputs.len());
        }
        Ok(outs)
    }

    fn validate_inputs(&self, name: &str, inputs: &[Literal]) -> Result<()> {
        let meta = self.meta(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!("{name}: {} inputs, manifest wants {}", inputs.len(), meta.inputs.len());
        }
        for (lit, (iname, dtype, shape)) in inputs.iter().zip(&meta.inputs) {
            let count = lit.element_count();
            let want: usize = shape.iter().product();
            if count != want {
                bail!("{name}.{iname}: literal has {count} elements, manifest wants {want} {shape:?}");
            }
            let ty = lit.ty().map_err(|e| anyhow!("{e:?}"))?;
            let want_ty = match dtype.as_str() {
                "float32" => xla::ElementType::F32,
                "int32" => xla::ElementType::S32,
                "uint8" => xla::ElementType::U8,
                other => bail!("{name}.{iname}: unsupported manifest dtype {other}"),
            };
            if ty != want_ty {
                bail!("{name}.{iname}: literal type {ty:?}, manifest wants {want_ty:?}");
            }
        }
        Ok(())
    }
}

/// Whether this build can actually execute artifacts (`true`: real PJRT).
/// Callers that have a native fallback (e.g. [`crate::dist`]) check this
/// up front instead of failing at the first `execute_named`.
pub fn engine_available() -> bool {
    true
}

fn log_compile(name: &str, dt: std::time::Duration) {
    if std::env::var_os("MICROADAM_QUIET").is_none() {
        eprintln!("[runtime] compiled {name} in {:.2}s", dt.as_secs_f32());
    }
}

// ---------------------------------------------------------------------------
// Literal construction / readback helpers
// ---------------------------------------------------------------------------

/// f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let bytes = as_bytes(data);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("lit_f32: {e:?}"))
}

/// i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let bytes = as_bytes(data);
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("lit_i32: {e:?}"))
}

/// u8 literal of the given shape.
pub fn lit_u8(data: &[u8], shape: &[usize]) -> Result<Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, shape, data)
        .map_err(|e| anyhow!("lit_u8: {e:?}"))
}

/// f32 scalar literal (shape []).
pub fn lit_scalar_f32(v: f32) -> Result<Literal> {
    lit_f32(&[v], &[])
}

/// i32 scalar literal (shape []).
pub fn lit_scalar_i32(v: i32) -> Result<Literal> {
    lit_i32(&[v], &[])
}

/// Zero-element f32 literal (state-swap placeholder).
pub fn empty_f32() -> Literal {
    xla::Literal::create_from_shape(xla::PrimitiveType::F32, &[0])
}

/// Zero-element i32 literal (state-swap placeholder).
pub fn empty_i32() -> Literal {
    xla::Literal::create_from_shape(xla::PrimitiveType::S32, &[0])
}

/// Zero-element u8 literal (state-swap placeholder).
pub fn empty_u8() -> Literal {
    xla::Literal::create_from_shape(xla::PrimitiveType::U8, &[0])
}

fn as_bytes<T: Copy>(data: &[T]) -> &[u8] {
    // SAFETY: `T: Copy` here is always a primitive numeric type with no
    // padding; the byte view covers exactly `size_of_val(data)` initialized
    // bytes and lives only for the literal upload call.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// Read a literal back as `Vec<f32>`.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_f32: {e:?}"))
}

/// Read a literal back as `Vec<i32>`.
pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_i32: {e:?}"))
}

/// Read a literal back as `Vec<u8>`.
pub fn to_u8(lit: &Literal) -> Result<Vec<u8>> {
    lit.to_vec::<u8>().map_err(|e| anyhow!("to_u8: {e:?}"))
}

/// Read a scalar f32 literal.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(to_f32(lit)?[0])
}
