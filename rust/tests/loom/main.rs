//! Model-checked concurrency suite (`make loom`).
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"`; a plain `cargo test`
//! builds this target as an empty harness. Each test hands a closure to
//! `loom::model`, which re-runs it under a cooperative scheduler that
//! explores every non-preemptive schedule plus every schedule with a
//! bounded number of forced preemptions (see `rust/tools/minloom`), and
//! fails with the offending schedule on any assertion, panic, deadlock
//! or livelock.
//!
//! Two subsystems are modelled:
//!
//! * the `ExecPool` parked-worker dispatch/barrier protocol — job
//!   pointer publication, the atomic shard cursor, and the panic-safe
//!   `WaitGuard` that keeps workers from outliving borrowed buffers;
//! * the `StreamHub` pipelined gather/relay loop — the relay-ordering
//!   invariant (no relay bytes to a worker before its own uplink frame
//!   has fully landed) over scheduler-instrumented in-memory pipes.
#![cfg(loom)]

use loom::sync::atomic::{AtomicUsize, Ordering};
use microadam::exec::ExecPool;

#[test]
fn exec_pool_dispatch_barrier() {
    loom::model(|| {
        let pool = ExecPool::new(2);
        let hits = AtomicUsize::new(0);
        // 3 shards on 2 workers: the atomic cursor must hand each shard
        // to exactly one worker, and the barrier must not release the
        // caller until all three ran.
        pool.run_shards(vec![0usize, 1, 2], |_, _| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3, "every shard runs exactly once");
    });
}

#[test]
fn exec_pool_epoch_gating_survives_reuse() {
    loom::model(|| {
        let pool = ExecPool::new(2);
        let hits = AtomicUsize::new(0);
        // Two back-to-back dispatches: the epoch counter must stop a
        // worker from re-running the first job or missing the second.
        pool.run_shards(vec![0usize, 1], |_, _| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        pool.run_shards(vec![0usize, 1], |_, _| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4, "both dispatches complete");
    });
}

#[test]
fn panicking_shard_releases_barrier() {
    loom::model(|| {
        let pool = ExecPool::new(2);
        // A panicking shard must never deadlock the barrier on any
        // schedule: the WaitGuard drains the workers, the panic
        // surfaces on the caller, and the pool stays usable.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_shards(vec![0usize, 1], |_, v| {
                if v == 1 {
                    panic!("model shard down");
                }
            });
        }));
        assert!(r.is_err(), "the shard panic must propagate");
        let hits = AtomicUsize::new(0);
        pool.run_shards(vec![0usize, 1], |_, _| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2, "pool usable after a shard panic");
    });
}

#[test]
fn stream_hub_relay_ordering() {
    loom::model(microadam::dist::transport::loom_model::relay_ordering_model);
}
