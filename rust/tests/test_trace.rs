//! Integration tests for the `trace::` observability layer.
//!
//! These tests take real [`microadam::trace`] sessions (which serialize
//! on a process-wide lock), so they live here rather than in the lib's
//! unit tests: the lib test binary runs its tests concurrently in one
//! process, and a session taken there would race every other test that
//! happens to touch an instrumented code path.

use microadam::coordinator::config::TrainConfig;
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::dist::{DistTrainer, ReducerKind};
use microadam::exec::ExecPool;
use microadam::optim::microadam::{MicroAdam, MicroAdamConfig, PHASE_NAMES};
use microadam::optim::{Optimizer, OptimizerKind};
use microadam::trace;
use microadam::util::json::Json;

/// 8 blocks: enough shards for every worker count the tests sweep.
const D: usize = 8 * microadam::BLOCK;

fn grads(d: usize) -> Vec<f32> {
    (0..d).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect()
}

#[test]
fn disabled_tracing_records_nothing_and_allocates_nothing() {
    // session_disabled holds the session lock with the gate OFF, so no
    // parallel test can enable tracing mid-flight.
    let session = trace::session_disabled();
    assert!(!trace::enabled());
    let pool = ExecPool::new(4);
    let mut opt = MicroAdam::new(D, MicroAdamConfig::default());
    let mut params = vec![0.1f32; D];
    let g = grads(D);
    for _ in 0..3 {
        opt.step_sharded(&mut params, &g, 1e-3, &pool);
    }
    assert_eq!(trace::collected_len(), 0, "disabled run must record nothing");
    assert_eq!(trace::span_count("optim.phase"), 0);
    // Zero-cost also means zero allocation: this thread's event buffer
    // must never have grown.
    assert_eq!(trace::local_buffer_stats(), (0, 0));
    session.finish().unwrap();
}

#[test]
fn phase_span_count_is_shards_times_phases() {
    let g = grads(D);
    for workers in [1usize, 2, 4, 8] {
        let session = trace::session();
        let pool = ExecPool::new(workers);
        let mut opt = MicroAdam::new(D, MicroAdamConfig::default());
        let mut params = vec![0.1f32; D];
        opt.step_sharded(&mut params, &g, 1e-3, &pool);
        // nshards = min(workers, nb) = workers here (nb = 8): every shard
        // emits exactly one span per fused phase, plus one exec-level
        // shard span.
        assert_eq!(
            trace::span_count("optim.phase"),
            workers * PHASE_NAMES.len(),
            "workers = {workers}"
        );
        assert_eq!(trace::span_count("exec"), workers, "workers = {workers}");
        session.finish().unwrap();
    }
}

#[test]
fn chrome_trace_parses_and_ts_is_monotonic() {
    let session = trace::session();
    let pool = ExecPool::new(2);
    let mut opt = MicroAdam::new(D, MicroAdamConfig::default());
    let mut params = vec![0.1f32; D];
    let g = grads(D);
    opt.step_sharded(&mut params, &g, 1e-3, &pool);
    trace::gauge("test.gauge", 1.25);

    let doc = session.chrome_json();
    // Round-trip through the serializer: the file the CLI writes is
    // exactly this document's to_string().
    let parsed = Json::parse(&doc.to_string()).expect("chrome trace must be valid JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    let mut last_ts = f64::NEG_INFINITY;
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("ph");
        assert!(ph == "X" || ph == "C", "unexpected ph {ph:?}");
        assert!(ev.get("name").and_then(Json::as_str).is_some());
        let ts = ev.get("ts").and_then(Json::as_f64).expect("ts");
        assert!(ts >= last_ts, "ts must be sorted ({ts} < {last_ts})");
        last_ts = ts;
    }
    assert!(events.iter().any(|e| {
        e.get("cat").and_then(Json::as_str) == Some("optim.phase")
    }));
    session.finish().unwrap();
}

#[test]
fn jsonl_records_roundtrip_through_util_json() {
    let session = trace::session();
    let sp = trace::begin();
    std::hint::black_box(0u64);
    sp.end("t", "work", 3);
    trace::counter("t.bytes", 128.0);
    trace::gauge("ef.residual_norm", 0.5);

    let recs = trace::drain_step_records(7);
    assert_eq!(recs.len(), 3, "one span summary + one counter + one gauge");
    for rec in &recs {
        let back = Json::parse(&rec.to_string()).expect("record must reparse");
        assert_eq!(back.get("kind").and_then(Json::as_str), Some("trace"));
        assert_eq!(
            back.get("v").and_then(Json::as_f64),
            Some(trace::SCHEMA_VERSION as f64)
        );
        assert_eq!(back.get("step").and_then(Json::as_f64), Some(7.0));
        let ty = back.get("type").and_then(Json::as_str).unwrap();
        match ty {
            "spans" => {
                assert_eq!(back.get("cat").and_then(Json::as_str), Some("t"));
                assert_eq!(back.get("count").and_then(Json::as_f64), Some(1.0));
                assert!(back.get("total_us").and_then(Json::as_f64).unwrap() >= 0.0);
            }
            "counter" => {
                assert_eq!(back.get("value").and_then(Json::as_f64), Some(128.0));
            }
            "gauge" => {
                assert_eq!(
                    back.get("name").and_then(Json::as_str),
                    Some("ef.residual_norm")
                );
                assert_eq!(back.get("value").and_then(Json::as_f64), Some(0.5));
            }
            other => panic!("unexpected record type {other:?}"),
        }
    }
    // A second drain with nothing new collected is empty (the cursor
    // advanced past everything).
    assert!(trace::drain_step_records(8).is_empty());
    session.finish().unwrap();
}

#[test]
fn traced_eftopk_training_emits_ef_health_records() {
    let path = std::env::temp_dir().join("microadam_test_trace_dist.jsonl");
    let path = path.to_string_lossy().to_string();
    let _ = std::fs::remove_file(&path);

    let cfg = TrainConfig {
        model: "mlp_tiny".into(),
        optimizer: OptimizerKind::MicroAdam,
        schedule: LrSchedule::Const { lr: 3e-3 },
        steps: 6,
        seed: 11,
        log_every: 10_000,
        workers: 1,
        ranks: 2,
        reduce: ReducerKind::EfTopK,
        out: path.clone(),
        ..Default::default()
    };
    let session = trace::session();
    let mut tr = DistTrainer::new(cfg).unwrap();
    let mut logger = MetricsLogger::new(&path).unwrap();
    tr.train(&mut logger).unwrap();
    logger.flush().unwrap();
    session.finish().unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let mut gauges = Vec::new();
    let mut span_cats = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let j = Json::parse(line).expect("every JSONL line parses");
        if j.get("kind").and_then(Json::as_str) != Some("trace") {
            continue;
        }
        match j.get("type").and_then(Json::as_str) {
            Some("gauge") => {
                gauges.push(j.get("name").and_then(Json::as_str).unwrap().to_string())
            }
            Some("spans") => {
                span_cats.push(j.get("cat").and_then(Json::as_str).unwrap().to_string())
            }
            _ => {}
        }
    }
    // The per-step EF-health telemetry the paper's convergence story
    // rests on, plus the phase/transport spans.
    for name in ["ef.residual_norm", "ef.topk_mass", "ef.quant_abs_err", "ef.slab_density"] {
        assert!(gauges.iter().any(|g| g == name), "missing gauge {name}: {gauges:?}");
    }
    assert!(span_cats.iter().any(|c| c == "optim.phase"), "cats: {span_cats:?}");
    assert!(span_cats.iter().any(|c| c == "dist"), "cats: {span_cats:?}");
    let _ = std::fs::remove_file(&path);
}
