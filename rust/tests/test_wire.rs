//! Wire-format conformance: the frames of `rust/src/dist/wire.rs` against
//! the normative spec in `rust/src/dist/README.md`.
//!
//! Three legs:
//! * worked byte counts — the spec's examples, asserted against real
//!   reducers' `wire_bytes_per_rank()`;
//! * corrupt-frame rejection — bad magic, wrong version, unknown tag,
//!   every possible truncation, CRC damage, lying length fields;
//! * property round trip — arbitrary slab geometries, payload contents,
//!   stats blocks and header values encode -> decode bit-exactly.

use microadam::dist::wire::{
    crc32, dense_from_payload, dense_payload, slab_from_payload, slab_payload, Frame, FrameReader,
    PayloadTag, WireError, CRC_BYTES, FRAME_OVERHEAD, HEADER_BYTES, MAGIC, VERSION,
};
use microadam::dist::{build_reducer, ReducerKind, SparseReduceConfig};
use microadam::quant::BucketStats;
use microadam::util::rng::Rng;

fn frame(payload: Vec<u8>, stats: Vec<BucketStats>) -> Frame {
    Frame { rank: 2, step: 17, tag: PayloadTag::EfTopK, flags: 0, loss: 0.75, payload, stats }
}

// ---------------------------------------------------------------------------
// Worked examples from the spec (README §4)
// ---------------------------------------------------------------------------

#[test]
fn spec_worked_examples_match_reducers() {
    // §4.1: eftopk at d = 65536, paper geometry: 16 blocks of 4096,
    // k_b = 41 -> payload 4 * 16 * 41 = 2624 B, frame 2624 + 34 = 2658 B.
    let ef = build_reducer(ReducerKind::EfTopK, 1 << 16, 4, SparseReduceConfig::default());
    assert_eq!(ef.wire_bytes_per_rank(), 2624);
    assert_eq!(FRAME_OVERHEAD, 34);
    let f = frame(vec![0u8; ef.wire_bytes_per_rank()], vec![]);
    assert_eq!(f.encoded_len(), 2658);
    assert_eq!(f.encode().len(), 2658);

    // §4.2: dense at d = 2659 (mlp_tiny): payload 4 * 2659 = 10636 B,
    // frame 10670 B.
    let dense = build_reducer(ReducerKind::Dense, 2659, 2, SparseReduceConfig::default());
    assert_eq!(dense.wire_bytes_per_rank(), 10636);
    let f = frame(vec![0u8; 10636], vec![]);
    assert_eq!(f.encoded_len(), 10670);

    // header/crc split of the overhead
    assert_eq!(FRAME_OVERHEAD, HEADER_BYTES + CRC_BYTES);
    assert_eq!((HEADER_BYTES, CRC_BYTES), (30, 4));
}

// ---------------------------------------------------------------------------
// Corrupt-frame rejection
// ---------------------------------------------------------------------------

#[test]
fn rejects_bad_magic() {
    let mut bytes = frame(vec![1, 2, 3], vec![]).encode();
    bytes[0] ^= 0xFF;
    assert!(matches!(Frame::decode(&bytes), Err(WireError::BadMagic(_))));
}

#[test]
fn rejects_wrong_version() {
    let mut bytes = frame(vec![1, 2, 3], vec![]).encode();
    // version lives at offset 4..6; bump it and re-seal the CRC so only
    // the version check can fire
    bytes[4] = 2;
    let n = bytes.len();
    let crc = crc32(&bytes[..n - 4]).to_le_bytes();
    bytes[n - 4..].copy_from_slice(&crc);
    assert!(matches!(Frame::decode(&bytes), Err(WireError::BadVersion(2))));
}

#[test]
fn rejects_unknown_tag() {
    let mut bytes = frame(vec![1, 2, 3], vec![]).encode();
    bytes[16] = 9;
    let n = bytes.len();
    let crc = crc32(&bytes[..n - 4]).to_le_bytes();
    bytes[n - 4..].copy_from_slice(&crc);
    assert!(matches!(Frame::decode(&bytes), Err(WireError::BadTag(9))));
}

#[test]
fn rejects_every_truncation() {
    // A frame cut anywhere — mid-header, mid-payload, mid-stats, mid-CRC —
    // must decode to an error, never a panic or a bogus frame.
    let bytes = frame((0..64).collect(), vec![BucketStats { lo: -1.0, hi: 3.0 }; 5]).encode();
    for cut in 0..bytes.len() {
        match Frame::decode(&bytes[..cut]) {
            Err(WireError::Truncated { .. }) => {}
            other => panic!("truncation at {cut} gave {other:?}"),
        }
    }
    // the untruncated frame still decodes (the loop above really was the
    // only thing failing)
    assert!(Frame::decode(&bytes).is_ok());
}

#[test]
fn rejects_crc_damage_anywhere() {
    let clean = frame((0..32).collect(), vec![BucketStats { lo: 0.0, hi: 1.0 }]).encode();
    // flip one bit in a spread of positions across payload, stats and the
    // CRC itself (skipping bytes whose damage a structural check catches
    // first: magic, version, tag, lengths)
    for pos in [HEADER_BYTES, HEADER_BYTES + 7, HEADER_BYTES + 33, clean.len() - 1] {
        let mut bytes = clean.clone();
        bytes[pos] ^= 0x10;
        assert!(
            matches!(Frame::decode(&bytes), Err(WireError::BadCrc { .. })),
            "flip at {pos}"
        );
    }
}

#[test]
fn rejects_lying_length_fields() {
    // payload_len larger than the buffer -> truncated, not a wild read
    let mut bytes = frame(vec![5; 8], vec![]).encode();
    bytes[22..26].copy_from_slice(&100u32.to_le_bytes());
    assert!(matches!(Frame::decode(&bytes), Err(WireError::Truncated { .. })));
    // absurd payload_len -> capped before any allocation
    let mut bytes = frame(vec![5; 8], vec![]).encode();
    bytes[22..26].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(Frame::decode(&bytes), Err(WireError::TooLarge(_))));
    // absurd stats_count -> same
    let mut bytes = frame(vec![5; 8], vec![]).encode();
    bytes[26..30].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(Frame::decode(&bytes), Err(WireError::TooLarge(_))));
}

#[test]
fn rejects_wrong_size_slab_payloads() {
    let mut idx = vec![0u16; 4];
    let mut val = vec![0u16; 4];
    assert!(slab_from_payload(&[0u8; 15], &mut idx, &mut val).is_err());
    assert!(slab_from_payload(&[0u8; 17], &mut idx, &mut val).is_err());
    assert!(slab_from_payload(&[0u8; 16], &mut idx, &mut val).is_ok());
    let mut out = vec![0f32; 4];
    assert!(dense_from_payload(&[0u8; 15], &mut out).is_err());
    assert!(dense_from_payload(&[0u8; 16], &mut out).is_ok());
}

// ---------------------------------------------------------------------------
// Property tests: arbitrary geometries round-trip bit-exactly
// ---------------------------------------------------------------------------

#[test]
fn arbitrary_frames_roundtrip_bit_exactly() {
    let mut rng = Rng::seed_from_u64(0xF4A3E);
    for iter in 0..300 {
        let payload_len = rng.gen_range(2048);
        let payload: Vec<u8> = (0..payload_len).map(|_| rng.next_u64() as u8).collect();
        let stats_count = rng.gen_range(40);
        let stats: Vec<BucketStats> = (0..stats_count)
            .map(|_| BucketStats {
                // arbitrary bit patterns, NaNs and infinities included:
                // the codec moves bits, not numbers
                lo: f32::from_bits(rng.next_u64() as u32),
                hi: f32::from_bits(rng.next_u64() as u32),
            })
            .collect();
        let f = Frame {
            rank: rng.next_u64() as u16,
            step: rng.next_u64(),
            tag: match rng.gen_range(3) {
                0 => PayloadTag::Dense,
                1 => PayloadTag::TopK,
                _ => PayloadTag::EfTopK,
            },
            flags: (rng.next_u64() & 1) as u8,
            loss: f32::from_bits(rng.next_u64() as u32),
            payload,
            stats,
        };
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        assert_eq!(bytes[0..4], MAGIC);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), VERSION);
        let (back, used) = Frame::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len(), "iter {iter}");
        // bit-level equality (PartialEq would reject NaN losses)
        assert_eq!(back.rank, f.rank);
        assert_eq!(back.step, f.step);
        assert_eq!(back.tag, f.tag);
        assert_eq!(back.flags, f.flags);
        assert_eq!(back.loss.to_bits(), f.loss.to_bits(), "iter {iter}");
        assert_eq!(back.payload, f.payload);
        assert_eq!(back.stats.len(), f.stats.len());
        for (a, b) in back.stats.iter().zip(&f.stats) {
            assert_eq!(a.lo.to_bits(), b.lo.to_bits());
            assert_eq!(a.hi.to_bits(), b.hi.to_bits());
        }
    }
}

#[test]
fn arbitrary_slab_geometries_roundtrip_bit_exactly() {
    let mut rng = Rng::seed_from_u64(0x51AB);
    for _ in 0..200 {
        let entries = 1 + rng.gen_range(1500);
        let idx: Vec<u16> = (0..entries).map(|_| rng.next_u64() as u16).collect();
        let val: Vec<u16> = (0..entries).map(|_| rng.next_u64() as u16).collect();
        let payload = slab_payload(&idx, &val);
        assert_eq!(payload.len(), 4 * entries);
        let mut idx2 = vec![0u16; entries];
        let mut val2 = vec![0u16; entries];
        slab_from_payload(&payload, &mut idx2, &mut val2).unwrap();
        assert_eq!(idx, idx2);
        assert_eq!(val, val2);
    }
    // dense payloads carry raw f32 bit patterns
    for _ in 0..50 {
        let n = 1 + rng.gen_range(700);
        let g: Vec<f32> = (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        let payload = dense_payload(&g);
        let mut g2 = vec![0f32; n];
        dense_from_payload(&payload, &mut g2).unwrap();
        for (a, b) in g.iter().zip(&g2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

// ---------------------------------------------------------------------------
// Streaming fault injection: the incremental FrameReader under short
// reads, slow writers, disconnects and stale peers
// ---------------------------------------------------------------------------

/// The worst-case slow writer: at most one byte per read, every other
/// call a `WouldBlock` hiccup, then EOF.
struct Trickle {
    bytes: Vec<u8>,
    pos: usize,
    hiccup: bool,
}

impl Trickle {
    fn new(bytes: Vec<u8>) -> Self {
        Self { bytes, pos: 0, hiccup: false }
    }
}

impl std::io::Read for Trickle {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.hiccup {
            self.hiccup = false;
            return Err(std::io::ErrorKind::WouldBlock.into());
        }
        self.hiccup = true;
        if self.pos >= self.bytes.len() {
            return Ok(0);
        }
        buf[0] = self.bytes[self.pos];
        self.pos += 1;
        Ok(1)
    }
}

#[test]
fn frame_reader_reassembles_one_byte_segments() {
    // A frame delivered one byte at a time, interleaved with WouldBlock,
    // reassembles bit-exactly — the slow-writer / short-read case the
    // pipelined TCP gather must survive.
    let f = frame((0..96).collect(), vec![BucketStats { lo: -1.0, hi: 3.0 }; 3]);
    let bytes = f.encode();
    let mut src = Trickle::new(bytes.clone());
    let mut reader = FrameReader::new();
    let mut polls = 0usize;
    let got = loop {
        polls += 1;
        assert!(polls < 10 * bytes.len(), "reader never completed");
        match reader.poll_read(&mut src) {
            Ok(Some(frame)) => break frame,
            Ok(None) => {}
            Err(e) => panic!("trickled frame failed: {e}"),
        }
    };
    assert_eq!(got, f);
    assert_eq!(reader.pending_bytes(), 0);
    // the stream then closes between frames: a typed error, not a hang
    // (skip the trickler's WouldBlock hiccups to reach the EOF)
    let err = loop {
        match reader.poll_read(&mut src) {
            Ok(Some(f)) => panic!("closed stream yielded {f:?}"),
            Ok(None) => {}
            Err(e) => break e,
        }
    };
    assert!(matches!(err, WireError::Truncated { .. }), "{err}");
}

#[test]
fn frame_reader_mid_frame_disconnect_is_truncated() {
    // Disconnects anywhere — mid-header, mid-payload, mid-stats, mid-CRC —
    // surface as WireError::Truncated, never a partial frame or a hang.
    let bytes = frame((0..64).collect(), vec![BucketStats { lo: 0.0, hi: 1.0 }; 2]).encode();
    for cut in [1, HEADER_BYTES - 1, HEADER_BYTES + 5, bytes.len() - 9, bytes.len() - 1] {
        let mut src = Trickle::new(bytes[..cut].to_vec());
        let mut reader = FrameReader::new();
        let err = loop {
            match reader.poll_read(&mut src) {
                Ok(Some(f)) => panic!("cut at {cut} still yielded {f:?}"),
                Ok(None) => {}
                Err(e) => break e,
            }
        };
        assert!(matches!(err, WireError::Truncated { .. }), "cut {cut}: {err}");
    }
}

#[test]
fn frame_reader_rejects_stale_version_as_soon_as_the_header_arrives() {
    // A v2 peer is rejected the moment its header is complete — the
    // payload (which never arrives here) is not waited for.
    let mut bytes = frame(vec![9; 500], vec![]).encode();
    bytes[4] = 2; // version field
    let mut src = std::io::Cursor::new(bytes[..HEADER_BYTES].to_vec());
    let mut reader = FrameReader::new();
    assert!(matches!(reader.poll_read(&mut src), Err(WireError::BadVersion(2))));
    // same for garbage magic
    let mut bytes = frame(vec![9; 500], vec![]).encode();
    bytes[0] = b'X';
    let mut src = std::io::Cursor::new(bytes[..HEADER_BYTES].to_vec());
    let mut reader = FrameReader::new();
    assert!(matches!(reader.poll_read(&mut src), Err(WireError::BadMagic(_))));
}

#[test]
fn frame_reader_caps_lying_length_fields() {
    // An absurd payload_len fails at the header, before any allocation.
    let mut bytes = frame(vec![5; 8], vec![]).encode();
    bytes[22..26].copy_from_slice(&u32::MAX.to_le_bytes());
    let mut src = std::io::Cursor::new(bytes);
    let mut reader = FrameReader::new();
    assert!(matches!(reader.poll_read(&mut src), Err(WireError::TooLarge(_))));
}

#[test]
fn frame_reader_keeps_bytes_past_a_frame_boundary() {
    // A peer that runs ahead (two frames in one segment) loses nothing:
    // the second frame is served from the buffered remainder.
    let a = frame(vec![1, 2, 3], vec![]);
    let b = Frame { rank: 9, step: 18, ..frame(vec![4, 5], vec![]) };
    let mut bytes = a.encode();
    bytes.extend_from_slice(&b.encode());
    let mut src = std::io::Cursor::new(bytes);
    let mut reader = FrameReader::new();
    assert_eq!(reader.poll_read(&mut src).unwrap().unwrap(), a);
    assert!(reader.pending_bytes() > 0, "second frame buffered");
    assert_eq!(reader.poll_read(&mut src).unwrap().unwrap(), b);
    assert_eq!(reader.pending_bytes(), 0);
}

#[test]
fn frames_survive_stream_reassembly() {
    // A bundle written through an arbitrary-chunk stream (as a socket
    // would deliver it) re-parses into the same frames.
    let mut rng = Rng::seed_from_u64(7);
    let frames: Vec<Frame> = (0..5u16)
        .map(|r| {
            let n = rng.gen_range(300);
            Frame {
                rank: r,
                step: 3,
                tag: PayloadTag::TopK,
                flags: 0,
                loss: r as f32,
                payload: (0..n).map(|_| rng.next_u64() as u8).collect(),
                stats: vec![],
            }
        })
        .collect();
    let mut bytes = Vec::new();
    for f in &frames {
        f.encode_into(&mut bytes);
    }
    let mut cursor = std::io::Cursor::new(bytes);
    for f in &frames {
        assert_eq!(&Frame::read_from(&mut cursor).unwrap(), f);
    }
}
