//! Regression tests for the `dist::` no-panic guarantee.
//!
//! repolint (rust/tools/repolint, run by `make lint`) statically forbids
//! `unwrap`/`expect`/`panic!` in the dist wire/transport/reducer decode
//! paths; these tests pin the behavioural side of that contract: feed
//! the paths the failure modes that used to be "can't happen" expects —
//! truncated frames, a peer that dies mid-round, malformed ring hop
//! payloads, a tree child that vanishes — and assert they come
//! back as typed errors on `Result`, never as panics or hangs. (The
//! poisoned-lock leg lives with the `ExecPool` unit tests:
//! `pool_survives_a_caught_shard_panic` and
//! `every_shard_panicking_cannot_deadlock_the_barrier`.)

use microadam::dist::transport::{
    RingDriver, TcpPending, TcpTransport, Transport, TreeDriver, UdsPending, UdsTransport,
};
use microadam::dist::wire::{self, Frame, FrameReader, PayloadTag, WireError, FLAG_HOP};

fn gframe(rank: usize, step: u64) -> Frame {
    Frame {
        rank: rank as u16,
        step,
        tag: PayloadTag::Dense,
        flags: 0,
        loss: 0.0,
        payload: vec![1, 2, 3, 4],
        stats: Vec::new(),
    }
}

#[test]
fn truncated_frames_are_typed_errors_not_panics() {
    let bytes = gframe(0, 1).encode();
    // cut inside the header, at field boundaries, and one byte short of
    // a complete frame — every prefix is a typed Truncated error
    for cut in [0usize, 4, 12, 29, bytes.len() - 1] {
        match Frame::decode(&bytes[..cut]) {
            Err(WireError::Truncated { .. }) => {}
            other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
        }
    }
    // and through the incremental reader: a peer that disconnects
    // mid-frame is a typed error, not a hang or a partial frame
    let mut r = FrameReader::new();
    let mut cut = std::io::Cursor::new(bytes[..bytes.len() - 3].to_vec());
    assert!(matches!(r.poll_read(&mut cut), Err(WireError::Truncated { .. })));
}

#[test]
fn unsupported_optimizer_reducer_combo_is_err_not_panic() {
    // The optimizer x reducer gate is part of the same contract: an
    // unsupported combination must surface as a constructor `Err`, not a
    // panic mid-run after state is already allocated.
    use microadam::coordinator::config::TrainConfig;
    use microadam::dist::{DistTrainer, ReducerKind};
    use microadam::optim::OptimizerKind;
    for kind in [OptimizerKind::LdAdam, OptimizerKind::AdamMini] {
        let cfg = TrainConfig {
            model: "mlp_tiny".into(),
            optimizer: kind,
            steps: 1,
            ranks: 2,
            reduce: ReducerKind::TopK,
            ..Default::default()
        };
        let res = std::panic::catch_unwind(|| DistTrainer::new(cfg).map(|_| ()));
        match res {
            Ok(inner) => assert!(inner.is_err(), "{kind:?} x topk must be a typed error"),
            Err(_) => panic!("{kind:?} x topk panicked instead of returning Err"),
        }
    }
}

#[test]
fn tcp_worker_survives_a_dead_coordinator() {
    let pending = TcpPending::bind("127.0.0.1:0", 2).unwrap();
    let addr = pending.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || {
        let mut t = TcpTransport::connect(&addr, 1, 2).unwrap();
        // The coordinator dies between rendezvous and the exchange. The
        // send may succeed (kernel-buffered) or fail with a broken pipe;
        // either way the round must end in an error, not a panic.
        let posted = t.post_send(vec![gframe(1, 1)]);
        match posted {
            Ok(()) => t.collect().map(|_| ()),
            Err(e) => Err(e),
        }
    });
    let coord = pending.accept().unwrap();
    drop(coord);
    let res = h.join().expect("worker thread must not panic");
    assert!(res.is_err(), "a dead coordinator must surface as a typed error");
}

#[test]
fn uds_worker_survives_a_dead_coordinator() {
    let path = std::env::temp_dir().join(format!(
        "microadam-nopanic-{}-{:?}.sock",
        std::process::id(),
        std::thread::current().id()
    ));
    let pending = UdsPending::bind(&path, 2).unwrap();
    let sock = path.clone();
    let h = std::thread::spawn(move || {
        let mut t = UdsTransport::connect(&sock, 1, 2).unwrap();
        let posted = t.post_send(vec![gframe(1, 1)]);
        match posted {
            Ok(()) => t.collect().map(|_| ()),
            Err(e) => Err(e),
        }
    });
    let coord = pending.accept().unwrap();
    drop(coord);
    let res = h.join().expect("worker thread must not panic");
    assert!(res.is_err(), "a dead coordinator must surface as a typed error");
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// Topology decode paths: hop payloads and ring/tree endpoints
// ---------------------------------------------------------------------------

fn tcp_link_pair() -> (std::net::TcpStream, std::net::TcpStream) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let a = std::net::TcpStream::connect(addr).unwrap();
    let (b, _) = listener.accept().unwrap();
    (a, b)
}

#[test]
fn malformed_hop_payloads_are_typed_errors_not_panics() {
    let good = wire::hop_payload(3, &[1.0, -2.5, 0.0]);
    assert!(wire::hop_from_payload(&good).is_ok());
    // every truncation — inside the fan-in prefix, at its boundary minus
    // one, mid-f32 — decodes to a typed error, never an index panic
    for cut in [0usize, 1, wire::HOP_PREFIX_BYTES - 1, good.len() - 1, good.len() - 3] {
        assert!(
            wire::hop_from_payload(&good[..cut]).is_err(),
            "cut at {cut} must be a typed error"
        );
    }
}

#[test]
fn ring_endpoint_survives_a_dead_neighbor() {
    // Both the all-gather and the in-ring reduction wait on the
    // predecessor link; a vanished neighbor must end the round in a typed
    // error on Result — no panic, no 120 s hang.
    for reduced in [false, true] {
        let (next, _next_peer) = tcp_link_pair();
        let (prev, prev_peer) = tcp_link_pair();
        let mut ring = RingDriver::from_streams("tcp-ring", 1, 2, next, prev).unwrap();
        drop(prev_peer);
        ring.post_send(vec![gframe(1, 1)]).unwrap();
        let t0 = std::time::Instant::now();
        let res = if reduced {
            ring.collect_reduced(&mut |payload, acc| {
                if acc.is_empty() {
                    acc.resize(payload.len() / 4, 0.0);
                }
                for (i, c) in payload.chunks_exact(4).enumerate() {
                    acc[i] += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
                Ok(())
            })
        } else {
            ring.collect()
        };
        assert!(res.is_err(), "reduced={reduced}: dead neighbor must be a typed error");
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "reduced={reduced}: ring round hung: {:?}",
            t0.elapsed()
        );
    }
}

#[test]
fn ring_endpoint_rejects_a_garbage_hop_frame_typed() {
    // A predecessor that sends a FLAG_HOP frame whose payload is shorter
    // than the fan-in prefix exercises the hop decode path end to end: it
    // must surface the typed wire error, not slice-index panic.
    let (next, _next_peer) = tcp_link_pair();
    let (prev, mut prev_peer) = tcp_link_pair();
    let mut ring = RingDriver::from_streams("tcp-ring", 1, 2, next, prev).unwrap();
    let garbage = Frame {
        rank: 0,
        step: 1,
        tag: PayloadTag::Dense,
        flags: FLAG_HOP,
        loss: 0.0,
        payload: vec![9u8; wire::HOP_PREFIX_BYTES - 1],
        stats: Vec::new(),
    };
    use std::io::Write;
    prev_peer.write_all(&garbage.encode()).unwrap();
    ring.post_send(vec![gframe(1, 1)]).unwrap();
    let err = ring
        .collect_reduced(&mut |_, _| Ok(()))
        .err()
        .expect("a short hop payload must be a typed error");
    let msg = format!("{err:#}");
    assert!(msg.contains("hop"), "{msg}");
}

#[test]
fn tree_root_survives_a_dead_child() {
    let (child_link, child_peer) = tcp_link_pair();
    let mut tree = TreeDriver::from_streams("tcp-tree", 0, 2, None, vec![(1, child_link)]).unwrap();
    drop(child_peer);
    tree.post_send(vec![gframe(0, 1)]).unwrap();
    let t0 = std::time::Instant::now();
    let res = tree.collect();
    assert!(res.is_err(), "a dead tree child must surface as a typed error");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "tree gather hung: {:?}",
        t0.elapsed()
    );
}
