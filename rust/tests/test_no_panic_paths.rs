//! Regression tests for the `dist::` no-panic guarantee.
//!
//! repolint (rust/tools/repolint, run by `make lint`) statically forbids
//! `unwrap`/`expect`/`panic!` in the dist wire/transport/reducer decode
//! paths; these tests pin the behavioural side of that contract: feed
//! the paths the failure modes that used to be "can't happen" expects —
//! truncated frames, a peer that dies mid-round — and assert they come
//! back as typed errors on `Result`, never as panics or hangs. (The
//! poisoned-lock leg lives with the `ExecPool` unit tests:
//! `pool_survives_a_caught_shard_panic` and
//! `every_shard_panicking_cannot_deadlock_the_barrier`.)

use microadam::dist::transport::{TcpPending, TcpTransport, Transport, UdsPending, UdsTransport};
use microadam::dist::wire::{Frame, FrameReader, PayloadTag, WireError};

fn gframe(rank: usize, step: u64) -> Frame {
    Frame {
        rank: rank as u16,
        step,
        tag: PayloadTag::Dense,
        flags: 0,
        loss: 0.0,
        payload: vec![1, 2, 3, 4],
        stats: Vec::new(),
    }
}

#[test]
fn truncated_frames_are_typed_errors_not_panics() {
    let bytes = gframe(0, 1).encode();
    // cut inside the header, at field boundaries, and one byte short of
    // a complete frame — every prefix is a typed Truncated error
    for cut in [0usize, 4, 12, 29, bytes.len() - 1] {
        match Frame::decode(&bytes[..cut]) {
            Err(WireError::Truncated { .. }) => {}
            other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
        }
    }
    // and through the incremental reader: a peer that disconnects
    // mid-frame is a typed error, not a hang or a partial frame
    let mut r = FrameReader::new();
    let mut cut = std::io::Cursor::new(bytes[..bytes.len() - 3].to_vec());
    assert!(matches!(r.poll_read(&mut cut), Err(WireError::Truncated { .. })));
}

#[test]
fn unsupported_optimizer_reducer_combo_is_err_not_panic() {
    // The optimizer x reducer gate is part of the same contract: an
    // unsupported combination must surface as a constructor `Err`, not a
    // panic mid-run after state is already allocated.
    use microadam::coordinator::config::TrainConfig;
    use microadam::dist::{DistTrainer, ReducerKind};
    use microadam::optim::OptimizerKind;
    for kind in [OptimizerKind::LdAdam, OptimizerKind::AdamMini] {
        let cfg = TrainConfig {
            model: "mlp_tiny".into(),
            optimizer: kind,
            steps: 1,
            ranks: 2,
            reduce: ReducerKind::TopK,
            ..Default::default()
        };
        let res = std::panic::catch_unwind(|| DistTrainer::new(cfg).map(|_| ()));
        match res {
            Ok(inner) => assert!(inner.is_err(), "{kind:?} x topk must be a typed error"),
            Err(_) => panic!("{kind:?} x topk panicked instead of returning Err"),
        }
    }
}

#[test]
fn tcp_worker_survives_a_dead_coordinator() {
    let pending = TcpPending::bind("127.0.0.1:0", 2).unwrap();
    let addr = pending.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || {
        let mut t = TcpTransport::connect(&addr, 1, 2).unwrap();
        // The coordinator dies between rendezvous and the exchange. The
        // send may succeed (kernel-buffered) or fail with a broken pipe;
        // either way the round must end in an error, not a panic.
        let posted = t.post_send(vec![gframe(1, 1)]);
        match posted {
            Ok(()) => t.collect().map(|_| ()),
            Err(e) => Err(e),
        }
    });
    let coord = pending.accept().unwrap();
    drop(coord);
    let res = h.join().expect("worker thread must not panic");
    assert!(res.is_err(), "a dead coordinator must surface as a typed error");
}

#[test]
fn uds_worker_survives_a_dead_coordinator() {
    let path = std::env::temp_dir().join(format!(
        "microadam-nopanic-{}-{:?}.sock",
        std::process::id(),
        std::thread::current().id()
    ));
    let pending = UdsPending::bind(&path, 2).unwrap();
    let sock = path.clone();
    let h = std::thread::spawn(move || {
        let mut t = UdsTransport::connect(&sock, 1, 2).unwrap();
        let posted = t.post_send(vec![gframe(1, 1)]);
        match posted {
            Ok(()) => t.collect().map(|_| ()),
            Err(e) => Err(e),
        }
    });
    let coord = pending.accept().unwrap();
    drop(coord);
    let res = h.join().expect("worker thread must not panic");
    assert!(res.is_err(), "a dead coordinator must surface as a typed error");
    let _ = std::fs::remove_file(&path);
}
