//! Property-based tests over the coordinator substrates.
//!
//! proptest is not in the offline vendored crate set, so these are
//! hand-rolled property sweeps: seeded random case generators + shrink-free
//! assertion loops (100+ cases per property). Failures print the seed so a
//! case can be replayed exactly.

use microadam::coordinator::layout::{Init, ParamLayout, TensorSpec};
use microadam::optim::microadam::{EfMode, MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;
use microadam::quant::{BucketStats, Dynamic8, Quant4};
use microadam::topk::{topk_abs_block, SlidingWindow};
use microadam::util::json::Json;
use microadam::util::rng::Rng;

fn randvec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
}

#[test]
fn prop_topk_matches_full_sort() {
    for seed in 0..150u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let n = 2 + rng.gen_range(200);
        let k = 1 + rng.gen_range(n);
        let block = randvec(&mut rng, n, 10.0);
        let mut idx = vec![0u16; k];
        let mut vals = vec![0f32; k];
        let mut scratch = Vec::new();
        topk_abs_block(&block, k, &mut idx, &mut vals, &mut scratch);
        // reference: full sort by |.| descending
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| block[b].abs().partial_cmp(&block[a].abs()).unwrap());
        let min_selected = idx.iter().map(|&i| block[i as usize].abs()).fold(f32::INFINITY, f32::min);
        let kth = block[order[k - 1]].abs();
        // the k selected values must all be >= the true k-th largest
        assert!(min_selected >= kth - 1e-6, "seed {seed}: {min_selected} < {kth}");
        // indices unique and sorted
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "seed {seed}: unsorted/dup indices");
        }
        // values are the true block values at those indices
        for (&i, &v) in idx.iter().zip(&vals) {
            assert_eq!(v, block[i as usize], "seed {seed}");
        }
    }
}

#[test]
fn prop_quant4_roundtrip_bound_and_determinism() {
    for seed in 0..120u64 {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let buckets = 1 + rng.gen_range(8);
        let bucket = [4usize, 8, 16, 64][rng.gen_range(4)];
        let n = buckets * bucket;
        let scale = 10f32.powf(rng.gen_f32() * 6.0 - 3.0);
        let x = randvec(&mut rng, n, scale);
        let q = Quant4::new(bucket);
        let mut packed = vec![0u8; n / 2];
        let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; buckets];
        q.quantize(&x, &mut packed, &mut stats);
        let packed2 = {
            let mut p = vec![0u8; n / 2];
            let mut s = stats.clone();
            q.quantize(&x, &mut p, &mut s);
            p
        };
        assert_eq!(packed, packed2, "seed {seed}: quantize not deterministic");
        let mut out = vec![0f32; n];
        q.dequantize(&packed, &stats, &mut out);
        for b in 0..buckets {
            let u = stats[b].step(4);
            for i in 0..bucket {
                let err = (out[b * bucket + i] - x[b * bucket + i]).abs();
                assert!(err <= u / 2.0 + u.abs() * 1e-4 + 1e-7, "seed {seed}: err {err} u {u}");
            }
        }
    }
}

#[test]
fn prop_dynamic8_closer_than_codebook_spacing() {
    let q = Dynamic8::unsigned();
    for seed in 0..100u64 {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let n = 32;
        let x: Vec<f32> = (0..n).map(|_| rng.gen_f32() * rng.gen_f32()).collect();
        let mut codes = vec![0u8; n];
        let mut scales = vec![0f32; 1];
        q.quantize(&x, n, &mut codes, &mut scales);
        let mut out = vec![0f32; n];
        q.dequantize(&codes, n, &scales, &mut out);
        for i in 0..n {
            if x[i] > scales[0] * 1e-6 {
                let rel = (out[i] - x[i]).abs() / x[i];
                assert!(rel < 0.035 + 1e-3, "seed {seed} coord {i}: rel {rel}");
            }
        }
    }
}

#[test]
fn prop_window_weights_sum_to_one_and_order_by_age() {
    for seed in 0..100u64 {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let m = 1 + rng.gen_range(16);
        let t = 1 + rng.gen_range(60) as u64;
        let mut w = SlidingWindow::new(m, 1, 1);
        for _ in 0..t {
            w.commit_row();
        }
        let ws = w.folded_weights(t, 0.9);
        let sum: f32 = ws.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "seed {seed}: m={m} t={t} sum={sum}");
        // weights strictly decrease with age among valid rows
        let mut by_age: Vec<(usize, f32)> = (0..m)
            .filter(|&r| w.is_valid(r, t))
            .map(|r| (w.age(r, t), ws[r]))
            .collect();
        by_age.sort_by_key(|&(a, _)| a);
        for pair in by_age.windows(2) {
            assert!(pair[0].1 > pair[1].1, "seed {seed}: not decaying {by_age:?}");
        }
    }
}

#[test]
fn prop_microadam_never_touches_more_than_mk_coords() {
    for seed in 0..30u64 {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let d = 64 * (1 + rng.gen_range(4));
        let m = 1 + rng.gen_range(6);
        let cfg = MicroAdamConfig {
            m,
            block: 64,
            density: 0.02 + rng.gen_f32() as f64 * 0.1,
            qbucket: 16,
            ..Default::default()
        };
        let mut opt = MicroAdam::new(d, cfg);
        let mut x = vec![0f32; d];
        let mut moved = vec![false; d];
        // The m*k bound is on the coordinates the *window* can touch; the
        // union over the first t <= m steps stays within it (after that,
        // overwritten rows legitimately contribute fresh index sets).
        for _ in 0..m {
            let g = randvec(&mut rng, d, 1.0);
            let before = x.clone();
            opt.step(&mut x, &g, 0.01);
            for i in 0..d {
                moved[i] |= x[i] != before[i];
            }
        }
        let density = moved.iter().filter(|&&b| b).count() as f64 / d as f64;
        assert!(
            density <= opt.max_update_density() + 1e-12,
            "seed {seed}: density {density} > bound {}",
            opt.max_update_density()
        );
    }
}

#[test]
fn prop_microadam_ef_modes_converge_on_quadratic() {
    // Every EF mode must drive a quadratic toward zero; EF modes must not
    // be wildly worse than dense EF (the paper's compressed-EF claim).
    for seed in 0..10u64 {
        let mut finals = Vec::new();
        for ef in [EfMode::Dense, EfMode::Quant4] {
            let d = 256;
            let mut opt = MicroAdam::new(d, MicroAdamConfig {
                m: 5,
                block: 64,
                density: 0.05,
                qbucket: 16,
                ef,
                ..Default::default()
            });
            let mut rng = Rng::seed_from_u64(5000 + seed);
            let mut x = randvec(&mut rng, d, 1.0);
            for _ in 0..250 {
                let g = x.clone();
                opt.step(&mut x, &g, 0.05);
            }
            finals.push(x.iter().map(|v| v * v).sum::<f32>().sqrt());
        }
        assert!(finals[1] < 4.0 * finals[0] + 0.5, "seed {seed}: q4 {} vs dense {}", finals[1], finals[0]);
    }
}

#[test]
fn prop_layout_init_padding_invariant() {
    for seed in 0..60u64 {
        let mut rng = Rng::seed_from_u64(6000 + seed);
        let nt = 1 + rng.gen_range(6);
        let mut tensors = Vec::new();
        let mut inits = Vec::new();
        let mut off = 0;
        for i in 0..nt {
            let rows = 1 + rng.gen_range(8);
            let cols = 1 + rng.gen_range(8);
            tensors.push(TensorSpec::new(&format!("t{i}"), &[rows, cols], off));
            off += rows * cols;
            inits.push((
                [Init::Normal, Init::Zeros, Init::Ones][rng.gen_range(3)],
                0.02,
            ));
        }
        let d_pad = off + rng.gen_range(32);
        let layout = ParamLayout::new(tensors, inits, d_pad);
        layout.validate().unwrap();
        let flat = layout.init_flat(seed);
        assert_eq!(flat.len(), d_pad);
        assert!(flat[off..].iter().all(|&v| v == 0.0), "seed {seed}: padding not zero");
        assert!(flat.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    // random JSON trees: parse(to_string(v)) == v
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_f32() < 0.5),
            2 => Json::Num((rng.gen_f32() * 2000.0 - 1000.0).round() as f64 / 8.0),
            3 => Json::Str(format!("s{}-\"x\"\n{}", rng.next_u64() % 1000, rng.gen_range(10))),
            4 => Json::Arr((0..rng.gen_range(4)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..200u64 {
        let mut rng = Rng::seed_from_u64(7000 + seed);
        let v = gen_value(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}: {text}");
    }
}

#[test]
fn prop_ring_row_for_step_cycles() {
    for m in 1..20usize {
        let w = SlidingWindow::new(m, 1, 1);
        for t in 1..100u64 {
            let r = w.row_for_step(t);
            assert!(r < m);
            assert_eq!(w.row_for_step(t + m as u64), r, "period m");
        }
    }
}
