//! Data-parallel engine parity and accounting (test_parallel_parity.rs
//! style, one layer up):
//!
//! * `ranks = 1` + `DenseAllReduce` must reproduce the single-process
//!   trajectory **bit-for-bit** for every optimizer kind — the reducer is
//!   an exact identity and the chunked `step_multi` equals the flat step.
//! * the whole engine (replica fan-out + reducer + sharded optimizer) must
//!   be invariant to the worker count.
//! * `EfTopKReduce` residual accounting must report the paper-dtype bytes
//!   (4-bit codes + per-bucket stats, per rank).

use microadam::coordinator::config::TrainConfig;
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::dist::wire::HELLO_DIGEST_BYTES;
use microadam::dist::{
    native_model_spec, rank_data_seed, DistTrainer, EfTopKReduce, GradReducer, ReducerKind,
    SparseReduceConfig, TopKReduce, FRAME_OVERHEAD,
};
use microadam::models::mlp::Mlp;
use microadam::optim::{self, OptimizerKind};
use microadam::quant::Quant4;

fn cfg(ranks: usize, reduce: ReducerKind, opt: OptimizerKind, steps: u64) -> TrainConfig {
    TrainConfig {
        model: "mlp_tiny".into(),
        optimizer: opt,
        schedule: LrSchedule::Const { lr: 3e-3 },
        steps,
        seed: 7,
        log_every: 10_000,
        workers: 2,
        ranks,
        reduce,
        ..Default::default()
    }
}

#[test]
fn rank1_dense_matches_single_process_bitwise_for_every_optimizer() {
    // The single-process reference: same model, same rank-0 data stream,
    // same optimizer, flat `step` (which the chunked trainer path is
    // bit-equal to, pinned in optim::tests::layout_chunks_*).
    let spec = native_model_spec("mlp_tiny");
    for &kind in OptimizerKind::all() {
        let steps = 5u64;
        let mut dist = DistTrainer::new(cfg(1, ReducerKind::Dense, kind, steps)).unwrap();
        assert!(dist.is_native());

        let mlp = Mlp::new(spec.sizes.clone());
        let d = mlp.dim();
        assert_eq!(d, dist.dim());
        let mut params = mlp.init(7);
        let mut opt = optim::build(kind, d, mlp.specs(), 0.0);
        let mut ds = microadam::data::NliDataset::new(
            spec.vocab,
            spec.n_classes,
            rank_data_seed(7, 0),
        );
        let (mut toks, mut labs, mut feats) = (vec![], vec![], vec![]);
        let mut grads = vec![0f32; d];

        for s in 0..steps {
            let dist_loss = dist.step(3e-3).unwrap();
            ds.next_batch(spec.batch, spec.seq, &mut toks, &mut labs);
            Mlp::featurize_tokens(spec.vocab, &toks, spec.seq, &mut feats);
            let ref_loss = mlp.loss_grad(&params, &feats, &labs, &mut grads);
            opt.step(&mut params, &grads, 3e-3);
            assert_eq!(dist_loss, ref_loss, "{kind:?} loss diverged at step {s}");
            assert_eq!(dist.params_vec(), params, "{kind:?} params diverged at step {s}");
        }
    }
}

#[test]
fn dist_trajectory_is_worker_count_invariant() {
    for reduce in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
        let mut reference: Option<Vec<f32>> = None;
        for workers in [1usize, 2, 4, 8] {
            let mut c = cfg(4, reduce, OptimizerKind::MicroAdam, 8);
            c.workers = workers;
            let mut t = DistTrainer::new(c).unwrap();
            let mut logger = MetricsLogger::new("").unwrap();
            t.train(&mut logger).unwrap();
            let params = t.params_vec();
            match &reference {
                None => reference = Some(params),
                Some(r) => assert_eq!(r, &params, "{reduce:?} workers={workers}"),
            }
        }
    }
}

#[test]
fn ranks_change_the_trajectory_but_not_stability() {
    // More ranks = more data per step: trajectories differ, training stays
    // finite and the loss does not blow up.
    let mut finals = Vec::new();
    for ranks in [1usize, 2, 4, 8] {
        let mut t =
            DistTrainer::new(cfg(ranks, ReducerKind::EfTopK, OptimizerKind::MicroAdam, 30))
                .unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        t.train(&mut logger).unwrap();
        assert!(logger.history.iter().all(|m| m.loss.is_finite()), "ranks={ranks}");
        assert!(
            logger.tail_loss(5) < logger.first_loss() + 0.1,
            "ranks={ranks}: {} -> {}",
            logger.first_loss(),
            logger.tail_loss(5)
        );
        finals.push(t.params_vec());
    }
    assert_ne!(finals[0], finals[1], "rank count must change the data seen");
}

#[test]
fn dense_reduce_training_decreases_loss() {
    // With the exact mean gradient this is ordinary training — the loss
    // must actually go down, multi-rank included. (AdamW: the same recipe
    // the Mlp unit test pins as learnable.)
    let mut t =
        DistTrainer::new(cfg(4, ReducerKind::Dense, OptimizerKind::AdamW, 120)).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    t.train(&mut logger).unwrap();
    assert!(
        logger.tail_loss(10) < logger.first_loss(),
        "{} -> {}",
        logger.first_loss(),
        logger.tail_loss(10)
    );
}

#[test]
fn zoo_optimizers_train_end_to_end_at_ranks_2_dense() {
    // The acceptance shape for the optimizer zoo: `--optim ldadam` /
    // `--optim adammini` with `--ranks 2 --reduce dense` runs end-to-end
    // and actually trains.
    for kind in [OptimizerKind::LdAdam, OptimizerKind::AdamMini] {
        let mut t = DistTrainer::new(cfg(2, ReducerKind::Dense, kind, 80)).unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        t.train(&mut logger).unwrap();
        assert!(logger.history.iter().all(|m| m.loss.is_finite()), "{kind:?}");
        assert!(
            logger.tail_loss(10) < logger.first_loss(),
            "{kind:?}: {} -> {}",
            logger.first_loss(),
            logger.tail_loss(10)
        );
    }
}

#[test]
fn unsupported_optimizer_reducer_combos_are_typed_errors() {
    // Plain Top-K drops gradient mass with no error feedback; LDAdam and
    // Adam-mini compound that bias into their own compressed state, so the
    // combination must be refused up front — a typed error naming the
    // reducer, never a panic or a silently-biased run.
    for kind in [OptimizerKind::LdAdam, OptimizerKind::AdamMini] {
        let err = DistTrainer::new(cfg(2, ReducerKind::TopK, kind, 1))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("topk"), "{kind:?}: {err}");
        // the self-correcting sparse exchange stays available
        assert!(DistTrainer::new(cfg(2, ReducerKind::EfTopK, kind, 1)).is_ok(), "{kind:?}");
    }
}

#[test]
fn eftopk_residual_accounting_reports_paper_dtype_bytes() {
    // Paper geometry: block 4096, bucket 64 -> per rank the residual costs
    // exactly what Quant4 reports (d/2 packed nibbles + 2 f32 stats per
    // bucket), and nothing else.
    let d = 4 * 4096;
    for ranks in [1usize, 2, 4, 8] {
        let ef = EfTopKReduce::new(d, ranks, SparseReduceConfig::default());
        let expect = ranks * Quant4::new(microadam::QBUCKET).state_bytes(d);
        assert_eq!(ef.residual_state_bytes(), expect);
        assert_eq!(expect, ranks * (d / 2 + 2 * 4 * (d / 64)));
        // plain TopK keeps no residual
        let topk = TopKReduce::new(d, ranks, SparseReduceConfig::default());
        assert_eq!(topk.residual_state_bytes(), 0);
    }
}

#[test]
fn wire_accounting_scales_with_ranks_and_steps() {
    for (reduce, sparse) in
        [(ReducerKind::Dense, false), (ReducerKind::TopK, true), (ReducerKind::EfTopK, true)]
    {
        let steps = 6u64;
        let ranks = 4usize;
        let mut t =
            DistTrainer::new(cfg(ranks, reduce, OptimizerKind::MicroAdam, steps)).unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        t.train(&mut logger).unwrap();
        let per_step = t.wire_bytes_total() / steps;
        assert_eq!(t.wire_bytes_total() % steps, 0);
        if sparse {
            // compressed exchange must be far below the dense 4 B/param
            assert!(
                (per_step as usize) < ranks * 4 * t.dim() / 10,
                "{reduce:?}: {per_step} B/step vs dense {}",
                ranks * 4 * t.dim()
            );
        } else {
            // framed accounting: payload (4 B/param) + fixed frame overhead
            assert_eq!(per_step as usize, ranks * (4 * t.dim() + FRAME_OVERHEAD));
        }
        // the loopback transport physically framed every accounted byte
        // (plus the one-time config-digest handshake round)
        let handshake = (ranks * (FRAME_OVERHEAD + HELLO_DIGEST_BYTES)) as u64;
        assert_eq!(
            t.transport_bytes_sent(),
            t.wire_bytes_total() + handshake,
            "{reduce:?}"
        );
        assert_eq!(
            t.frame_bytes_per_rank() as u64 * ranks as u64 * steps,
            t.wire_bytes_total(),
            "{reduce:?}"
        );
    }
}
