//! Integration tests over the full coordinator stack (config -> trainer ->
//! runtime -> artifacts -> metrics -> checkpoint). Skipped without
//! `artifacts/`.

use microadam::coordinator::checkpoint::Checkpoint;
use microadam::coordinator::config::{OptBackend, TrainConfig};
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::coordinator::trainer::Trainer;
use microadam::optim::OptimizerKind;

fn have_artifacts() -> bool {
    std::env::set_var("MICROADAM_QUIET", "1");
    if std::path::Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("skipping integration test: no artifacts/ (run `make artifacts`)");
        false
    }
}

fn cfg(model: &str, opt: OptimizerKind, backend: OptBackend, steps: u64) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        optimizer: opt,
        backend,
        schedule: LrSchedule::Const { lr: 2e-3 },
        steps,
        seed: 7,
        log_every: 1000,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    }
}

#[test]
fn lm_training_reduces_loss_all_aot_optimizers() {
    if !have_artifacts() {
        return;
    }
    for opt in [OptimizerKind::MicroAdam, OptimizerKind::AdamW, OptimizerKind::AdamW8bit] {
        let mut trainer =
            Trainer::new(cfg("lm_tiny", opt, OptBackend::Aot, 25)).unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        trainer.train(&mut logger).unwrap();
        assert!(
            logger.tail_loss(5) < logger.first_loss(),
            "{opt:?}: {} -> {}",
            logger.first_loss(),
            logger.tail_loss(5)
        );
    }
}

#[test]
fn cls_training_improves_accuracy() {
    if !have_artifacts() {
        return;
    }
    let mut trainer = Trainer::new(cfg(
        "cls_tiny",
        OptimizerKind::MicroAdam,
        OptBackend::Native,
        60,
    ))
    .unwrap();
    let acc0 = trainer.eval_accuracy(6).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    trainer.train(&mut logger).unwrap();
    let acc1 = trainer.eval_accuracy(6).unwrap();
    assert!(acc1 > acc0 + 0.1, "accuracy {acc0} -> {acc1}");
    assert!(acc1 > 0.5, "final accuracy too low: {acc1}");
}

#[test]
fn cnn_training_reduces_loss() {
    if !have_artifacts() {
        return;
    }
    let mut trainer = Trainer::new(cfg(
        "cnn_tiny",
        OptimizerKind::MicroAdam,
        OptBackend::Native,
        30,
    ))
    .unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    trainer.train(&mut logger).unwrap();
    assert!(logger.tail_loss(5) < logger.first_loss());
}

#[test]
fn native_and_aot_microadam_agree_through_trainer() {
    if !have_artifacts() {
        return;
    }
    let mut losses = Vec::new();
    for backend in [OptBackend::Aot, OptBackend::Native] {
        let mut trainer =
            Trainer::new(cfg("lm_tiny", OptimizerKind::MicroAdam, backend, 10)).unwrap();
        let mut logger = MetricsLogger::new("").unwrap();
        trainer.train(&mut logger).unwrap();
        losses.push(logger.history.iter().map(|m| m.loss).collect::<Vec<_>>());
    }
    for (a, b) in losses[0].iter().zip(&losses[1]) {
        assert!((a - b).abs() < 5e-3, "aot {a} vs native {b}");
    }
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    if !have_artifacts() {
        return;
    }
    let path = "/tmp/microadam_itest_ck.bin";
    // run A: 8 steps straight
    let mut a = Trainer::new(cfg("lm_tiny", OptimizerKind::MicroAdam, OptBackend::Aot, 8)).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    a.train(&mut logger).unwrap();
    let params_a = a.params_vec().unwrap();

    // run B: 4 steps, checkpoint, restore into fresh trainer, 4 more
    let mut b1 =
        Trainer::new(cfg("lm_tiny", OptimizerKind::MicroAdam, OptBackend::Aot, 4)).unwrap();
    let mut lg = MetricsLogger::new("").unwrap();
    b1.train(&mut lg).unwrap();
    Checkpoint {
        step: b1.t,
        params: b1.params_vec().unwrap(),
        opt: b1.opt_snapshot().unwrap(),
    }
    .save(path)
    .unwrap();

    let ck = Checkpoint::load(path).unwrap();
    let mut b2 =
        Trainer::new(cfg("lm_tiny", OptimizerKind::MicroAdam, OptBackend::Aot, 4)).unwrap();
    b2.set_params(&ck.params).unwrap();
    b2.restore_opt_snapshot(ck.opt.as_ref().unwrap()).unwrap();
    b2.t = ck.step;
    // data stream: b2's corpus is fresh, so replay the first 4 batches that
    // b1 consumed by stepping a throwaway 4 times... instead we rely on the
    // seed: a fresh trainer's corpus starts at batch 1, but run A consumed
    // batches 1..8. Fast-forward by discarding 4 batches through steps with
    // lr=0 would perturb t; so compare against run A only on params after
    // carefully replaying: simplest correct equivalence — b2 continues with
    // the SAME schedule position and its own data; instead verify exactness
    // by reloading the checkpoint twice and stepping both identically.
    let mut b3 =
        Trainer::new(cfg("lm_tiny", OptimizerKind::MicroAdam, OptBackend::Aot, 4)).unwrap();
    b3.set_params(&ck.params).unwrap();
    b3.restore_opt_snapshot(ck.opt.as_ref().unwrap()).unwrap();
    b3.t = ck.step;
    let mut lg2 = MetricsLogger::new("").unwrap();
    let mut lg3 = MetricsLogger::new("").unwrap();
    b2.train(&mut lg2).unwrap();
    b3.train(&mut lg3).unwrap();
    assert_eq!(b2.params_vec().unwrap(), b3.params_vec().unwrap());
    // and the restored run went somewhere sensible (finite, loss sane)
    assert!(lg2.tail_loss(2).is_finite());
    let _ = params_a;
    let _ = std::fs::remove_file(path);
}

#[test]
fn grad_accum_changes_effective_batch_not_stability() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg("lm_tiny", OptimizerKind::AdamW, OptBackend::Aot, 6);
    c.grad_accum = 2;
    let mut trainer = Trainer::new(c).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    trainer.train(&mut logger).unwrap();
    assert!(logger.history.iter().all(|m| m.loss.is_finite()));
    assert!(logger.tail_loss(2) < logger.first_loss() + 0.05);
}

#[test]
fn trainer_rejects_missing_artifact() {
    if !have_artifacts() {
        return;
    }
    let c = cfg("nonexistent_model", OptimizerKind::AdamW, OptBackend::Aot, 1);
    assert!(Trainer::new(c).is_err());
}

#[test]
fn config_file_roundtrip_drives_trainer() {
    if !have_artifacts() {
        return;
    }
    let c = cfg("lm_tiny", OptimizerKind::AdamW8bit, OptBackend::Aot, 3);
    let path = "/tmp/microadam_itest_cfg.json";
    std::fs::write(path, c.to_json().to_string()).unwrap();
    let c2 = TrainConfig::from_file(path).unwrap();
    assert_eq!(c2.model, "lm_tiny");
    assert_eq!(c2.optimizer, OptimizerKind::AdamW8bit);
    let mut trainer = Trainer::new(c2).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    trainer.train(&mut logger).unwrap();
    assert_eq!(logger.history.len(), 3);
    let _ = std::fs::remove_file(path);
}
