//! Transport-layer parity: the multi-process exchange must be a
//! bit-perfect re-plumbing of the loopback engine.
//!
//! * `uds` / `shm` runs (one endpoint per thread, real sockets / mailbox
//!   files) produce the **same loss series and final parameters, to the
//!   bit**, as the loopback run with the same seeds.
//! * the framed bytes measured over the real socket equal
//!   `wire_bytes_per_rank() + FRAME_OVERHEAD` per rank per step — the
//!   accounting identity the wire spec (`rust/src/dist/README.md`)
//!   promises.
//! * the actual `microadam train --transport uds|shm` launcher (separate
//!   OS processes via fork/exec) reproduces the loopback metrics file.

use std::path::PathBuf;

use microadam::coordinator::config::TrainConfig;
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::dist::wire::HELLO_DIGEST_BYTES;
use microadam::dist::{
    DistTrainer, ReducerKind, ShmTransport, Transport, TransportKind, UdsPending, UdsTransport,
    FRAME_OVERHEAD,
};
use microadam::optim::OptimizerKind;
use microadam::util::json::Json;

const RANKS: usize = 3;
const STEPS: u64 = 8;

fn cfg(reduce: ReducerKind, transport: TransportKind) -> TrainConfig {
    TrainConfig {
        model: "mlp_tiny".into(),
        optimizer: OptimizerKind::MicroAdam,
        schedule: LrSchedule::Const { lr: 3e-3 },
        steps: STEPS,
        seed: 7,
        log_every: 10_000,
        workers: 2,
        ranks: RANKS,
        reduce,
        transport,
        ..Default::default()
    }
}

fn unique_path(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "microadam-tpar-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::SeqCst)
    ))
}

/// Loss series (bit patterns) + final params of a loopback run.
fn run_loopback(reduce: ReducerKind) -> (Vec<u32>, Vec<f32>) {
    let mut t = DistTrainer::new(cfg(reduce, TransportKind::Loopback)).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    t.train(&mut logger).unwrap();
    (logger.history.iter().map(|m| m.loss.to_bits()).collect(), t.params_vec())
}

struct EndpointReport {
    losses: Vec<u32>,
    params: Vec<f32>,
    bytes_sent: u64,
    bytes_received: u64,
    wire_per_rank: usize,
}

/// Run one endpoint (coordinator or worker) to completion in the calling
/// thread. The trainer is built inside so nothing non-Send crosses.
fn run_endpoint(
    reduce: ReducerKind,
    kind: TransportKind,
    transport: Box<dyn Transport>,
    rank: usize,
) -> EndpointReport {
    let mut t = DistTrainer::with_transport(cfg(reduce, kind), transport, vec![rank]).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    t.train(&mut logger).unwrap();
    EndpointReport {
        losses: logger.history.iter().map(|m| m.loss.to_bits()).collect(),
        params: t.params_vec(),
        bytes_sent: t.transport_bytes_sent(),
        bytes_received: t.transport_bytes_received(),
        wire_per_rank: t.frame_bytes_per_rank() - FRAME_OVERHEAD,
    }
}

fn run_multiproc(reduce: ReducerKind, kind: TransportKind) -> (EndpointReport, Vec<EndpointReport>) {
    let rdv = unique_path(match kind {
        TransportKind::Uds => "uds",
        TransportKind::Shm => "shm",
        TransportKind::Loopback => unreachable!(),
    });
    match kind {
        TransportKind::Uds => {
            let pending = UdsPending::bind(&rdv, RANKS).unwrap();
            let workers: Vec<_> = (1..RANKS)
                .map(|r| {
                    let rdv = rdv.clone();
                    std::thread::spawn(move || {
                        let t = UdsTransport::connect(&rdv, r, RANKS).unwrap();
                        run_endpoint(reduce, kind, Box::new(t), r)
                    })
                })
                .collect();
            let coord = run_endpoint(reduce, kind, Box::new(pending.accept().unwrap()), 0);
            (coord, workers.into_iter().map(|w| w.join().unwrap()).collect())
        }
        TransportKind::Shm => {
            let coord_t = ShmTransport::coordinator(&rdv, RANKS).unwrap();
            let workers: Vec<_> = (1..RANKS)
                .map(|r| {
                    let rdv = rdv.clone();
                    std::thread::spawn(move || {
                        let t = ShmTransport::worker(&rdv, r, RANKS).unwrap();
                        run_endpoint(reduce, kind, Box::new(t), r)
                    })
                })
                .collect();
            let coord = run_endpoint(reduce, kind, Box::new(coord_t), 0);
            (coord, workers.into_iter().map(|w| w.join().unwrap()).collect())
        }
        TransportKind::Loopback => unreachable!(),
    }
}

#[test]
fn uds_and_shm_match_loopback_bitwise() {
    for reduce in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
        let (loop_losses, loop_params) = run_loopback(reduce);
        assert_eq!(loop_losses.len(), STEPS as usize);
        for kind in [TransportKind::Uds, TransportKind::Shm] {
            let (coord, workers) = run_multiproc(reduce, kind);
            assert_eq!(coord.losses, loop_losses, "{reduce:?} {kind:?} loss series");
            assert_eq!(coord.params, loop_params, "{reduce:?} {kind:?} final params");
            // the replicated state never drifted: every worker holds the
            // coordinator's exact parameters
            for (i, w) in workers.iter().enumerate() {
                assert_eq!(w.params, loop_params, "{reduce:?} {kind:?} worker {}", i + 1);
                // workers run silent: no logged history
                assert!(w.losses.is_empty());
            }
        }
    }
}

#[test]
fn framed_socket_bytes_match_accounting() {
    // Acceptance criterion: bytes measured over the real socket equal the
    // reducer's accounted wire bytes plus the documented frame overhead.
    let digest = (FRAME_OVERHEAD + HELLO_DIGEST_BYTES) as u64;
    for kind in [TransportKind::Uds, TransportKind::Shm] {
        let (coord, workers) = run_multiproc(ReducerKind::EfTopK, kind);
        let framed = (coord.wire_per_rank + FRAME_OVERHEAD) as u64;
        for w in &workers {
            // uplink: one config-digest handshake frame, then exactly one
            // gradient frame per step (uds additionally sends the one-time
            // empty rendezvous hello)
            let hello = if kind == TransportKind::Uds { FRAME_OVERHEAD as u64 } else { 0 };
            assert_eq!(
                w.bytes_sent,
                STEPS * framed + digest + hello,
                "{kind:?} worker uplink"
            );
            // downlink: the full bundle (all ranks) for the handshake
            // round and every step
            assert_eq!(
                w.bytes_received,
                (STEPS * framed + digest) * RANKS as u64,
                "{kind:?} bundle"
            );
        }
        // the coordinator gathered one frame per worker per round
        assert_eq!(
            coord.bytes_received,
            (STEPS * framed + digest) * (RANKS as u64 - 1),
            "{kind:?} coordinator gather"
        );
    }
}

#[test]
fn silent_uds_connection_cannot_hold_the_accept_loop() {
    // Regression: a peer that connects but never sends its hello frame
    // used to hold the accept loop for the full PEER_TIMEOUT while the
    // other ranks queued behind it. The hello wait is now bounded per
    // connection, so the rendezvous fails fast and typed instead.
    use std::os::unix::net::UnixStream;
    let rdv = unique_path("hello");
    let mut pending = UdsPending::bind(&rdv, 3).unwrap();
    pending.set_hello_wait(std::time::Duration::from_millis(300));
    // one legitimate worker (connect + hello; the aborted run is expected)
    let rdv2 = rdv.clone();
    let real = std::thread::spawn(move || {
        let _t = UdsTransport::connect(&rdv2, 1, 3).unwrap();
    });
    // ...and one that connects but never speaks, held open so the failure
    // is the bounded hello wait, not a disconnect
    let _silent = UnixStream::connect(&rdv).unwrap();
    let t0 = std::time::Instant::now();
    let err = pending.accept().err().expect("silent peer must abort the rendezvous");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "accept loop hung for {:?}",
        t0.elapsed()
    );
    assert!(format!("{err:#}").contains("hello"), "{err:#}");
    real.join().unwrap();
}

#[test]
fn mismatched_worker_config_is_rejected_at_handshake() {
    // A hand-started worker with a different seed must fail the round-0
    // config-digest exchange on BOTH endpoints — never train divergently.
    let rdv = unique_path("digest");
    let pending = UdsPending::bind(&rdv, 2).unwrap();
    let worker = std::thread::spawn(move || {
        let t = UdsTransport::connect(&rdv, 1, 2).unwrap();
        let mut bad = cfg(ReducerKind::EfTopK, TransportKind::Uds);
        bad.ranks = 2;
        bad.seed = 999; // trajectory-relevant mismatch
        DistTrainer::with_transport(bad, Box::new(t), vec![1]).err().map(|e| e.to_string())
    });
    let mut good = cfg(ReducerKind::EfTopK, TransportKind::Uds);
    good.ranks = 2;
    let coord =
        DistTrainer::with_transport(good, Box::new(pending.accept().unwrap()), vec![0]);
    let coord_err = coord.err().expect("coordinator must reject the mismatch").to_string();
    assert!(coord_err.contains("digest"), "{coord_err}");
    let worker_err = worker.join().unwrap().expect("worker must reject the mismatch");
    assert!(worker_err.contains("digest"), "{worker_err}");
}

// ---------------------------------------------------------------------------
// True multi-process: drive the real `microadam train` launcher
// ---------------------------------------------------------------------------

/// Extract the (step, loss-as-string) series and the final_loss record
/// from a metrics JSONL file. Losses compare as their serialized strings:
/// equal f32 bits serialize identically, so string equality is bit
/// equality.
fn metrics_series(path: &std::path::Path) -> (Vec<(u64, String)>, Option<String>) {
    let text = std::fs::read_to_string(path).unwrap();
    let mut series = Vec::new();
    let mut final_loss = None;
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        if let (Some(step), Some(loss)) = (j.get("step"), j.get("loss")) {
            series.push((step.as_f64().unwrap() as u64, loss.to_string()));
        }
        if let Some(fl) = j.get("final_loss") {
            final_loss = Some(fl.to_string());
        }
    }
    (series, final_loss)
}

fn launch(transport: &str, out: &std::path::Path) {
    let status = std::process::Command::new(env!("CARGO_BIN_EXE_microadam"))
        .args([
            "train",
            "--model",
            "mlp_tiny",
            "--optimizer",
            "micro-adam",
            "--ranks",
            "3",
            "--reduce",
            "eftopk",
            "--transport",
            transport,
            "--steps",
            "8",
            "--seed",
            "7",
            "--workers",
            "2",
            "--lr",
            "3e-3",
            "--out",
        ])
        .arg(out)
        .status()
        .expect("spawn microadam train");
    assert!(status.success(), "microadam train --transport {transport} failed");
}

#[test]
fn launcher_processes_match_loopback_metrics() {
    let dir = unique_path("launch");
    std::fs::create_dir_all(&dir).unwrap();
    let loop_out = dir.join("loopback.jsonl");
    launch("loopback", &loop_out);
    let (loop_series, loop_final) = metrics_series(&loop_out);
    assert_eq!(loop_series.len(), 8);
    for transport in ["uds", "shm"] {
        let out = dir.join(format!("{transport}.jsonl"));
        launch(transport, &out);
        let (series, final_loss) = metrics_series(&out);
        assert_eq!(series, loop_series, "{transport} per-step losses");
        assert_eq!(final_loss, loop_final, "{transport} final loss");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
