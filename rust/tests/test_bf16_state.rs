//! bf16 storage substrate: property tests for the converter (RN-even
//! rounding, NaN/inf/subnormal passthrough, exhaustive round-trip) and the
//! window save/load checkpoint round trip — the state the bf16-native
//! sliding window now depends on bit-for-bit.

use microadam::coordinator::checkpoint::Checkpoint;
use microadam::optim::microadam::{MicroAdam, MicroAdamConfig};
use microadam::optim::{OptSnapshot, Optimizer};
use microadam::util::bf16::{bf16_to_f32, f32_to_bf16};
use microadam::util::rng::Rng;

fn randvec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
}

#[test]
fn prop_round_to_nearest_even_against_neighbours() {
    // For any finite f32, the result must be the nearer of the two
    // adjacent bf16 values (computed exactly in f64), ties going to the
    // even mantissa — 4000 random bit patterns plus handpicked midpoints.
    let mut rng = Rng::seed_from_u64(0);
    let mut cases: Vec<u32> = (0..4000).map(|_| rng.next_u64() as u32).collect();
    // exact midpoints (low half == 0x8000) around even and odd kept bits,
    // plus near-midpoint neighbours
    for hi in [0x3F80u32, 0x3F81, 0x4000, 0x0001, 0x7F7E, 0x7F7F] {
        for lo in [0x7FFFu32, 0x8000, 0x8001, 0x0000, 0x0001] {
            cases.push((hi << 16) | lo);
            cases.push((hi << 16) | lo | 0x8000_0000);
        }
    }
    for bits in cases {
        let x = f32::from_bits(bits);
        if !x.is_finite() {
            continue;
        }
        let got = f32_to_bf16(x);
        let lo = (bits >> 16) as u16;
        if bits & 0xFFFF == 0 {
            assert_eq!(got, lo, "exact value must pass through ({bits:#x})");
            continue;
        }
        // neighbours in the bf16 domain: IEEE bit patterns of one sign are
        // ordered, so +1 on the bits is the next representable magnitude
        let hi = lo.wrapping_add(1);
        let (a, b) = (bf16_to_f32(lo) as f64, bf16_to_f32(hi) as f64);
        if !b.is_finite() {
            // top-binade overflow: the finite-distance comparison below
            // does not model the "half an ulp past max-finite rounds to
            // infinity" rule; pinned separately in
            // overflow_rounds_to_infinity_past_the_midpoint.
            continue;
        }
        let xf = x as f64;
        let (da, db) = ((xf - a).abs(), (b - xf).abs());
        let expect = if da < db {
            lo
        } else if db < da {
            hi
        } else if lo & 1 == 0 {
            lo
        } else {
            hi
        };
        assert_eq!(
            got, expect,
            "bits {bits:#010x} (x={x:e}): got {got:#06x}, expected {expect:#06x} (da={da:e} db={db:e})"
        );
    }
}

#[test]
fn overflow_rounds_to_infinity_past_the_midpoint() {
    // lo = 0x7F7F is the largest finite bf16; its f32 midpoint to the
    // infinity encoding is 0x7F7F8000. RNE: below -> max finite, at the
    // midpoint -> even (0x7F80 = inf), above -> inf. Mirrored for -inf.
    assert_eq!(f32_to_bf16(f32::from_bits(0x7F7F_7FFF)), 0x7F7F);
    assert_eq!(f32_to_bf16(f32::from_bits(0x7F7F_8000)), 0x7F80);
    assert_eq!(f32_to_bf16(f32::from_bits(0x7F7F_8001)), 0x7F80);
    assert_eq!(f32_to_bf16(f32::from_bits(0xFF7F_7FFF)), 0xFF7F);
    assert_eq!(f32_to_bf16(f32::from_bits(0xFF7F_8000)), 0xFF80);
    assert_eq!(f32_to_bf16(f32::from_bits(0xFF7F_8001)), 0xFF80);
}

#[test]
fn exhaustive_bf16_roundtrip_is_identity() {
    // Every one of the 65536 bf16 bit patterns survives widen + re-round.
    for bits in 0..=u16::MAX {
        let f = bf16_to_f32(bits);
        if f.is_nan() {
            assert!(bf16_to_f32(f32_to_bf16(f)).is_nan(), "{bits:#06x}");
        } else {
            assert_eq!(f32_to_bf16(f), bits, "{bits:#06x} -> {f:e}");
        }
    }
}

#[test]
fn specials_and_subnormals_pass_through() {
    assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
    assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xFF80);
    assert_eq!(f32_to_bf16(0.0), 0x0000);
    assert_eq!(f32_to_bf16(-0.0), 0x8000);
    assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    // bf16 shares f32's exponent range, so f32 subnormals map onto bf16
    // subnormals: values whose magnitude survives the kept 7 mantissa bits
    // must not flush to zero
    let sub = f32::from_bits(0x0001_0000); // == bf16 subnormal 0x0001 exactly
    assert!(sub > 0.0 && !sub.is_normal());
    assert_eq!(f32_to_bf16(sub), 0x0001);
    assert_eq!(bf16_to_f32(f32_to_bf16(sub)), sub, "representable subnormal must pass through");
    // exactly half the smallest bf16 subnormal is a tie -> even -> zero
    assert_eq!(f32_to_bf16(f32::from_bits(0x0000_8000)), 0x0000);
    // and anything past the midpoint rounds up to the smallest subnormal
    assert_eq!(f32_to_bf16(f32::from_bits(0x0000_8001)), 0x0001);
    // the smallest f32 subnormal rounds to zero
    assert_eq!(f32_to_bf16(f32::from_bits(1)), 0x0000);
    // sign symmetry on finite values
    let mut rng = Rng::seed_from_u64(7);
    for _ in 0..500 {
        let x = f32::from_bits(rng.next_u64() as u32);
        if x.is_nan() {
            continue;
        }
        assert_eq!(f32_to_bf16(-x), f32_to_bf16(x) ^ 0x8000, "{x:e}");
    }
}

#[test]
fn window_checkpoint_roundtrip_resumes_bit_exactly() {
    // Save the native MicroAdam state (bf16 window included) through the
    // binary checkpoint format, reload into a fresh optimizer, and require
    // the continuation to be bit-identical: the bf16 bits must survive the
    // f32-typed snapshot encoding exactly.
    let path = "/tmp/microadam_bf16_window_ck_test.bin";
    let d = 300; // padded tail included
    let cfg = MicroAdamConfig { m: 4, block: 64, density: 0.05, qbucket: 16, ..Default::default() };
    let mut a = MicroAdam::new(d, cfg);
    let mut rng = Rng::seed_from_u64(41);
    let mut xa = randvec(&mut rng, d, 1.0);
    for _ in 0..6 {
        let g = randvec(&mut rng, d, 1.0);
        a.step(&mut xa, &g, 0.01);
    }
    let snap = a.snapshot().unwrap();
    Checkpoint { step: a.t(), params: xa.clone(), opt: Some(OptSnapshot::MicroAdam(snap)) }
        .save(path)
        .unwrap();

    let back = Checkpoint::load(path).unwrap();
    assert_eq!(back.step, 6);
    assert_eq!(back.params, xa);
    let mut b = MicroAdam::new(d, cfg);
    b.restore_state(back.opt.as_ref().unwrap()).unwrap();
    assert_eq!(b.t(), 6);
    let mut xb = back.params.clone();

    for s in 0..5 {
        let g = randvec(&mut rng, d, 1.0);
        a.step(&mut xa, &g, 0.01);
        b.step(&mut xb, &g, 0.01);
        assert_eq!(xa, xb, "step {s} after checkpoint resume");
        assert_eq!(a.error_norm(), b.error_norm(), "step {s} EF after resume");
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn window_resident_bytes_per_value_is_two() {
    // The memory-report acceptance target, end to end: a default-config
    // MicroAdam allocates exactly 2 bytes per window value and its paper
    // accounting equals the measured window bytes.
    let opt = MicroAdam::new(1 << 16, MicroAdamConfig::default());
    assert_eq!(opt.window_value_bytes(), 2);
    let ef_paper = (1usize << 16) / 2;
    assert_eq!(opt.paper_state_bytes() - ef_paper, opt.window_state_bytes());
}
